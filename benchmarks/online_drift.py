"""Rolling-horizon re-optimization under open-loop workload drift.

The paper claims the routing policy "adapts to dynamic workloads" via
periodic small-scale NSGA-II re-optimization (§IV-B.6). This benchmark makes
that claim testable: each scenario is a sequence of open-loop windows whose
arrival rate / category mix / prompt lengths drift after window 0, and two
policies are compared on the post-drift windows:

* **static** — Algorithm-2 thresholds tuned once on window 0 (the stale
  window) with the 4-objective QoE fitness, then frozen;
* **adaptive** — the runtime router's rolling-horizon loop: after serving
  each window it records the observed requests + realized objectives and
  calls ``RequestRouter.maybe_reoptimize`` (open-loop re-fit on the recorded
  window, NSGA-II warm-started from the previous front archive).

Both start from the identical window-0 policy, so any gap is pure
adaptation. Reported per (scenario, strategy): post-drift mean quality, mean
cost, SLO attainment, mean RT, and the §V-D-style composite score over
(quality↑, cost↓, attainment↑) normalized across strategies (cloud-only is
included as a normalization anchor). Writes results/online_drift.csv.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policy import BOUNDS_HI, BOUNDS_LO
from repro.core.router import RequestRouter, RouteDecision
from repro.workload.arrivals import PhaseSpec, build_open_loop_trace
from repro.workload.slo import attach_slos

from .common import write_csv

WINDOW_REQUESTS = 60
N_WINDOWS = 4          # window 0 tunes; windows 1.. are post-drift
POP, GENS = 16, 10

# Each scenario: one PhaseSpec per window. Drift is a step change after the
# tuning window; the adaptive policy has re-fit on window 1's observations by
# window 2 while the static policy stays tuned on stale window 0. The
# post-drift phases are sized to *break* the stale policy: the burst exceeds
# the cloud node's service capacity (~7 req/s) and the math-heavy long-prompt
# mix saturates it at a much lower rate, so a policy tuned on the calm window
# (which concentrates traffic on the cloud) collapses on attainment unless it
# re-learns to spill load onto the edge tier.
SCENARIOS = {
    # category-mix + prompt-length drift: easy code-heavy -> hard math-heavy
    "mix_shift": [
        PhaseSpec(rate=2.0, duration=1e9, mix=(0.70, 0.10, 0.10, 0.10)),
    ] + [
        PhaseSpec(rate=4.0, duration=1e9, mix=(0.10, 0.70, 0.10, 0.10),
                  length_scale=1.8),
    ] * (N_WINDOWS - 1),
    # arrival-rate drift: calm tuning window -> sustained overload burst
    "burst": [
        PhaseSpec(rate=1.2, duration=1e9, mix=(0.25, 0.25, 0.25, 0.25)),
    ] + [
        PhaseSpec(rate=10.0, duration=1e9, mix=(0.25, 0.25, 0.25, 0.25)),
    ] * (N_WINDOWS - 1),
}

# Eq. (1)-style selection weights over (RQ, C, RT, V): attainment-leaning,
# applied identically to the static window-0 tuning and every adaptive
# re-fit, so the comparison isolates *adaptation*, not selection taste.
WEIGHTS = (0.20, 0.15, 0.15, 0.50)


@dataclasses.dataclass
class WindowStats:
    quality: float
    cost: float
    rt: float
    attainment: float


def _make_windows(phases, seed):
    """One open-loop trace + evaluator per window (equal sizes so the jitted
    trace scan compiles once)."""
    out = []
    for k, ph in enumerate(phases):
        tr = build_open_loop_trace(WINDOW_REQUESTS, (ph,),
                                   seed=seed * 100 + k)
        attach_slos(tr, tightness=1.0, seed=seed * 100 + k)
        out.append((tr, TraceEvaluator(tr, paper_testbed(),
                                       EvalConfig(mode="open"))))
    return out


def _eval_thresholds(ev: TraceEvaluator, thresholds) -> tuple:
    res = ev.run_thresholds(jnp.asarray(thresholds, jnp.float32))
    s = ev.summarize(res)
    return res, WindowStats(quality=s["avg_quality"], cost=s["avg_cost"],
                            rt=s["avg_response_time"],
                            attainment=s["slo_attainment"])


def _eval_assignment(ev: TraceEvaluator, assign) -> WindowStats:
    s = ev.summarize(ev.run_assignment(jnp.asarray(assign)))
    return WindowStats(quality=s["avg_quality"], cost=s["avg_cost"],
                       rt=s["avg_response_time"],
                       attainment=s["slo_attainment"])


def tune_window0(ev: TraceEvaluator, seed: int = 0) -> np.ndarray:
    """The shared starting policy: NSGA-II over window 0's QoE fitness."""
    cfg = NSGA2Config(pop_size=POP, n_generations=GENS,
                      lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("threshold", objectives="qoe"), cfg)
    state = opt.evolve_scan(jax.random.key(seed), GENS)
    genome, _ = opt.select_by_weights(state, jnp.asarray(WEIGHTS))
    return np.asarray(genome, np.float32)


def _record_window(router: RequestRouter, trace, res) -> None:
    """Feed one served window into the router's rolling history."""
    q = np.asarray(res.q); c = np.asarray(res.cost); rt = np.asarray(res.rt)
    assign = np.asarray(res.assign)
    pair_node = np.asarray(router.arrays.pair_node)
    is_edge = np.asarray(router.arrays.pair_is_edge)
    for i, req in enumerate(trace.requests):
        p = int(assign[i])
        dec = RouteDecision(
            pair=p, node=int(pair_node[p]), model=0, go_edge=bool(is_edge[p]),
            features=(float(trace.complexity[i]),
                      int(trace.pred_category[i]),
                      float(trace.pred_conf[i])))
        router.record(req, dec, quality=float(q[i]), cost=float(c[i]),
                      rt=float(rt[i]), now=float(trace.arrival_time[i]),
                      ttft_deadline=float(trace.ttft_deadline[i]),
                      tpot_deadline=float(trace.tpot_deadline[i]))


def run_scenario(name: str, phases, seed: int = 0):
    windows = _make_windows(phases, seed)
    cluster = paper_testbed()
    policy0 = tune_window0(windows[0][1], seed=seed)

    router = RequestRouter(cluster, policy0)   # the adaptive policy lives here
    static = policy0.copy()

    rows = []
    agg = {"static": [], "adaptive": [], "cloud_only": []}
    for k, (tr, ev) in enumerate(windows):
        res_a, st_a = _eval_thresholds(ev, router.thresholds)
        _, st_s = _eval_thresholds(ev, static)
        st_c = _eval_assignment(ev, baselines.cloud_only(tr, cluster))
        for sname, st in (("static", st_s), ("adaptive", st_a),
                          ("cloud_only", st_c)):
            rows.append([name, k, sname, f"{st.quality:.4f}",
                         f"{st.cost:.4e}", f"{st.attainment:.4f}",
                         f"{st.rt:.4f}"])
            if k >= 1:                      # post-drift aggregation
                agg[sname].append(st)
        # close the loop: record what the adaptive policy just observed and
        # re-fit (window size ~= history window; warm start from the archive)
        _record_window(router, tr, res_a)
        router.maybe_reoptimize(force=True, window=WINDOW_REQUESTS,
                                generations=GENS, pop_size=POP, seed=seed,
                                weights=WEIGHTS)

    def mean(stats, f):
        return float(np.mean([getattr(s, f) for s in stats]))

    summary = {s: WindowStats(quality=mean(v, "quality"),
                              cost=mean(v, "cost"), rt=mean(v, "rt"),
                              attainment=mean(v, "attainment"))
               for s, v in agg.items()}

    # §V-D-style composite over (quality ↑, cost ↓, attainment ↑), min-max
    # normalized across the compared strategies
    names = list(summary)
    def norm(vals, larger_better):
        v = np.asarray(vals, np.float64)
        rng = v.max() - v.min()
        if rng <= 0:
            return np.ones_like(v)
        n = (v - v.min()) / rng
        return n if larger_better else 1.0 - n
    comp = (norm([summary[n].quality for n in names], True)
            + norm([summary[n].cost for n in names], False)
            + norm([summary[n].attainment for n in names], True)) / 3.0
    composite = dict(zip(names, comp))

    for sname in names:
        st = summary[sname]
        rows.append([name, "post_drift_mean", sname, f"{st.quality:.4f}",
                     f"{st.cost:.4e}", f"{st.attainment:.4f}",
                     f"{st.rt:.4f}"])
    return rows, summary, composite


def run(seed: int = 0):
    all_rows = []
    verdicts = {}
    for name, phases in SCENARIOS.items():
        rows, summary, composite = run_scenario(name, phases, seed=seed)
        all_rows.extend(rows)
        verdicts[name] = (summary, composite)
    write_csv("online_drift.csv",
              ["scenario", "window", "strategy", "avg_quality", "avg_cost",
               "slo_attainment", "avg_rt_s"], all_rows)
    return all_rows, verdicts


def main():
    _, verdicts = run()
    wins = 0
    for name, (summary, composite) in verdicts.items():
        a, s = summary["adaptive"], summary["static"]
        better = (composite["adaptive"] > composite["static"]
                  and a.attainment >= s.attainment)
        wins += better
        for sname, st in summary.items():
            print(f"online_drift.{name}.{sname},,"
                  f"quality={st.quality:.4f} cost={st.cost:.4e} "
                  f"attain={st.attainment:.4f} rt={st.rt:.4f} "
                  f"composite={composite[sname]:.4f}")
        print(f"online_drift.{name}.adaptive_beats_static,,{better}")
    assert wins >= 2, (
        "rolling-horizon re-optimization failed to beat the stale static "
        f"policy in >=2 drift scenarios (won {wins})")


if __name__ == "__main__":
    main()
