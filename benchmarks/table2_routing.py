"""Paper Table II: the five routing strategies on the 500-request mixed
trace — avg_quality / avg_response_time / avg_cost / overall.

Reports BOTH router operating points:
  * ``proposed(equal-w)``   — Eq. (1) with ω = (1/3, 1/3, 1/3), our primary
    reproduction row;
  * ``proposed(paper-op)``  — the Pareto-front policy closest (normalized L2)
    to the paper's published triple, showing the front covers the paper's
    deployment point.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.objectives import overall_scores
from repro.core.policy import BOUNDS_HI, BOUNDS_LO

from .common import write_csv

PAPER = {
    "Cloud Only": (0.5736, 1.0624, 1.13e-4),
    "Edge Only": (0.4207, 3.9673, 9.00e-6),
    "Random Router": (0.4361, 2.3571, 5.71e-5),
    "Round Robin Router": (0.4618, 2.4971, 6.16e-5),
    "Proposed Router": (0.5462, 1.1137, 7.36e-5),
}


def optimize_router(ev: TraceEvaluator, pop: int = 100, gens: int = 100,
                    seed: int = 42):
    cfg = NSGA2Config(pop_size=pop, n_generations=gens,
                      lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("threshold"), cfg)
    t0 = time.time()
    state = opt.evolve_scan(jax.random.key(seed), gens)
    jax.block_until_ready(state.F)
    return opt, state, time.time() - t0


def select_operating_point(opt, state, ev: TraceEvaluator, baseline_rows,
                           min_cost_saving: float = 0.2):
    """Pick the front policy maximizing the paper's §V-D composite ``overall``
    against the four baselines, **subject to ≥ min_cost_saving cost reduction
    vs Cloud-Only** — the paper's deployment intent (its point cut cost
    34.9%). Without the constraint the composite metric selects the
    pure-cloud corner of the front under our calibration (noted in
    EXPERIMENTS.md). Deterministic, unlike the raw equal-weight normalized
    sum whose knee is seed-sensitive."""
    mask = np.asarray((state.rank == 0) & (state.violation <= 0))
    G = np.unique(np.asarray(state.genomes)[mask], axis=0)
    base_q = [r["avg_quality"] for r in baseline_rows]
    base_t = [r["avg_response_time"] for r in baseline_rows]
    base_c = [r["avg_cost"] for r in baseline_rows]
    cloud_cost = baseline_rows[0]["avg_cost"]
    best, best_score = None, -1.0
    fallback, fallback_score = None, -1.0
    for g in G:
        s = ev.summarize(ev.run_thresholds(jnp.asarray(g)))
        ov = overall_scores(np.array(base_q + [s["avg_quality"]]),
                            np.array(base_t + [s["avg_response_time"]]),
                            np.array(base_c + [s["avg_cost"]]))[-1]
        if ov > fallback_score:
            fallback, fallback_score = g, ov
        if s["avg_cost"] <= (1 - min_cost_saving) * cloud_cost                 and ov > best_score:
            best, best_score = g, ov
    return jnp.asarray(best if best is not None else fallback)


def run(n_requests: int = 500, seed: int = 0):
    from repro.workload.trace import build_trace
    trace = build_trace(n_requests, seed=seed)
    cluster = paper_testbed()
    ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=1))

    rows = {}
    for name, a in [("Cloud Only", baselines.cloud_only(trace, cluster)),
                    ("Edge Only", baselines.edge_only(trace, cluster)),
                    ("Random Router", baselines.random_router(trace, cluster)),
                    ("Round Robin Router", baselines.round_robin(trace, cluster))]:
        rows[name] = ev.summarize(ev.run_assignment(jnp.asarray(a)))

    opt, state, opt_time = optimize_router(ev)
    genome = select_operating_point(opt, state, ev, list(rows.values()))
    rows["Proposed Router"] = ev.summarize(ev.run_thresholds(genome))

    # paper-operating-point row: front policy closest to the published triple
    mask = np.asarray((state.rank == 0) & (state.violation <= 0))
    G = np.asarray(state.genomes)[mask]
    F = np.asarray(state.F_raw)[mask]
    target = np.array([1 - PAPER["Proposed Router"][0],
                       PAPER["Proposed Router"][2],
                       PAPER["Proposed Router"][1]])
    lo, hi = F.min(0), F.max(0)
    span = np.where(hi - lo <= 0, 1.0, hi - lo)
    d = np.linalg.norm((F - target) / span, axis=1)
    rows["Proposed (paper-op)"] = ev.summarize(
        ev.run_thresholds(jnp.asarray(G[np.argmin(d)])))

    names = list(rows)
    ov = overall_scores(np.array([rows[n]["avg_quality"] for n in names]),
                        np.array([rows[n]["avg_response_time"] for n in names]),
                        np.array([rows[n]["avg_cost"] for n in names]))
    out_rows = []
    for n, o in zip(names, ov):
        r = rows[n]
        pq, pt, pc = PAPER.get(n, PAPER["Proposed Router"])
        out_rows.append([n, f"{r['avg_quality']:.4f}", pq,
                         f"{r['avg_response_time']:.4f}", pt,
                         f"{r['avg_cost']:.3e}", pc, f"{o:.4f}"])
    write_csv("table2.csv",
              ["router", "avg_quality", "paper_quality", "avg_rt_s",
               "paper_rt_s", "avg_cost", "paper_cost", "overall"], out_rows)
    return rows, ov, opt_time


def main():
    rows, ov, opt_time = run()
    evals = 100 * 100 * 2
    us = opt_time / evals * 1e6
    # name,us_per_call,derived
    print(f"table2.nsga2_policy_eval,{us:.1f},"
          f"{evals / opt_time:.0f} policy-evals/s over 500-request trace")
    for (name, r), o in zip(rows.items(), ov):
        tag = name.lower().replace(" ", "_").replace("(", "").replace(")", "")
        print(f"table2.{tag},,q={r['avg_quality']:.4f}"
              f" rt={r['avg_response_time']:.4f}"
              f" cost={r['avg_cost']:.3e} overall={o:.4f}")


if __name__ == "__main__":
    main()
