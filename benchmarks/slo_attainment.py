"""SLO attainment vs cost across deadline tightness (QoE extension).

Sweeps the global deadline-tightness factor over a contended closed-loop
trace (G=8 clients) and compares, per tightness:

* the four paper baselines (Cloud Only / Edge Only / Random / Round Robin);
* Algorithm 2 with the paper's quality-oriented default thresholds;
* the SLO-aware phase-split policy with hand defaults ([γ, κ] = SLO_DEFAULTS);
* the SLO policy tuned by a small NSGA-II over the 4-objective QoE fitness
  (RQ, C, RT, violation-rate), picking the max-attainment Pareto policy.

Reported per strategy: SLO attainment (fraction of requests meeting both the
TTFT and TPOT deadline), avg cost, avg RT, avg TTFT/TPOT — plus which
baselines the SLO policy *dominates* (≥ attainment at ≤ cost, one strict).
Writes results/slo_attainment.csv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policy import (PAPER_DEFAULTS, SLO_BOUNDS_HI, SLO_BOUNDS_LO,
                               SLO_DEFAULTS)
from repro.workload.slo import attach_slos
from repro.workload.trace import build_trace

from .common import write_csv

TIGHTNESS = (0.5, 1.0, 2.0, 4.0)
CONCURRENCY = 8


def tune_slo_policy(ev: TraceEvaluator, pop: int = 16, gens: int = 12,
                    seed: int = 0) -> jnp.ndarray:
    """Small NSGA-II over [γ, κ] with the 4-objective QoE fitness; return the
    feasible front policy with max attainment (min V), tie-broken by cost."""
    cfg = NSGA2Config(pop_size=pop, n_generations=gens,
                      lo=jnp.asarray(SLO_BOUNDS_LO),
                      hi=jnp.asarray(SLO_BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("slo", objectives="qoe"), cfg)
    state = opt.evolve_scan(jax.random.key(seed), gens)
    mask = np.asarray((state.rank == 0) & (state.violation <= 0))
    if not mask.any():
        return jnp.asarray(SLO_DEFAULTS)
    F = np.asarray(state.F_raw)[mask]
    G = np.asarray(state.genomes)[mask]
    order = np.lexsort((F[:, 1], F[:, 3]))  # primary: violation, then cost
    return jnp.asarray(G[order[0]])


def run(n_requests: int = 240, seed: int = 0):
    base_trace = build_trace(n_requests, seed=seed)
    cluster = paper_testbed()
    rows = []
    dominated_total = {}
    for tight in TIGHTNESS:
        trace = attach_slos(base_trace, tightness=tight, seed=1)
        ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=CONCURRENCY))
        results = {}
        for name, a in [
                ("cloud_only", baselines.cloud_only(trace, cluster)),
                ("edge_only", baselines.edge_only(trace, cluster)),
                ("random", baselines.random_router(trace, cluster)),
                ("round_robin", baselines.round_robin(trace, cluster))]:
            results[name] = ev.summarize(ev.run_assignment(jnp.asarray(a)))
        results["alg2_defaults"] = ev.summarize(
            ev.run_thresholds(jnp.asarray(PAPER_DEFAULTS)))
        results["slo_default"] = ev.summarize(
            ev.run_slo_policy(jnp.asarray(SLO_DEFAULTS)))
        results["slo_nsga2"] = ev.summarize(
            ev.run_slo_policy(tune_slo_policy(ev, seed=seed)))

        slo = results["slo_nsga2"]
        dominated = [
            n for n in ("cloud_only", "edge_only", "random", "round_robin",
                        "alg2_defaults")
            if slo["slo_attainment"] >= results[n]["slo_attainment"]
            and slo["avg_cost"] <= results[n]["avg_cost"]
            and (slo["slo_attainment"] > results[n]["slo_attainment"]
                 or slo["avg_cost"] < results[n]["avg_cost"])]
        dominated_total[tight] = dominated
        for name, s in results.items():
            rows.append([tight, name, f"{s['slo_attainment']:.4f}",
                         f"{s['avg_cost']:.4e}",
                         f"{s['avg_response_time']:.4f}",
                         f"{s['avg_ttft']:.4f}", f"{s['avg_tpot']:.4f}",
                         f"{s['avg_quality']:.4f}",
                         ";".join(dominated) if name == "slo_nsga2" else ""])
    write_csv("slo_attainment.csv",
              ["tightness", "strategy", "slo_attainment", "avg_cost",
               "avg_rt_s", "avg_ttft_s", "avg_tpot_s", "avg_quality",
               "dominates"], rows)
    return rows, dominated_total


def main():
    rows, dominated = run()
    for r in rows:
        tight, name = r[0], r[1]
        print(f"slo_attainment.t{tight}.{name},,"
              f"attain={r[2]} cost={r[3]} rt={r[4]} ttft={r[5]} tpot={r[6]}")
    for tight, doms in dominated.items():
        print(f"slo_attainment.t{tight}.dominates,,"
              f"{';'.join(doms) if doms else 'NONE'}")
    assert any(dominated.values()), \
        "SLO-aware routing failed to dominate any baseline at any tightness"


if __name__ == "__main__":
    main()
