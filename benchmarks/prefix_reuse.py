"""Prefix-reuse / cache-affinity routing benchmark (beyond paper).

Sweeps session-reuse intensity (mean turns per conversation) over open-loop
multi-turn workloads (``workload.sessions``: growing per-session prompts +
shared agent system prompts) with the prefix-cache environment model enabled
(``EvalConfig(prefix_cache=True)``: a served whole-block prefix stays
resident on its node; hits shorten prefill and discount cached prompt
tokens — for *every* strategy, since the cache is physical).

Compared per intensity:

* **cloud_only** — anchor: everything on the big cloud model;
* **slo_blind** — cache-blind SLO routing (``decide_pair_slo_py`` family):
  cheapest deadline-feasible pair, no knowledge of cache state;
* **affinity** — the cache-affinity policy at hand defaults
  (``core.policy.AFFINITY_DEFAULTS``): expected cached-prefix fraction
  discounts the prefill term and cached-token price, ρ adds stickiness;
* **affinity_nsga** — the same policy with [γ, κ, ρ] tuned by NSGA-II over
  the 4-objective QoE fitness on this workload.

The run asserts, at every intensity, that the NSGA-tuned affinity policy
beats cache-blind routing on the (rt↓, cost↓) latency/cost composite at
greater-or-equal quality. Writes results/prefix_reuse.csv.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policy import (AFFINITY_BOUNDS_HI, AFFINITY_BOUNDS_LO,
                               AFFINITY_DEFAULTS, SLO_DEFAULTS)
from repro.workload.sessions import SessionConfig, build_session_trace
from repro.workload.slo import attach_slos

from .common import write_csv

N_REQUESTS = 160
TURN_SWEEP = (1.5, 3.0, 6.0)     # mean turns/session: reuse intensity
POP, GENS = 16, 10
TIGHTNESS = 2.0                  # deadlines loose enough that edge competes
# Eq. (1)-style selection weights over (RQ, C, RT, V) for the NSGA pick
WEIGHTS = (0.22, 0.40, 0.28, 0.10)

SMOKE = "--smoke" in sys.argv    # CI: tiny shapes, same code path


def _workload(mean_turns: float, seed: int):
    n = 36 if SMOKE else N_REQUESTS
    cfg = SessionConfig(n_sessions=max(2, int(round(n / mean_turns))),
                        mean_turns=mean_turns, session_rate=1.5,
                        think_time_s=3.0)
    tr = build_session_trace(cfg, seed=seed, n_requests=n)
    attach_slos(tr, tightness=TIGHTNESS, seed=seed)
    return tr


def tune_affinity(ev: TraceEvaluator, seed: int = 0) -> np.ndarray:
    gens = 4 if SMOKE else GENS
    cfg = NSGA2Config(pop_size=8 if SMOKE else POP, n_generations=gens,
                      lo=jnp.asarray(AFFINITY_BOUNDS_LO),
                      hi=jnp.asarray(AFFINITY_BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("affinity", objectives="qoe"), cfg)
    state = opt.evolve_scan(jax.random.key(seed), gens)
    genome, _ = opt.select_by_weights(state, jnp.asarray(WEIGHTS))
    return np.asarray(genome, np.float32)


def run(seed: int = 0):
    cluster = paper_testbed()
    rows, verdicts = [], {}
    for mean_turns in (TURN_SWEEP[:2] if SMOKE else TURN_SWEEP):
        tr = _workload(mean_turns, seed)
        ev = TraceEvaluator(tr, cluster,
                            EvalConfig(mode="open", prefix_cache=True))
        tuned = tune_affinity(ev, seed=seed)
        results = {
            "cloud_only": ev.run_assignment(
                jnp.asarray(baselines.cloud_only(tr, cluster))),
            "slo_blind": ev.run_slo_policy(SLO_DEFAULTS),
            "affinity": ev.run_affinity_policy(AFFINITY_DEFAULTS),
            "affinity_nsga": ev.run_affinity_policy(tuned),
        }
        summaries = {name: ev.summarize(res)
                     for name, res in results.items()}
        # latency/cost composite, min-max normalized across strategies
        names = list(summaries)

        def norm(vals):
            v = np.asarray(vals, np.float64)
            rng = v.max() - v.min()
            return (np.ones_like(v) if rng <= 0
                    else 1.0 - (v - v.min()) / rng)     # smaller is better

        comp = (norm([summaries[n]["avg_response_time"] for n in names])
                + norm([summaries[n]["avg_cost"] for n in names])) / 2.0
        composite = dict(zip(names, comp))
        for name in names:
            s = summaries[name]
            rows.append([f"{mean_turns}", name, f"{s['avg_quality']:.4f}",
                         f"{s['avg_cost']:.4e}",
                         f"{s['avg_response_time']:.4f}",
                         f"{s['avg_ttft']:.4f}", f"{s['slo_attainment']:.4f}",
                         f"{s['cache_hit_frac']:.4f}",
                         f"{composite[name]:.4f}"])
        verdicts[mean_turns] = (summaries, composite, tuned)
    # smoke runs write a separate file so CI cannot clobber the committed
    # full-sweep results
    write_csv("prefix_reuse_smoke.csv" if SMOKE else "prefix_reuse.csv",
              ["mean_turns", "strategy", "avg_quality", "avg_cost",
               "avg_rt_s", "avg_ttft_s", "slo_attainment", "cache_hit_frac",
               "latency_cost_composite"], rows)
    return rows, verdicts


def main():
    _, verdicts = run()
    for mean_turns, (summaries, composite, tuned) in verdicts.items():
        for name, s in summaries.items():
            print(f"prefix_reuse.turns{mean_turns}.{name},,"
                  f"quality={s['avg_quality']:.4f} cost={s['avg_cost']:.4e} "
                  f"rt={s['avg_response_time']:.4f} "
                  f"attain={s['slo_attainment']:.4f} "
                  f"hit={s['cache_hit_frac']:.4f} "
                  f"composite={composite[name]:.4f}")
        aff, blind = summaries["affinity_nsga"], summaries["slo_blind"]
        beats = (composite["affinity_nsga"] > composite["slo_blind"]
                 and aff["avg_quality"] >= blind["avg_quality"] - 1e-3)
        print(f"prefix_reuse.turns{mean_turns}.affinity_beats_blind,,{beats} "
              f"(tuned genome {np.round(tuned, 3).tolist()})")
        assert beats, (
            "cache-affinity NSGA-II policy failed to dominate cache-blind "
            f"routing at mean_turns={mean_turns}: {summaries}")


if __name__ == "__main__":
    main()
