"""Hot-path performance benchmark: compile-once re-fit, sharded fitness,
host-sync-free engine stepping (PR: "Compile-once hot paths").

Three sections, each a control-plane or data-plane hot path:

* **refit** — rolling-horizon re-optimization latency across a sweep of
  drifting window lengths. Status quo: an unbucketed ``TraceEvaluator`` per
  window (every distinct window length retraces + recompiles the evaluator
  and the NSGA-II step). Bucketed: ``TraceEvaluator(bucket="pow2")`` + the
  module-level jitted NSGA-II — one compile on the first window, cache hits
  after. Acceptance: warm re-fit ≥ 5× faster than per-window retracing.
* **engine** — continuous-batching decode throughput and host syncs:
  ``LLMEngine.step`` (one device->host transfer per decoded token) vs
  ``step_n`` chunks (one transfer per chunk), byte-identical outputs
  asserted. Acceptance: syncs drop from O(tokens) to O(tokens/chunk).
* **sharded** — policy evaluations/s of the population fitness vs device
  count, device-sharded via ``make_fitness(..., mesh=population_mesh())``.
  Multi-device CPU runs fabricate devices with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
  imports, hence the subprocess workers). Acceptance: sharded ≡
  single-device numerically.

Writes results/hotpath.csv + BENCH_hotpath.json (the repo's perf
trajectory record, uploaded as a CI artifact). ``--smoke`` runs tiny shapes
through the same code paths.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

SMOKE = "--smoke" in sys.argv

REFIT_WINDOWS = (44, 52, 60) if SMOKE else (150, 190, 170, 230, 210, 250)
REFIT_POP, REFIT_GENS = (8, 3) if SMOKE else (16, 8)
ENGINE_BUDGET = 12 if SMOKE else 48
ENGINE_CHUNK = 6 if SMOKE else 16
SHARD_DEVS = (1, 2) if SMOKE else (1, 2, 4)
SHARD_POP = 16 if SMOKE else 64
SHARD_TRACE = 48 if SMOKE else 120


def _block(x):
    import jax
    return jax.block_until_ready(x)


# ---------------------------------------------------------------------------
# (a) re-fit latency: bucketed vs per-window retracing
# ---------------------------------------------------------------------------

def bench_refit():
    import jax
    import jax.numpy as jnp

    from repro.cluster.spec import paper_testbed
    from repro.core.fitness import EvalConfig, TraceEvaluator
    from repro.core.nsga2 import NSGA2, NSGA2Config
    from repro.core.policy import SLO_BOUNDS_HI, SLO_BOUNDS_LO
    from repro.workload.slo import attach_slos
    from repro.workload.trace import build_trace

    cluster = paper_testbed()
    cfg = NSGA2Config(pop_size=REFIT_POP, n_generations=REFIT_GENS,
                      lo=jnp.asarray(SLO_BOUNDS_LO),
                      hi=jnp.asarray(SLO_BOUNDS_HI))

    def refit(n, seed, bucket):
        tr = build_trace(n, seed=seed)
        attach_slos(tr, seed=seed)
        ev = TraceEvaluator(tr, cluster, EvalConfig(concurrency=4),
                            bucket=bucket)
        opt = NSGA2(ev.make_fitness("slo", objectives="qoe"), cfg)
        t0 = time.perf_counter()
        state = opt.evolve_scan(jax.random.key(seed), REFIT_GENS)
        _block(state.genomes)
        return time.perf_counter() - t0

    status_quo = [refit(n, i, None) for i, n in enumerate(REFIT_WINDOWS)]
    bucketed = [refit(n, i, "pow2") for i, n in enumerate(REFIT_WINDOWS)]
    # warm = every window after the first compile; the status quo has no
    # warm regime (every distinct window length recompiles), so its mean
    # over the same windows is the honest baseline
    base_mean = float(np.mean(status_quo[1:]))
    warm_mean = float(np.mean(bucketed[1:]))
    return {
        "windows": list(REFIT_WINDOWS),
        "statusquo_s": [round(t, 4) for t in status_quo],
        "bucketed_s": [round(t, 4) for t in bucketed],
        "statusquo_warm_mean_s": round(base_mean, 4),
        "bucketed_warm_mean_s": round(warm_mean, 4),
        "warm_speedup": round(base_mean / warm_mean, 2),
    }


# ---------------------------------------------------------------------------
# (b) engine decode: step vs step_n
# ---------------------------------------------------------------------------

def bench_engine():
    import jax

    from repro.configs import get
    from repro.models import lm
    from repro.serving.engine import EngineConfig, LLMEngine

    cfg = get("stablelm-3b").smoke()
    params = lm.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, size=6 + 2 * i)
               for i in range(4)}

    def run(chunk):
        eng = LLMEngine(cfg, params, EngineConfig(
            max_slots=4, max_seq=128, max_new_tokens=ENGINE_BUDGET))
        for i, p in prompts.items():
            eng.submit(i, p, max_new_tokens=ENGINE_BUDGET)
        eng.host_syncs = 0
        t0 = time.perf_counter()
        res = eng.run_to_completion(chunk=chunk)
        dt = time.perf_counter() - t0
        toks = sum(len(r["tokens"]) for r in res.values())
        return res, dt, eng.host_syncs, toks

    # cold pass to compile both paths, then measure warm
    run(1), run(ENGINE_CHUNK)
    res1, t1, syncs1, toks = run(1)
    resN, tN, syncsN, _ = run(ENGINE_CHUNK)
    identical = all(res1[i]["tokens"] == resN[i]["tokens"] for i in res1)
    return {
        "tokens": toks,
        "chunk": ENGINE_CHUNK,
        "step_s": round(t1, 4), "step_n_s": round(tN, 4),
        "tokens_per_s_step": round(toks / t1, 1),
        "tokens_per_s_step_n": round(toks / tN, 1),
        "host_syncs_step": syncs1, "host_syncs_step_n": syncsN,
        "byte_identical": bool(identical),
        "speedup": round(t1 / tN, 2),
    }


# ---------------------------------------------------------------------------
# (c) device-sharded fitness: evals/s vs device count (subprocess workers —
#     XLA_FLAGS must be set before the first jax import)
# ---------------------------------------------------------------------------

def _worker(ndev: int) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.cluster.spec import paper_testbed
    from repro.core.fitness import (EvalConfig, TraceEvaluator,
                                    population_mesh)
    from repro.workload.slo import attach_slos
    from repro.workload.trace import build_trace

    assert len(jax.devices()) >= ndev, \
        f"expected {ndev} devices, got {len(jax.devices())}"
    tr = build_trace(SHARD_TRACE, seed=0)
    attach_slos(tr, seed=0)
    ev = TraceEvaluator(tr, paper_testbed(), EvalConfig(concurrency=4),
                        bucket="pow2")
    lo = jnp.asarray([0.3, 0.0])
    span = jnp.asarray([0.8, 20.0])
    genomes = lo + jax.random.uniform(jax.random.key(0),
                                      (SHARD_POP, 2)) * span
    key = jax.random.key(1)

    fit = ev.make_fitness("slo", objectives="qoe",
                          mesh=population_mesh(ndev))
    _block(fit(genomes, key))                      # compile
    iters = 3 if SMOKE else 10
    t0 = time.perf_counter()
    for _ in range(iters):
        F, viol = _block(fit(genomes, key))
    dt = (time.perf_counter() - t0) / iters

    ref = ev.make_fitness("slo", objectives="qoe")
    F0, v0 = _block(ref(genomes, key))
    return {
        "ndev": ndev,
        "evals_per_s": round(SHARD_POP / dt, 1),
        "allclose": bool(np.allclose(F, F0, rtol=1e-5, atol=1e-6)
                         and np.allclose(viol, v0)),
        "viol_bitwise": bool((np.asarray(viol) == np.asarray(v0)).all()),
        "max_abs_diff": float(np.max(np.abs(np.asarray(F)
                                            - np.asarray(F0)))),
    }


def bench_sharded():
    out = []
    for ndev in SHARD_DEVS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={ndev}")
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "benchmarks.hotpath",
               "--worker-ndev", str(ndev)] + (["--smoke"] if SMOKE else [])
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=1200)
        lines = [ln for ln in proc.stdout.splitlines()
                 if ln.startswith("{")]
        assert proc.returncode == 0 and lines, \
            f"sharded worker ndev={ndev} failed:\n{proc.stdout}\n{proc.stderr}"
        out.append(json.loads(lines[-1]))
    return out


# ---------------------------------------------------------------------------

def run():
    refit = bench_refit()
    engine = bench_engine()
    sharded = bench_sharded()
    return {"refit": refit, "engine": engine, "sharded": sharded,
            "smoke": SMOKE}


def main():
    if "--worker-ndev" in sys.argv:
        ndev = int(sys.argv[sys.argv.index("--worker-ndev") + 1])
        print(json.dumps(_worker(ndev)))
        return

    from .common import write_bench_json, write_csv

    payload = run()
    rows = []
    r = payload["refit"]
    for i, n in enumerate(r["windows"]):
        rows.append(["refit", f"window_{n}", f"{r['statusquo_s'][i]:.4f}",
                     f"{r['bucketed_s'][i]:.4f}"])
    e = payload["engine"]
    rows.append(["engine", f"chunk_{e['chunk']}",
                 f"{e['tokens_per_s_step']}", f"{e['tokens_per_s_step_n']}"])
    rows.append(["engine", "host_syncs", f"{e['host_syncs_step']}",
                 f"{e['host_syncs_step_n']}"])
    for s in payload["sharded"]:
        rows.append(["sharded", f"ndev_{s['ndev']}", f"{s['evals_per_s']}",
                     f"allclose={s['allclose']}"])
    # smoke runs write separate files so CI cannot clobber full results
    write_csv("hotpath_smoke.csv" if SMOKE else "hotpath.csv",
              ["section", "case", "baseline", "optimized"], rows)
    write_bench_json("hotpath_smoke" if SMOKE else "hotpath", payload)

    print(f"hotpath.refit,,warm_speedup={r['warm_speedup']} "
          f"(statusquo {r['statusquo_warm_mean_s']}s -> bucketed "
          f"{r['bucketed_warm_mean_s']}s)")
    print(f"hotpath.engine,,tokens_per_s {e['tokens_per_s_step']} -> "
          f"{e['tokens_per_s_step_n']} syncs {e['host_syncs_step']} -> "
          f"{e['host_syncs_step_n']} byte_identical={e['byte_identical']}")
    for s in payload["sharded"]:
        print(f"hotpath.sharded.ndev{s['ndev']},,"
              f"evals_per_s={s['evals_per_s']} allclose={s['allclose']} "
              f"max_abs_diff={s['max_abs_diff']:.2e}")

    # acceptance criteria (ISSUE 4)
    assert r["warm_speedup"] >= 5.0, \
        f"bucketed warm re-fit speedup {r['warm_speedup']} < 5x"
    assert e["byte_identical"], "step_n outputs diverged from step"
    assert e["host_syncs_step_n"] <= e["host_syncs_step"] // 2, \
        "chunked stepping did not reduce host syncs"
    assert all(s["allclose"] for s in payload["sharded"]), \
        "sharded fitness diverged from single-device"


if __name__ == "__main__":
    main()
