"""Paper Fig. 2: average quality per dataset for each routing strategy."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed, TASKS
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.workload.trace import build_trace

from .common import write_csv
from .table2_routing import optimize_router, select_operating_point


def run(n_requests: int = 500, seed: int = 0):
    trace = build_trace(n_requests, seed=seed)
    cluster = paper_testbed()
    ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=1))
    results = {}
    summaries = []
    for name, a in [("Cloud Only", baselines.cloud_only(trace, cluster)),
                    ("Edge Only", baselines.edge_only(trace, cluster)),
                    ("Random Router", baselines.random_router(trace, cluster)),
                    ("Round Robin Router", baselines.round_robin(trace, cluster))]:
        res = ev.run_assignment(jnp.asarray(a))
        results[name] = ev.per_dataset_quality(res)
        summaries.append(ev.summarize(res))
    opt, state, _ = optimize_router(ev)
    genome = select_operating_point(opt, state, ev, summaries)
    results["Proposed Router"] = ev.per_dataset_quality(
        ev.run_thresholds(genome))

    rows = [[name] + [f"{q[t]:.4f}" for t in TASKS]
            for name, q in results.items()]
    write_csv("fig2.csv", ["router"] + list(TASKS), rows)
    return results


def main():
    results = run()
    for name, q in results.items():
        tag = name.lower().replace(" ", "_")
        print(f"fig2.{tag},," + " ".join(f"{t}={q[t]:.3f}" for t in q))


if __name__ == "__main__":
    main()
