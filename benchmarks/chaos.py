"""Chaos hardening: deterministic fault scenarios across both layers.

Four seeded fault scenarios from ``repro.faults.FaultSchedule`` are replayed
through the analytic evaluator (the same fault tables the NSGA-II fitness
scan and both DES oracles consume) and through the live serving runtime
(``ClusterServer`` with retries, circuit breakers, and load shedding armed):

* **crash-storm** — repeated node crashes with no spare (the cloud node
  crashes too). The ``resilient`` policy is NSGA-II-tuned against the
  *faulty* evaluator and compared to the naive-failover baseline: the same
  deadline-aware routing family (``slo`` hand defaults) relying solely on
  the router's stock dead-pair failover, with no brownout term and no
  fault-aware tuning — so the measured delta is exactly the resilience
  machinery. The paper's Algorithm-2 ``threshold`` defaults are reported
  alongside for context. The run asserts the tuned configuration reaches
  >= 1.2x the baseline's SLO attainment at matched quality
  (quality >= baseline - 5e-3).
* **link-flap** — the disaggregated KV link degrades 20x in repeated
  windows; the ``disagg`` policy is evaluated clean vs flapping on long
  prompts (transfer seconds must grow, attainment must not improve).
* **straggler** — two nodes run 4x slow for long stretches; the
  crash-tuned resilient genome is transferred unchanged to show regime
  robustness.
* **overload** — a serving-runtime arrival burst past admission capacity,
  SLO-class shedding on vs off (batch sheds first, interactive survives).

Every scenario also drives a live ``ClusterServer`` under the same schedule
and asserts per-node ledger conservation (``dispatched == completed +
failed + cancelled``) and **zero leaked KV blocks** — these asserts run in
smoke mode too.

Reported: capacity availability (time-mean alive/slowdown-discounted node
fraction of the schedule), SLO attainment, goodput (attained requests per
second of makespan; served requests per tick on the serving side), quality,
cost, and the serving retry/timeout/shed/breaker counters.

Writes ``results/chaos.csv`` + ``BENCH_chaos.json`` (``*_smoke`` variants
under ``--smoke`` so CI cannot clobber committed full-run results).
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import numpy as np

from repro.cluster.spec import disagg_testbed, paper_testbed
from repro.configs import get
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policies import get_policy
from repro.core.policy import PAPER_DEFAULTS
from repro.faults import FaultSchedule, TransientErrors, node_available_np, \
    node_slowdown_np
from repro.models import lm
from repro.serving import ClusterServer, EngineConfig, ResilienceConfig, \
    ServeRequest
from repro.workload.sessions import SessionConfig, build_session_trace
from repro.workload.slo import attach_slos
from repro.workload.trace import build_trace

from .common import timed, write_bench_json, write_csv

SMOKE = "--smoke" in sys.argv    # CI: tiny shapes, same code path

N_REQUESTS = 160
POP, GENS = 16, 10
TIGHTNESS = 2.0
STORM_SEED = 3                   # crash-storm regime the verdict is run on
ATTAIN_RATIO = 1.2               # tuned resilient vs naive failover
QUALITY_TOL = 5e-3               # "matched quality" tolerance
NO_HEDGE = 10 ** 9

HEADER = ["scenario", "config", "layer", "capacity_avail",
          "slo_attainment", "goodput", "avg_quality", "avg_cost", "avg_rt",
          "served_frac", "retries", "timeouts", "sheds", "breaker_opens"]


# ---------------------------------------------------------------------------
# analytic layer: faulty TraceEvaluator
# ---------------------------------------------------------------------------
def _workload(seed: int = 0, prompt_scale: float = 1.0):
    n = 48 if SMOKE else N_REQUESTS
    cfg = SessionConfig(n_sessions=max(2, n // 3), mean_turns=3.0,
                        session_rate=1.5, think_time_s=3.0)
    tr = build_session_trace(cfg, seed=seed, n_requests=n)
    attach_slos(tr, tightness=TIGHTNESS, seed=seed)
    if prompt_scale != 1.0:
        tr.prompt_tokens = np.maximum(
            (tr.prompt_tokens * prompt_scale).astype(np.int32), 1)
    return tr


def _capacity_availability(sched: FaultSchedule, n_nodes: int,
                           horizon: float) -> float:
    """Time-mean fraction of scheduled node capacity: alive nodes weighted
    by the inverse of their straggler slowdown."""
    ft = sched.compile(n_nodes)
    grid = np.linspace(0.0, horizon, 257, dtype=np.float32)
    cap = [np.mean(node_available_np(ft, t).astype(np.float32)
                   / node_slowdown_np(ft, t)) for t in grid]
    return float(np.mean(cap))


def _eval(ev: TraceEvaluator, name: str, genome, tr) -> dict:
    res = ev.run_policy(name, genome)
    s = ev.summarize(res)
    rt = np.asarray(res.rt)
    makespan = float(np.max(tr.arrival_time[:len(rt)] + rt))
    att = s.get("slo_attainment", 0.0)
    s["goodput"] = att * len(rt) / max(makespan, 1e-9)
    s["transfer_s"] = float(np.mean(np.asarray(res.transfer)))
    return s


def _tune_resilient(ev: TraceEvaluator, tr, qfloor: float, seed: int = 0):
    """NSGA-II fit against the *faulty* evaluator, then pick the survivor
    with the highest SLO attainment among candidates at matched quality
    (>= qfloor) — attainment must never be bought by trading quality below
    the baseline. Hand defaults join the candidate set so tuning cannot
    regress them."""
    pop = 8 if SMOKE else POP
    gens = 4 if SMOKE else GENS
    cfg = NSGA2Config.from_policy(get_policy("resilient"), pop_size=pop,
                                  n_generations=gens)
    opt = NSGA2(ev.make_fitness("resilient", objectives="qoe"), cfg)
    state, fit_s = timed(
        lambda: opt.evolve_scan(jax.random.key(seed), gens),
        warmup=0, iters=1)
    cands = np.unique(np.asarray(state.genomes), axis=0)
    defaults = np.asarray(get_policy("resilient").genome_spec.defaults,
                          cands.dtype)
    cands = np.vstack([cands, defaults])
    scored = [(g, _eval(ev, "resilient", g, tr)) for g in cands]
    matched = [(g, s) for g, s in scored if s["avg_quality"] >= qfloor]
    pool = matched or scored       # smoke fallback: tiny fronts may miss
    g, s = max(pool, key=lambda t: (t[1]["slo_attainment"],
                                    -t[1]["avg_cost"]))
    return g, s, fit_s


def _analytic_row(scenario: str, config: str, avail: float, s: dict):
    return [scenario, config, "analytic", f"{avail:.3f}",
            f"{s.get('slo_attainment', 0.0):.4f}", f"{s['goodput']:.3f}",
            f"{s['avg_quality']:.4f}", f"{s['avg_cost']:.4e}",
            f"{s['avg_response_time']:.4f}", "", "", "", "", ""]


# ---------------------------------------------------------------------------
# serving layer: live ClusterServer under the same schedules
# ---------------------------------------------------------------------------
def _builders():
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    pb = lm.init(jax.random.key(0), big)
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


def _ecfg(**over):
    kw = dict(max_slots=2, max_seq=48, max_new_tokens=4, prefix_cache=True,
              block_size=8, cache_blocks=32)
    kw.update(over)
    return EngineConfig(**kw)


def _assert_conserved(srv):
    for node, s in srv.monitor.stats.items():
        assert s.total_dispatched == (s.total_completed + s.total_failed
                                      + s.total_cancelled), (node, s)
        assert s.outstanding == 0, (node, s)


def _leaked_blocks(srv) -> int:
    leaked = 0
    for eng in srv.engines.values():
        if eng.kv is not None:
            eng.kv.cache.check_invariants()
            leaked += int(np.sum(eng.kv.cache.pool.ref > 0))
    return leaked


def _serve(srv, sreqs, scenario: str, config: str):
    """Drive the server to drain, assert conservation + zero leaked KV
    blocks (the hard chaos invariants — asserted in smoke mode too), and
    return the serving-side row + counters."""
    for sr in sreqs:
        srv.submit(sr)
    done = srv.run()
    assert sorted(done) == sorted(sr.request_id for sr in sreqs)
    st = srv.stats()
    served = sum(1 for d in done.values()
                 if isinstance(d, dict) and "tokens" in d)
    _assert_conserved(srv)
    leaked = _leaked_blocks(srv)
    assert leaked == 0, (scenario, config, leaked)
    counters = {
        "served": served, "total": len(sreqs),
        "served_frac": served / max(len(sreqs), 1),
        "retries": st["retries"], "timeouts": st["timeouts"],
        "sheds": st["sheds"], "transients": st["transient_faults"],
        "breaker_opens": sum(st["breaker_opens"]),   # per-node open counts
        "ticks": srv.ticks,
        "goodput": served / max(srv.ticks, 1),
        "leaked_blocks": leaked,
    }
    row = [scenario, config, "serving", "", "", f"{counters['goodput']:.3f}",
           "", "", "", f"{counters['served_frac']:.3f}",
           counters["retries"], counters["timeouts"], counters["sheds"],
           counters["breaker_opens"]]
    return row, counters


def _paper_server(builders, faults=None, resilience=None):
    return ClusterServer(paper_testbed(), builders, PAPER_DEFAULTS, _ecfg(),
                         hedge_after=NO_HEDGE,
                         router_kwargs={"mode": "threshold"},
                         faults=faults, resilience=resilience)


def _serve_reqs(n: int, max_new: int = 3, classes=None):
    reqs = build_trace(max(24, n), seed=5).requests[:n]
    out = []
    for i, r in enumerate(reqs):
        kw = {"slo_class": classes[i % len(classes)]} if classes else {}
        out.append(ServeRequest(request_id=i, req=r,
                                max_new_tokens=max_new, **kw))
    return out


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------
def _crash_storm(rows, bench, builders):
    tr = _workload()
    horizon = float(np.max(tr.arrival_time))
    cluster = paper_testbed()
    n_nodes = len(cluster.nodes)
    sched = FaultSchedule.crash_storm(
        n_nodes, seed=STORM_SEED, n_crashes=6, horizon=horizon,
        mean_down=0.25 * horizon, spare=0)
    avail = _capacity_availability(sched, n_nodes, horizon)
    ev = TraceEvaluator(tr, cluster,
                        EvalConfig(mode="open", prefix_cache=True),
                        bucket="pow2", faults=sched)
    naive = _eval(ev, "slo", get_policy("slo").genome_spec.defaults, tr)
    alg2 = _eval(ev, "threshold",
                 get_policy("threshold").genome_spec.defaults, tr)
    qfloor = naive["avg_quality"] - QUALITY_TOL
    genome, tuned, fit_s = _tune_resilient(ev, tr, qfloor)

    rows.append(_analytic_row("crash_storm", "naive-failover(slo)",
                              avail, naive))
    rows.append(_analytic_row("crash_storm", "alg2-threshold", avail, alg2))
    rows.append(_analytic_row("crash_storm", "resilient-tuned", avail,
                              tuned))

    # serving replay: same storm shape in scheduler ticks plus transient
    # dispatch errors (retries + breakers exercised); spare=1 keeps one
    # node up so the run drains
    serve_sched = dataclasses.replace(
        FaultSchedule.crash_storm(n_nodes, seed=0, n_crashes=4,
                                  horizon=40.0, mean_down=10.0, spare=1),
        transient=TransientErrors(rate=0.2, seed=7))
    srow, counters = _serve(_paper_server(builders, faults=serve_sched),
                            _serve_reqs(10 if SMOKE else 16),
                            "crash_storm", "serving-replay")
    rows.append(srow)

    ratio = tuned["slo_attainment"] / max(naive["slo_attainment"], 1e-9)
    bench["crash_storm"] = {
        "capacity_availability": avail,
        "naive_failover": naive, "alg2_threshold": alg2,
        "resilient_tuned": tuned,
        "tuned_genome": [float(x) for x in genome],
        "attain_ratio": ratio,
        "quality_margin": tuned["avg_quality"] - naive["avg_quality"],
        "nsga2_fit_s": fit_s,
        "serving": counters,
    }
    return genome


def _link_flap(rows, bench, builders):
    tr = _workload(prompt_scale=3.0)    # long prompts: the KV link matters
    horizon = float(np.max(tr.arrival_time))
    cluster = disagg_testbed()
    sched = FaultSchedule.link_flap(seed=STORM_SEED, n_flaps=4,
                                    horizon=horizon, factor=20.0,
                                    mean_len=0.3 * horizon)
    dflt = get_policy("disagg").genome_spec.defaults
    cfg = EvalConfig(mode="open", prefix_cache=True, disaggregated=True)
    clean = _eval(TraceEvaluator(tr, cluster, cfg, bucket="pow2"),
                  "disagg", dflt, tr)
    flap = _eval(TraceEvaluator(tr, cluster, cfg, bucket="pow2",
                                faults=sched), "disagg", dflt, tr)
    avail = _capacity_availability(sched, len(cluster.nodes), horizon)
    rows.append(_analytic_row("link_flap", "disagg-clean", 1.0, clean))
    rows.append(_analytic_row("link_flap", "disagg-flap", avail, flap))

    # serving replay: disagg server with real KV handoffs through a
    # flapping link (single-model long-prompt requests, whole-block KV)
    dcfg, dparams = builders["gemma3:27b"]
    dsrv = ClusterServer(
        disagg_testbed(), {"gemma3:27b": (dcfg, dparams)}, PAPER_DEFAULTS,
        _ecfg(max_new_tokens=3),
        router_kwargs={"mode": "disagg"},
        faults=FaultSchedule.link_flap(seed=0, n_flaps=2, horizon=30.0,
                                       factor=20.0, mean_len=8.0))
    base = build_trace(24, seed=5).requests
    dreqs = [ServeRequest(
        request_id=i, max_new_tokens=3,
        req=dataclasses.replace(r, text=" ".join(f"w{i}_{j}"
                                                 for j in range(20)),
                                prompt_tokens=20))
        for i, r in enumerate(base[:6 if SMOKE else 8])]
    srow, counters = _serve(dsrv, dreqs, "link_flap", "serving-replay")
    rows.append(srow)
    assert dsrv.stats()["handoffs"] >= 1     # split routes actually taken

    bench["link_flap"] = {
        "clean": clean, "flap": flap,
        "transfer_s_clean": clean["transfer_s"],
        "transfer_s_flap": flap["transfer_s"],
        "serving": counters,
    }


def _straggler(rows, bench, builders, tuned_genome):
    tr = _workload()
    horizon = float(np.max(tr.arrival_time))
    cluster = paper_testbed()
    n_nodes = len(cluster.nodes)
    sched = FaultSchedule.straggler_storm(
        n_nodes, seed=STORM_SEED, n_stragglers=2, horizon=horizon,
        factor=4.0, mean_len=0.4 * horizon)
    avail = _capacity_availability(sched, n_nodes, horizon)
    ev = TraceEvaluator(tr, cluster,
                        EvalConfig(mode="open", prefix_cache=True),
                        bucket="pow2", faults=sched)
    naive = _eval(ev, "slo", get_policy("slo").genome_spec.defaults, tr)
    # the crash-tuned genome transfers unchanged (regime robustness)
    tuned = _eval(ev, "resilient", tuned_genome, tr)
    rows.append(_analytic_row("straggler", "naive-failover(slo)",
                              avail, naive))
    rows.append(_analytic_row("straggler", "resilient-crash-tuned",
                              avail, tuned))

    srow, counters = _serve(
        _paper_server(builders,
                      faults=FaultSchedule.straggler_storm(
                          n_nodes, seed=0, n_stragglers=2, horizon=40.0,
                          factor=3.0, mean_len=20.0)),
        _serve_reqs(8 if SMOKE else 12), "straggler", "serving-replay")
    rows.append(srow)
    bench["straggler"] = {"naive_failover": naive,
                          "resilient_crash_tuned": tuned,
                          "capacity_availability": avail,
                          "serving": counters}


def _overload(rows, bench, builders):
    """Serving-only: an admission burst past capacity with SLO-class
    shedding on vs off. Shedding must shed batch work only; with it off
    nothing sheds and the drain takes longer."""
    n = 24 if SMOKE else 40
    classes = ("interactive", "batch")
    out = {}
    for config, rcfg in (
            ("shed-on", ResilienceConfig(shed_threshold=0.5,
                                         shed_interactive_threshold=3.0)),
            ("shed-off", None)):
        srv = _paper_server(builders, resilience=rcfg)
        srow, counters = _serve(srv, _serve_reqs(n, max_new=4,
                                                 classes=classes),
                                "overload", config)
        shed_ids = [i for i, d in srv.done.items()
                    if isinstance(d, dict) and d.get("status") == "shed"]
        counters["shed_classes"] = sorted(
            {classes[i % 2] for i in shed_ids})
        rows.append(srow)
        out[config] = counters
    assert out["shed-on"]["sheds"] > 0, "overload never shed"
    assert out["shed-on"]["shed_classes"] == ["batch"]   # interactive kept
    assert out["shed-off"]["sheds"] == 0
    bench["overload"] = out


# ---------------------------------------------------------------------------
def run(seed: int = 0):
    rows, bench = [], {"smoke": SMOKE}
    builders = _builders()
    tuned_genome = _crash_storm(rows, bench, builders)
    _link_flap(rows, bench, builders)
    _straggler(rows, bench, builders, tuned_genome)
    _overload(rows, bench, builders)

    leaked = (bench["crash_storm"]["serving"]["leaked_blocks"]
              + bench["link_flap"]["serving"]["leaked_blocks"]
              + bench["straggler"]["serving"]["leaked_blocks"]
              + sum(c["leaked_blocks"] for c in bench["overload"].values()))
    bench["verdict"] = {
        "attain_ratio": bench["crash_storm"]["attain_ratio"],
        "attain_ratio_required": ATTAIN_RATIO,
        "quality_margin": bench["crash_storm"]["quality_margin"],
        "quality_tol": QUALITY_TOL,
        "leaked_blocks_total": leaked,
    }
    suffix = "_smoke" if SMOKE else ""
    write_csv(f"chaos{suffix}.csv", HEADER, rows)
    write_bench_json(f"chaos{suffix}", bench)
    return rows, bench


def main():
    rows, bench = run()
    fit_us = bench["crash_storm"]["nsga2_fit_s"] * 1e6
    for r in rows:
        us = f"{fit_us:.0f}" if (r[0], r[1]) == ("crash_storm",
                                                 "resilient-tuned") else ""
        derived = (f"att={r[4]},goodput={r[5]}" if r[2] == "analytic"
                   else f"served={r[9]},goodput={r[5]}")
        print(f"chaos.{r[0]}.{r[1]},{us},{derived}")
    v = bench["verdict"]
    print(f"chaos.verdict,,ratio={v['attain_ratio']:.3f},"
          f"qmargin={v['quality_margin']:+.4f},leaked={v['leaked_blocks_total']}")
    assert v["leaked_blocks_total"] == 0
    if SMOKE:
        return                      # tiny shapes: the verdict is not judged
    assert v["attain_ratio"] >= ATTAIN_RATIO, v
    assert v["quality_margin"] >= -QUALITY_TOL, v
    tf = bench["link_flap"]
    assert tf["transfer_s_flap"] >= tf["transfer_s_clean"], tf


if __name__ == "__main__":
    main()
