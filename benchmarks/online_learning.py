"""Online-learned estimators vs static priors under drift + stragglers.

The static pair tables the router plans with (``build_tables``) know nothing
about *runtime* conditions: a straggling node serves every token 3-4x slower
than its table entry, and no amount of genome tuning can see that through
stale estimates. This benchmark measures what closing that loop is worth:
per-(node, category) online estimators (``src/repro/learn/``) observe
realized TTFT/TPOT at completion, learn multiplicative residuals, and
override the estimate rows every policy reads.

Scenario: the ``mix_shift``-style drift from ``online_drift.py`` (calm
code-heavy window 0, then math-heavy longer-prompt windows at higher rate)
overlaid with *unannounced* stragglers — the cloud node at 3x and the first
edge node at 4x — that no static table reflects. Four windows are served
back-to-back through the DES oracle with the learner state carried across
windows (``SimResult.learn_state`` -> ``run(learn_state=)``), for each of:

* ``slo``   x {static, learned}: an existing deadline-feasibility policy,
  EWMA residual learner;
* ``bandit`` x {static, learned}: the LinUCB-style explore-exploit policy,
  Bayesian linear-regression learner.

Reported per (policy, variant, window): mean quality, mean cost, mean RT,
SLO attainment, and the **estimator error** — MAE between the prefill/TPOT
estimates each decision acted on and the realized values (static variants
have no estimate rows recorded, reported as ``nan``). The headline check:
per policy, the learned variant must beat its static-prior twin on the
post-drift min-max composite over (quality up, cost down, rt down,
attainment up), and the learned MAE must *decrease* over the run (the
estimator is actually converging, not just perturbing decisions).

Writes results/online_learning.csv and BENCH_learning.json (the per-window
MAE trajectories + composite verdicts CI uploads as an artifact).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.spec import paper_testbed
from repro.core.policies import get_policy
from repro.faults import FaultSchedule, Straggler
from repro.learn import LearnConfig
from repro.workload.arrivals import PhaseSpec, build_open_loop_trace
from repro.workload.slo import attach_slos

from .common import write_bench_json, write_csv

SMOKE = "--smoke" in sys.argv

WINDOW_REQUESTS = 20 if SMOKE else 60
N_WINDOWS = 3 if SMOKE else 4

# (policy, learner kind): the EWMA pairs with the deadline-feasibility
# policy (cheap, scalar residuals suffice), the BLR with the bandit (its
# LinUCB width *is* the BLR posterior uncertainty).
VARIANTS = (("slo", "ewma"), ("bandit", "blr"))

# Calm code-heavy tuning window, then a math-heavy longer-prompt drift at a
# moderate rate — deliberately *below* hard saturation so routing (not pure
# queueing) decides outcomes and corrected estimates can matter.
PHASES = [
    PhaseSpec(rate=1.5, duration=1e9, mix=(0.70, 0.10, 0.10, 0.10)),
] + [
    PhaseSpec(rate=2.5, duration=1e9, mix=(0.10, 0.70, 0.10, 0.10),
              length_scale=1.5),
] * (N_WINDOWS - 1)

# Unannounced stragglers on *both* tiers: the cloud node (the quality-seeking
# bandit's preferred target) and the first edge node (the cheapest
# deadline-feasible pair the slo policy leans on). Static tables see neither.
STRAGGLERS = FaultSchedule(stragglers=(Straggler(0, 0.0, 1e9, 3.0),
                                       Straggler(1, 0.0, 1e9, 4.0)))


def _windows(seed: int):
    out = []
    for k, ph in enumerate(PHASES):
        tr = build_open_loop_trace(WINDOW_REQUESTS, (ph,), seed=seed * 100 + k)
        attach_slos(tr, tightness=1.0, seed=seed * 100 + k)
        out.append(tr)
    return out


def run_variant(policy: str, learned: bool, kind: str, seed: int = 0):
    """Serve all windows back-to-back, carrying learner state across them.
    Returns per-window (quality, cost, rt, attainment, mae_ttft, mae_tpot)."""
    cluster = paper_testbed()
    genome = get_policy(policy).genome_spec.defaults
    state = None
    rows = []
    for tr in _windows(seed):
        sim = ClusterSimulator(tr, cluster, faults=STRAGGLERS,
                               learned=learned, learner=LearnConfig(kind=kind))
        res = sim.run(policy=policy, genome=genome, learn_state=state)
        if learned:
            state = res.learn_state
        if res.est_prefill is None:
            mae_p = mae_t = float("nan")
        else:
            mae_p = float(np.mean(np.abs(np.asarray(res.est_prefill)
                                         - np.asarray(res.real_prefill))))
            mae_t = float(np.mean(np.abs(np.asarray(res.est_tpot)
                                         - np.asarray(res.real_tpot))))
        rows.append((float(res.q.mean()), float(res.cost.mean()),
                     float(res.rt.mean()),
                     res.slo_attainment(tr.ttft_deadline, tr.tpot_deadline),
                     mae_p, mae_t))
    return rows


def _post_drift_mean(rows):
    """Mean (quality, cost, rt, attainment) over the post-drift windows."""
    return np.mean(np.asarray(rows, np.float64)[1:, :4], axis=0)


def _composite(static_m, learned_m):
    """Min-max composite over (quality up, cost down, rt down, attain up)
    between the two variants of one policy — §V-D style, smaller field."""
    arr = np.stack([static_m, learned_m])

    def norm(col, larger_better):
        rng = col.max() - col.min()
        if rng <= 1e-12:
            return np.full_like(col, 0.5)
        n = (col - col.min()) / rng
        return n if larger_better else 1.0 - n

    comp = (norm(arr[:, 0], True) + norm(arr[:, 1], False)
            + norm(arr[:, 2], False) + norm(arr[:, 3], True)) / 4.0
    return float(comp[0]), float(comp[1])


def run(seed: int = 0):
    csv_rows = []
    verdicts = {}
    for policy, kind in VARIANTS:
        per = {}
        for learned in (False, True):
            rows = run_variant(policy, learned, kind, seed=seed)
            per[learned] = rows
            variant = "learned" if learned else "static"
            for k, r in enumerate(rows):
                csv_rows.append([policy, variant, kind if learned else "-", k,
                                 f"{r[0]:.4f}", f"{r[1]:.4e}", f"{r[2]:.4f}",
                                 f"{r[3]:.4f}", f"{r[4]:.4f}", f"{r[5]:.4f}"])
        c_static, c_learned = _composite(_post_drift_mean(per[False]),
                                         _post_drift_mean(per[True]))
        maes_p = [r[4] for r in per[True]]
        maes_t = [r[5] for r in per[True]]
        verdicts[policy] = {
            "kind": kind,
            "composite_static": c_static,
            "composite_learned": c_learned,
            "learned_beats_static": c_learned > c_static,
            "attainment_static": float(_post_drift_mean(per[False])[3]),
            "attainment_learned": float(_post_drift_mean(per[True])[3]),
            "mae_ttft_by_window": maes_p,
            "mae_tpot_by_window": maes_t,
            "mae_ttft_decreasing": maes_p[-1] < maes_p[0],
        }
    suffix = "_smoke" if SMOKE else ""
    write_csv(f"online_learning{suffix}.csv",
              ["policy", "variant", "learner", "window", "avg_quality",
               "avg_cost", "avg_rt_s", "slo_attainment", "mae_ttft",
               "mae_tpot"], csv_rows)
    write_bench_json(f"learning{suffix}", {
        "window_requests": WINDOW_REQUESTS, "n_windows": N_WINDOWS,
        "stragglers": [[s.node, s.factor] for s in STRAGGLERS.stragglers],
        "policies": verdicts,
    })
    return csv_rows, verdicts


def main():
    _, verdicts = run()
    for policy, v in verdicts.items():
        print(f"online_learning.{policy}.composite,,"
              f"static={v['composite_static']:.4f} "
              f"learned={v['composite_learned']:.4f} "
              f"attain={v['attainment_static']:.3f}->"
              f"{v['attainment_learned']:.3f}")
        print(f"online_learning.{policy}.mae_ttft,,"
              + " ".join(f"{m:.4f}" for m in v["mae_ttft_by_window"]))
    # the estimator must actually converge (error falls), even on tiny shapes
    for policy, v in verdicts.items():
        assert v["mae_ttft_decreasing"], \
            f"{policy} estimator error did not decrease over the run"
    if SMOKE:
        return   # tiny windows: the composite verdicts are not stable
    assert verdicts["bandit"]["learned_beats_static"], \
        "bandit with learned estimates failed to beat its static prior"
    assert verdicts["slo"]["learned_beats_static"], \
        "slo with learned estimates failed to beat its static prior"


if __name__ == "__main__":
    main()
