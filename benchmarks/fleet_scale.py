"""Fleet-vectorized serving at cluster scale: open-loop replay on 64 nodes.

The tentpole claim of the fleet refactor is that decode cost per scheduler
tick is O(#cohorts), not O(#engines): every engine sharing a
``(ModelConfig, EngineConfig, params)`` identity decodes inside ONE vmapped
jit dispatch with one stacked host transfer (``serving.fleet``). This
benchmark measures that end to end with real tiny models:

* **replay** — an open-loop arrival replay of >= 100k single-turn sessions
  against the 64-node ``fleet_testbed`` (8 cloud + 56 edge nodes -> 176
  engines -> exactly 2 cohorts), arrivals paced above service capacity so
  the decode plane stays saturated. Reported: tokens/s over the cold window
  (first ticks, includes trace + XLA compile of the cohort dispatch) vs the
  warm remainder, router decisions/s (the submit-side routing hot path),
  and **decode dispatches per saturated tick** — asserted to equal the
  cohort count exactly, the O(#cohorts) evidence.
* **head-to-head** — the same replay at moderate scale on an 8-node fleet,
  fleet cohorts vs the per-engine Python loop (``fleet=False``), which is
  byte-identical (tests/test_fleet.py) but pays one jit dispatch per busy
  engine per tick.

Writes ``results/fleet_scale.csv`` + ``BENCH_fleet.json`` (``*_smoke``
variants under ``--smoke`` so CI cannot clobber committed full-scale
results).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.cluster.spec import fleet_testbed
from repro.configs import get
from repro.core.policy import PAPER_DEFAULTS
from repro.models import lm
from repro.serving import ClusterServer, EngineConfig, ServeRequest
from repro.workload.trace import build_trace

from .common import write_bench_json, write_csv

SMOKE = "--smoke" in sys.argv    # CI: tiny fleet + short replay, same paths

N_SESSIONS = 400 if SMOKE else 100_000
TRACE_POOL = 400 if SMOKE else 10_000   # distinct requests, cycled to N
ARRIVALS_PER_TICK = 40 if SMOKE else 400  # > capacity: keeps decode saturated
HEAD_TO_HEAD_N = 200 if SMOKE else 2_000
WARM_TICKS = 3                   # cold window: compile + first dispatches
MAX_NEW = 2

ECFG = EngineConfig(max_slots=4, max_seq=32, max_new_tokens=MAX_NEW,
                    prefill_bucket=16)


def _builders():
    """Two real tiny models for the testbed's four names; the three edge
    names share ONE (cfg, params) identity so all edge engines form a
    single cohort (the grouping rule in docs/architecture.md)."""
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    pb = lm.init(jax.random.key(0), big)
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


def _server(cluster, builders, fleet=True):
    return ClusterServer(cluster, builders, PAPER_DEFAULTS, ECFG,
                         hedge_after=10**9, fleet=fleet)


def _emitted(srv) -> int:
    return sum(e.tokens_emitted for e in srv.engines.values())


def replay(srv, reqs, n_sessions: int, rate: int) -> dict:
    """Open-loop replay: session ``i`` arrives at tick ``i // rate``; every
    iteration submits the due arrivals (timed separately — the router
    decision hot path) then runs one scheduler tick. The dispatch-count
    window spans the saturated phase: from the end of the cold window until
    the arrival process drains."""
    i = 0
    route_s = 0.0
    cold_s = warm_s = 0.0
    cold_toks = 0
    sat = None                    # (dispatches, ticks) at saturation start
    disp_per_tick = float("nan")
    while i < n_sessions or srv.inflight or srv.transfers:
        t0 = time.perf_counter()
        while i < n_sessions and i // rate <= srv.ticks:
            srv.submit(ServeRequest(request_id=i, req=reqs[i % len(reqs)],
                                    max_new_tokens=MAX_NEW))
            i += 1
        t1 = time.perf_counter()
        route_s += t1 - t0
        srv.step()
        dt = time.perf_counter() - t0
        if srv.ticks <= WARM_TICKS:
            cold_s += dt
            cold_toks = _emitted(srv)
        else:
            warm_s += dt
        if srv.ticks == WARM_TICKS:
            sat = (srv.decode_dispatches, srv.ticks)
        if i == n_sessions and sat is not None and srv.ticks > sat[1]:
            disp_per_tick = ((srv.decode_dispatches - sat[0])
                             / (srv.ticks - sat[1]))
            sat = None            # freeze the window at arrival exhaustion
    toks = _emitted(srv)
    return {
        "sessions": n_sessions,
        "completed": len(srv.done),
        "ticks": srv.ticks,
        "tokens": toks,
        "wall_s": cold_s + warm_s,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "tokens_per_s": toks / (cold_s + warm_s),
        "cold_tokens_per_s": cold_toks / cold_s if cold_s else 0.0,
        "warm_tokens_per_s": (toks - cold_toks) / warm_s if warm_s else 0.0,
        "router_decisions_per_s": n_sessions / route_s,
        "dispatches_per_tick": disp_per_tick,
        "decode_dispatches": srv.decode_dispatches,
    }


def run(seed: int = 7):
    builders = _builders()
    reqs = build_trace(TRACE_POOL, seed=seed).requests
    rows, bench = [], {}

    # -- 64-node open-loop replay (the scale proof) -------------------------
    cluster = (fleet_testbed(n_edge=6, n_cloud=2) if SMOKE
               else fleet_testbed(n_edge=56, n_cloud=8))
    srv = _server(cluster, builders)
    rep = replay(srv, reqs, N_SESSIONS, ARRIVALS_PER_TICK)
    rep.update(nodes=len(cluster.nodes), engines=len(srv.engines),
               cohorts=len(srv._cohorts))
    assert rep["completed"] == N_SESSIONS
    bench["replay"] = rep
    rows.append(["replay", rep["nodes"], rep["engines"], rep["cohorts"],
                 rep["sessions"], rep["ticks"],
                 f"{rep['wall_s']:.2f}", rep["tokens"],
                 f"{rep['cold_tokens_per_s']:.1f}",
                 f"{rep['warm_tokens_per_s']:.1f}",
                 f"{rep['router_decisions_per_s']:.1f}",
                 f"{rep['dispatches_per_tick']:.3f}"])

    # -- fleet vs per-engine head-to-head (moderate scale) ------------------
    h2h_cluster = fleet_testbed(n_edge=6, n_cloud=2)
    for mode, fleet in (("fleet", True), ("per-engine", False)):
        srv = _server(h2h_cluster, builders, fleet=fleet)
        rep = replay(srv, reqs, HEAD_TO_HEAD_N, ARRIVALS_PER_TICK // 4)
        rep.update(nodes=len(h2h_cluster.nodes), engines=len(srv.engines),
                   cohorts=len(srv._cohorts))
        bench[f"h2h_{mode}"] = rep
        rows.append([f"h2h-{mode}", rep["nodes"], rep["engines"],
                     rep["cohorts"], rep["sessions"], rep["ticks"],
                     f"{rep['wall_s']:.2f}", rep["tokens"],
                     f"{rep['cold_tokens_per_s']:.1f}",
                     f"{rep['warm_tokens_per_s']:.1f}",
                     f"{rep['router_decisions_per_s']:.1f}",
                     f"{rep['dispatches_per_tick']:.3f}"])

    suffix = "_smoke" if SMOKE else ""
    write_csv(f"fleet_scale{suffix}.csv",
              ["section", "nodes", "engines", "cohorts", "sessions", "ticks",
               "wall_s", "tokens", "cold_tokens_per_s", "warm_tokens_per_s",
               "router_decisions_per_s", "dispatches_per_tick"], rows)
    write_bench_json(f"fleet{suffix}", bench)
    return bench


def main():
    bench = run()
    rep = bench["replay"]
    print(f"fleet_scale.replay,{rep['wall_s'] / rep['ticks'] * 1e6:.0f},"
          f"nodes={rep['nodes']} cohorts={rep['cohorts']} "
          f"warm_tok_s={rep['warm_tokens_per_s']:.0f} "
          f"disp_per_tick={rep['dispatches_per_tick']:.3f}")
    f, p = bench["h2h_fleet"], bench["h2h_per-engine"]
    print(f"fleet_scale.h2h,{f['wall_s'] * 1e6:.0f},"
          f"fleet_tok_s={f['tokens_per_s']:.0f} "
          f"perengine_tok_s={p['tokens_per_s']:.0f} "
          f"dispatches={f['decode_dispatches']}vs{p['decode_dispatches']}")
    # the saturated decode plane must cost exactly one dispatch per cohort
    # per tick — O(#cohorts), the refactor's core claim
    assert rep["dispatches_per_tick"] == rep["cohorts"], rep
    if SMOKE:
        return   # tiny replay: throughput verdicts are noise
    assert rep["sessions"] >= 100_000 and rep["nodes"] == 64
    # fewer dispatches must not cost throughput: once the cohort jit's
    # participant-bucket variants are compiled (the cold window), the
    # stacked path wins (or at minimum matches) the per-engine loop at
    # equal byte-exact output
    assert f["warm_tokens_per_s"] >= 0.9 * p["warm_tokens_per_s"], (f, p)
    assert f["decode_dispatches"] < p["decode_dispatches"]


if __name__ == "__main__":
    main()
