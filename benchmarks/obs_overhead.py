"""Telemetry overhead: full obs (spans + audit + metrics) vs no-op mode.

The observability layer (``repro.obs``) instruments the serving hot path
unconditionally — every dispatch/complete/failure/cancel emits a span event
mirroring the monitor accounting call, every retire feeds the percentile
registry, every ``route()`` appends a decision audit record. The design
contract is that this stays invisible in fleet throughput: the no-op tracer
costs one Python method call per event, and the full tracer only ever does
bounded-ring appends on the host (never a device sync).

This benchmark replays the same open-loop fleet workload twice — once with
``Obs.noop()`` (the default) and once with a full ``Obs`` bundle sized to
hold every span — and reports warm tokens/s for both plus the ratio. The
full run's span log is exported as a Chrome-trace JSON artifact
(``results/obs_trace*.json``, loadable in chrome://tracing / Perfetto).

Asserted (full mode; the smoke replay is too short to be signal):
traced warm throughput >= 95% of no-op warm throughput. Writes
``results/obs_overhead.csv`` + ``BENCH_obs.json`` (``*_smoke`` variants
under ``--smoke`` so CI cannot clobber committed full results).
"""
from __future__ import annotations

import sys
import time

import jax

from repro.cluster.spec import fleet_testbed
from repro.configs import get
from repro.core.policy import PAPER_DEFAULTS
from repro.models import lm
from repro.obs import AuditLog, MetricsRegistry, Obs, Tracer, chrome_trace
from repro.serving import ClusterServer, EngineConfig, ServeRequest
from repro.workload.trace import build_trace

from .common import RESULTS, write_bench_json, write_csv

SMOKE = "--smoke" in sys.argv    # CI: tiny fleet + short replay, same paths

N_SESSIONS = 400 if SMOKE else 4_000
TRACE_POOL = 400 if SMOKE else 2_000
ARRIVALS_PER_TICK = 40           # > capacity: keeps the decode plane busy
WARM_TICKS = 3                   # cold window: compile + first dispatches
MAX_NEW = 2

ECFG = EngineConfig(max_slots=4, max_seq=32, max_new_tokens=MAX_NEW,
                    prefill_bucket=16)


def _builders():
    """Two real tiny models over the testbed's four names (edge names share
    one identity so the edge engines form a single cohort)."""
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    pb = lm.init(jax.random.key(0), big)
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


def _full_obs() -> Obs:
    """An Obs bundle that drops nothing at this workload size."""
    cap = max(N_SESSIONS * 2, 8192)
    return Obs(tracer=Tracer(capacity=cap), metrics=MetricsRegistry(),
               audit=AuditLog(capacity=cap))


def replay(srv, reqs, n_sessions: int, rate: int) -> dict:
    """Open-loop replay (same pacing as fleet_scale): session ``i`` arrives
    at tick ``i // rate``; reports cold/warm split so the compile window
    never pollutes the overhead ratio."""
    i = 0
    cold_s = warm_s = 0.0
    cold_toks = 0

    def emitted():
        return sum(e.tokens_emitted for e in srv.engines.values())

    while i < n_sessions or srv.inflight or srv.transfers:
        t0 = time.perf_counter()
        while i < n_sessions and i // rate <= srv.ticks:
            srv.submit(ServeRequest(request_id=i, req=reqs[i % len(reqs)],
                                    max_new_tokens=MAX_NEW))
            i += 1
        srv.step()
        dt = time.perf_counter() - t0
        if srv.ticks <= WARM_TICKS:
            cold_s += dt
            cold_toks = emitted()
        else:
            warm_s += dt
    toks = emitted()
    return {
        "sessions": n_sessions,
        "completed": len(srv.done),
        "ticks": srv.ticks,
        "tokens": toks,
        "wall_s": cold_s + warm_s,
        "warm_s": warm_s,
        "tokens_per_s": toks / (cold_s + warm_s),
        "warm_tokens_per_s": (toks - cold_toks) / warm_s if warm_s else 0.0,
    }


def run(seed: int = 7):
    builders = _builders()
    reqs = build_trace(TRACE_POOL, seed=seed).requests
    cluster = fleet_testbed(n_edge=6, n_cloud=2)
    suffix = "_smoke" if SMOKE else ""

    # untimed pre-warm replay: populates the process-wide jit cache (cohort
    # dispatch variants per participant bucket) so neither timed run pays
    # compile — without it, whichever mode runs second looks faster
    warm_srv = ClusterServer(cluster, builders, PAPER_DEFAULTS, ECFG,
                             hedge_after=10**9)
    replay(warm_srv, reqs, min(N_SESSIONS, 400), ARRIVALS_PER_TICK)

    rows, bench = [], {}
    obs = None
    for mode in ("noop", "traced"):
        obs = None if mode == "noop" else _full_obs()
        srv = ClusterServer(cluster, builders, PAPER_DEFAULTS, ECFG,
                            hedge_after=10**9, obs=obs)
        rep = replay(srv, reqs, N_SESSIONS, ARRIVALS_PER_TICK)
        assert rep["completed"] == N_SESSIONS, rep
        if mode == "traced":
            spans = obs.tracer.spans()
            assert len(spans) + obs.tracer.dropped == N_SESSIONS
            rep["spans"] = len(spans)
            rep["span_events"] = sum(len(s.events) for s in spans)
            rep["audit_records"] = len(obs.audit)
            RESULTS.mkdir(parents=True, exist_ok=True)
            chrome_trace(obs.tracer, path=str(
                RESULTS / f"obs_trace{suffix}.json"),
                time_unit=srv.tick_seconds)
        bench[mode] = rep
        rows.append([mode, rep["sessions"], rep["ticks"],
                     f"{rep['wall_s']:.2f}",
                     f"{rep['warm_tokens_per_s']:.1f}",
                     rep.get("spans", 0), rep.get("span_events", 0),
                     rep.get("audit_records", 0)])

    ratio = (bench["traced"]["warm_tokens_per_s"]
             / bench["noop"]["warm_tokens_per_s"])
    bench["overhead"] = {"warm_throughput_ratio": ratio,
                         "budget_ratio": 0.95}
    write_csv(f"obs_overhead{suffix}.csv",
              ["mode", "sessions", "ticks", "wall_s", "warm_tokens_per_s",
               "spans", "span_events", "audit_records"], rows)
    write_bench_json(f"obs{suffix}", bench)
    return bench


def main():
    bench = run()
    t, n = bench["traced"], bench["noop"]
    ratio = bench["overhead"]["warm_throughput_ratio"]
    print(f"obs_overhead.replay,{t['wall_s'] * 1e6:.0f},"
          f"noop_tok_s={n['warm_tokens_per_s']:.0f} "
          f"traced_tok_s={t['warm_tokens_per_s']:.0f} "
          f"ratio={ratio:.3f} spans={t['spans']} "
          f"events={t['span_events']} audit={t['audit_records']}")
    if SMOKE:
        return   # tiny replay: the ratio is timer noise
    # the telemetry contract: full spans + audit + metrics cost <= 5% of
    # warm fleet throughput
    assert ratio >= 0.95, bench["overhead"]


if __name__ == "__main__":
    main()
