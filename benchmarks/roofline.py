"""§Roofline: three-term roofline per (arch × shape × mesh) from the dry-run
artifacts in results/dryrun/*.json.

Terms (seconds, per step, per device — the SPMD program IS the per-device
program):

    compute    = FLOPs_dev / 197e12        (v5e bf16 peak)
    memory     = bytes_dev / 819e9         (HBM)
    collective = coll_bytes_dev / (4 × 50e9)   (4 ICI links/chip, ring terms)

FLOPs come from the *unrolled* compile; XLA's cost analysis counts while-loop
bodies once (verified empirically), so cells whose model keeps inner
sequence loops (chunked prefill attention, Mamba/xLSTM scans) get an
analytic correction of (trips − 1) × per-trip FLOPs — formulas below, all
derived from the architecture config. MODEL_FLOPS = 6·N_active·D for train,
2·N_active per decoded token for serving.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.configs import all_ids, get
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, ICI_LINKS_2D, \
    PEAK_FLOPS_BF16
from repro.models.config import SHAPES
from repro.models.layers import _CHUNKED_THRESHOLD, _Q_CHUNK

from .common import RESULTS, write_csv

DRYRUN = RESULTS / "dryrun"


# ---------------------------------------------------------------------------
# analytic in-loop FLOPs corrections (global FLOPs; divided by chips later)
# ---------------------------------------------------------------------------

def _attn_chunk_correction(cfg, B, S, train: bool) -> float:
    """Prefill attention runs a fori_loop over S // _Q_CHUNK q-chunks; HLO
    counts one chunk. Correction adds the other (n-1) chunks' score+value
    FLOPs: 4·B·H·S_chunk·S·hd per chunk per layer (per fwd pass)."""
    if S <= _CHUNKED_THRESHOLD:
        return 0.0
    n = S // _Q_CHUNK
    n_attn = sum(m in ("attn", "attn_bidir", "attn_cross")
                 for m, _ in cfg.pattern) * cfg.n_periods
    per_chunk = 4.0 * B * cfg.n_heads * _Q_CHUNK * S * cfg.hd
    passes = 4.0 if train else 1.0      # fwd+bwd+remat-fwd vs fwd
    return per_chunk * (n - 1) * n_attn * passes


def _ssm_scan_correction(cfg, B, S, train: bool) -> float:
    if cfg.ssm is None:
        return 0.0
    di = cfg.ssm.expand * cfg.d_model
    ds = cfg.ssm.d_state
    n_mamba = sum(m == "mamba" for m, _ in cfg.pattern) * cfg.n_periods
    chunk = min(cfg.ssm.chunk, S)
    trips = S // chunk
    # per chunk: associative scan ~ 3 ops on (B, chunk, di, ds) × log2 depth
    per_chunk = 3.0 * B * chunk * di * ds * max(1, int(np.log2(max(chunk, 2))))
    passes = 4.0 if train else 1.0
    return per_chunk * (trips - 1) * n_mamba * passes


def _xlstm_correction(cfg, B, S, train: bool) -> float:
    if cfg.xlstm is None:
        return 0.0
    from repro.models.xlstm import m_dims, s_dims
    di, dh = m_dims(cfg)
    H = cfg.n_heads
    Lc = min(cfg.xlstm.chunk, S)
    trips = S // Lc
    n_m = sum(m == "mlstm" for m, _ in cfg.pattern) * cfg.n_periods
    # per chunk: qk & sv (2·B·H·Lc²·dh each) + cross/state (≈4·B·H·Lc·dh²)
    per_chunk_m = 4.0 * B * H * Lc * Lc * dh + 4.0 * B * H * Lc * dh * dh
    d, sdh = s_dims(cfg)
    Hs = cfg.n_kv_heads
    n_s = sum(m == "slstm" for m, _ in cfg.pattern) * cfg.n_periods
    per_step_s = 8.0 * B * Hs * sdh * sdh     # 4 recurrent gate matmuls
    passes = 4.0 if train else 1.0
    return ((trips - 1) * per_chunk_m * n_m
            + (S - 1) * per_step_s * n_s) * passes


def loop_correction(cfg, shape_name: str) -> float:
    cell = SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        return 0.0                      # single step, no sequence loops
    train = cell.kind == "train"
    return (_attn_chunk_correction(cfg, B, S, train)
            + _ssm_scan_correction(cfg, B, S, train)
            + _xlstm_correction(cfg, B, S, train))


def hbm_bytes(cfg, rec: dict, shape_name: str) -> float:
    """Analytic per-device HBM traffic per step.

    XLA-CPU's ``bytes accessed`` counts every HLO op's operands/results with
    no fusion, over-stating HBM traffic by 10–40× vs a fused TPU program (it
    is still recorded in the CSV as a diagnostic). The roofline memory term
    instead uses the standard analytic model:

      train:   params(2r+1w as bf16 compute copies) + opt state (1r+1w)
               + saved period-boundary activations (w+r) + logits (w+r)
      prefill: params 1r + KV cache 1w + boundary activations 1w
      decode:  params 1r + KV/state cache 1r + small vectors

    using the *sharded* per-device sizes (argument bytes from the dry-run's
    memory analysis give params+opt+cache exactly as placed).
    """
    cell = SHAPES[shape_name]
    chips = rec["n_chips"]
    arg = float((rec.get("memory") or {}).get("argument_bytes") or 0.0)
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    act_bytes = 2.0 * B * S * d / chips * cfg.n_periods  # bf16 boundaries
    logits = 4.0 * B * (S if cell.kind != "decode" else 1) * cfg.vocab / chips
    if cell.kind == "train":
        # argument bytes ≈ params(f32/bf16) + opt state + batch
        return 3.0 * arg + 2.0 * act_bytes + 2.0 * logits
    if cell.kind == "prefill":
        return arg + 2.0 * act_bytes + logits
    # decode: weights + cache are the argument bytes; read once
    return arg + logits


def model_flops(cfg, shape_name: str) -> float:
    """Useful FLOPs: 6·N_active·D (train) / 2·N_active·tokens (serve)."""
    cell = SHAPES[shape_name]
    n = cfg.param_counts()["active"] - cfg.param_counts()["embed"]
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch      # one token per row


# ---------------------------------------------------------------------------

def load_cells(mesh: str = "single"):
    out = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def analyze(rec: dict) -> dict | None:
    if rec["status"] != "ok":
        return None
    cfg = get(rec["arch"]).config()
    chips = rec["n_chips"]
    corr = loop_correction(cfg, rec["shape"]) / chips
    flops_dev = rec["flops"] + corr
    bytes_dev = hbm_bytes(cfg, rec, rec["shape"])
    coll_dev = rec["collectives"]["total_bytes"]

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / (ICI_LINKS_2D * ICI_BW_PER_LINK)
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_collective, "collective"))[1]
    mf = model_flops(cfg, rec["shape"])
    useful = mf / (flops_dev * chips) if flops_dev > 0 else 0.0
    bound = max(t_compute, t_memory, t_collective)
    # roofline fraction: useful-compute time over the modeled step time
    frac = (mf / chips / PEAK_FLOPS_BF16) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "optimizer": rec.get("optimizer", ""),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_collective, "dominant": dominant,
        "model_flops": mf, "hlo_flops_dev": rec["flops"],
        "hlo_bytes_dev": rec["bytes_accessed"],
        "loop_corr_dev": corr, "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_bytes_dev": (rec.get("memory") or {}).get("peak_bytes"),
        "arg_bytes_dev": (rec.get("memory") or {}).get("argument_bytes"),
        "coll_bytes_dev": coll_dev,
    }


def run(mesh: str = "single"):
    rows = []
    for rec in load_cells(mesh):
        a = analyze(rec)
        if a is None:
            rows.append([rec["arch"], rec["shape"], rec["mesh"],
                         rec["status"], rec.get("reason", rec.get("error", ""))[:60]]
                        + [""] * 8)
            continue
        rows.append([a["arch"], a["shape"], a["mesh"], "ok", a["dominant"],
                     f"{a['t_compute_s']:.4e}", f"{a['t_memory_s']:.4e}",
                     f"{a['t_collective_s']:.4e}",
                     f"{a['useful_ratio']:.3f}",
                     f"{a['roofline_fraction']:.3f}",
                     f"{(a['arg_bytes_dev'] or 0) / 2 ** 30:.2f}",
                     f"{a['coll_bytes_dev'] / 2 ** 20:.1f}",
                     a["optimizer"]])
    write_csv(f"roofline_{mesh}.csv",
              ["arch", "shape", "mesh", "status", "dominant", "t_compute_s",
               "t_memory_s", "t_collective_s", "useful_flops_ratio",
               "roofline_fraction", "arg_GiB_dev", "coll_MiB_dev",
               "optimizer"], rows)
    return rows


def main():
    for mesh in ("single", "multipod"):
        rows = run(mesh)
        ok = [r for r in rows if r[3] == "ok"]
        print(f"roofline.{mesh},,{len(ok)}/{len(rows)} cells analyzed")
        for r in ok:
            print(f"roofline.{r[0]}.{r[1]}.{mesh},,dominant={r[4]} "
                  f"tc={r[5]} tm={r[6]} tcoll={r[7]} useful={r[8]} "
                  f"frac={r[9]}")


if __name__ == "__main__":
    main()
