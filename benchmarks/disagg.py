"""Disaggregated prefill/decode vs colocated serving across link regimes.

The disaggregation question is regime-dependent: splitting the prefill and
decode phases across role-specialized nodes wins only while the KV-transfer
time ``prompt_blocks x bytes_per_block / bandwidth`` stays small against the
phase times it overlaps. This benchmark sweeps the cloud-edge KV link
bandwidth x prompt-length mix on ``disagg_testbed`` and, per regime,
NSGA-II-tunes

* the route-valued ``disagg`` policy under ``EvalConfig(disaggregated=True)``
  (its genome may still pick colocated routes — the search decides *whether*
  to split), and
* every runtime-capable colocated baseline policy under the ordinary pair
  model, keeping the best of them on the (rt, cost) composite.

Reported per regime: quality / cost / rt / TTFT for both, the tuned policy's
**split fraction** (share of requests routed through a split
prefill != decode route) and mean KV-transfer seconds. The expected shape —
asserted by ``main()`` — is a crossover: with a fast link the tuned disagg
policy beats the best colocated baseline on the composite at matched
quality, and with a slow link it collapses onto colocated routes instead of
paying the transfer.

Writes ``results/disagg.csv`` + ``BENCH_disagg.json`` (``*_smoke`` variants
under ``--smoke`` so CI cannot clobber committed full-sweep results).
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.cluster.spec import disagg_testbed
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policies import get_policy, runtime_policies
from repro.workload.sessions import SessionConfig, build_session_trace
from repro.workload.slo import attach_slos

from .common import timed, write_bench_json, write_csv

N_REQUESTS = 160
POP, GENS = 16, 10
TIGHTNESS = 2.0
# composite the regime verdict is judged on: response time + dollar cost,
# cost rescaled into seconds-comparable units so neither axis vanishes
RT_W, COST_W = 1.0, 2e4

# (link regime, KV bandwidth bytes/s) x (prompt mix, prompt-length scale)
LINKS = (("fast", 2.5e9), ("slow", 2.0e6))
MIXES = (("short", 1.0), ("long", 3.0))

SMOKE = "--smoke" in sys.argv    # CI: tiny shapes, same code path


def _workload(seed: int, scale: float):
    n = 48 if SMOKE else N_REQUESTS
    cfg = SessionConfig(n_sessions=max(2, n // 3), mean_turns=3.0,
                        session_rate=1.5, think_time_s=3.0)
    tr = build_session_trace(cfg, seed=seed, n_requests=n)
    attach_slos(tr, tightness=TIGHTNESS, seed=seed)
    tr.prompt_tokens = np.maximum(
        (tr.prompt_tokens * scale).astype(np.int32), 1)
    return tr


def _tune(ev: TraceEvaluator, name: str, seed: int):
    """NSGA-II fit, then pick the survivor that minimizes the benchmark's
    own (rt, cost) composite — the regime verdict below is judged on that
    composite, so selection must target it rather than the generic Eq. (1)
    weighted pick (which is free to trade rt away for cost)."""
    pop = 8 if SMOKE else POP
    gens = 4 if SMOKE else GENS
    cfg = NSGA2Config.from_policy(get_policy(name), pop_size=pop,
                                  n_generations=gens)
    opt = NSGA2(ev.make_fitness(name, objectives="qoe"), cfg)
    state, fit_s = timed(
        lambda: opt.evolve_scan(jax.random.key(seed), gens),
        warmup=0, iters=1)
    cands = np.unique(np.asarray(state.genomes), axis=0)
    spec = get_policy(name).genome_spec
    if spec.defaults is not None:   # tuned must not regress the hand genome
        cands = np.vstack([cands, np.asarray(spec.defaults, cands.dtype)])
    best, best_s = None, None
    for g in cands:
        s = _eval(ev, name, g)
        if best_s is None or s["composite"] < best_s["composite"]:
            best, best_s = g, s
    return best, best_s, fit_s


def _eval(ev: TraceEvaluator, name: str, genome) -> dict:
    res = ev.run_policy(name, genome)
    s = ev.summarize(res)
    s["composite"] = (RT_W * s["avg_response_time"]
                      + COST_W * s["avg_cost"])
    s["transfer_s"] = float(np.mean(np.asarray(res.transfer)))
    arr = ev.arrays
    if ev.cfg.disaggregated:
        rp = np.asarray(arr.route_prefill)
        rq = np.asarray(arr.route_decode)
        assign = np.asarray(res.assign)
        s["split_frac"] = float(np.mean(rp[assign] != rq[assign]))
    else:
        s["split_frac"] = 0.0
    return s


def run(seed: int = 0):
    rows, bench = [], {}
    colocated = [p for p in runtime_policies()
                 if get_policy(p).decides == "pair"]
    for link, bw in LINKS:
        cluster = disagg_testbed(kv_bw_bps=bw)
        for mix, scale in MIXES:
            regime = f"{link}-{mix}"
            tr = _workload(seed, scale)
            ev_d = TraceEvaluator(
                tr, cluster,
                EvalConfig(mode="open", prefix_cache=True,
                           disaggregated=True), bucket="pow2")
            _, sd, fit_s = _tune(ev_d, "disagg", seed)

            ev_c = TraceEvaluator(
                tr, cluster,
                EvalConfig(mode="open", prefix_cache=True), bucket="pow2")
            best_name, sc = None, None
            for name in colocated:
                _, s, _ = _tune(ev_c, name, seed)
                if sc is None or s["composite"] < sc["composite"]:
                    best_name, sc = name, s

            for label, s in (("disagg", sd), (f"colo:{best_name}", sc)):
                rows.append([regime, label, f"{s['avg_quality']:.4f}",
                             f"{s['avg_cost']:.4e}",
                             f"{s['avg_response_time']:.4f}",
                             f"{s['avg_ttft']:.4f}",
                             f"{s['composite']:.4f}",
                             f"{s['split_frac']:.3f}",
                             f"{s['transfer_s']:.4f}"])
            bench[regime] = {
                "kv_bw_bps": bw, "prompt_scale": scale,
                "disagg": {k: sd[k] for k in
                           ("avg_quality", "avg_cost", "avg_response_time",
                            "composite", "split_frac", "transfer_s")},
                "best_colocated": best_name,
                "colocated": {k: sc[k] for k in
                              ("avg_quality", "avg_cost",
                               "avg_response_time", "composite")},
                "nsga2_fit_s": fit_s,
            }

    suffix = "_smoke" if SMOKE else ""
    write_csv(f"disagg{suffix}.csv",
              ["regime", "policy", "avg_quality", "avg_cost", "avg_rt_s",
               "avg_ttft_s", "composite", "split_frac", "transfer_s"], rows)
    write_bench_json(f"disagg{suffix}", {
        "n_requests": tr.n_requests, "regimes": bench,
    })
    return rows, bench


def main():
    _, bench = run()
    for regime, r in bench.items():
        print(f"disagg.{regime},{r['nsga2_fit_s'] * 1e6:.0f},"
              f"split={r['disagg']['split_frac']:.3f} "
              f"composite={r['disagg']['composite']:.4f} "
              f"vs {r['best_colocated']}={r['colocated']['composite']:.4f}")
    if SMOKE:
        return   # tiny pop/gens: the code path runs, verdicts are not stable
    # regime verdicts: disaggregation must WIN the composite at matched
    # quality somewhere on the fast link, and must COLLAPSE to colocated
    # routes (not pay the transfer) when the link is slow
    wins = [k for k, r in bench.items()
            if k.startswith("fast")
            and r["disagg"]["composite"] < r["colocated"]["composite"]
            and r["disagg"]["avg_quality"]
            >= r["colocated"]["avg_quality"] - 5e-3]
    assert wins, f"disaggregation never won a fast-link regime: {bench}"
    slow_split = max(r["disagg"]["split_frac"]
                     for k, r in bench.items() if k.startswith("slow"))
    assert slow_split <= 0.25, \
        f"tuned policy kept splitting over a slow link: {slow_split}"


if __name__ == "__main__":
    main()
