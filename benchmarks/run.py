"""Benchmark aggregator: one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines (per harness convention) and
writes full tables under results/.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (chaos, disagg, fig2_quality, fig3_tradeoff,
                   fig4_concurrency, fleet_scale, hotpath, nsga2_perf,
                   obs_overhead, online_drift, online_learning, policy_matrix,
                   prefix_reuse, roofline, slo_attainment, table2_routing)
    modules = [("table2_routing", table2_routing),
               ("fig2_quality", fig2_quality),
               ("fig3_tradeoff", fig3_tradeoff),
               ("fig4_concurrency", fig4_concurrency),
               ("slo_attainment", slo_attainment),
               ("online_drift", online_drift),
               ("online_learning", online_learning),
               ("prefix_reuse", prefix_reuse),
               ("policy_matrix", policy_matrix),
               ("disagg", disagg),
               ("chaos", chaos),
               ("nsga2_perf", nsga2_perf),
               ("fleet_scale", fleet_scale),
               ("obs_overhead", obs_overhead),
               ("hotpath", hotpath),
               ("roofline", roofline)]
    failures = 0
    for name, mod in modules:
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},,FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
