"""NSGA-II engine performance (measured wall-clock on this host — the
control plane genuinely runs here, unlike the TPU data plane).

Benchmarks:
  * generation throughput vs population size (policy-evals/s),
  * the Pallas dominance kernel (interpret mode — correctness-representative
    op counts; TPU wall-clock is the roofline's job) vs the jnp reference,
  * pymoo-style Python-loop NSGA-II baseline comparison (pure-Python
    generation step) quantifying the vectorization win.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.pareto import crowding_distance, non_dominated_sort
from repro.core.policy import BOUNDS_HI, BOUNDS_LO
from repro.workload.trace import build_trace

from .common import timed, write_csv


def _python_nsga2_generation(F: np.ndarray) -> np.ndarray:
    """pymoo-style pure-Python non-dominated sort (the paper's engine)."""
    n = len(F)
    rank = -np.ones(n, int)
    alive = np.ones(n, bool)
    cur = 0
    while alive.any():
        front = []
        for i in range(n):
            if not alive[i]:
                continue
            dominated = False
            for j in range(n):
                if alive[j] and j != i and \
                        (F[j] <= F[i]).all() and (F[j] < F[i]).any():
                    dominated = True
                    break
            if not dominated:
                front.append(i)
        for i in front:
            rank[i] = cur
            alive[i] = False
        cur += 1
    return rank


def run():
    rows = []
    trace = build_trace(500, seed=0)
    ev = TraceEvaluator(trace, paper_testbed(), EvalConfig(concurrency=1))

    # 1) full-optimization throughput vs population
    for pop in (32, 100, 256):
        cfg = NSGA2Config(pop_size=pop, n_generations=20,
                          lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
        opt = NSGA2(ev.make_fitness("threshold"), cfg)
        state = opt.evolve_scan(jax.random.key(0), 20)   # compile
        jax.block_until_ready(state.F)
        t0 = time.perf_counter()
        state = opt.evolve_scan(jax.random.key(1), 20)
        jax.block_until_ready(state.F)
        dt = time.perf_counter() - t0
        evals = 20 * pop * 2
        rows.append(["evolve_pop%d" % pop, dt / 20 * 1e6,
                     f"{evals / dt:.0f} policy-evals/s (500-req trace)"])

    # 2) non-dominated sort: vectorized JAX vs pure Python at P=256
    rng = np.random.default_rng(0)
    F = rng.random((256, 3)).astype(np.float32)
    Fj = jnp.asarray(F)
    sort_jit = jax.jit(non_dominated_sort)
    _, dt_jax = timed(lambda: jax.block_until_ready(sort_jit(Fj)), iters=10)
    t0 = time.perf_counter()
    _python_nsga2_generation(F)
    dt_py = time.perf_counter() - t0
    rows.append(["nds_jax_p256", dt_jax * 1e6, "vectorized jit"])
    rows.append(["nds_python_p256", dt_py * 1e6,
                 f"pymoo-style loop; jax speedup {dt_py / dt_jax:.0f}x"])

    # 3) dominance kernel interpret-mode vs ref (semantic check + op parity)
    from repro.kernels import ops
    Fbig = jnp.asarray(rng.random((512, 3)), jnp.float32)
    a = ops.dominance_matrix(Fbig, mode="interpret")
    b = ops.dominance_matrix(Fbig, mode="ref")
    assert (np.asarray(a) == np.asarray(b)).all()
    rows.append(["dominance_kernel_p512", 0.0,
                 "pallas interpret == jnp ref (512x512 bool)"])

    write_csv("nsga2_perf.csv", ["name", "us_per_call", "derived"], rows)
    return rows


def main():
    for name, us, derived in run():
        print(f"nsga2_perf.{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
