"""Shared benchmark utilities: timed runs + CSV output under results/."""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results"
REPO = Path(__file__).resolve().parent.parent


def write_csv(name: str, header, rows):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / name
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def write_bench_json(name: str, payload: dict):
    """Write a BENCH_<name>.json perf record at the repo root (the perf
    trajectory CI uploads as an artifact)."""
    path = REPO / f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def _block(out):
    """Wait for async-dispatched JAX work before reading the clock; no-op
    for plain Python outputs."""
    try:
        import jax
        return jax.block_until_ready(out)
    except Exception:
        return out


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Mean **warm** seconds per call (post-compile), with
    ``jax.block_until_ready`` on the outputs — without it JAX's async
    dispatch returns before the work ran and the numbers under-measure.
    Returns (last output, warm seconds)."""
    out, _, warm = timed_full(fn, *args, warmup=warmup, iters=iters)
    return out, warm


def timed_full(fn, *args, warmup: int = 1, iters: int = 3):
    """Like :func:`timed` but reports cold (first call — includes trace +
    XLA compile) and warm time separately: (output, cold_s, warm_s)."""
    t0 = time.perf_counter()
    out = _block(fn(*args))
    cold = time.perf_counter() - t0
    for _ in range(max(warmup - 1, 0)):
        out = _block(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = _block(fn(*args))
    warm = (time.perf_counter() - t0) / iters
    return out, cold, warm
