"""Shared benchmark utilities: timed runs + CSV output under results/."""
from __future__ import annotations

import csv
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results"


def write_csv(name: str, header, rows):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / name
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        out = fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / iters
    return out, dt
