"""Paper Fig. 3: quality/latency/cost trade-off points (the 5 strategies +
the full NSGA-II Pareto front, which the paper's figure summarizes)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.pareto import hypervolume_mc
from repro.workload.trace import build_trace

from .common import write_csv
from .table2_routing import optimize_router


def run(n_requests: int = 500, seed: int = 0):
    import jax
    trace = build_trace(n_requests, seed=seed)
    cluster = paper_testbed()
    ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=1))
    rows = []
    for name, a in [("Cloud Only", baselines.cloud_only(trace, cluster)),
                    ("Edge Only", baselines.edge_only(trace, cluster)),
                    ("Random Router", baselines.random_router(trace, cluster)),
                    ("Round Robin Router", baselines.round_robin(trace, cluster))]:
        s = ev.summarize(ev.run_assignment(jnp.asarray(a)))
        rows.append([name, f"{s['avg_quality']:.4f}",
                     f"{s['avg_response_time']:.4f}", f"{s['avg_cost']:.3e}"])
    opt, state, _ = optimize_router(ev)
    mask = np.asarray((state.rank == 0) & (state.violation <= 0))
    F = np.unique(np.round(np.asarray(state.F_raw)[mask], 6), axis=0)
    for i, f in enumerate(F[np.argsort(F[:, 2])]):
        rows.append([f"front_{i}", f"{1 - f[0]:.4f}", f"{f[2]:.4f}",
                     f"{f[1]:.3e}"])
    ref = jnp.asarray(F.max(0) * 1.1 + 1e-9)
    ideal = jnp.asarray(F.min(0))
    hv = float(hypervolume_mc(jnp.asarray(F), ref, ideal, jax.random.key(0)))
    write_csv("fig3.csv", ["point", "quality", "rt_s", "cost"], rows)
    return rows, hv, len(F)


def main():
    rows, hv, n = run()
    print(f"fig3.pareto_front,,{n} distinct front points, "
          f"MC hypervolume={hv:.3e}")


if __name__ == "__main__":
    main()
