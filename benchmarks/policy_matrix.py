"""Policy matrix: every registered RoutingPolicy on one shared workload.

The registry (``repro.core.policies``) makes the policy the unit of
extension; this benchmark is the harness half of that contract: it
enumerates ``list_policies()`` at run time, NSGA-II-fits each policy with a
config derived from its own ``GenomeSpec``
(``NSGA2Config.from_policy``), and evaluates both the hand defaults (when
the spec carries any) and the tuned genome on one shared open-loop
multi-turn session trace with the prefix-cache model enabled — so a policy
module dropped into ``core/policies/`` shows up here with **zero edits**.

Per policy the matrix reports quality, cost, response time, TTFT, SLO
attainment, cache hit fraction, the wall-clock NSGA-II fit time, and a
**learned** column pair: the same genome replayed under an unannounced
cloud-node straggler with static priors vs the online estimators
(``repro.learn``, ``EvalConfig(learned=True)``) correcting the estimate
rows.
Writes ``results/policy_matrix.csv`` + ``BENCH_policy_matrix.json``
(``*_smoke`` variants under ``--smoke`` so CI cannot clobber committed
full-sweep results).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policies import get_policy, list_policies
from repro.faults import FaultSchedule, Straggler
from repro.learn import LearnConfig
from repro.obs.metrics import Histogram
from repro.workload.sessions import SessionConfig, build_session_trace
from repro.workload.slo import attach_slos

from .common import timed, write_bench_json, write_csv

N_REQUESTS = 192
POP, GENS = 16, 12
TIGHTNESS = 2.0
# Eq. (1)-style selection weights over (RQ, C, RT, V) for the NSGA pick
WEIGHTS = (0.25, 0.30, 0.30, 0.15)

SMOKE = "--smoke" in sys.argv    # CI: tiny shapes, same code path


def _workload(seed: int):
    n = 48 if SMOKE else N_REQUESTS
    cfg = SessionConfig(n_sessions=max(2, n // 3), mean_turns=3.0,
                        session_rate=1.5, think_time_s=3.0)
    tr = build_session_trace(cfg, seed=seed, n_requests=n)
    attach_slos(tr, tightness=TIGHTNESS, seed=seed)
    return tr


def run(seed: int = 0):
    cluster = paper_testbed()
    tr = _workload(seed)
    ev_pair = TraceEvaluator(tr, cluster,
                             EvalConfig(mode="open", prefix_cache=True),
                             bucket="pow2")
    # route-valued policies (decides == "route", e.g. disagg) index the
    # cluster's (prefill, decode) route table, so they need the
    # disaggregated environment model; every paper_testbed node is
    # unified-role, so colocated routes exist and the comparison stays on
    # the same hardware
    ev_route = TraceEvaluator(tr, cluster,
                              EvalConfig(mode="open", prefix_cache=True,
                                         disaggregated=True),
                              bucket="pow2")
    # the `learned` column: the same genome replayed under an unannounced
    # straggler (cloud node 3x slower than its static table), once on static
    # priors and once with the online estimators (repro.learn) correcting
    # the estimate rows in the scan carry — so every registered policy
    # reports what closing the observation loop is worth, with zero edits
    sched = FaultSchedule(stragglers=(Straggler(0, 0.0, 1e9, 3.0),))
    ev_strag = {}
    for disagg in (False, True):
        for learned in (False, True):
            ev_strag[(disagg, learned)] = TraceEvaluator(
                tr, cluster,
                EvalConfig(mode="open", prefix_cache=True, faulty=True,
                           disaggregated=disagg, learned=learned,
                           learner=LearnConfig()),
                bucket="pow2", faults=sched)
    pop = 8 if SMOKE else POP
    gens = 4 if SMOKE else GENS

    rows, bench = [], {}
    for name in list_policies():
        pol = get_policy(name)
        spec = pol.genome_spec
        ev = ev_route if pol.decides == "route" else ev_pair
        if spec.per_request:
            cfg = NSGA2Config.from_policy(pol, pop_size=pop,
                                          n_generations=gens,
                                          genome_length=tr.n_requests,
                                          n_choices=cluster.n_pairs)
        else:
            cfg = NSGA2Config.from_policy(pol, pop_size=pop,
                                          n_generations=gens)
        opt = NSGA2(ev.make_fitness(name, objectives="qoe"), cfg)
        state, fit_s = timed(
            lambda o=opt: o.evolve_scan(jax.random.key(seed), gens),
            warmup=0, iters=1)
        genome, _ = opt.select_by_weights(state, jnp.asarray(WEIGHTS))

        variants = {"tuned": np.asarray(genome)}
        if spec.defaults is not None:
            variants["default"] = np.asarray(spec.defaults)
        for variant, g in variants.items():
            res = ev.run_policy(name, g)
            s = ev.summarize(res)
            # tail latency off the shared log-bucket histogram (repro.obs):
            # means hide exactly the p95/p99 regressions routing policies
            # trade against, so the matrix reports both
            h_rt, h_tt = Histogram(), Histogram()
            h_rt.observe(np.asarray(res.rt, np.float64))
            h_tt.observe(np.asarray(res.ttft, np.float64))
            rt_p, tt_p = h_rt.percentiles(), h_tt.percentiles()
            att_strag = {}
            for learned in (False, True):
                ev_f = ev_strag[(pol.decides == "route", learned)]
                att_strag[learned] = ev_f.summarize(
                    ev_f.run_policy(name, g))["slo_attainment"]
            rows.append([name, variant, f"{s['avg_quality']:.4f}",
                         f"{s['avg_cost']:.4e}",
                         f"{s['avg_response_time']:.4f}",
                         f"{rt_p['p50']:.4f}", f"{rt_p['p95']:.4f}",
                         f"{rt_p['p99']:.4f}",
                         f"{s['avg_ttft']:.4f}", f"{tt_p['p99']:.4f}",
                         f"{s['slo_attainment']:.4f}",
                         f"{s['cache_hit_frac']:.4f}",
                         f"{att_strag[False]:.4f}", f"{att_strag[True]:.4f}",
                         f"{fit_s:.3f}"])
            bench[f"{name}.{variant}"] = {
                "policy": name, "variant": variant,
                "avg_quality": s["avg_quality"], "avg_cost": s["avg_cost"],
                "avg_rt_s": s["avg_response_time"],
                "rt_p50_s": float(rt_p["p50"]),
                "rt_p95_s": float(rt_p["p95"]),
                "rt_p99_s": float(rt_p["p99"]),
                "ttft_p99_s": float(tt_p["p99"]),
                "slo_attainment": s["slo_attainment"],
                "cache_hit_frac": s["cache_hit_frac"],
                "attain_straggler_static": att_strag[False],
                "attain_straggler_learned": att_strag[True],
                "nsga2_fit_s": fit_s,
            }

    suffix = "_smoke" if SMOKE else ""
    write_csv(f"policy_matrix{suffix}.csv",
              ["policy", "variant", "avg_quality", "avg_cost", "avg_rt_s",
               "rt_p50_s", "rt_p95_s", "rt_p99_s", "avg_ttft_s",
               "ttft_p99_s", "slo_attainment", "cache_hit_frac",
               "attain_straggler_static", "attain_straggler_learned",
               "nsga2_fit_s"], rows)
    write_bench_json(f"policy_matrix{suffix}", {
        "n_requests": tr.n_requests, "pop_size": pop, "generations": gens,
        "policies": bench,
    })
    return rows, bench


def main():
    rows, bench = run()
    for key, r in bench.items():
        print(f"policy_matrix.{key},{r['nsga2_fit_s'] * 1e6:.0f},"
              f"quality={r['avg_quality']:.4f} cost={r['avg_cost']:.4e} "
              f"rt={r['avg_rt_s']:.4f} rt_p99={r['rt_p99_s']:.4f} "
              f"attain={r['slo_attainment']:.4f} "
              f"hit={r['cache_hit_frac']:.4f} "
              f"strag={r['attain_straggler_static']:.4f}->"
              f"{r['attain_straggler_learned']:.4f}")
    # the registry contract: every registered policy produced a tuned row
    missing = [p for p in list_policies()
               if f"{p}.tuned" not in bench]
    assert not missing, f"policy matrix missed registered policies: {missing}"


if __name__ == "__main__":
    main()
