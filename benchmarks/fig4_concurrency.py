"""Paper Fig. 4: proposed-router performance at concurrency 1 / 4 / 8 / 10
(closed-loop clients over the queued cluster model), plus the capacity-limit
point the paper mentions (§V-E: degradation near concurrency 11)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cluster.spec import paper_testbed
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.workload.trace import build_trace

from .common import write_csv
from .table2_routing import optimize_router, select_operating_point

PAPER = {1: (0.5462, 1.1137, 7.36e-5), 4: (0.5536, 1.1141, 7.36e-5),
         8: (0.5542, 1.1660, 7.40e-5), 10: (0.5438, 1.2061, 7.41e-5)}


def run(n_requests: int = 500, seed: int = 0,
        levels=(1, 4, 8, 10, 12)):
    trace = build_trace(n_requests, seed=seed)
    cluster = paper_testbed()
    # optimize thresholds once at concurrency 1 (as the paper does), then
    # evaluate the same policy under increasing concurrency
    from repro.core import baselines as B
    ev1 = TraceEvaluator(trace, cluster, EvalConfig(concurrency=1))
    summaries = [ev1.summarize(ev1.run_assignment(jnp.asarray(a)))
                 for a in (B.cloud_only(trace, cluster),
                           B.edge_only(trace, cluster),
                           B.random_router(trace, cluster),
                           B.round_robin(trace, cluster))]
    opt, state, _ = optimize_router(ev1)
    genome = select_operating_point(opt, state, ev1, summaries)

    rows = []
    out = {}
    for g in levels:
        ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=g))
        s = ev.summarize(ev.run_thresholds(genome))
        out[g] = s
        pq, pt, pc = PAPER.get(g, ("", "", ""))
        rows.append([g, f"{s['avg_quality']:.4f}", pq,
                     f"{s['avg_response_time']:.4f}", pt,
                     f"{s['avg_cost']:.3e}", pc])
    write_csv("fig4.csv", ["concurrency", "avg_quality", "paper_quality",
                           "avg_rt_s", "paper_rt_s", "avg_cost",
                           "paper_cost"], rows)
    return out


def main():
    out = run()
    for g, s in out.items():
        print(f"fig4.concurrency_{g},,q={s['avg_quality']:.4f} "
              f"rt={s['avg_response_time']:.4f} cost={s['avg_cost']:.3e}")


if __name__ == "__main__":
    main()
