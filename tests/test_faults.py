"""Chaos hardening: deterministic fault injection across the three analytic
layers (JAX fitness scan == loop DES == heap DES under a non-trivial
``FaultSchedule``, for every registered policy), circuit-breaker state
machine, retry/backoff/budget and load-shedding behavior of the serving
runtime, monitor clock-domain regression, and phase-B exception safety
(an error mid-commit must not leak KV pins or cohort write-backs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (crash_storm_schedule, link_flap_schedule,
                      make_session_trace, shared_cluster, straggler_schedule)
from repro.cluster.monitor import ClusterMonitor
from repro.cluster.simulator import ClusterSimulator
from repro.cluster.spec import paper_testbed
from repro.configs import get
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.policies import get_policy, list_policies, runtime_policies
from repro.core.policy import PAPER_DEFAULTS
from repro.faults import (CrashWindow, FaultSchedule, HeartbeatLoss,
                          LinkFlap, Straggler, TransientErrors,
                          backoff_jitter_u, heartbeat_lost, jnp_tables,
                          link_slowdown_jnp, link_slowdown_np,
                          node_available_jnp, node_available_np,
                          node_slowdown_jnp, node_slowdown_np,
                          transient_delay_jnp, transient_delay_np)
from repro.models import lm
from repro.serving import (ClusterServer, EngineConfig, ResilienceConfig,
                           ServeRequest)
from repro.workload.trace import build_trace

CLUSTER = shared_cluster()
NO_HEDGE = 10 ** 9


def _chaos_schedule(n_nodes: int) -> FaultSchedule:
    """The non-trivial mixed regime of the equivalence tests: two crash
    windows, a straggler, a link flap, and per-request transient errors."""
    return FaultSchedule(
        crashes=(CrashWindow(1, 1.0, 12.0), CrashWindow(0, 20.0, 26.0)),
        stragglers=(Straggler(2 % n_nodes, 4.0, 30.0, 3.0),),
        link_flaps=(LinkFlap(2.0, 18.0, 15.0),),
        transient=TransientErrors(rate=0.15, backoff=0.08, seed=11))


# ---------------------------------------------------------------------------
# numpy / jnp twins
# ---------------------------------------------------------------------------
def test_fault_table_twins_agree():
    """Every fault-table query has a numpy and a jnp twin; they must agree
    on a dense time grid (and per request index for the transient draws)."""
    sched = FaultSchedule(
        crashes=(CrashWindow(0, 2.0, 9.0), CrashWindow(2, 5.0, 6.5)),
        stragglers=(Straggler(1, 1.0, 20.0, 4.0), Straggler(1, 3.0, 7.0, 2.0)),
        link_flaps=(LinkFlap(4.0, 11.0, 25.0),),
        heartbeat_losses=(HeartbeatLoss(3, 2.0, 4.0),),
        transient=TransientErrors(rate=0.4, backoff=0.1, jitter=0.6, seed=7))
    ft = sched.compile(4)
    jt = jnp_tables(ft)
    for t in np.linspace(0.0, 25.0, 101, dtype=np.float32):
        np.testing.assert_array_equal(
            node_available_np(ft, t), np.asarray(node_available_jnp(jt, t)))
        np.testing.assert_allclose(
            node_slowdown_np(ft, t), np.asarray(node_slowdown_jnp(jt, t)),
            rtol=1e-6)
        np.testing.assert_allclose(
            link_slowdown_np(ft, t), float(link_slowdown_jnp(jt, t)),
            rtol=1e-6)
    for i in range(200):
        np.testing.assert_allclose(
            transient_delay_np(ft, i),
            float(transient_delay_jnp(jt, jnp.int32(i))), rtol=1e-6)
    # the jitter stream is deterministic, bounded, and attempt-sensitive
    us = [backoff_jitter_u(7, 3, a) for a in range(5)]
    assert all(0.0 <= u < 1.0 for u in us) and len(set(us)) == 5
    assert us == [backoff_jitter_u(7, 3, a) for a in range(5)]
    # heartbeat loss is schedule-level (no analytic effect, host-side query)
    assert heartbeat_lost(sched, 3, 3.0) and not heartbeat_lost(sched, 3, 5.0)
    assert not heartbeat_lost(sched, 0, 3.0)


def test_fault_presets_deterministic():
    for mk in (lambda: crash_storm_schedule(seed=4),
               lambda: link_flap_schedule(seed=4),
               lambda: straggler_schedule(seed=4)):
        assert mk() == mk()
    assert crash_storm_schedule(seed=1) != crash_storm_schedule(seed=2)
    # spare nodes never crash in a crash storm
    sched = FaultSchedule.crash_storm(4, seed=3, spare=2)
    assert all(c.node >= 2 for c in sched.crashes)


# ---------------------------------------------------------------------------
# 3-way equivalence under a non-trivial fault regime, every policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", list_policies())
def test_policy_decisions_match_des_oracles_under_faults(policy):
    """The JAX fitness scan and both DES oracles replay the SAME fault
    schedule (crashes mask + fail over, stragglers stretch service/TPOT,
    link flaps stretch KV transfers, transients delay arrivals) and must
    still route every request identically and agree on all realized
    metrics — in-loop decisions on all three sides."""
    tr = make_session_trace(n_requests=70, seed=7)
    sched = _chaos_schedule(len(CLUSTER.nodes))
    pol = get_policy(policy)
    if pol.genome_spec.per_request:
        genome = np.random.default_rng(0).integers(
            0, CLUSTER.n_pairs, tr.n_requests).astype(np.int32)
    else:
        genome = pol.genome_spec.defaults
    disagg = pol.decides == "route"
    ev = TraceEvaluator(tr, CLUSTER,
                        EvalConfig(mode="open", prefix_cache=True,
                                   disaggregated=disagg), faults=sched)
    res = ev.run_policy(policy, genome)
    sim = ClusterSimulator(tr, CLUSTER, prefix_cache=True,
                           disaggregated=disagg, faults=sched)
    fields = ("q", "cost", "rt", "ttft", "tpot", "hit")
    if disagg:
        fields += ("transfer",)
    for sr in (sim.run(policy=policy, genome=genome),
               sim.run_event_heap(policy=policy, genome=genome)):
        np.testing.assert_array_equal(np.asarray(res.assign), sr.assign)
        for f in fields:
            np.testing.assert_allclose(np.asarray(getattr(res, f)),
                                       getattr(sr, f), rtol=1e-4, atol=1e-5,
                                       err_msg=f"{policy}:{f}")


def test_faulty_run_differs_from_clean():
    """The schedule must actually bite: same trace/policy with and without
    faults may not produce identical response times."""
    tr = make_session_trace(n_requests=70, seed=7)
    g = get_policy("threshold").genome_spec.defaults
    clean = TraceEvaluator(tr, CLUSTER, EvalConfig(mode="open"))
    faulty = TraceEvaluator(tr, CLUSTER, EvalConfig(mode="open"),
                            faults=_chaos_schedule(len(CLUSTER.nodes)))
    rc = clean.run_policy("threshold", g)
    rf = faulty.run_policy("threshold", g)
    assert not np.allclose(np.asarray(rc.rt), np.asarray(rf.rt))
    assert float(np.asarray(rf.rt).mean()) > float(np.asarray(rc.rt).mean())


# ---------------------------------------------------------------------------
# circuit breaker state machine (monitor level)
# ---------------------------------------------------------------------------
def _breaker_monitor():
    # huge heartbeat timeout: these tests advance the clock to exercise
    # breaker cooldowns and must not trip the (orthogonal) staleness sweep
    return ClusterMonitor(2, heartbeat_timeout=10.0 ** 9,
                          breaker_threshold=0.5, breaker_min_obs=4,
                          breaker_cooldown=10.0)


def test_breaker_opens_on_error_ewma():
    mon = _breaker_monitor()
    for _ in range(3):
        mon.on_dispatch(0)
        mon.on_failure(0)
    assert mon.breaker_states()[0] == "closed"   # min_obs not reached
    mon.on_dispatch(0)
    mon.on_failure(0)
    assert mon.breaker_states()[0] == "open"
    assert mon.healthy_mask() == (False, True)   # open breaker masks routing
    assert int(mon.breaker_opens[0]) == 1


def test_breaker_half_open_probe_success_closes():
    mon = _breaker_monitor()
    for _ in range(4):
        mon.on_dispatch(0)
        mon.on_failure(0)
    mon.advance(5.0)
    assert mon.breaker_states()[0] == "open"     # still cooling down
    mon.advance(11.0)
    assert mon.breaker_states()[0] == "half-open"
    assert mon.healthy_mask()[0]                 # one probe admitted
    mon.on_dispatch(0)                           # the probe
    assert not mon.healthy_mask()[0]             # masked while it resolves
    mon.on_complete(0, latency=1.0)
    assert mon.breaker_states()[0] == "closed"
    assert mon.healthy_mask()[0]


def test_breaker_half_open_probe_failure_reopens():
    mon = _breaker_monitor()
    for _ in range(4):
        mon.on_dispatch(0)
        mon.on_failure(0)
    mon.advance(11.0)
    mon.on_dispatch(0)
    mon.on_failure(0)                            # probe failed
    assert mon.breaker_states()[0] == "open"
    assert int(mon.breaker_opens[0]) == 2
    mon.advance(12.0)
    assert mon.breaker_states()[0] == "open"     # cooldown restarted
    # explicit recovery is the only shortcut back to closed
    mon.reset_breaker(0)
    assert mon.breaker_states()[0] == "closed"
    assert mon.stats[0].err_ewma == 0.0


# ---------------------------------------------------------------------------
# serving runtime under chaos
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def builders():
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    pb = lm.init(jax.random.key(0), big)
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


@pytest.fixture(scope="module")
def reqs():
    return build_trace(24, seed=5).requests


def _server(builders, policy="threshold", hedge_after=NO_HEDGE, **kw):
    return ClusterServer(paper_testbed(), builders, PAPER_DEFAULTS,
                         EngineConfig(max_slots=2, max_seq=48,
                                      max_new_tokens=4, prefix_cache=True,
                                      block_size=8, cache_blocks=32),
                         hedge_after=hedge_after,
                         router_kwargs={"mode": policy}, **kw)


def _assert_conserved(srv):
    for node, s in srv.monitor.stats.items():
        assert s.total_dispatched == (s.total_completed + s.total_failed
                                      + s.total_cancelled), (node, s)
        assert s.outstanding == 0, (node, s)


def _assert_no_leaks(srv):
    for eng in srv.engines.values():
        if eng.kv is not None:
            eng.kv.cache.check_invariants()
            assert int(np.sum(eng.kv.cache.pool.ref > 0)) == 0


def test_tick_clock_server_never_marks_live_nodes_stale(builders):
    """Clock-domain regression: a server driven purely on its tick clock
    (many idle ticks, no explicit heartbeats) must keep every live node
    healthy — the per-tick auto-heartbeat and ``monitor.advance`` share one
    clock, so simulated time passing cannot look like heartbeat loss."""
    srv = _server(builders)
    for _ in range(10 * int(srv.monitor.heartbeat_timeout) + 5):
        srv.step()
    assert all(srv.monitor.healthy_mask())
    assert srv.monitor.now == srv.ticks


def test_heartbeat_loss_masks_routing_but_not_progress(builders, reqs):
    """A heartbeat-dark node goes stale (masked from routing) without
    crashing: its engines keep executing, and when the window ends the
    auto-heartbeat revives it."""
    sched = FaultSchedule(heartbeat_losses=(HeartbeatLoss(0, 0.0, 40.0),))
    srv = _server(builders, faults=sched)
    timeout = srv.monitor.heartbeat_timeout
    for _ in range(int(timeout) + 2):
        srv.step()
    assert not srv.monitor.healthy_mask()[0]      # stale -> routing-masked
    assert 0 not in srv._down_nodes               # ...but alive
    for i, r in enumerate(reqs[:6]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    arr = srv.router._np_arrays
    assert all(int(arr.pair_node[fl.pair]) != 0
               for fl in srv.inflight.values())   # nothing routed to node 0
    done = srv.run()
    assert sorted(done) == list(range(6))
    _assert_conserved(srv)
    for _ in range(45):
        srv.step()
    assert srv.monitor.healthy_mask()[0]          # window over: revived


def test_straggler_slow_credit_gates_progress(builders):
    """A factor-2 straggler's engines execute every other tick (slow-credit
    integration), everyone else every tick."""
    sched = FaultSchedule(stragglers=(Straggler(1, 0.0, 1000.0, 2.0),))
    srv = _server(builders, faults=sched)
    adv = []
    for _ in range(8):
        srv.step()
        adv.append(bool(srv._advance[1]))
        assert all(srv._advance[[0, 2, 3]])
    assert adv == [False, True] * 4


def test_transient_errors_retry_to_completion(builders, reqs):
    """Transient dispatch errors bounce into the jittered-backoff retry
    queue and drain to completion; the failed dispatches feed the per-node
    ledger (and breakers) without breaking conservation."""
    sched = FaultSchedule(transient=TransientErrors(rate=0.5, seed=11))
    srv = _server(builders, faults=sched)
    for i, r in enumerate(reqs[:12]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    done = srv.run()
    assert sorted(done) == list(range(12))
    st = srv.stats()
    assert st["transient_faults"] > 0 and st["retries"] > 0
    assert all(isinstance(d, dict) and "tokens" in d for d in done.values())
    _assert_conserved(srv)
    _assert_no_leaks(srv)


def test_timeouts_retry_within_budget(builders, reqs):
    """A timeout cancels every copy of the flight, re-queues it with
    backoff, and stops consuming the global budget once attempts run out —
    the request then completes degraded instead of being dropped."""
    rcfg = ResilienceConfig(request_timeout_ticks=3, min_timeout_ticks=1,
                            deadline_timeout_factor=1e9, max_retries=1,
                            backoff_base_ticks=1.0)
    srv = _server(builders, resilience=rcfg)
    for i, r in enumerate(reqs[:8]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=6))
    done = srv.run()
    assert sorted(done) == list(range(8))
    st = srv.stats()
    assert st["timeouts"] > 0 and st["retries"] == st["timeouts"]
    assert st["retries"] <= max(rcfg.retry_budget_min,
                                int(rcfg.retry_budget_frac
                                    * sum(s.total_dispatched
                                          for s in srv.monitor.stats.values())))
    _assert_conserved(srv)
    _assert_no_leaks(srv)


def test_shedding_by_slo_class(builders, reqs):
    """Above the utilization threshold, admission sheds batch-class work
    first; interactive requests keep being admitted until the (higher)
    interactive threshold."""
    rcfg = ResilienceConfig(shed_threshold=0.5, shed_interactive_threshold=3.0)
    srv = _server(builders, resilience=rcfg)
    statuses = {}
    for i, r in enumerate((reqs * 2)[:40]):
        cls = "batch" if i % 2 else "interactive"
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4,
                                slo_class=cls))
        d = srv.done.get(i)
        if isinstance(d, dict) and d.get("status") == "shed":
            statuses[i] = cls
    assert statuses, "overload never shed anything"
    assert set(statuses.values()) == {"batch"}   # interactive survived
    done = srv.run()
    assert len(done) == 40
    assert srv.stats()["sheds"] == len(statuses)
    _assert_conserved(srv)
    _assert_no_leaks(srv)


@pytest.mark.parametrize("policy", runtime_policies())
def test_retry_hedge_failover_conservation(builders, reqs, policy):
    """The adversarial interaction: aggressive hedging, tight timeouts with
    retries, transient errors, and a schedule-driven node crash mid-run —
    per-node ``dispatched == completed + failed + cancelled`` must hold for
    every runtime policy, with zero outstanding and zero leaked KV blocks."""
    sched = FaultSchedule(
        crashes=(CrashWindow(1, 3.0, 10.0 ** 9),),
        transient=TransientErrors(rate=0.3, seed=11))
    rcfg = ResilienceConfig(request_timeout_ticks=6, min_timeout_ticks=4,
                            deadline_timeout_factor=1e9, max_retries=2,
                            backoff_base_ticks=1.0)
    srv = _server(builders, policy=policy, hedge_after=2, faults=sched,
                  resilience=rcfg)
    for i, r in enumerate(reqs[:10]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4))
    done = srv.run(max_ticks=4000)
    assert sorted(done) == list(range(10))
    _assert_conserved(srv)
    _assert_no_leaks(srv)


def test_phase_b_exception_releases_pins(builders, reqs, monkeypatch):
    """Exception safety for ``step`` phase B: an engine blowing up
    mid-commit is treated as a node crash — its flights re-route, its pools
    flush, and pool refcounts return to baseline (nothing pinned, ledger
    conserved)."""
    srv = _server(builders)
    for i, r in enumerate(reqs[:8]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4))
    victim_pair = next(iter(srv.inflight.values())).pair
    victim_node = int(srv.router._np_arrays.pair_node[victim_pair])
    eng = srv.engines[victim_pair]
    boom = {"armed": True}

    def exploding_commit(work):
        if boom.pop("armed", False):
            raise RuntimeError("injected mid-commit fault")
        return type(eng)._commit_chunk(eng, work)

    monkeypatch.setattr(eng, "_commit_chunk", exploding_commit)
    monkeypatch.setattr(
        eng, "step", lambda *a, **k: (_ for _ in ()).throw(
            RuntimeError("injected mid-commit fault"))
        if boom.pop("armed", False) else type(eng).step(eng))
    done = srv.run(max_ticks=4000)
    assert sorted(done) == list(range(8))
    assert victim_node in srv._down_nodes         # crash semantics applied
    assert srv.stats()["reroutes"] >= 1
    _assert_conserved(srv)
    _assert_no_leaks(srv)
