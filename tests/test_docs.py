"""Docs checks: the architecture doc must mention every src/repro package,
and the README must carry the quickstart + tier-1 commands. CI runs these on
every push (.github/workflows/ci.yml) so docs cannot silently rot."""
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _packages():
    src = REPO / "src" / "repro"
    return sorted(p.name for p in src.iterdir()
                  if p.is_dir() and (p / "__init__.py").exists())


def test_architecture_doc_mentions_every_package():
    doc = (REPO / "docs" / "architecture.md").read_text()
    missing = [pkg for pkg in _packages()
               if f"repro.{pkg}" not in doc and f"repro/{pkg}" not in doc]
    assert not missing, f"docs/architecture.md misses packages: {missing}"


def test_readme_has_quickstart_and_tier1_command():
    readme = (REPO / "README.md").read_text()
    assert "examples/quickstart.py" in readme
    assert "python -m pytest -x -q" in readme
    assert "benchmarks" in readme


def test_benchmarks_readme_covers_every_module():
    doc = (REPO / "benchmarks" / "README.md").read_text()
    mods = [p.stem for p in (REPO / "benchmarks").glob("*.py")
            if p.stem not in ("common", "run", "__init__")]
    missing = [m for m in mods if f"{m}.py" not in doc]
    assert not missing, f"benchmarks/README.md misses: {missing}"


def test_architecture_doc_has_policy_registry_guide():
    """The extension guide must exist and name every registered policy, so
    a policy shipped without docs fails tier-1."""
    from repro.core.policies import list_policies
    doc = (REPO / "docs" / "architecture.md").read_text()
    assert "Policy registry & extension guide" in doc
    assert "register_policy" in doc and "GenomeSpec" in doc
    missing = [p for p in list_policies() if f"`{p}`" not in doc]
    assert not missing, \
        f"docs/architecture.md policy guide misses policies: {missing}"


def test_readme_mentions_policy_registry():
    readme = (REPO / "README.md").read_text()
    assert "core/policies" in readme
    assert "p2c-hedge" in readme and "budget" in readme
    assert "disagg" in readme


def test_architecture_doc_has_disagg_section():
    """The disaggregated-serving section must exist and cover roles, the
    link model, transfer accounting, failure semantics, and the
    route-valued registry-extension note."""
    doc = (REPO / "docs" / "architecture.md").read_text()
    assert "Disaggregated prefill/decode & KV handoff" in doc
    for needle in ("route table", "kv_bw_bps", "disagg_testbed",
                   "EvalConfig(disaggregated=True)", "export_blocks",
                   "prefill_only", "transfer-in-flight",
                   'decides = "route"'):
        assert needle in doc, f"disagg docs miss: {needle}"


def test_benchmarks_readme_names_disagg():
    doc = (REPO / "benchmarks" / "README.md").read_text()
    assert "disagg.py" in doc and "split fraction" in doc


def test_architecture_doc_has_fleet_section():
    """The fleet-vectorized-serving section must exist and cover the cohort
    grouping rules, the host/device split, and the fallback conditions."""
    doc = (REPO / "docs" / "architecture.md").read_text()
    assert "Fleet-vectorized serving" in doc
    for needle in ("Cohort grouping rules", "build_cohorts", "fleet_ok",
                   "FleetState", "FleetMemberStore", "Fallback conditions",
                   "O(#cohorts)", "fleet=False", "fleet_testbed",
                   "record_fleet", "byte-identical"):
        assert needle in doc, f"fleet docs miss: {needle}"


def test_readme_and_bench_readme_name_fleet():
    readme = (REPO / "README.md").read_text()
    assert "serving/fleet.py" in readme and "cohort" in readme
    bench = (REPO / "benchmarks" / "README.md").read_text()
    assert "fleet_scale.py" in bench and "fleet_testbed" in bench
    assert "dispatches per saturated tick" in bench


def test_architecture_doc_has_observability_section():
    """The observability section must exist and cover the span model, the
    metric vocabulary, clock discipline, the audit, export, and the
    overhead budget."""
    doc = (REPO / "docs" / "architecture.md").read_text()
    assert "## Observability" in doc
    for needle in ("span conservation", "accounting mirror", "NOOP_TRACER",
                   "MetricsRegistry", "RouteAudit", "chrome_trace",
                   "Obs.noop()", "kv-transfer", "route-decision",
                   "scheduler ticks", "byte-identical",
                   'stats()["percentiles"]', "ewma_initialized",
                   "DeprecationWarning"):
        assert needle in doc, f"observability docs miss: {needle}"
    # the documented vocabulary stays in lockstep with the code
    from repro.obs.metrics import METRIC_NAMES
    from repro.obs.trace import EVENT_NAMES, PHASE_NAMES
    for name in PHASE_NAMES + EVENT_NAMES + METRIC_NAMES:
        assert name in doc, f"observability docs miss vocabulary: {name}"


def test_readme_and_bench_readme_name_obs():
    readme = (REPO / "README.md").read_text()
    assert "src/repro/obs/" in readme and "obs_overhead.py" in readme
    assert "p50/p95/p99" in readme
    bench = (REPO / "benchmarks" / "README.md").read_text()
    assert "obs_overhead.py" in bench and "BENCH_obs.json" in bench
    assert "Chrome-trace" in bench


def test_architecture_doc_has_resilience_section():
    """The resilience section must exist and cover the fault vocabulary,
    three-layer equivalence, breaker state machine, retry-budget semantics,
    slow-credit straggling, and the shed policy."""
    doc = (REPO / "docs" / "architecture.md").read_text()
    assert "Resilience & fault injection" in doc
    for needle in ("FaultSchedule", "CrashWindow", "Straggler", "LinkFlap",
                   "HeartbeatLoss", "TransientErrors", "crash_storm",
                   "EvalConfig.faulty", "half-open", "breaker_threshold",
                   "Retry-budget semantics", "backoff_jitter_u",
                   "deadline-aware", "slow-credit", "Shed policy",
                   "shed_threshold", "brownout", "ResilienceConfig",
                   "reset_breaker", "chaos.py"):
        assert needle in doc, f"resilience docs miss: {needle}"


def test_architecture_doc_has_learning_section():
    """The online-learning section must exist and cover both update rules,
    the cold-start/residual contract, the clock/feature contract, the
    PolicyInputs override, and the bandit policy."""
    doc = (REPO / "docs" / "architecture.md").read_text()
    assert "Online-learned estimators & bandit routing" in doc
    for needle in ("repro.learn", "LearnConfig", "Sherman–Morrison",
                   "EvalConfig(learned=True", "OnlineEstimator",
                   "corrected_rows", "feed_estimator", "cold start",
                   "Clock/feature contract", "PolicyInputs override",
                   "`bandit`", "learn_state", "residual",
                   'requires={"quality"}'):
        assert needle in doc, f"learning docs miss: {needle}"


def test_readme_and_bench_readme_name_learning():
    readme = (REPO / "README.md").read_text()
    assert "src/repro/learn/" in readme and "bandit" in readme
    assert "learned" in readme
    bench = (REPO / "benchmarks" / "README.md").read_text()
    assert "online_learning.py" in bench and "BENCH_learning.json" in bench
    assert "estimator error" in bench


def test_readme_and_bench_readme_name_chaos():
    readme = (REPO / "README.md").read_text()
    assert "src/repro/faults/" in readme and "chaos.py" in readme
    assert "circuit breaker" in readme and "shed" in readme
    bench = (REPO / "benchmarks" / "README.md").read_text()
    assert "chaos.py" in bench and "BENCH_chaos.json" in bench
    assert "crash-storm" in bench and "SLO attainment" in bench
