"""Compile-once hot paths: bucketed trace evaluation, jit-cache reuse across
re-fit windows and NSGA-II instances, host-sync-free engine stepping, the
prefill bucket, and device-sharded population fitness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.spec import paper_testbed
from repro.configs import get
from repro.core import nsga2 as nsga2_mod
from repro.core.fitness import (EvalConfig, TraceEvaluator, _run_trace,
                                bucket_size, next_pow2, population_mesh)
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.pareto import dominance_matrix, non_dominated_sort
from repro.core.policy import (AFFINITY_DEFAULTS, PAPER_DEFAULTS,
                               SLO_BOUNDS_HI, SLO_BOUNDS_LO, SLO_DEFAULTS)
from repro.models import lm
from repro.serving import engine as engine_mod
from repro.serving.engine import EngineConfig, LLMEngine
from repro.workload.sessions import SessionConfig, build_session_trace
from repro.workload.slo import attach_slos
from repro.workload.trace import build_trace

from _hypothesis_compat import given, settings, st  # soft optional dep

CLUSTER = paper_testbed()


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get("stablelm-3b").smoke()
    params = lm.init(jax.random.key(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# bucket arithmetic
# ---------------------------------------------------------------------------

def test_bucket_size():
    assert next_pow2(1) == 1 and next_pow2(129) == 256
    assert bucket_size(150, "pow2") == 256
    assert bucket_size(256, "pow2") == 256
    assert bucket_size(33, 32) == 64
    assert bucket_size(32, 32) == 32


# ---------------------------------------------------------------------------
# masked-tail invariance: padded trace ≡ unpadded, every policy kind
# ---------------------------------------------------------------------------

def _res_equal(a, b):
    assert np.allclose(a.q, b.q) and np.allclose(a.cost, b.cost)
    assert np.allclose(a.rt, b.rt) and np.allclose(a.ttft, b.ttft)
    assert np.allclose(float(a.violation), float(b.violation))
    assert (np.asarray(a.assign) == np.asarray(b.assign)).all()
    assert np.allclose(a.hit, b.hit)


@pytest.mark.parametrize("mode", ["eq5", "queued"])
def test_masked_tail_invariance_closed_loop(mode):
    tr = build_trace(75, seed=0)
    attach_slos(tr, seed=0)
    cfg = EvalConfig(mode=mode, concurrency=4)
    plain = TraceEvaluator(tr, CLUSTER, cfg)
    padded = TraceEvaluator(tr, CLUSTER, cfg, bucket="pow2")
    assert padded.n_padded == 128 and padded.n_valid == 75
    _res_equal(plain.run_thresholds(PAPER_DEFAULTS),
               padded.run_thresholds(PAPER_DEFAULTS))
    _res_equal(plain.run_slo_policy(SLO_DEFAULTS),
               padded.run_slo_policy(SLO_DEFAULTS))
    rng = np.random.default_rng(0)
    assign = rng.integers(0, CLUSTER.n_pairs, size=75)
    _res_equal(plain.run_assignment(assign), padded.run_assignment(assign))


def test_masked_tail_invariance_prefix_cache():
    """Open-loop session trace with the cache model on: padding must not
    leak into queue *or* cache-carry state."""
    tr = build_session_trace(SessionConfig(n_sessions=8, mean_turns=3.0),
                             seed=1, n_requests=50)
    attach_slos(tr, seed=1)
    cfg = EvalConfig(mode="open", prefix_cache=True)
    plain = TraceEvaluator(tr, CLUSTER, cfg)
    padded = TraceEvaluator(tr, CLUSTER, cfg, bucket="pow2")
    _res_equal(plain.run_affinity_policy(AFFINITY_DEFAULTS),
               padded.run_affinity_policy(AFFINITY_DEFAULTS))
    s1 = plain.summarize(plain.run_affinity_policy(AFFINITY_DEFAULTS))
    s2 = padded.summarize(padded.run_affinity_policy(AFFINITY_DEFAULTS))
    for k in s1:
        assert np.isclose(s1[k], s2[k]), k


def test_padded_fitness_matches_unpadded():
    tr = build_trace(60, seed=2)
    attach_slos(tr, seed=2)
    cfg = EvalConfig(concurrency=4)
    plain = TraceEvaluator(tr, CLUSTER, cfg)
    padded = TraceEvaluator(tr, CLUSTER, cfg, bucket="pow2")
    g = jnp.asarray(np.random.default_rng(0).uniform(
        size=(6, 2)).astype(np.float32)) * jnp.asarray([0.8, 20.0]) \
        + jnp.asarray([0.3, 0.0])
    F1, v1 = plain.make_fitness("slo", objectives="qoe")(g, jax.random.key(0))
    F2, v2 = padded.make_fitness("slo", objectives="qoe")(g, jax.random.key(0))
    assert np.allclose(F1, F2, rtol=1e-5, atol=1e-7)
    assert np.allclose(v1, v2)


# ---------------------------------------------------------------------------
# compile reuse: re-fits across window sizes / NSGA2 instances share traces
# ---------------------------------------------------------------------------

def test_refit_compile_reuse_across_windows_and_instances():
    cfg = NSGA2Config(pop_size=8, n_generations=2,
                      lo=jnp.asarray(SLO_BOUNDS_LO),
                      hi=jnp.asarray(SLO_BOUNDS_HI))

    def refit(n, seed):
        tr = build_trace(n, seed=seed)
        attach_slos(tr, seed=seed)
        ev = TraceEvaluator(tr, CLUSTER, EvalConfig(concurrency=4),
                            bucket="pow2")
        opt = NSGA2(ev.make_fitness("slo", objectives="qoe"), cfg)
        return jax.block_until_ready(
            opt.evolve_scan(jax.random.key(seed), 2).genomes)

    refit(70, 0)  # first re-fit compiles
    runs_before = nsga2_mod._nsga2_run._cache_size()
    traces_before = _run_trace._cache_size()
    # different window length (same pow2 bucket), fresh evaluator + NSGA2
    refit(90, 1)
    refit(100, 2)
    assert nsga2_mod._nsga2_run._cache_size() == runs_before, \
        "re-fit across windows retraced the NSGA-II run"
    assert _run_trace._cache_size() == traces_before, \
        "re-fit across windows retraced the trace evaluator"


def test_fitness_kernel_identity_is_stable():
    """make_fitness hands NSGA2 the same kernel object for equal statics."""
    tr1 = build_trace(40, seed=0)
    tr2 = build_trace(55, seed=1)
    for t in (tr1, tr2):
        attach_slos(t, seed=0)
    ev1 = TraceEvaluator(tr1, CLUSTER, EvalConfig(concurrency=4),
                         bucket="pow2")
    ev2 = TraceEvaluator(tr2, CLUSTER, EvalConfig(concurrency=4),
                         bucket="pow2")
    f1 = ev1.make_fitness("slo", objectives="qoe")
    f2 = ev2.make_fitness("slo", objectives="qoe")
    assert f1.kernel is f2.kernel
    # different static config -> different kernel
    f3 = ev1.make_fitness("slo", objectives="paper")
    assert f3.kernel is not f1.kernel


def test_warm_start_archive_dynamic():
    """evolve_scan(archive=...) warm-starts without a fresh trace."""
    tr = build_trace(50, seed=0)
    attach_slos(tr, seed=0)
    ev = TraceEvaluator(tr, CLUSTER, EvalConfig(concurrency=4),
                        bucket="pow2")
    cfg = NSGA2Config(pop_size=8, n_generations=2,
                      lo=jnp.asarray(SLO_BOUNDS_LO),
                      hi=jnp.asarray(SLO_BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("slo", objectives="qoe"), cfg)
    s0 = opt.evolve_scan(jax.random.key(0), 2)
    before = nsga2_mod._nsga2_run._cache_size()
    s1 = opt.evolve_scan(jax.random.key(1), 2, archive=s0.genomes)
    # warm-started run has its own trace (extra archive arg) but repeats
    # must reuse it
    s2 = opt.evolve_scan(jax.random.key(2), 2, archive=s1.genomes)
    assert nsga2_mod._nsga2_run._cache_size() <= before + 1
    assert s2.genomes.shape == s0.genomes.shape


# ---------------------------------------------------------------------------
# top-P early-exit non-dominated sort
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 24), st.integers(2, 4))
def test_top_p_sort_matches_full_sort_up_to_cutoff(seed, P, M):
    rng = np.random.default_rng(seed)
    F = jnp.asarray(rng.normal(size=(P, M)).astype(np.float32))
    full = np.asarray(non_dominated_sort(F))
    top = P // 2
    part = np.asarray(non_dominated_sort(F, top=top))
    # the fronts peeled before the quota filled are identical; everything
    # beyond carries the sentinel last rank
    order = np.argsort(full, kind="stable")
    n_ranked = 0
    cutoff_rank = 0
    for r in range(P):
        cnt = int((full == r).sum())
        if cnt == 0:
            break
        n_ranked += cnt
        cutoff_rank = r
        if n_ranked >= top:
            break
    done = full <= cutoff_rank
    assert (part[done] == full[done]).all()
    assert (part[~done] == P - 1).all()
    del order


def test_top_p_sort_dominance_matrix_arg():
    F = jnp.asarray(np.random.default_rng(0).normal(size=(12, 3)),
                    jnp.float32)
    dom = dominance_matrix(F)
    assert (np.asarray(non_dominated_sort(F, dom, top=6))
            == np.asarray(non_dominated_sort(F, top=6))).all()


# ---------------------------------------------------------------------------
# engine: step_n parity + prefill bucket jit-cache regression
# ---------------------------------------------------------------------------

def test_step_n_token_parity_and_sync_reduction(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    prompts = {i: rng.integers(0, cfg.vocab, size=5 + 2 * i)
               for i in range(3)}

    def run(chunk):
        eng = LLMEngine(cfg, params, EngineConfig(max_slots=3, max_seq=64,
                                                  max_new_tokens=10))
        for i, p in prompts.items():
            eng.submit(i, p, max_new_tokens=6 + i)
        res = eng.run_to_completion(chunk=chunk)
        return res, eng.host_syncs

    r1, syncs1 = run(1)
    rN, syncsN = run(8)
    for i in r1:
        assert r1[i]["tokens"] == rN[i]["tokens"], i
        assert r1[i]["ttft_steps"] == rN[i]["ttft_steps"], i
        assert r1[i]["finish_step"] == rN[i]["finish_step"], i
    assert syncsN < syncs1, (syncs1, syncsN)


def test_step_n_with_queued_work_falls_back(tiny_model):
    """step_n must stay exact when admissions are pending: 6 requests
    through 2 slots (continuous batching admits mid-run)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(4)
    prompts = {i: rng.integers(0, cfg.vocab, size=6) for i in range(6)}

    def run(chunk):
        eng = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                                  max_new_tokens=4))
        for i, p in prompts.items():
            eng.submit(i, p)
        return eng.run_to_completion(chunk=chunk)

    r1, rN = run(1), run(8)
    assert sorted(rN) == list(range(6))
    for i in r1:
        assert r1[i]["tokens"] == rN[i]["tokens"], i


def test_prefill_bucket_jit_cache_regression(tiny_model):
    """Admission pads prompts to the bucket: many distinct prompt lengths
    must share one compiled prefill executable per bucket."""
    cfg, params = tiny_model
    before = engine_mod._prefill_bucketed._cache_size()
    eng = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                              max_new_tokens=2,
                                              prefill_bucket=32))
    rng = np.random.default_rng(5)
    for i, n in enumerate(range(4, 18)):      # 14 distinct prompt lengths
        eng.submit(i, rng.integers(0, cfg.vocab, size=n))
        eng.run_to_completion()
    after = engine_mod._prefill_bucketed._cache_size()
    assert after - before <= 1, \
        f"bucketed prefill retraced per length: {after - before} new entries"


def test_prefill_bucket_matches_offline_greedy(tiny_model):
    """Padding + dynamic last-row logits must not perturb outputs."""
    cfg, params = tiny_model
    rng = np.random.default_rng(6)
    tokens = rng.integers(0, cfg.vocab, size=9)
    eng = LLMEngine(cfg, params, EngineConfig(max_slots=1, max_seq=64,
                                              max_new_tokens=5,
                                              prefill_bucket=32))
    eng.submit(0, tokens)
    got = eng.run_to_completion()[0]["tokens"]
    toks = list(tokens)
    want = []
    for _ in range(5):
        logits, _ = lm.train_logits(params, cfg,
                                    {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        toks.append(nxt)
    assert got == want


def test_prefix_cache_bucketed_extend_exact(tiny_model):
    """Bucketed prefix-extension admission (padded suffix + fixed-size
    prefix gather) stays byte-identical to the non-caching engine."""
    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab, size=16)
    ecfg = dict(max_slots=2, max_seq=64, max_new_tokens=4, block_size=8,
                cache_blocks=16)
    plain = LLMEngine(cfg, params, EngineConfig(**ecfg))
    cached = LLMEngine(cfg, params, EngineConfig(prefix_cache=True, **ecfg))
    for rid, ext in enumerate((0, 3, 7)):   # shared 16-token prefix
        toks = np.concatenate([base, rng.integers(0, cfg.vocab, size=ext)]) \
            if ext else base
        for eng in (plain, cached):
            eng.submit(100 + rid, toks)
            eng.run_to_completion()
    for rid in (100, 101, 102):
        assert plain.results[rid]["tokens"] == cached.results[rid]["tokens"]
    st_ = cached.cache_stats()
    assert st_["prefill_tokens_run"] < st_["prefill_tokens_total"]


# ---------------------------------------------------------------------------
# device-sharded population fitness
# ---------------------------------------------------------------------------

def test_sharded_fitness_single_device_mesh_equivalence():
    """In-process equivalence on whatever devices exist (>= 1)."""
    tr = build_trace(40, seed=0)
    attach_slos(tr, seed=0)
    ev = TraceEvaluator(tr, CLUSTER, EvalConfig(concurrency=4),
                        bucket="pow2")
    mesh = population_mesh()
    g = jnp.asarray([[0.9, 3.0], [0.5, 1.0], [1.0, 10.0]], jnp.float32)
    F0, v0 = ev.make_fitness("slo", objectives="qoe")(g, jax.random.key(0))
    F1, v1 = ev.make_fitness("slo", objectives="qoe", mesh=mesh)(
        g, jax.random.key(0))
    assert np.allclose(F0, F1, rtol=1e-5, atol=1e-7)
    assert np.allclose(v0, v1)


@pytest.mark.slow
def test_sharded_fitness_multi_device_subprocess():
    """True multi-device equivalence: XLA_FLAGS must precede the jax
    import, so this runs the hotpath benchmark's worker in a subprocess."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.hotpath", "--worker-ndev", "2",
         "--smoke"],
        env=env, cwd=root, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr
    import json
    out = json.loads([ln for ln in proc.stdout.splitlines()
                      if ln.startswith("{")][-1])
    assert out["ndev"] == 2 and out["allclose"], out
