"""Skip hygiene guard: skipping a test is a conscious, recorded choice.

Tier-1 historically carried 25 silent skips — every ``@given`` property
vanished in containers without hypothesis. The ``_hypothesis_compat`` shim
now runs those properties as deterministic fixed-sample sweeps instead, so
the suite's only remaining skip site is the shim's unsupported-strategy
escape hatch. This module fails the build if

* a skip/xfail site appears outside the recorded allowlist (new skips must
  be added here deliberately),
* a skip site omits an explicit ``reason`` string, or
* a ``@given`` declares a strategy the deterministic fallback cannot sample
  (which would silently re-introduce environment-dependent skips).
"""
import re
from pathlib import Path

TESTS = Path(__file__).resolve().parent

# file -> number of skip/xfail *sites* it is allowed to contain
SKIP_SITE_ALLOWLIST = {
    # the shim's escape hatch for strategies without a fallback sampler;
    # unreachable today (see test_given_strategies_* below) but kept so an
    # unsupported strategy degrades loudly instead of crashing collection
    "_hypothesis_compat.py": 1,
}

_SKIP_PAT = re.compile(
    r"pytest\s*\.\s*(?:mark\s*\.\s*)?(?:skip|skipif|importorskip|xfail)\b")
_FALLBACK_STRATEGIES = {"integers", "floats", "booleans"}


def _source_files():
    return [p for p in sorted(TESTS.glob("*.py"))
            if p.name != Path(__file__).name]


def test_skip_sites_are_allowlisted_with_reasons():
    for path in _source_files():
        lines = path.read_text().splitlines()
        hits = [(i + 1, ln) for i, ln in enumerate(lines)
                if _SKIP_PAT.search(ln)
                and not ln.lstrip().startswith(("#", "`"))]
        allowed = SKIP_SITE_ALLOWLIST.get(path.name, 0)
        assert len(hits) <= allowed, (
            f"{path.name} has {len(hits)} skip site(s), allowlist permits "
            f"{allowed}: {hits}\nadd it to SKIP_SITE_ALLOWLIST only as a "
            f"conscious choice")
        for lineno, _ in hits:
            stmt = " ".join(lines[lineno - 1:lineno + 2])
            assert "reason" in stmt, (
                f"{path.name}:{lineno} skips without an explicit reason")


def test_given_strategies_have_deterministic_fallback():
    """Every ``@given`` must stay runnable without hypothesis: its strategies
    must all be ones the shim can sample deterministically."""
    pat = re.compile(r"@given\(([^)]*)\)")
    for path in _source_files():
        for m in pat.finditer(path.read_text()):
            used = set(re.findall(r"st\.(\w+)", m.group(1)))
            unsupported = used - _FALLBACK_STRATEGIES
            assert not unsupported, (
                f"{path.name}: @given uses st.{unsupported} which the "
                f"deterministic fallback in _hypothesis_compat.py cannot "
                f"sample — extend the shim or the property will skip")
