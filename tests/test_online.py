"""Dynamic-workload / online re-optimization subsystem tests: open-loop
arrival generators, JAX-vs-DES equivalence on open-loop traces, the rolling-
horizon ``maybe_reoptimize`` loop (history re-fit, warm start, drift
trigger), and the ClusterMonitor clock fixes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_session_trace, shared_cluster

from repro.cluster.monitor import ClusterMonitor
from repro.cluster.simulator import ClusterSimulator
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config, archive_init
from repro.core.policies import get_policy, list_policies
from repro.core.policy import BOUNDS_HI, BOUNDS_LO, PAPER_DEFAULTS
from repro.core.router import RequestRouter
from repro.workload.arrivals import (PhaseSpec, build_open_loop_trace,
                                     mmpp_arrivals, onoff_arrivals,
                                     poisson_arrivals)

CLUSTER = shared_cluster()

CALM = (PhaseSpec(rate=0.4, duration=200.0, mix=(0.05, 0.05, 0.85, 0.05)),)
STORM = (PhaseSpec(rate=8.0, duration=200.0, mix=(0.05, 0.85, 0.05, 0.05),
                   length_scale=2.0),)
DIURNAL = (PhaseSpec(rate=1.0, duration=30.0, mix=(0.7, 0.1, 0.1, 0.1)),
           PhaseSpec(rate=6.0, duration=30.0, mix=(0.1, 0.7, 0.1, 0.1),
                     length_scale=1.5),
           PhaseSpec(rate=2.5, duration=30.0))


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------
def test_poisson_arrivals_rate_and_determinism():
    t1 = poisson_arrivals(4000, rate=5.0, seed=0)
    t2 = poisson_arrivals(4000, rate=5.0, seed=0)
    np.testing.assert_array_equal(t1, t2)
    assert (np.diff(t1) >= 0).all()
    # empirical rate within 10% of lambda
    rate = len(t1) / float(t1[-1])
    assert 0.9 * 5.0 <= rate <= 1.1 * 5.0


def test_mmpp_cycles_phases_and_modulates_rate():
    phases = (PhaseSpec(rate=10.0, duration=10.0),
              PhaseSpec(rate=1.0, duration=10.0))
    times, ids = mmpp_arrivals(600, phases, seed=1)
    assert times.shape == ids.shape == (600,)
    assert (np.diff(times) >= 0).all()
    assert set(np.unique(ids)) == {0, 1}
    # the high-rate phase must produce ~10x the arrivals of the low-rate one
    n_hi, n_lo = int((ids == 0).sum()), int((ids == 1).sum())
    assert n_hi > 4 * n_lo


def test_onoff_is_bursty():
    t = onoff_arrivals(400, rate_on=20.0, rate_off=0.5, on_s=5.0, off_s=5.0,
                       seed=2)
    gaps = np.diff(t)
    # burst gaps (~0.05 s) and idle gaps (~2 s) both present
    assert gaps.min() < 0.2 and gaps.max() > 1.0


def test_open_loop_trace_mix_drift():
    tr = build_open_loop_trace(300, DIURNAL, seed=3)
    assert tr.has_arrivals and (np.diff(tr.arrival_time) >= 0).all()
    assert tr.phase_id.shape == (300,)
    # phase 0 is code-heavy (mbpp = task 0), phase 1 math-heavy (gsm8k = 1)
    t0 = tr.task[tr.phase_id == 0]
    t1 = tr.task[tr.phase_id == 1]
    assert (t0 == 0).mean() > 0.5
    assert (t1 == 1).mean() > 0.5
    # phase 1 scales prompt lengths by 1.5x
    p0 = tr.prompt_tokens[tr.phase_id == 0].mean()
    p1 = tr.prompt_tokens[tr.phase_id == 1].mean()
    assert p1 > 1.15 * p0


def test_open_loop_trace_deterministic():
    a = build_open_loop_trace(120, DIURNAL, seed=5)
    b = build_open_loop_trace(120, DIURNAL, seed=5)
    np.testing.assert_array_equal(a.arrival_time, b.arrival_time)
    np.testing.assert_array_equal(a.task, b.task)
    np.testing.assert_array_equal(a.prompt_tokens, b.prompt_tokens)


# ---------------------------------------------------------------------------
# Open-loop equivalence: JAX evaluator == both DES oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("phases", [CALM, STORM, DIURNAL],
                         ids=["calm", "storm", "diurnal"])
def test_open_loop_jax_matches_des_oracles(phases):
    tr = build_open_loop_trace(120, phases, seed=7)
    rng = np.random.default_rng(0)
    assign = rng.integers(0, CLUSTER.n_pairs, tr.n_requests).astype(np.int32)
    ev = TraceEvaluator(tr, CLUSTER, EvalConfig(mode="open"))
    res = ev.run_assignment(jnp.asarray(assign))
    sim = ClusterSimulator(tr, CLUSTER)
    a = sim.run(assign)            # picks up trace.arrival_time
    b = sim.run_event_heap(assign)
    for got, want in ((np.asarray(res.rt), a.rt),
                      (np.asarray(res.q), a.q),
                      (np.asarray(res.cost), a.cost),
                      (np.asarray(res.ttft), a.ttft),
                      (np.asarray(res.tpot), a.tpot)):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # the two independent DES implementations agree bit-tight open-loop
    np.testing.assert_allclose(a.rt, b.rt, rtol=1e-9)
    np.testing.assert_allclose(a.ttft, b.ttft, rtol=1e-9)


@pytest.mark.parametrize("policy", list_policies())
def test_policy_decisions_jax_match_des_oracles(policy):
    """Registry-wide JAX/DES equivalence with the decisions made *in-loop*
    on both sides: the evaluator's in-scan ``decide_jnp`` and the DES
    oracles' ``decide_py`` (busy slots, cache hit fractions, deadline
    contract, per-policy state) must route every request identically and
    agree on all realized metrics — for every registered policy, with the
    prefix-cache model enabled. Route-valued policies (``decides ==
    "route"``) run all three implementations in disaggregated mode, and the
    per-request KV-transfer seconds must match too."""
    tr = make_session_trace(n_requests=70, seed=7)
    pol = get_policy(policy)
    if pol.genome_spec.per_request:
        genome = np.random.default_rng(0).integers(
            0, CLUSTER.n_pairs, tr.n_requests).astype(np.int32)
    else:
        genome = pol.genome_spec.defaults
    disagg = pol.decides == "route"
    ev = TraceEvaluator(tr, CLUSTER, EvalConfig(mode="open",
                                                prefix_cache=True,
                                                disaggregated=disagg))
    res = ev.run_policy(policy, genome)
    sim = ClusterSimulator(tr, CLUSTER, prefix_cache=True,
                           disaggregated=disagg)
    fields = ("q", "cost", "rt", "ttft", "tpot", "hit")
    if disagg:
        fields += ("transfer",)
    for sr in (sim.run(policy=policy, genome=genome),
               sim.run_event_heap(policy=policy, genome=genome)):
        np.testing.assert_array_equal(np.asarray(res.assign), sr.assign)
        for f in fields:
            np.testing.assert_allclose(np.asarray(getattr(res, f)),
                                       getattr(sr, f), rtol=1e-4, atol=1e-5,
                                       err_msg=f"{policy}:{f}")


@pytest.mark.parametrize("policy", list_policies())
def test_policy_decisions_match_with_learning_enabled(policy):
    """3-way equivalence with EvalConfig(learned=True): the learned-estimator
    carry (repro.learn) updates inside the JAX scan and inside both DES event
    loops must stay bit-compatible, so every registered policy still routes
    identically across all three implementations — under a straggler schedule
    that makes the latency observations non-trivially non-zero."""
    from repro.faults import FaultSchedule, Straggler
    from repro.learn import LearnConfig

    tr = make_session_trace(n_requests=60, seed=7)
    pol = get_policy(policy)
    if pol.genome_spec.per_request:
        genome = np.random.default_rng(0).integers(
            0, CLUSTER.n_pairs, tr.n_requests).astype(np.int32)
    else:
        genome = pol.genome_spec.defaults
    disagg = pol.decides == "route"
    sched = FaultSchedule(stragglers=(Straggler(1, 0.0, 1e9, 3.0),
                                      Straggler(2, 5.0, 60.0, 2.0)))
    # the BLR kind gets its registry-wide coverage from the bandit (its
    # primary consumer); everything else runs the EWMA kind to keep the
    # parametrized sweep cheap
    kind = "blr" if policy == "bandit" else "ewma"
    cfg = EvalConfig(mode="open", prefix_cache=True, disaggregated=disagg,
                     learned=True, learner=LearnConfig(kind=kind),
                     faulty=True)
    ev = TraceEvaluator(tr, CLUSTER, cfg, faults=sched)
    res = ev.run_policy(policy, genome)
    sim = ClusterSimulator(tr, CLUSTER, prefix_cache=True,
                           disaggregated=disagg, faults=sched, learned=True,
                           learner=LearnConfig(kind=kind))
    for sr in (sim.run(policy=policy, genome=genome),
               sim.run_event_heap(policy=policy, genome=genome)):
        np.testing.assert_array_equal(np.asarray(res.assign), sr.assign)
        for f in ("q", "cost", "rt", "ttft", "tpot"):
            np.testing.assert_allclose(np.asarray(getattr(res, f)),
                                       getattr(sr, f), rtol=1e-4, atol=1e-5,
                                       err_msg=f"{policy}:{f}")


def test_open_loop_sparse_arrivals_have_no_wait():
    """Arrivals far apart ⇒ every slot free on arrival ⇒ zero queue wait."""
    tr = build_open_loop_trace(40, (PhaseSpec(rate=0.01, duration=1e5),),
                               seed=9)
    assign = np.zeros(40, np.int64)  # everything on the cloud pair
    r = ClusterSimulator(tr, CLUSTER).run(assign)
    np.testing.assert_allclose(r.wait, 0.0, atol=1e-9)


def test_explicit_arrivals_override_trace_timestamps():
    """run(..., arrivals=) overrides the trace's own arrival_time: squeezing
    every arrival to t=0 can only increase queueing."""
    tr = build_open_loop_trace(60, CALM, seed=11)
    assign = np.zeros(60, np.int64)
    sim = ClusterSimulator(tr, CLUSTER)
    spread = sim.run(assign)
    squeezed = sim.run(assign, arrivals=np.zeros(60))
    assert squeezed.wait.sum() > spread.wait.sum()
    assert squeezed.rt.mean() > spread.rt.mean()


# ---------------------------------------------------------------------------
# Warm start
# ---------------------------------------------------------------------------
def _zdt1(genomes, key):
    f1 = genomes[:, 0]
    g = 1 + 9 * jnp.mean(genomes[:, 1:], axis=1)
    f2 = g * (1 - jnp.sqrt(f1 / g))
    return jnp.stack([f1, f2], axis=1), jnp.zeros(genomes.shape[0])


def test_archive_init_seeds_and_fills():
    D = 5
    cfg = NSGA2Config(pop_size=12, n_generations=1, lo=jnp.zeros(D),
                      hi=jnp.ones(D))
    arch = jnp.full((4, D), 0.25)
    pop = archive_init(arch, cfg)(jax.random.key(0))
    assert pop.shape == (12, D)
    np.testing.assert_allclose(np.asarray(pop[:4]), 0.25, rtol=1e-6)
    rest = np.asarray(pop[4:])
    assert (rest >= 0).all() and (rest <= 1).all()
    assert not np.allclose(rest, 0.25)  # random fill actually explores


def test_warm_start_front_no_worse_than_cold():
    """The rolling-horizon regime: a *small* re-opt budget (2 generations)
    warm-started from the previous window's survival-ordered population must
    (a) never lose the archived front's ground (elitism keeps the seeds) and
    (b) beat a cold start at the same equal-generation budget."""
    from repro.core.pareto import hypervolume_2d
    D = 16
    ref = jnp.array([1.5, 10.0])
    cfg_long = NSGA2Config(pop_size=24, n_generations=20, lo=jnp.zeros(D),
                           hi=jnp.ones(D))
    s_prev = NSGA2(_zdt1, cfg_long).evolve_scan(jax.random.key(0), 20)
    hv_arch = float(hypervolume_2d(s_prev.F_raw[s_prev.rank == 0], ref))

    cfg = NSGA2Config(pop_size=24, n_generations=2, lo=jnp.zeros(D),
                      hi=jnp.ones(D))
    warm = NSGA2(_zdt1, cfg, init_fn=archive_init(s_prev.genomes, cfg))
    s_warm = warm.evolve_scan(jax.random.key(1), 2)
    s_cold = NSGA2(_zdt1, cfg).evolve_scan(jax.random.key(1), 2)

    hv_warm = float(hypervolume_2d(s_warm.F_raw[s_warm.rank == 0], ref))
    hv_cold = float(hypervolume_2d(s_cold.F_raw[s_cold.rank == 0], ref))
    assert hv_warm >= hv_arch - 1e-3   # (a) no ground lost across windows
    assert hv_warm >= hv_cold          # (b) beats cold at equal budget


# ---------------------------------------------------------------------------
# Rolling-horizon maybe_reoptimize
# ---------------------------------------------------------------------------
def _feed(router, trace):
    for i, r in enumerate(trace.requests):
        d = router.route(r)
        router.record(r, d, quality=0.5, cost=0.01, rt=1.0,
                      now=float(trace.arrival_time[i]))


def test_maybe_reoptimize_uses_recorded_history():
    """Two routers with very different observed windows must re-fit to
    different policies (fails when maybe_reoptimize ignores its history),
    and the same window must re-fit deterministically."""
    calm_tr = build_open_loop_trace(64, CALM, seed=0)
    storm_tr = build_open_loop_trace(64, STORM, seed=0)

    ra = RequestRouter(CLUSTER, PAPER_DEFAULTS)
    _feed(ra, calm_tr)
    rb = RequestRouter(CLUSTER, PAPER_DEFAULTS)
    _feed(rb, storm_tr)
    rc = RequestRouter(CLUSTER, PAPER_DEFAULTS)
    _feed(rc, calm_tr)

    pa = ra.maybe_reoptimize(force=True, generations=12, pop_size=16, seed=0)
    pb = rb.maybe_reoptimize(force=True, generations=12, pop_size=16, seed=0)
    pc = rc.maybe_reoptimize(force=True, generations=12, pop_size=16, seed=0)
    assert pa is not None and pb is not None
    assert not np.allclose(pa, pb), \
        "re-optimization ignored the recorded history window"
    np.testing.assert_allclose(pa, pc)          # deterministic re-fit
    np.testing.assert_allclose(ra.thresholds, pa)  # policy installed


def test_maybe_reoptimize_respects_drift_trigger():
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS)
    _feed(router, build_open_loop_trace(64, CALM, seed=1))
    # stationary latencies -> no drift -> skip
    for _ in range(50):
        router.monitor.on_complete(0, 1.0)
    assert not router.should_reoptimize()
    assert router.maybe_reoptimize(generations=4, pop_size=8) is None
    # latency regime shift -> drift -> re-optimize
    for _ in range(12):
        router.monitor.on_complete(0, 5.0)
    assert router.monitor.drift_score() > 0.25
    assert router.should_reoptimize()
    out = router.maybe_reoptimize(generations=4, pop_size=8)
    assert out is not None
    # cooldown: the re-fit re-baselines the drift detector and requires new
    # observations, so the same shift does not re-fire on the next check
    assert not router.should_reoptimize()
    assert router.maybe_reoptimize(generations=4, pop_size=8) is None


def test_maybe_reoptimize_warm_starts_from_archive():
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS)
    _feed(router, build_open_loop_trace(64, STORM, seed=2))
    assert router._archive is None
    p1 = router.maybe_reoptimize(force=True, generations=6, pop_size=16)
    assert router._archive is not None and router._archive.shape == (16, 6)
    p2 = router.maybe_reoptimize(force=True, generations=6, pop_size=16,
                                 seed=1)
    assert p1 is not None and p2 is not None


def test_maybe_reoptimize_needs_history():
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS)
    assert router.maybe_reoptimize(force=True) is None


# ---------------------------------------------------------------------------
# ClusterMonitor clock fixes
# ---------------------------------------------------------------------------
def test_sweep_does_not_expire_fresh_nodes():
    """A node that has never heartbeated is healthy until a full timeout has
    elapsed since construction (the seed expired it at now > timeout)."""
    mon = ClusterMonitor(2, heartbeat_timeout=10.0)
    mon.sweep(now=9.0)
    assert all(mon.healthy_mask())
    mon.sweep(now=11.0)
    assert not any(mon.healthy_mask())


def test_monitor_construction_time_offsets_expiry():
    mon = ClusterMonitor(1, heartbeat_timeout=10.0, now=100.0)
    mon.sweep(now=105.0)
    assert mon.healthy_mask() == (True,)
    mon.sweep(now=111.0)
    assert mon.healthy_mask() == (False,)


def test_heartbeat_explicit_now_keeps_simulated_time():
    mon = ClusterMonitor(1, heartbeat_timeout=10.0)
    mon.heartbeat(0, now=42.0)
    assert mon.stats[0].last_heartbeat == 42.0
    mon.sweep(now=50.0)
    assert mon.healthy_mask() == (True,)


def test_drift_score_flat_then_shift():
    mon = ClusterMonitor(1)
    assert mon.drift_score() == 0.0
    for _ in range(60):
        mon.on_complete(0, 2.0)
    assert mon.drift_score() < 0.05
    for _ in range(10):
        mon.on_complete(0, 8.0)
    assert mon.drift_score() > 0.25
