"""MoE dispatch correctness: the sort-based ragged dispatch must agree with
a dense reference when nothing is dropped, drop deterministically when over
capacity, and balance its aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft optional dep

from repro.models import moe as M
from repro.models.config import ModelConfig, MoECfg


def _cfg(n_experts=4, top_k=2, cap=8.0, d=32, ff=48):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=2, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=ff, vocab=64, pattern=(("attn", "moe"),),
        moe=MoECfg(n_experts=n_experts, top_k=top_k, d_ff=ff,
                   capacity_factor=cap))


def _dense_reference(p, cfg, x):
    """All experts on all tokens, combined by renormalized top-k weights."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)
    h = jnp.einsum("td,edf->etf", xt, p["wi"]["w"])
    g = jnp.einsum("td,edf->etf", xt, p["wg"]["w"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    y_all = jnp.einsum("etf,efd->etd", h, p["wo"]["w"])       # (E, T, d)
    mask = jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.float32)  # (T,k,E)
    w_e = jnp.einsum("tke,tk->te", mask, top_w)               # (T, E)
    y = jnp.einsum("etd,te->td", y_all.astype(jnp.float32), w_e)
    return y.reshape(B, S, d).astype(x.dtype)


@pytest.mark.parametrize("n_experts,top_k", [(4, 1), (4, 2), (8, 4)])
def test_moe_matches_dense_reference_without_drops(n_experts, top_k):
    cfg = _cfg(n_experts=n_experts, top_k=top_k, cap=float(n_experts))
    p = M.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    got, _ = M.moe_apply(p, cfg, x)
    want = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_rounds_to_sublane():
    cfg = _cfg()
    assert M.capacity(cfg, 100) % 8 == 0
    assert M.capacity(cfg, 1) >= 8


def test_moe_drops_when_capacity_tiny():
    """capacity_factor ~ 0 forces drops; outputs must stay finite and the
    dropped tokens contribute (weighted) zeros, not garbage."""
    cfg = _cfg(cap=0.01)
    p = M.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, aux = M.moe_apply(p, cfg, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    dense = _dense_reference(p, cfg, x)
    # with C=8 slots per expert most tokens drop: output norm must be lower
    assert (np.linalg.norm(np.asarray(y, np.float32))
            < np.linalg.norm(np.asarray(dense, np.float32)))


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_moe_slot_accounting(seed):
    """No slot is assigned twice and every kept token's slot is < C."""
    cfg = _cfg()
    m = cfg.moe
    rng = np.random.default_rng(seed)
    T, E, k = 64, m.n_experts, m.top_k
    C = M.capacity(cfg, T)
    flat_e = rng.integers(0, E, T * k)
    order = np.argsort(flat_e, kind="stable")
    sorted_e = flat_e[order]
    counts = np.bincount(sorted_e, minlength=E)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(T * k) - starts[sorted_e]
    keep = pos < C
    taken = set()
    for e, s, kp in zip(sorted_e, pos, keep):
        if kp:
            assert (e, s) not in taken
            assert s < C
            taken.add((e, s))


def test_moe_aux_loss_uniform_router_is_one():
    """With a uniform router, E * sum(f_e * p_e) -> 1 (balanced)."""
    cfg = _cfg(n_experts=4, top_k=1)
    p = M.moe_init(jax.random.key(0), cfg)
    p = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])})
    x = jax.random.normal(jax.random.key(2), (4, 64, cfg.d_model))
    _, aux = M.moe_apply(p, cfg, x)
    assert 0.9 < float(aux) < 1.1


def test_moe_gradients_flow_to_experts_and_router():
    cfg = _cfg()
    p = M.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = M.moe_apply(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "wi", "wg", "wo"):
        gn = float(jnp.sum(jnp.abs(g[name]["w"].astype(jnp.float32))))
        assert gn > 0, name
