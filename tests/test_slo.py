"""QoE/SLO subsystem tests: phase-split (TTFT/TPOT) accounting equivalence
between the JAX evaluator and the discrete-event oracle, the SLO decision
rule against its numpy oracle, SLO-aware routing improving attainment over
quality-weighted routing, and the engine's step-level QoE accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import (EvalConfig, TraceEvaluator,
                                request_pair_estimates)
from repro.core.policy import (PAPER_DEFAULTS, SLO_BOUNDS_HI, SLO_BOUNDS_LO,
                               SLO_DEFAULTS, decide_pair_slo_jnp,
                               decide_pair_slo_py)
from repro.core.router import RequestRouter
from repro.workload.slo import (BATCH_SCALE, INTERACTIVE_SCALE, attach_slos,
                                slo_arrays)
from repro.workload.trace import build_trace

CLUSTER = paper_testbed()
TRACE = attach_slos(build_trace(120, seed=3), tightness=1.0, seed=1)


# ---------------------------------------------------------------------------
# SLO attachment
# ---------------------------------------------------------------------------
def test_attach_slos_shapes_and_determinism():
    t1 = attach_slos(build_trace(60, seed=7), seed=9)
    t2 = attach_slos(build_trace(60, seed=7), seed=9)
    assert t1.has_slos
    assert t1.ttft_deadline.shape == (60,)
    np.testing.assert_array_equal(t1.ttft_deadline, t2.ttft_deadline)
    np.testing.assert_array_equal(t1.tpot_deadline, t2.tpot_deadline)
    assert (t1.ttft_deadline > 0).all() and (t1.tpot_deadline > 0).all()
    # deadline classes actually separate: batch budgets are larger
    base_ttft, _ = slo_arrays()
    inter = t1.slo_interactive
    assert inter.any() and (~inter).any()
    ratio = BATCH_SCALE / INTERACTIVE_SCALE
    np.testing.assert_allclose(
        t1.ttft_deadline[~inter].mean()
        / np.mean(base_ttft[t1.pred_category[~inter]]), BATCH_SCALE,
        rtol=1e-5)
    assert ratio > 1


def test_trace_without_slos_has_inf_deadlines():
    ev = TraceEvaluator(build_trace(20, seed=0), CLUSTER)
    assert np.isinf(np.asarray(ev.tables.ttft_deadline)).all()
    assert "slo_attainment" not in ev.summarize(
        ev.run_assignment(jnp.zeros(20, jnp.int32)))


# ---------------------------------------------------------------------------
# Phase-split accounting: JAX scan == discrete-event oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("concurrency", [1, 4, 10])
def test_ttft_tpot_match_des_oracle(concurrency):
    rng = np.random.default_rng(0)
    assign = rng.integers(0, CLUSTER.n_pairs, TRACE.n_requests).astype(np.int32)
    ev = TraceEvaluator(TRACE, CLUSTER, EvalConfig(concurrency=concurrency))
    res = ev.run_assignment(jnp.asarray(assign))
    sim = ClusterSimulator(TRACE, CLUSTER).run(assign, concurrency=concurrency)
    np.testing.assert_allclose(np.asarray(res.ttft), sim.ttft,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.tpot), sim.tpot, rtol=1e-5)


def test_event_heap_ttft_agrees():
    # conc=1 only: at conc>1 the two oracles issue requests to clients in a
    # different order (completion- vs index-order), as in the seed's rt test
    rng = np.random.default_rng(1)
    assign = rng.integers(0, CLUSTER.n_pairs, TRACE.n_requests)
    sim = ClusterSimulator(TRACE, CLUSTER)
    a = sim.run(assign, concurrency=1)
    b = sim.run_event_heap(assign, concurrency=1)
    np.testing.assert_allclose(a.ttft, b.ttft, rtol=1e-9)
    np.testing.assert_allclose(a.tpot, b.tpot, rtol=1e-9)


def test_eq5_ttft_is_up_plus_prefill():
    """Without queueing, TTFT must reduce to upload + prefill exactly."""
    rng = np.random.default_rng(2)
    assign = rng.integers(0, CLUSTER.n_pairs, TRACE.n_requests)
    ev = TraceEvaluator(TRACE, CLUSTER, EvalConfig(mode="eq5"))
    res = ev.run_assignment(jnp.asarray(assign))
    idx = np.arange(TRACE.n_requests)
    want = (np.asarray(ev.tables.up_time)[idx, assign]
            + np.asarray(ev.tables.prefill_time)[idx, assign])
    np.testing.assert_allclose(np.asarray(res.ttft), want, rtol=1e-6)
    # TTFT is always a lower bound on RT
    assert (np.asarray(res.ttft) <= np.asarray(res.rt) + 1e-6).all()


def test_sim_slo_attainment_method():
    assign = baselines.cloud_only(TRACE, CLUSTER)
    sim = ClusterSimulator(TRACE, CLUSTER).run(assign, concurrency=1)
    att = sim.slo_attainment(TRACE.ttft_deadline, TRACE.tpot_deadline)
    assert 0.0 <= att <= 1.0
    # infinite deadlines -> everything attains
    inf = np.full(TRACE.n_requests, np.inf, np.float32)
    assert sim.slo_attainment(inf, inf) == 1.0


# ---------------------------------------------------------------------------
# SLO decision rule: jnp == numpy oracle
# ---------------------------------------------------------------------------
def test_decide_pair_slo_jnp_matches_py_oracle():
    arrays = CLUSTER.to_arrays()
    for seed in range(60):
        rng = np.random.default_rng(seed)
        g = SLO_BOUNDS_LO + rng.random(2).astype(np.float32) * \
            (SLO_BOUNDS_HI - SLO_BOUNDS_LO)
        est = request_pair_estimates(float(rng.integers(20, 400)),
                                     float(rng.integers(10, 300)),
                                     float(rng.integers(100, 4000)), arrays)
        ttft_dl = float(rng.uniform(0.05, 6.0))
        tpot_dl = float(rng.uniform(0.03, 0.8))
        queue = rng.integers(0, 12, size=arrays.n_nodes)
        got = int(decide_pair_slo_jnp(
            jnp.asarray(g), ttft_deadline=jnp.float32(ttft_dl),
            tpot_deadline=jnp.float32(tpot_dl), up=jnp.asarray(est["up"]),
            prefill=jnp.asarray(est["prefill"]), tpot=jnp.asarray(est["tpot"]),
            cost=jnp.asarray(est["cost"]), queue_len=jnp.asarray(queue),
            arrays=arrays))
        want = decide_pair_slo_py(
            g, ttft_deadline=ttft_dl, tpot_deadline=tpot_dl, up=est["up"],
            prefill=est["prefill"], tpot=est["tpot"], cost=est["cost"],
            queue_len=queue, arrays=arrays)
        assert got == want, seed


def test_slo_rule_prefers_cheap_edge_when_relaxed_cloud_when_tight():
    arrays = CLUSTER.to_arrays()
    est = request_pair_estimates(100.0, 80.0, 800.0, arrays)
    kw = dict(up=est["up"], prefill=est["prefill"], tpot=est["tpot"],
              cost=est["cost"], queue_len=np.zeros(arrays.n_nodes, int),
              arrays=arrays)
    is_edge = np.asarray(arrays.pair_is_edge)
    # relaxed deadlines: cheapest edge pair qualifies
    p = decide_pair_slo_py(SLO_DEFAULTS, ttft_deadline=5.0, tpot_deadline=0.8,
                           **kw)
    assert is_edge[p]
    # tight TPOT: only the cloud pair (19 tok/s) can stream fast enough
    p = decide_pair_slo_py(SLO_DEFAULTS, ttft_deadline=1.0, tpot_deadline=0.08,
                           **kw)
    assert not is_edge[p]
    # infeasible everywhere: degrade to the least-overshooting (fast) pair
    p = decide_pair_slo_py(SLO_DEFAULTS, ttft_deadline=1e-4,
                           tpot_deadline=1e-4, **kw)
    assert not is_edge[p]


# ---------------------------------------------------------------------------
# SLO-aware routing beats quality-weighted routing on attainment
# ---------------------------------------------------------------------------
def test_slo_routing_improves_attainment_over_quality_weighted():
    """On a deadline-heavy contended trace, the SLO policy must strictly
    improve attainment over Algorithm 2 with the paper's quality-oriented
    defaults, at no higher cost than Cloud-Only."""
    ev = TraceEvaluator(TRACE, CLUSTER, EvalConfig(concurrency=8))
    slo = ev.summarize(ev.run_slo_policy(jnp.asarray(SLO_DEFAULTS)))
    alg2 = ev.summarize(ev.run_thresholds(jnp.asarray(PAPER_DEFAULTS)))
    cloud = ev.summarize(ev.run_assignment(
        jnp.asarray(baselines.cloud_only(TRACE, CLUSTER))))
    assert slo["slo_attainment"] > alg2["slo_attainment"]
    assert slo["slo_attainment"] >= cloud["slo_attainment"]
    assert slo["avg_cost"] < cloud["avg_cost"]


def test_qoe_fitness_returns_four_objectives():
    ev = TraceEvaluator(TRACE, CLUSTER, EvalConfig(concurrency=4))
    fit = ev.make_fitness("slo", objectives="qoe")
    pop = jnp.asarray(np.stack([SLO_DEFAULTS,
                                SLO_BOUNDS_LO, SLO_BOUNDS_HI]))
    F, viol = fit(pop, jax.random.key(0))
    assert F.shape == (3, 4) and viol.shape == (3,)
    assert (np.asarray(F[:, 3]) >= 0).all() and (np.asarray(F[:, 3]) <= 1).all()


# ---------------------------------------------------------------------------
# Runtime router SLO mode
# ---------------------------------------------------------------------------
def test_router_slo_mode_splits_by_deadline_class():
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS, mode="slo")
    req = TRACE.requests[0]
    tight = router.route(req, ttft_deadline=0.6, tpot_deadline=0.08)
    relaxed = router.route(req, ttft_deadline=5.0, tpot_deadline=0.8)
    assert not tight.go_edge          # only cloud decodes fast enough
    assert relaxed.go_edge            # cheap edge pair qualifies
    assert relaxed.pair != tight.pair


def test_router_slo_mode_failover_to_healthy_node():
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS, mode="slo")
    router.monitor.mark_down(0)  # kill the cloud
    d = router.route(TRACE.requests[0], ttft_deadline=0.6, tpot_deadline=0.08)
    assert d.node != 0


# ---------------------------------------------------------------------------
# Engine step-level QoE accounting
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get
    from repro.models import lm
    cfg = get("stablelm-3b").smoke()
    params = lm.init(jax.random.key(0), cfg)
    return cfg, params


def test_engine_reports_phase_accounting(tiny_model):
    from repro.serving import EngineConfig, LLMEngine
    cfg, params = tiny_model
    eng = LLMEngine(cfg, params, EngineConfig(max_slots=1, max_seq=64,
                                              max_new_tokens=4))
    rng = np.random.default_rng(0)
    eng.submit(0, rng.integers(0, cfg.vocab, size=6))
    eng.submit(1, rng.integers(0, cfg.vocab, size=6))
    results = eng.run_to_completion()
    r0, r1 = results[0], results[1]
    # first request admitted instantly; second waited for the single slot
    assert r0["ttft_steps"] == 0
    assert r1["ttft_steps"] > 0
    # iteration-level batching: exactly one decode step per token after the
    # first, so TPOT is 1 step/token for both
    for r in (r0, r1):
        assert r["tpot_steps"] == pytest.approx(1.0)
        assert r["finish_step"] >= r["first_token_step"] >= r["submit_step"]
    qoe = eng.qoe_summary()
    assert qoe["avg_ttft_steps"] == pytest.approx((r0["ttft_steps"]
                                                   + r1["ttft_steps"]) / 2)
