"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft optional dep

import repro.kernels.decode_attention as dec
import repro.kernels.dominance as dom
import repro.kernels.flash_attention as fa
import repro.kernels.paged_attention as paged
from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# dominance
# ---------------------------------------------------------------------------
DOM_SHAPES = [(8, 2), (100, 3), (128, 3), (130, 4), (256, 1), (300, 8)]


@pytest.mark.parametrize("P,M", DOM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dominance_matrix_matches_ref(P, M, dtype):
    rng = np.random.default_rng(P * 31 + M)
    F = jnp.asarray(rng.random((P, M)), dtype)
    got = dom.dominance_matrix_pallas(F, block=64, interpret=True)
    want = ref.dominance_matrix(F.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(got, bool), np.asarray(want))


@pytest.mark.parametrize("P,M", [(64, 3), (129, 3), (257, 5)])
def test_dominance_counts_matches_ref(P, M):
    rng = np.random.default_rng(P)
    # ties included: quantized objectives
    F = jnp.asarray(np.round(rng.random((P, M)), 1), jnp.float32)
    got = dom.dominance_counts_pallas(F, block=64, interpret=True)
    want = ref.dominance_counts(F)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_dominance_kernel_property_duplicates(seed):
    """Duplicated rows never dominate each other; padding never leaks."""
    rng = np.random.default_rng(seed)
    P = int(rng.integers(3, 70))
    F = rng.random((P, 3)).astype(np.float32)
    F[P // 2] = F[0]
    D = np.asarray(dom.dominance_matrix_pallas(jnp.asarray(F), block=32,
                                               interpret=True), bool)
    assert not D[0, P // 2] and not D[P // 2, 0]
    assert not D.diagonal().any()
    np.testing.assert_array_equal(
        D, np.asarray(ref.dominance_matrix(jnp.asarray(F))))


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------
FA_CASES = [
    # (B, Hq, Hkv, S, D, block_q, block_k)
    (1, 4, 4, 128, 64, 64, 64),      # MHA
    (2, 8, 2, 256, 64, 128, 128),    # GQA 4:1
    (1, 8, 1, 128, 128, 64, 32),     # MQA, uneven blocks
    (1, 2, 2, 64, 32, 64, 64),       # single q block
    (2, 4, 2, 512, 64, 128, 256),    # bk > bq
]


@pytest.mark.parametrize("B,Hq,Hkv,S,D,bq,bk", FA_CASES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, bq, bk, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(S + Hq), 3)
    q = jax.random.normal(k1, (B, Hq, S, D), dtype)
    k = jax.random.normal(k2, (B, Hkv, S, D), dtype)
    v = jax.random.normal(k3, (B, Hkv, S, D), dtype)
    got = fa.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                             interpret=True)
    want = ref.mha_prefill(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_extreme_logits_stable():
    """Online softmax must survive large score magnitudes (no inf/nan)."""
    q = jnp.full((1, 1, 128, 64), 12.0, jnp.float32)
    k = jnp.full((1, 1, 128, 64), 12.0, jnp.float32)
    v = jnp.ones((1, 1, 128, 64), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)


def test_flash_attention_first_row_attends_self_only():
    """Causal row 0 output == v[0] regardless of other positions."""
    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 2, 128, 64))
    k = jax.random.normal(jax.random.key(1), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.key(2), (1, 2, 128, 64))
    out = fa.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0, :, 0]),
                               np.asarray(v[0, :, 0]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
DEC_CASES = [
    # (B, Hq, Hkv, Smax, D, bk)
    (1, 8, 8, 256, 64, 128),     # MHA
    (2, 8, 2, 512, 64, 128),     # GQA 4:1
    (1, 32, 8, 1024, 128, 256),  # assigned-arch shape (GQA 4:1, D=128)
    (3, 4, 1, 128, 32, 64),      # MQA
]


@pytest.mark.parametrize("B,Hq,Hkv,Smax,D,bk", DEC_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, Hq, Hkv, Smax, D, bk, dtype):
    keys = jax.random.split(jax.random.key(Smax + Hq), 4)
    q = jax.random.normal(keys[0], (B, Hq, D), dtype)
    kc = jax.random.normal(keys[1], (B, Hkv, Smax, D), dtype)
    vc = jax.random.normal(keys[2], (B, Hkv, Smax, D), dtype)
    kv_len = jax.random.randint(keys[3], (B,), 1, Smax + 1)
    got = dec.gqa_decode_attention(q, kc, vc, kv_len, block_k=bk,
                                   interpret=True)
    want = ref.gqa_decode(q, kc, vc, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_decode_attention_kv_len_property(seed):
    """Tokens beyond kv_len must not affect the output: growing the cache
    with garbage while holding kv_len fixed leaves results unchanged."""
    rng = np.random.default_rng(seed)
    B, Hq, Hkv, D = 2, 4, 2, 32
    Smax = 256
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, Hkv, Smax, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, Hkv, Smax, D)), jnp.float32)
    kv_len = jnp.asarray(rng.integers(1, 128, B), jnp.int32)
    a = dec.gqa_decode_attention(q, kc, vc, kv_len, block_k=64,
                                 interpret=True)
    kc2 = kc.at[:, :, 128:].set(999.0)
    vc2 = vc.at[:, :, 128:].set(-999.0)
    b = dec.gqa_decode_attention(q, kc2, vc2, kv_len, block_k=64,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# paged decode attention (block-table gather, see serving.kvcache)
# ---------------------------------------------------------------------------
PAGED_CASES = [
    # (B, Hq, Hkv, n_blocks, block_size, max_blocks, D)
    (1, 8, 8, 16, 16, 4, 64),     # MHA
    (2, 8, 2, 24, 16, 4, 64),     # GQA 4:1
    (3, 4, 1, 12, 8, 6, 32),      # MQA, small blocks
]


@pytest.mark.parametrize("B,Hq,Hkv,nb,bs,mb,D", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_matches_ref(B, Hq, Hkv, nb, bs, mb, D, dtype):
    rng = np.random.default_rng(B * 100 + Hq)
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), dtype)
    kp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), dtype)
    vp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), dtype)
    # distinct physical blocks per row (blocks are shared across rows in
    # serving, but distinctness makes aliasing bugs visible)
    bt = jnp.asarray(np.stack([rng.choice(nb, mb, replace=False)
                               for _ in range(B)]), jnp.int32)
    kv_len = jnp.asarray(rng.integers(1, mb * bs + 1, B), jnp.int32)
    got = paged.paged_gqa_decode_attention(q, kp, vp, bt, kv_len,
                                           interpret=True)
    want = ref.paged_gqa_decode(q, kp, vp, bt, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_paged_decode_matches_contiguous_kernel_on_gathered_cache():
    """The paged kernel gathering through the block table must agree with
    the contiguous kernel on the explicitly gathered cache — same online-
    softmax math, different addressing."""
    rng = np.random.default_rng(7)
    B, Hq, Hkv, D, bs, nb, mb = 2, 8, 2, 64, 16, 24, 4
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), jnp.float32)
    bt = jnp.asarray(np.stack([rng.choice(nb, mb, replace=False)
                               for _ in range(B)]), jnp.int32)
    kv_len = jnp.asarray([9, mb * bs], jnp.int32)
    got = paged.paged_gqa_decode_attention(q, kp, vp, bt, kv_len,
                                           interpret=True)

    def gather(pool):
        g = jnp.take(pool, bt, axis=0)
        return jnp.transpose(g, (0, 2, 1, 3, 4)).reshape(B, Hkv, mb * bs, D)

    cont = dec.gqa_decode_attention(q, gather(kp), gather(vp), kv_len,
                                    block_k=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(cont),
                               atol=1e-6, rtol=1e-6)


def test_paged_decode_pad_entries_are_masked():
    """Block-table entries beyond kv_len (and negative pads) must not
    affect the output."""
    rng = np.random.default_rng(11)
    B, Hq, Hkv, D, bs, nb, mb = 1, 4, 2, 32, 8, 8, 4
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), jnp.float32)
    kv_len = jnp.asarray([10], jnp.int32)      # 2 live blocks of 4
    bt_a = jnp.asarray([[3, 5, 6, 7]], jnp.int32)
    bt_b = jnp.asarray([[3, 5, -1, 1]], jnp.int32)   # different dead tail
    a = paged.paged_gqa_decode_attention(q, kp, vp, bt_a, kv_len,
                                         interpret=True)
    b = paged.paged_gqa_decode_attention(q, kp, vp, bt_b, kv_len,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_ops_paged_dispatch_ref_matches_interpret():
    rng = np.random.default_rng(3)
    B, Hq, Hkv, D, bs, nb, mb = 2, 4, 2, 32, 8, 10, 3
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, Hkv, bs, D)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, nb, (B, mb)), jnp.int32)
    kv_len = jnp.asarray(rng.integers(1, mb * bs + 1, B), jnp.int32)
    a = ops.paged_gqa_decode_attention(q, kp, vp, bt, kv_len, mode="ref")
    b = ops.paged_gqa_decode_attention(q, kp, vp, bt, kv_len,
                                       mode="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------
def test_ops_auto_mode_on_cpu_uses_ref():
    F = jnp.asarray(np.random.default_rng(0).random((16, 3)), jnp.float32)
    out = ops.dominance_matrix(F, mode="auto")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.dominance_matrix(F)))


def test_ops_interpret_equals_ref_for_attention():
    q = jax.random.normal(jax.random.key(0), (1, 4, 128, 64))
    k = jax.random.normal(jax.random.key(1), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.key(2), (1, 2, 128, 64))
    a = ops.flash_attention(q, k, v, mode="interpret")
    b = ops.flash_attention(q, k, v, mode="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
