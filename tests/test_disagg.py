"""Disaggregated prefill/decode serving: KV handoff byte-identity against a
colocated engine, end-to-end disagg routing through the cluster server, and
fault injection on both handoff endpoints (prefill node dies after prefill
but before delivery; decode node dies mid-transfer) — each must re-dispatch
to completion, leak no KV blocks, and keep the per-node dispatch ledger
conserved."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster.spec import disagg_testbed
from repro.configs import get
from repro.core.policy import PAPER_DEFAULTS
from repro.models import lm
from repro.serving import ClusterServer, EngineConfig, LLMEngine, ServeRequest
from repro.workload.trace import build_trace

BLOCK = 8
CACHE_BLOCKS = 32


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get("stablelm-3b").smoke()
    return cfg, lm.init(jax.random.key(0), cfg)


def _ecfg(**over):
    kw = dict(max_slots=2, max_seq=48, max_new_tokens=3, prefix_cache=True,
              block_size=BLOCK, cache_blocks=CACHE_BLOCKS)
    kw.update(over)
    return EngineConfig(**kw)


@pytest.fixture(scope="module")
def disagg_parts(tiny_model):
    """disagg testbed + single-model builders + long-prompt requests (every
    prompt spans >= 2 whole KV blocks so a handoff always has payload)."""
    cfg, params = tiny_model
    cluster = disagg_testbed()
    builders = {"gemma3:27b": (cfg, params)}
    reqs = [dataclasses.replace(r, text=" ".join(f"w{i}_{j}"
                                                 for j in range(20)),
                                prompt_tokens=20)
            for i, r in enumerate(build_trace(24, seed=5).requests[:10])]
    return cluster, builders, reqs


def _server(cluster, builders, faults=None):
    return ClusterServer(cluster, builders, PAPER_DEFAULTS, _ecfg(),
                         router_kwargs={"mode": "disagg"}, faults=faults)


def _split_route(srv):
    """First route whose prefill and decode legs live on different nodes."""
    arr = srv.router._np_arrays
    rp, rq = arr.route_prefill, arr.route_decode
    r = next(i for i in range(len(rp))
             if arr.pair_node[rp[i]] != arr.pair_node[rq[i]])
    return int(rp[r]), int(rq[r])


def _assert_conserved(srv):
    for node, s in srv.monitor.stats.items():
        assert s.total_dispatched == (s.total_completed + s.total_failed
                                      + s.total_cancelled), (node, s)
        assert s.outstanding == 0, (node, s)


def _active_blocks(eng):
    return int(np.sum(eng.kv.cache.pool.ref > 0))


# ---------------------------------------------------------------------------
# byte-identity: decode after KV import == colocated prefill+decode
# ---------------------------------------------------------------------------
def test_kv_handoff_decode_is_byte_identical(tiny_model):
    """Export whole-block KV on a prefill engine, import it on a separate
    decode engine, decode there: tokens must equal a colocated run, and the
    decode engine must have *reused* the imported blocks, not re-prefilled
    them."""
    cfg, params = tiny_model
    ecfg = _ecfg(max_seq=64, max_new_tokens=5)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, size=19).astype(np.int32)

    colo = LLMEngine(cfg, params, ecfg)
    colo.submit(0, prompt, max_new_tokens=5)
    want = colo.run_to_completion()[0]["tokens"]

    eng_p = LLMEngine(cfg, params, ecfg)
    eng_q = LLMEngine(cfg, params, ecfg)
    blocks = eng_p.prefill_only(7, prompt)
    assert len(blocks) == len(prompt) // BLOCK   # whole blocks only
    payload = eng_p.export_kv(blocks)
    n_cov = len(blocks) * BLOCK
    assert eng_q.import_kv(prompt[:n_cov], payload)
    eng_p.release_export(blocks)
    # source pins released: blocks survive as evictable cache, none active
    assert _active_blocks(eng_p) == 0
    eng_p.kv.cache.check_invariants()
    eng_q.kv.cache.check_invariants()

    eng_q.submit(0, prompt, max_new_tokens=5)
    got = eng_q.run_to_completion()[0]["tokens"]
    assert got == want
    st = eng_q.cache_stats()
    assert st["hits"] >= 1 and st["hit_tokens"] >= n_cov - BLOCK, st


# ---------------------------------------------------------------------------
# end-to-end: disagg router drives real handoffs through the server
# ---------------------------------------------------------------------------
def test_disagg_server_serves_all_with_handoffs(disagg_parts):
    cluster, builders, reqs = disagg_parts
    srv = _server(cluster, builders)
    for i, r in enumerate(reqs):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    done = srv.run()
    assert sorted(done) == list(range(len(reqs)))
    stats = srv.stats()
    assert stats["handoffs"] >= 1, stats       # split routes actually taken
    assert stats["transfers_inflight"] == 0
    _assert_conserved(srv)
    for eng in srv.engines.values():
        eng.kv.cache.check_invariants()
        assert _active_blocks(eng) == 0


# ---------------------------------------------------------------------------
# fault injection on both handoff endpoints
# ---------------------------------------------------------------------------
def test_prefill_node_death_before_delivery(disagg_parts):
    """Kill the prefill node after prefill-complete but pre-delivery: the
    transfer aborts, the request re-dispatches elsewhere to completion, and
    the dead node's pool drains to empty (its export pins died with it).
    The crash arrives via a deterministic ``FaultSchedule`` window replayed
    by the server's per-tick fault hook (tick 1 — before the delivery loop
    can run), not a manual ``fail_node`` call."""
    from conftest import targeted_crash_schedule

    cluster, builders, reqs = disagg_parts
    probe = _server(cluster, builders)
    p, q = _split_route(probe)
    arr = probe.router._np_arrays
    node_p = int(arr.pair_node[p])
    srv = _server(cluster, builders,
                  faults=targeted_crash_schedule(node_p))
    assert srv._start_handoff(
        ServeRequest(request_id=0, req=reqs[0], max_new_tokens=3), p, q)
    assert srv.stats()["transfers_inflight"] == 1

    done = srv.run()
    assert 0 in done and len(done[0]["tokens"]) == 3
    assert srv.stats()["reroutes"] >= 1
    _assert_conserved(srv)
    pair_node = arr.pair_node
    for pr, eng in srv.engines.items():
        eng.kv.cache.check_invariants()
        if int(pair_node[pr]) == node_p:       # restarted empty, no orphans
            assert eng.kv.cache.pool.n_free == CACHE_BLOCKS


def test_decode_node_death_mid_transfer(disagg_parts):
    """Kill the decode node while the KV payload is in flight: the live
    source must drop its export pins (refcounts back to baseline), and the
    request re-dispatches to completion with nothing leaked. The crash is
    schedule-driven (``FaultSchedule`` crash window at tick 1, mid-flight)
    rather than a manual ``fail_node`` call."""
    from conftest import targeted_crash_schedule

    cluster, builders, reqs = disagg_parts
    probe = _server(cluster, builders)
    p, q = _split_route(probe)
    arr = probe.router._np_arrays
    node_q = int(arr.pair_node[q])
    srv = _server(cluster, builders,
                  faults=targeted_crash_schedule(node_q))
    assert srv._start_handoff(
        ServeRequest(request_id=0, req=reqs[0], max_new_tokens=3), p, q)
    assert _active_blocks(srv.engines[p]) > 0  # export pins held

    done = srv.run()
    assert not srv.transfers
    assert 0 in done and len(done[0]["tokens"]) == 3
    # all pins released — aborted transfer's and the re-route's alike
    assert _active_blocks(srv.engines[p]) == 0
    _assert_conserved(srv)
    for eng in srv.engines.values():
        eng.kv.cache.check_invariants()
