"""Soft-import shim for the optional ``hypothesis`` dev dependency.

``pytest.importorskip("hypothesis")`` at module level skips *every* test in
the file — including plain regression tests that never touch hypothesis —
so in containers without the dep whole modules silently vanish from tier-1.

Importing ``given``/``settings``/``st`` from here instead degrades
gracefully: with hypothesis installed the real objects are re-exported;
without it, ``@given(...)`` marks just the decorated property test as
skipped and the rest of the module still runs.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: strategy expressions are
        evaluated at decoration time, so every attribute is a callable
        returning an inert placeholder."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
