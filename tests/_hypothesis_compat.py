"""Soft-import shim for the optional ``hypothesis`` dev dependency.

``pytest.importorskip("hypothesis")`` at module level skips *every* test in
the file — including plain regression tests that never touch hypothesis —
so in containers without the dep whole modules silently vanish from tier-1.

Importing ``given``/``settings``/``st`` from here instead degrades
gracefully: with hypothesis installed the real objects are re-exported;
without it, ``@given(...)`` runs the property as a *deterministic*
fixed-sample sweep — each declared strategy is sampled ``N_FALLBACK_EXAMPLES``
times from a seed derived from the test's name, so the property still
executes (identically on every run/machine) instead of silently skipping.
Only a strategy the fallback cannot sample (anything beyond
``st.integers``/``st.floats``/``st.booleans``) degrades to a skip, with an
explicit reason naming it — ``tests/test_skip_audit.py`` allowlists exactly
that site.
"""
import functools
import inspect
import zlib

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    import numpy as np

    HAS_HYPOTHESIS = False
    N_FALLBACK_EXAMPLES = 5

    class _Strategy:
        """A samplable stand-in for one hypothesis strategy expression."""

        def __init__(self, sample):
            self.sample = sample   # rng -> value, or None if unsupported

    class _Strategies:
        """Stands in for ``hypothesis.strategies``: the few strategies the
        suite uses become deterministic samplers; anything else returns an
        unsamplable placeholder that turns the test into a reasoned skip."""

        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        def __getattr__(self, _name):
            return lambda *a, **k: _Strategy(None)

    st = _Strategies()

    def given(*strategies, **kw_strategies):
        """Deterministic fallback: run the property over a fixed sample of
        each strategy, seeded by the test name (stable across runs).

        Like hypothesis, positional strategies bind to the *rightmost*
        parameters of the test function; anything to their left stays
        visible to pytest as fixtures/parametrization."""
        allst = list(strategies) + list(kw_strategies.values())
        if any(not isinstance(s, _Strategy) or s.sample is None
               for s in allst):
            return pytest.mark.skip(
                reason="hypothesis not installed and the declared strategy "
                       "has no deterministic fallback sampler")

        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            split = len(params) - len(strategies)
            drawn_names = [p.name for p in params[split:]]
            outer = [p for p in params[:split]
                     if p.name not in kw_strategies]

            @functools.wraps(fn)
            def run(**kwargs):
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for _ in range(N_FALLBACK_EXAMPLES):
                    kw = dict(kwargs)
                    kw.update({n: s.sample(rng)
                               for n, s in zip(drawn_names, strategies)})
                    kw.update({k: s.sample(rng)
                               for k, s in kw_strategies.items()})
                    fn(**kw)

            # hide the strategy-bound params from pytest's fixture
            # resolution (set before wraps' __wrapped__ can re-expose them)
            run.__signature__ = sig.replace(parameters=outer)
            return run
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
