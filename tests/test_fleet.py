"""Fleet-vectorized serving: cohort stepping must be byte-identical to the
per-engine loop across every runtime routing policy, disaggregated KV
handoffs and mid-run node failures — plus the satellite regressions
(tick-unit completion latencies under chunking, O(#cohorts) dispatch counts,
vectorized fleet counters, and jit-cache reuse across equal cohorts).

Hedging is disabled in the identity suite (``hedge_after=10**9``): a hedged
loser's cancel lands between cohort dispatch and host commit, one iteration
later than the per-engine interleaving — a documented fleet-mode caveat that
only ever touches the *discarded* copy (see docs/architecture.md).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster.spec import disagg_testbed, fleet_testbed, paper_testbed
from repro.configs import get
from repro.core.policy import PAPER_DEFAULTS
from repro.core.policies import runtime_policies
from repro.models import lm
from repro.serving import ClusterServer, EngineConfig, LLMEngine, ServeRequest
from repro.serving import fleet as fleet_mod
from repro.workload.trace import build_trace

NO_HEDGE = 10**9


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get("stablelm-3b").smoke()
    return cfg, lm.init(jax.random.key(0), cfg)


@pytest.fixture(scope="module")
def builders(tiny_model):
    """Two real tiny models standing in for the testbed's 4 names: the
    three edge names share ONE (cfg, params) pair, so all edge engines
    collapse into a single cohort."""
    big, pb = tiny_model
    small = get("qwen3-1.7b").smoke()
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


@pytest.fixture(scope="module")
def trace():
    return build_trace(24, seed=5)


def _server(cluster, builders, fleet, policy="threshold", ecfg=None, **kw):
    return ClusterServer(cluster, builders, PAPER_DEFAULTS,
                         ecfg or EngineConfig(max_slots=2, max_seq=48,
                                              max_new_tokens=4),
                         hedge_after=NO_HEDGE, fleet=fleet,
                         router_kwargs={"mode": policy}, **kw)


def _drive(srv, reqs, chunk, max_new=4, mid=None):
    """Submit ``reqs``, optionally run ``mid(srv)`` after two ticks, drain."""
    for i, r in enumerate(reqs):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=max_new))
    if mid is not None:
        srv.step(chunk=chunk)
        srv.step(chunk=chunk)
        mid(srv)
    return srv.run(chunk=chunk)


# ---------------------------------------------------------------------------
# byte-identity: fleet cohorts vs the per-engine loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", runtime_policies())
def test_fleet_identity_across_policies(builders, trace, policy):
    """Same cluster, same requests, same policy: fleet=True must reproduce
    fleet=False bit-for-bit — tokens AND the full QoE accounting (ttft/tpot
    step timestamps ride in each result dict)."""
    reqs = trace.requests[:10]
    done = {}
    for fleet in (False, True):
        srv = _server(paper_testbed(), builders, fleet, policy=policy)
        done[fleet] = _drive(srv, reqs, chunk=1)
    assert done[True] == done[False]


@pytest.mark.parametrize("chunk", [2, 4])
def test_fleet_identity_chunked(builders, trace, chunk):
    """Chunked cohort dispatch (one jit call for n iterations x M members)
    must equal per-engine ``step_n`` — including its fall-back-to-one-step
    behavior while admissions are queued."""
    reqs = trace.requests[:12]
    done = {}
    for fleet in (False, True):
        srv = _server(paper_testbed(), builders, fleet)
        done[fleet] = _drive(srv, reqs, chunk=chunk, max_new=6)
    assert done[True] == done[False]


def test_fleet_identity_disagg_handoffs_mid_chunk(tiny_model):
    """Disaggregated routes: prefilled KV rides the transfer queue and lands
    between cohort chunks; the import + admission must leave fleet results
    identical to the per-engine path, with no leaked blocks."""
    cfg, params = tiny_model
    cluster = disagg_testbed()
    bld = {"gemma3:27b": (cfg, params)}
    reqs = [dataclasses.replace(r, text=" ".join(f"w{i}_{j}"
                                                 for j in range(20)),
                                prompt_tokens=20)
            for i, r in enumerate(build_trace(24, seed=5).requests[:8])]
    ecfg = EngineConfig(max_slots=2, max_seq=48, max_new_tokens=3,
                        prefix_cache=True, block_size=8, cache_blocks=32)
    done, srvs = {}, {}
    for fleet in (False, True):
        srv = _server(cluster, bld, fleet, policy="disagg", ecfg=ecfg)
        done[fleet] = _drive(srv, reqs, chunk=2, max_new=3)
        srvs[fleet] = srv
    assert done[True] == done[False]
    assert srvs[True].stats()["handoffs"] >= 1      # splits actually taken
    for eng in srvs[True].engines.values():
        eng.kv.cache.check_invariants()
        assert int(np.sum(eng.kv.cache.pool.ref > 0)) == 0


def test_fleet_identity_node_failure_mid_chunk(builders, trace):
    """``fail_node`` kills a cohort member two ticks in: survivors must be
    byte-identical to the per-engine path and the dead member's paged pool
    must restart empty (no leaked blocks)."""
    reqs = trace.requests[:10]
    ecfg = EngineConfig(max_slots=2, max_seq=48, max_new_tokens=4,
                        prefix_cache=True, block_size=8, cache_blocks=32)
    done, srvs = {}, {}
    for fleet in (False, True):
        srv = _server(paper_testbed(), builders, fleet, ecfg=ecfg)
        done[fleet] = _drive(srv, reqs, chunk=2,
                             mid=lambda s: s.fail_node(1))
        srvs[fleet] = srv
    assert done[True] == done[False]
    assert srvs[True].stats()["reroutes"] == srvs[False].stats()["reroutes"]
    pair_node = np.asarray(srvs[True].router.arrays.pair_node)
    for p, eng in srvs[True].engines.items():
        eng.kv.cache.check_invariants()
        if int(pair_node[p]) == 1:   # restarted empty
            assert eng.kv.cache.pool.n_free == eng.ecfg.cache_blocks


def test_fleet_identity_mixed_workload(tiny_model):
    """The acceptance-criteria workload in one run: multi-turn session
    traffic with prefix reuse + disaggregated KV handoffs + a node failure
    mid-run, chunked — fleet must reproduce the per-engine loop exactly."""
    from repro.workload.sessions import SessionConfig, build_session_trace
    cfg, params = tiny_model
    cluster = disagg_testbed()
    bld = {"gemma3:27b": (cfg, params)}
    tr = build_session_trace(SessionConfig(n_sessions=4, mean_turns=3.0),
                             seed=3, n_requests=10)
    reqs = [dataclasses.replace(r, text=r.text + " " + " ".join(
                f"pad{i}_{j}" for j in range(12)),
                                prompt_tokens=r.prompt_tokens + 12)
            for i, r in enumerate(tr.requests)]
    ecfg = EngineConfig(max_slots=2, max_seq=48, max_new_tokens=3,
                        prefix_cache=True, block_size=8, cache_blocks=32)
    done, srvs = {}, {}
    for fleet in (False, True):
        srv = _server(cluster, bld, fleet, policy="disagg", ecfg=ecfg)
        done[fleet] = _drive(srv, reqs, chunk=2, max_new=3,
                             mid=lambda s: s.fail_node(1))
        srvs[fleet] = srv
    assert done[True] == done[False]
    assert len(done[True]) == len(reqs)
    assert srvs[True].stats()["handoffs"] >= 1
    for eng in srvs[True].engines.values():
        eng.kv.cache.check_invariants()


# ---------------------------------------------------------------------------
# satellite: completion latency unit (ticks, not decode iterations)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fleet", [False, True])
def test_completion_latency_in_ticks_under_chunking(builders, trace, fleet):
    """Regression: engine completions used to record ``iters + 1`` decode
    iterations while KV-handoff deliveries recorded scheduler ticks — under
    ``chunk=4`` the same wait produced a 4x larger 'latency' depending on
    which path closed it. Both paths now record ticks."""
    srv = _server(paper_testbed(), builders, fleet,
                  ecfg=EngineConfig(max_slots=2, max_seq=48,
                                    max_new_tokens=8))
    seen = []
    orig = srv.monitor.on_complete
    srv.monitor.on_complete = (
        lambda node, latency: (seen.append(latency), orig(node, latency))[1])
    _drive(srv, trace.requests[:6], chunk=4, max_new=8)
    assert seen
    # tick-unit latencies can never exceed the scheduler clock, and the
    # fastest completion (8 decode iterations = 2 chunks, no queueing) takes
    # 2 ticks — the old iteration unit would have recorded >= 8 for it
    assert all(1 <= lat <= srv.ticks for lat in seen), (seen, srv.ticks)
    assert min(seen) < 8


# ---------------------------------------------------------------------------
# satellite: O(#cohorts) dispatches + vectorized fleet counters
# ---------------------------------------------------------------------------
def test_saturated_tick_is_one_dispatch_per_cohort(builders, trace):
    """With every engine busy, one tick costs exactly ``len(cohorts)``
    jitted decode dispatches — not one per engine."""
    srv = _server(paper_testbed(), builders, True)
    assert len(srv._cohorts) == 2          # {big} + {small x 9 edge pairs}
    assert sum(len(c) for c in srv._cohorts) == len(srv.engines)
    for i, pair in enumerate(srv.engines):  # saturate every engine directly
        srv._dispatch(ServeRequest(request_id=100 + i,
                                   req=trace.requests[i % 12],
                                   max_new_tokens=4), pair)
    assert all(e.active_count > 0 for e in srv.engines.values())
    before = srv.decode_dispatches
    srv.step()
    assert srv.decode_dispatches - before == len(srv._cohorts)
    assert all(e._steps == 1 for e in srv.engines.values())


def test_fleet_counters_match_engine_ground_truth(builders, trace):
    """`active_count`/`queue_len`/`stats()` aggregate numpy cohort counters;
    they must track the per-engine Python-loop truth at every tick."""
    srv = _server(paper_testbed(), builders, True)
    for i, r in enumerate(trace.requests[:12]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4))
    while srv.inflight:
        assert srv.active_count == sum(e.active_count
                                       for e in srv.engines.values())
        assert srv.queue_len == sum(e.queue_len
                                    for e in srv.engines.values())
        srv.step()
    st = srv.stats()
    assert st["active"] == 0 and st["queued"] == 0
    assert st["fleet"]["emitted"] == sum(c["emitted"] for c in st["cohorts"])
    assert st["fleet"]["retired"] == len(srv.done)
    assert sum(c["dispatches"] for c in st["cohorts"]) >= 1
    assert st["decode_dispatches"] >= sum(c["dispatches"]
                                          for c in st["cohorts"])


def test_fleet_testbed_collapses_to_two_cohorts(builders):
    """64 nodes -> 176 (node, model) pairs -> exactly 2 cohorts when the
    edge names share one (cfg, params) identity (the benchmark's setup)."""
    cluster = fleet_testbed(n_edge=56, n_cloud=8)
    assert len(cluster.nodes) == 64
    srv = _server(cluster, builders, True)
    assert len(srv.engines) == 8 + 56 * 3
    assert len(srv._cohorts) == 2
    assert sorted(len(c) for c in srv._cohorts) == [8, 168]


# ---------------------------------------------------------------------------
# satellite: jit-cache reuse across cohorts with equal statics
# ---------------------------------------------------------------------------
def test_equal_cohorts_share_one_trace(tiny_model):
    """Two cohorts with identical (ModelConfig, member count, chunk, eos)
    must share ONE compiled executable: the second cohort's dispatches add
    zero new traces to the module-level jit cache."""
    cfg, params = tiny_model
    ecfg = EngineConfig(max_slots=2, max_seq=48, max_new_tokens=4)
    rng = np.random.default_rng(0)

    def make_cohort():
        engines = [LLMEngine(cfg, params, ecfg) for _ in range(2)]
        for e in engines:
            e.submit(0, rng.integers(0, cfg.vocab, size=6), max_new_tokens=4)
        return fleet_mod.Cohort(engines)

    c1, c2 = make_cohort(), make_cohort()
    before = fleet_mod._cohort_decode_chunk._cache_size()
    c1.dispatch(2, [0, 1])
    after_first = fleet_mod._cohort_decode_chunk._cache_size()
    assert after_first == before + 1       # one trace for this identity
    c2.dispatch(2, [0, 1])
    c1.dispatch(2, [0, 1])
    assert fleet_mod._cohort_decode_chunk._cache_size() == after_first, \
        "equal-static cohorts must reuse one executable"
