"""Observability layer (``repro.obs``): span tracing, percentile metrics,
decision audit — and their contracts against the DES oracles and the
serving runtime.

The load-bearing properties:

* **oracle stream identity** — both DES oracles (loop ``run`` and
  ``run_event_heap``) must emit byte-identical span and audit streams on
  open-loop workloads (arrivals pin absolute time, so even timestamps
  agree);
* **span conservation** — a completed span's phase durations sum to its
  recorded completion latency;
* **accounting mirror** — serving span events (dispatch/complete/failure/
  cancel) mirror the ``ClusterMonitor`` counter calls one-for-one, so
  ``total_dispatched == completed + failed + cancelled`` is checkable from
  the span log alone;
* **zero-overhead no-op** — ``Obs.noop()`` changes nothing observable.
"""
import json
import math
import warnings

import numpy as np
import pytest

from conftest import make_session_trace, shared_cluster
from repro.cluster.monitor import ClusterMonitor
from repro.cluster.simulator import ClusterSimulator
from repro.core.policies import get_policy
from repro.obs import (NOOP_TRACER, AuditLog, Histogram, MetricsRegistry,
                       Obs, Tracer, chrome_trace, metrics_flat)
from repro.workload.trace import build_trace

REL_TOL = 1e-5   # float32 table arithmetic: ~2.4e-6 max relative error


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def test_tracer_ring_eviction_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(7):
        tr.begin(i, float(i))
        tr.end(i, float(i) + 1)
    assert len(tr) == 4
    assert tr.dropped == 3
    assert [s.request_id for s in tr.spans()] == [3, 4, 5, 6]


def test_tracer_double_open_and_double_close_raise():
    tr = Tracer()
    tr.begin(0, 0.0)
    with pytest.raises(ValueError):
        tr.begin(0, 1.0)
    tr.end(0, 2.0)
    with pytest.raises(ValueError):
        tr.end(0, 3.0)


def test_noop_tracer_is_inert():
    NOOP_TRACER.begin(0, 0.0)
    NOOP_TRACER.event(0, "dispatch", 0.0, node=1)
    NOOP_TRACER.phase(0, "serve", 0.0, 1.0)
    NOOP_TRACER.end(0, 1.0)
    assert len(NOOP_TRACER) == 0 and NOOP_TRACER.spans() == []
    assert not NOOP_TRACER.enabled
    obs = Obs.noop()
    assert not obs.enabled and obs.metrics is None and obs.audit is None


def test_histogram_percentiles_track_numpy():
    """Log-bucket estimate within one bucket width (~26%) of the sample
    percentile, clamped exactly at the observed extremes."""
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=0.0, sigma=2.0, size=5000)
    h = Histogram()
    h.observe(vals)
    for q in (50, 95, 99):
        est, true = h.percentile(q), float(np.percentile(vals, q))
        assert abs(math.log(est / true)) < 0.27, (q, est, true)
    # every estimate is clamped into the observed range
    for q in (0, 50, 95, 99, 100):
        assert vals.min() <= h.percentile(q) <= vals.max()
    assert h.n == 5000 and abs(h.mean - vals.mean()) < 1e-9


def test_histogram_scalar_and_vector_paths_agree():
    vals = np.random.default_rng(1).lognormal(0, 3, 500)
    hv, hs = Histogram(), Histogram()
    hv.observe(vals)
    for v in vals:
        hs.observe_one(v)
    assert (hv.counts == hs.counts).all()
    assert hv.n == hs.n and abs(hv.total - hs.total) < 1e-6
    assert hv.vmin == hs.vmin and hv.vmax == hs.vmax


def test_histogram_merge_is_exact():
    rng = np.random.default_rng(2)
    a, b = rng.lognormal(0, 1, 300), rng.lognormal(1, 2, 700)
    ha, hb, hall = Histogram(), Histogram(), Histogram()
    ha.observe(a)
    hb.observe(b)
    hall.observe(np.concatenate([a, b]))
    ha.merge(hb)
    assert (ha.counts == hall.counts).all()
    assert ha.n == hall.n and ha.vmin == hall.vmin and ha.vmax == hall.vmax
    for q in (50, 95, 99):
        assert ha.percentile(q) == hall.percentile(q)


def test_degenerate_distributions_report_exactly():
    h = Histogram()
    h.observe(np.zeros(10))
    assert h.percentile(50) == 0.0 and h.percentile(99) == 0.0
    empty = Histogram()
    assert math.isnan(empty.percentile(50))


def test_registry_label_merge_matches_global():
    """Percentiles aggregated over one free label must equal the exact
    merge of the labelled histograms (shared fixed edges)."""
    m = MetricsRegistry()
    rng = np.random.default_rng(3)
    v0, v1 = rng.lognormal(0, 1, 200), rng.lognormal(1, 1, 200)
    m.observe("ttft", v0, node=0, category=2)
    m.observe("ttft", v1, node=1, category=2)
    by_cat = m.percentiles("ttft", node=None, category=2)
    overall = m.percentiles("ttft")
    assert by_cat["n"] == overall["n"] == 400
    assert by_cat["p95"] == overall["p95"]
    one = m.percentiles("ttft", node=0, category=2)
    assert one["n"] == 200


def test_registry_observe_by_groups_labels():
    m = MetricsRegistry()
    vals = np.array([1.0, 2.0, 3.0, 4.0])
    nodes = np.array([0, 1, 0, 1])
    cats = np.array([5, 5, 6, 6])
    m.observe_by("tpot", vals, nodes, cats)
    assert m.percentiles("tpot")["n"] == 4
    assert m.percentiles("tpot", node=0, category=5)["n"] == 1
    assert sorted(m.labels("tpot")) == [(0, 5), (0, 6), (1, 5), (1, 6)]


def test_counter_vec_scalar_and_scatter():
    m = MetricsRegistry()
    c = m.counter("fleet_tokens_emitted", 4)
    c.add(2, 5)
    c.add(np.array([0, 0, 3]), np.array([1, 1, 7]))
    assert c.values.tolist() == [2, 0, 5, 7]
    assert c.total == 14


def test_metrics_flat_keys():
    m = MetricsRegistry()
    m.observe("latency", [1.0, 2.0], node=3)
    m.counter("fleet_tokens_emitted", 2).add(1, 9)
    m.gauge("drift").set(0.25)
    flat = metrics_flat(m)
    assert "latency.p50" in flat and "latency.node3.p95" in flat
    assert flat["fleet_tokens_emitted.total"] == 9.0
    assert flat["fleet_tokens_emitted.node1"] == 9.0
    assert flat["drift"] == 0.25


def test_audit_ring_and_explain():
    al = AuditLog(capacity=3)
    for i in range(5):
        al.record(i, float(i), "threshold", "pair", (0.5,), i % 2, i % 2,
                  i % 2, healthy=np.ones(4), queue=np.zeros(4),
                  up=np.arange(4.0), prefill=np.arange(4.0),
                  tpot=np.arange(4.0), cost=np.arange(4.0),
                  failover="node-down" if i == 4 else None)
    assert len(al) == 3 and al.dropped == 2
    assert al.counts_by_policy() == {"threshold": 3}
    assert [r.index for r in al.failovers()] == [4]
    txt = al.explain(4)
    assert "policy=threshold" in txt and "failover[node-down]" in txt
    assert "<-- chosen" in txt
    assert al.explain(0) == "request 0: no audit record"


# ---------------------------------------------------------------------------
# monitor satellites: heartbeat clock contract + EWMA seeding regression
# ---------------------------------------------------------------------------
def test_heartbeat_requires_explicit_now():
    """The wall-clock fallback shim is gone: ``now`` is a required argument
    (callers own the clock), and passing it never warns."""
    mon = ClusterMonitor(2)
    with pytest.raises(TypeError):
        mon.heartbeat(0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mon.heartbeat(1, now=42.0)
    assert mon.stats[1].healthy
    assert mon.stats[1].last_heartbeat == 42.0


def test_ewma_seeds_on_first_completion_even_at_zero_latency():
    """Regression: the old ``ewma or latency`` idiom treated a legitimate
    0.0 EWMA as 'unseeded' and re-seeded on every completion."""
    mon = ClusterMonitor(1)
    mon.on_dispatch(0)
    mon.on_complete(0, latency=0.0)
    s = mon.stats[0]
    assert s.ewma_initialized and s.ewma_latency == 0.0
    mon.on_dispatch(0)
    mon.on_complete(0, latency=10.0)
    # second sample must blend, not re-seed to 10.0
    assert s.ewma_latency == pytest.approx(0.2 * 10.0)
    assert s.ewma_fast == pytest.approx(0.3 * 10.0)


# ---------------------------------------------------------------------------
# DES: oracle stream identity + conservation
# ---------------------------------------------------------------------------
def _des_obs():
    return Tracer(capacity=4096), AuditLog(capacity=4096), MetricsRegistry()


def _sorted_keys(tracer):
    return [s.key() for s in sorted(tracer.spans(),
                                    key=lambda s: s.request_id)]


def test_des_open_loop_span_streams_identical_across_oracles():
    """Loop oracle and event-heap oracle must emit byte-identical span AND
    audit streams on an open-loop session workload (absolute timestamps
    included — arrivals pin the clock)."""
    tr = make_session_trace(seed=3)
    sim = ClusterSimulator(tr, shared_cluster(), prefix_cache=True)
    pol = get_policy("threshold")
    g = np.asarray(pol.genome_spec.defaults)

    t1, a1, m1 = _des_obs()
    r1 = sim.run(policy="threshold", genome=g, concurrency=4,
                 tracer=t1, audit=a1, metrics=m1)
    t2, a2, m2 = _des_obs()
    r2 = sim.run_event_heap(policy="threshold", genome=g, concurrency=4,
                            tracer=t2, audit=a2, metrics=m2)

    assert len(t1) == len(t2) == tr.n_requests
    assert _sorted_keys(t1) == _sorted_keys(t2)
    k1 = sorted((r.key() for r in a1), key=lambda k: k[0])
    k2 = sorted((r.key() for r in a2), key=lambda k: k[0])
    assert k1 == k2
    np.testing.assert_allclose(r1.rt, r2.rt, rtol=1e-6)
    # metrics ingested identically
    assert m1.percentiles("latency") == m2.percentiles("latency")


def test_des_disagg_span_streams_identical_across_oracles():
    tr = build_trace(32, seed=5)
    sim = ClusterSimulator(tr, shared_cluster(), disaggregated=True)
    n_routes = len(sim.np_arrays.route_prefill)
    assign = [i % n_routes for i in range(tr.n_requests)]
    arrivals = np.arange(tr.n_requests) * 0.25

    t1, _, _ = _des_obs()
    sim.run(assign=assign, arrivals=arrivals, concurrency=4, tracer=t1)
    t2, _, _ = _des_obs()
    sim.run_event_heap(assign=assign, arrivals=arrivals, concurrency=4,
                       tracer=t2)
    assert len(t1) == tr.n_requests
    assert _sorted_keys(t1) == _sorted_keys(t2)
    # the route mix must actually exercise split routes
    assert any(p.name == "kv-transfer" for s in t1.spans()
               for p in s.phases)


def test_des_span_conservation():
    """Per span: phase durations sum to the span window AND to the
    simulator's recorded response time."""
    tr = make_session_trace(seed=3)
    sim = ClusterSimulator(tr, shared_cluster(), prefix_cache=True)
    pol = get_policy("threshold")
    g = np.asarray(pol.genome_spec.defaults)
    t1, _, _ = _des_obs()
    res = sim.run(policy="threshold", genome=g, concurrency=4, tracer=t1)
    for s in t1.spans():
        assert s.status == "completed"
        window = s.end - s.start
        assert s.phase_total() == pytest.approx(window, rel=REL_TOL)
        assert window == pytest.approx(float(res.rt[s.request_id]),
                                       rel=REL_TOL)


def test_des_disagg_span_conservation():
    tr = build_trace(32, seed=5)
    sim = ClusterSimulator(tr, shared_cluster(), disaggregated=True)
    n_routes = len(sim.np_arrays.route_prefill)
    assign = [i % n_routes for i in range(tr.n_requests)]
    t1, _, _ = _des_obs()
    res = sim.run(assign=assign, arrivals=np.arange(tr.n_requests) * 0.25,
                  concurrency=4, tracer=t1)
    for s in t1.spans():
        assert s.phase_total() == pytest.approx(s.end - s.start, rel=REL_TOL)
        assert s.end - s.start == pytest.approx(
            float(res.rt[s.request_id]), rel=REL_TOL)


def test_des_failover_audited_and_marked_in_spans():
    """Crash a node for a window: affected requests must carry the failover
    reason in both the audit record and the route-decision span event."""
    tr = make_session_trace(seed=3)
    sim = ClusterSimulator(tr, shared_cluster(), prefix_cache=True)
    pol = get_policy("threshold")
    g = np.asarray(pol.genome_spec.defaults)
    t1, a1, _ = _des_obs()
    sim.run(policy="threshold", genome=g, concurrency=4,
            down_nodes={1: (0.0, 1e9), 2: (0.0, 1e9), 3: (0.0, 1e9)},
            tracer=t1, audit=a1)
    fo = a1.failovers()
    if fo:   # the policy may already route everything to the cloud
        assert all(r.failover == "node-down" for r in fo)
        rid = fo[0].index
        span = t1.span(rid)
        ev = next(e for e in span.events if e.name == "route-decision")
        assert dict(ev.attrs)["failover"] == "node-down"
    # regardless of failovers, every audit record names the policy
    assert a1.counts_by_policy() == {"threshold": tr.n_requests}


def test_des_chrome_trace_round_trips(tmp_path):
    tr = make_session_trace(seed=3)
    sim = ClusterSimulator(tr, shared_cluster(), prefix_cache=True)
    pol = get_policy("threshold")
    t1, _, _ = _des_obs()
    sim.run(policy="threshold", genome=np.asarray(pol.genome_spec.defaults),
            concurrency=4, tracer=t1)
    path = tmp_path / "trace.json"
    doc = chrome_trace(t1, path=str(path))
    loaded = json.loads(path.read_text())
    assert loaded == doc
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"X", "i", "M"}
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and isinstance(e["ts"], float)
    # every request contributes at least one duration event
    tids = {e["tid"] for e in evs if e["ph"] == "X"}
    assert tids == set(range(tr.n_requests))


def test_des_metrics_percentiles_cover_all_series():
    tr = make_session_trace(seed=3)
    sim = ClusterSimulator(tr, shared_cluster(), prefix_cache=True)
    pol = get_policy("threshold")
    _, _, m = _des_obs()
    sim.run(policy="threshold", genome=np.asarray(pol.genome_spec.defaults),
            concurrency=4, metrics=m)
    summ = m.summary(names=("ttft", "tpot", "queue_wait", "transfer",
                            "cache_hit_frac", "spend", "latency"))
    assert set(summ) == {"ttft", "tpot", "queue_wait", "transfer",
                         "cache_hit_frac", "spend", "latency"}
    for name, p in summ.items():
        assert p["n"] == tr.n_requests, name
    assert np.isfinite(summ["latency"]["p99"])


def test_des_noop_default_changes_nothing():
    """Running without obs sinks must produce the exact same SimResult."""
    tr = make_session_trace(seed=3)
    sim = ClusterSimulator(tr, shared_cluster(), prefix_cache=True)
    pol = get_policy("threshold")
    g = np.asarray(pol.genome_spec.defaults)
    bare = sim.run(policy="threshold", genome=g, concurrency=4)
    t1, a1, m1 = _des_obs()
    obs = sim.run(policy="threshold", genome=g, concurrency=4,
                  tracer=t1, audit=a1, metrics=m1)
    np.testing.assert_array_equal(bare.rt, obs.rt)
    np.testing.assert_array_equal(bare.assign, obs.assign)


# ---------------------------------------------------------------------------
# serving: span/monitor mirror across fleet, failover, hedging, handoff
# ---------------------------------------------------------------------------
def _serve_builders():
    import jax

    from repro.configs import get
    from repro.models import lm
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    pb = lm.init(jax.random.key(0), big)
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


@pytest.fixture(scope="module")
def serve_parts():
    return shared_cluster(), _serve_builders(), build_trace(24, seed=5)


def _event_counts(tracer):
    """Per-node counts of the accounting events across all closed spans."""
    ev = {}
    for s in tracer.spans():
        for e in s.events:
            if e.name in ("dispatch", "complete", "failure", "cancel"):
                node = dict(e.attrs)["node"]
                ev.setdefault(node, {"dispatch": 0, "complete": 0,
                                     "failure": 0, "cancel": 0})
                ev[node][e.name] += 1
    return ev


def _assert_spans_mirror_monitor(srv, obs, n_req):
    spans = obs.tracer.spans()
    assert len(spans) == n_req
    assert not obs.tracer.open_spans()   # every span closed exactly once
    ev = _event_counts(obs.tracer)
    for node, st in srv.monitor.stats.items():
        got = ev.get(node, {"dispatch": 0, "complete": 0, "failure": 0,
                            "cancel": 0})
        assert got["dispatch"] == st.total_dispatched, (node, got)
        assert got["complete"] == st.total_completed, (node, got)
        assert got["failure"] == st.total_failed, (node, got)
        assert got["cancel"] == st.total_cancelled, (node, got)
        # the ledger closes from the span log alone
        assert got["dispatch"] == (got["complete"] + got["failure"]
                                   + got["cancel"]), node
    for s in spans:
        assert s.status == "completed"
        for p in s.phases:   # every phase inside the span window
            assert s.start <= p.start and p.start + p.duration <= s.end


def test_serving_spans_mirror_monitor_accounting(serve_parts, tmp_path):
    from repro.serving import ClusterServer, EngineConfig, ServeRequest
    cluster, builders, trace = serve_parts
    obs = Obs()
    srv = ClusterServer(cluster, builders, _paper_defaults(),
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=3), obs=obs)
    for i, r in enumerate(trace.requests[:8]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    done = srv.run()
    assert sorted(done) == list(range(8))
    _assert_spans_mirror_monitor(srv, obs, 8)
    # serve-phase duration == the monitor's completion-latency unit (ticks)
    st = srv.stats()
    assert st["percentiles"]["latency"]["n"] == 8
    assert st["percentiles"]["ttft"]["n"] == 8
    # audit captured one record per route() decision
    assert len(obs.audit) >= 8
    # chrome-trace export stays valid JSON on the tick clock
    path = tmp_path / "serve_trace.json"
    doc = chrome_trace(obs.tracer, path=str(path),
                       time_unit=srv.tick_seconds)
    assert json.loads(path.read_text()) == doc


def test_serving_failover_reroutes_traced(serve_parts):
    from repro.serving import ClusterServer, EngineConfig, ServeRequest
    cluster, builders, trace = serve_parts
    obs = Obs()
    srv = ClusterServer(cluster, builders, _paper_defaults(),
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=4), obs=obs)
    for i, r in enumerate(trace.requests[:6]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4))
    for node in (1, 2, 3):
        srv.fail_node(node)
    done = srv.run()
    assert sorted(done) == list(range(6))
    _assert_spans_mirror_monitor(srv, obs, 6)
    n_reroute = sum(1 for s in obs.tracer.spans()
                    for e in s.events if e.name == "reroute")
    assert n_reroute == srv.stats()["reroutes"] >= 1


def test_serving_hedged_cancel_traced(serve_parts):
    from repro.serving import ClusterServer, EngineConfig, ServeRequest
    cluster, builders, trace = serve_parts
    obs = Obs()
    srv = ClusterServer(cluster, builders, _paper_defaults(),
                        EngineConfig(max_slots=1, max_seq=48,
                                     max_new_tokens=4),
                        hedge_after=2, obs=obs)
    for i, r in enumerate(trace.requests[:6]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4))
    done = srv.run()
    assert sorted(done) == list(range(6))
    _assert_spans_mirror_monitor(srv, obs, 6)
    n_hedge = sum(1 for s in obs.tracer.spans()
                  for e in s.events if e.name == "hedge")
    assert n_hedge == srv.stats()["hedges"] >= 1


def test_serving_disagg_handoff_traced():
    """Split routes: one kv-transfer phase + handoff-start event per
    delivered handoff, and the transfer metric counts them."""
    import dataclasses as dc

    import jax

    from repro.cluster.spec import disagg_testbed
    from repro.configs import get
    from repro.models import lm
    from repro.serving import ClusterServer, EngineConfig, ServeRequest
    cfg = get("stablelm-3b").smoke()
    params = lm.init(jax.random.key(0), cfg)
    reqs = [dc.replace(r, text=" ".join(f"w{i}_{j}" for j in range(20)),
                       prompt_tokens=20)
            for i, r in enumerate(build_trace(24, seed=5).requests[:8])]
    obs = Obs()
    srv = ClusterServer(
        disagg_testbed(), {"gemma3:27b": (cfg, params)}, _paper_defaults(),
        EngineConfig(max_slots=2, max_seq=48, max_new_tokens=3,
                     prefix_cache=True, block_size=8, cache_blocks=32),
        router_kwargs={"mode": "disagg"}, obs=obs)
    for i, r in enumerate(reqs):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    done = srv.run()
    assert sorted(done) == list(range(8))
    _assert_spans_mirror_monitor(srv, obs, 8)
    spans = obs.tracer.spans()
    n_handoff = sum(1 for s in spans for e in s.events
                    if e.name == "handoff-start")
    assert n_handoff == srv.stats()["handoffs"] >= 1
    kv_phases = [p for s in spans for p in s.phases
                 if p.name == "kv-transfer"]
    assert srv.stats()["percentiles"]["transfer"]["n"] == len(kv_phases)


def _paper_defaults():
    from repro.core.policy import PAPER_DEFAULTS
    return PAPER_DEFAULTS
