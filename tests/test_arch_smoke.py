"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step + one prefill/decode step on CPU; asserts output
shapes and finiteness. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_ids, get
from repro.models import lm
from repro.models.config import SHAPES, cell_applicable


def _batch(cfg, B=2, S=16, key=0):
    tokens = jax.random.randint(jax.random.key(key), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.key(key + 1), (B, cfg.encoder.n_frames, cfg.d_model),
            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(key + 1), (B, cfg.cross_kv_tokens, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch_id", all_ids())
def test_smoke_train_step(arch_id):
    cfg = get(arch_id).smoke()
    params = lm.init(jax.random.key(0), cfg)
    batch = _batch(cfg)

    def loss(p):
        l, _ = lm.loss_fn(p, cfg, batch)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val)), arch_id
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch_id


@pytest.mark.parametrize("arch_id", all_ids())
def test_smoke_logits_shape_and_finite(arch_id):
    cfg = get(arch_id).smoke()
    params = lm.init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, aux = lm.train_logits(params, cfg, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id


@pytest.mark.parametrize("arch_id", all_ids())
def test_smoke_prefill_decode(arch_id):
    cfg = get(arch_id).smoke()
    params = lm.init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    del batch["labels"]
    B, S = batch["tokens"].shape
    logits, cache = lm.prefill(params, cfg, batch, max_seq=S + 4)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(2):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(cache.pos) == S + 2


@pytest.mark.parametrize("arch_id", ["stablelm-3b", "qwen3-4b", "jamba-v0.1-52b",
                                     "xlstm-1.3b", "whisper-tiny"])
def test_decode_matches_teacher_forcing(arch_id):
    """KV-cache/state decode must agree with the full forward pass."""
    cfg = get(arch_id).smoke()
    params = lm.init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    del batch["labels"]
    tokens = batch["tokens"]
    B, S = tokens.shape
    lp, cache = lm.prefill(params, cfg, batch, max_seq=S + 2)
    full, _ = lm.train_logits(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, -1]),
                               rtol=5e-2, atol=5e-2)
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    lg, _ = lm.decode_step(params, cfg, nxt, cache)
    batch2 = dict(batch, tokens=jnp.concatenate([tokens, nxt], 1))
    full2, _ = lm.train_logits(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full2[:, -1]),
                               rtol=6e-2, atol=6e-2)


def test_param_counts_match_published_sizes():
    expected = {  # arch id -> (total B, active B, rel tolerance)
        "stablelm-3b": (2.8, 2.8, 0.30),
        "qwen3-4b": (4.0, 4.0, 0.25),
        "stablelm-12b": (12.1, 12.1, 0.15),
        "qwen3-1.7b": (2.0, 2.0, 0.30),
        "dbrx-132b": (132.0, 36.0, 0.10),
        "llama4-maverick-400b-a17b": (400.0, 17.0, 0.20),
        "xlstm-1.3b": (1.3, 1.3, 0.45),
        "llama-3.2-vision-11b": (10.6, 10.6, 0.15),
        "jamba-v0.1-52b": (52.0, 12.0, 0.10),
    }
    for arch_id, (tot, act, tol) in expected.items():
        c = get(arch_id).config().param_counts()
        assert abs(c["total"] / 1e9 - tot) / tot < tol, \
            (arch_id, c["total"] / 1e9)
        assert abs(c["active"] / 1e9 - act) / act < tol + 0.1, \
            (arch_id, c["active"] / 1e9)


def test_long_context_applicability():
    assert cell_applicable(get("xlstm-1.3b").config(), "long_500k")[0]
    assert cell_applicable(get("jamba-v0.1-52b").config(), "long_500k")[0]
    ok, reason = cell_applicable(get("stablelm-3b").config(), "long_500k")
    assert not ok and "sub-quadratic" in reason
