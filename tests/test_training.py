"""Training substrate: optimizers, gradient compression, trainer loop,
checkpoint/restart fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft optional dep

from repro.configs import get
from repro.training.grad_compress import (compress_int8, decompress_int8,
                                          compress_topk, init_residual)
from repro.training.optim import (OptConfig, _dq8, _q8, adafactor, adamw,
                                  adamw8bit, make_optimizer,
                                  optimizer_for_arch)
from repro.training.trainer import TrainConfig, Trainer


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([[1.0, -1.0]])}


@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor"])
def test_optimizers_minimize_quadratic(name):
    opt = make_optimizer(name, OptConfig(lr=0.05, weight_decay=0.0))
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    step = jax.jit(lambda g, s, p: opt.update(g, s, p))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = step(grads, state, params)
    assert float(loss(params)) < 0.05 * l0, name


def test_optimizer_states_match_param_shapes():
    params = {"a": jnp.zeros((8, 16)), "b": jnp.zeros((5,))}
    st8 = adamw8bit().init(params)
    assert st8["m"]["a"]["q"].shape == (8, 16)
    assert st8["m"]["a"]["q"].dtype == jnp.int8
    stf = adafactor().init(params)
    assert stf["f"]["a"]["vr"].shape == (8,)
    assert stf["f"]["a"]["vc"].shape == (16,)
    assert stf["f"]["b"]["v"].shape == (5,)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_int8_quant_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((7, 300)) * 10, jnp.float32)
    q, s = _q8(x)
    back = _dq8(q, s, x.shape)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # blockwise absmax int8: error <= scale/2 per block
    scale = np.asarray(s)
    assert (err <= np.repeat(scale, 256, axis=-1)[:, :300] * 0.5 + 1e-6).all()


def test_optimizer_tiering():
    assert optimizer_for_arch(2e9) == "adamw"
    assert optimizer_for_arch(130e9) == "adamw8bit"
    assert optimizer_for_arch(400e9) == "adafactor"


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_error_feedback_telescopes():
    """Sum of dequantized payloads + final residual == sum of raw grads."""
    rng = np.random.default_rng(0)
    grads_seq = [
        {"w": jnp.asarray(rng.standard_normal((4, 300)), jnp.float32)}
        for _ in range(5)]
    residual = init_residual(grads_seq[0])
    sent_total = jnp.zeros((4, 300))
    for g in grads_seq:
        q, s, residual = compress_int8(g, residual)
        sent_total = sent_total + decompress_int8(q, s, g)["w"]
    raw_total = sum(g["w"] for g in grads_seq)
    np.testing.assert_allclose(np.asarray(sent_total + residual["w"]),
                               np.asarray(raw_total), rtol=1e-4, atol=1e-4)


def test_topk_keeps_largest():
    g = {"w": jnp.asarray(np.arange(100, dtype=np.float32))}
    res = init_residual(g)
    sent, new_res = compress_topk(g, res, frac=0.1)
    nz = np.flatnonzero(np.asarray(sent["w"]))
    assert set(nz) == set(range(90, 100))
    np.testing.assert_allclose(np.asarray(sent["w"] + new_res["w"]),
                               np.asarray(g["w"]))


# ---------------------------------------------------------------------------
# trainer: loss decreases + checkpoint/restart
# ---------------------------------------------------------------------------
def test_trainer_loss_decreases():
    cfg = get("stablelm-3b").smoke()
    t = Trainer(cfg, TrainConfig(seq_len=64, global_batch=8, steps=80,
                                 log_every=10, data_vocab=64, data_chains=1,
                                 data_branch=4,
                                 opt=OptConfig(lr=3e-3, weight_decay=0.0)))
    _, _, hist = t.run()
    first, last = hist[0]["nll"], hist[-1]["nll"]
    assert last < first - 0.5, (first, last)


def test_trainer_microbatching_matches_full_batch():
    cfg = get("qwen3-1.7b").smoke()
    kw = dict(seq_len=32, global_batch=4, steps=3, log_every=1,
              opt=OptConfig(lr=1e-3))
    t1 = Trainer(cfg, TrainConfig(microbatches=1, **kw))
    t2 = Trainer(cfg, TrainConfig(microbatches=2, **kw))
    _, _, h1 = t1.run()
    _, _, h2 = t2.run()
    # same data, same init: losses should track closely
    assert abs(h1[0]["loss"] - h2[0]["loss"]) < 2e-2


def test_checkpoint_restart_resumes_exactly(tmp_path):
    cfg = get("stablelm-3b").smoke()
    common = dict(seq_len=32, global_batch=4, log_every=1,
                  checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=5,
                  opt=OptConfig(lr=1e-3))
    # run 10 steps straight through
    t_full = Trainer(cfg, TrainConfig(steps=10, **common))
    p_full, _, _ = t_full.run(resume=False)
    # wipe and run 5, "crash", resume to 10
    import shutil
    shutil.rmtree(tmp_path / "ck")
    t_a = Trainer(cfg, TrainConfig(steps=5, **common))
    t_a.run(resume=False)
    t_b = Trainer(cfg, TrainConfig(steps=10, **common))
    p_b, _, _ = t_b.run(resume=True)
    assert t_b.ckpt.latest_step() == 10
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_checkpoint_atomic_no_partial_state(tmp_path):
    from repro.checkpoint.manager import latest_step, save_checkpoint
    tree = {"x": jnp.arange(10)}
    save_checkpoint(tmp_path, 1, tree)
    # simulate a crashed writer: stray tmp dir must be ignored + cleaned
    (tmp_path / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1
    save_checkpoint(tmp_path, 3, tree)
    assert latest_step(tmp_path) == 3
    assert not (tmp_path / "step_00000002.tmp").exists()


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore onto a 1-device 'mesh' with specs."""
    from repro.checkpoint.manager import load_checkpoint, save_checkpoint
    from jax.sharding import PartitionSpec as P
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    specs = {"w": P(None, "model")}
    save_checkpoint(tmp_path, 0, tree, specs=specs)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    restored, manifest = load_checkpoint(tmp_path, tree, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert manifest["specs"]["w"] == [None, "model"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_host_sharded():
    from repro.data import DataConfig, SyntheticLMData
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4)
    a = SyntheticLMData(cfg).batch(7)
    b = SyntheticLMData(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts partition the global batch exactly
    h0 = SyntheticLMData(cfg, host_index=0, n_hosts=2).batch(7)
    h1 = SyntheticLMData(cfg, host_index=1, n_hosts=2).batch(7)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
