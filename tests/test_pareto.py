"""Unit + property tests for repro.core.pareto (NSGA-II building blocks)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft optional dep

from repro.core.pareto import (crowding_distance, dominance_matrix,
                               hypervolume_2d, non_dominated_sort, pareto_mask)


def brute_ranks(F: np.ndarray) -> np.ndarray:
    n = len(F)
    rank = -np.ones(n, int)
    alive = np.ones(n, bool)
    cur = 0
    while alive.any():
        dom = ((F[:, None, :] <= F[None, :, :]).all(-1)
               & (F[:, None, :] < F[None, :, :]).any(-1))
        dom = dom & alive[:, None] & alive[None, :]
        front = alive & ~dom.any(0)
        rank[front] = cur
        alive &= ~front
        cur += 1
    return rank


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 48), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_non_dominated_sort_matches_bruteforce(seed, P, M):
    rng = np.random.default_rng(seed)
    # include ties with prob 1/2 (duplicated rows stress the strict-dominance edge)
    F = rng.random((P, M)).astype(np.float32)
    if seed % 2 == 0 and P > 2:
        F[P // 2] = F[0]
    got = np.asarray(non_dominated_sort(jnp.asarray(F)))
    want = brute_ranks(F)
    np.testing.assert_array_equal(got, want)


def test_dominance_matrix_antisymmetric_and_irreflexive():
    rng = np.random.default_rng(0)
    F = rng.random((32, 3)).astype(np.float32)
    D = np.asarray(dominance_matrix(jnp.asarray(F)))
    assert not D.diagonal().any()
    assert not (D & D.T).any()  # i dominates j => j not dominates i


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_pareto_mask_is_rank_zero(seed):
    rng = np.random.default_rng(seed)
    F = rng.random((20, 3)).astype(np.float32)
    mask = np.asarray(pareto_mask(jnp.asarray(F)))
    ranks = np.asarray(non_dominated_sort(jnp.asarray(F)))
    np.testing.assert_array_equal(mask, ranks == 0)


def test_crowding_boundaries_are_infinite():
    # one front, distinct objective values: extremes must get +inf
    F = np.array([[0.0, 1.0], [0.25, 0.75], [0.5, 0.5], [1.0, 0.0]],
                 np.float32)
    rank = non_dominated_sort(jnp.asarray(F))
    assert int(rank.max()) == 0
    d = np.asarray(crowding_distance(jnp.asarray(F), rank))
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert np.isfinite(d[1]) and np.isfinite(d[2])


def test_crowding_prefers_sparser_point():
    # middle points: one in a dense cluster, one isolated
    F = np.array([[0.0, 1.0], [0.1, 0.9], [0.12, 0.88], [0.5, 0.3],
                  [1.0, 0.0]], np.float32)
    rank = non_dominated_sort(jnp.asarray(F))
    d = np.asarray(crowding_distance(jnp.asarray(F), rank))
    assert d[3] > d[2]


def test_crowding_within_front_only():
    # two fronts; crowding of front-1 members must not use front-0 neighbors
    F0 = np.array([[0.0, 1.0], [0.5, 0.5], [1.0, 0.0]])
    F1 = F0 + 2.0
    F = np.concatenate([F0, F1]).astype(np.float32)
    rank = non_dominated_sort(jnp.asarray(F))
    assert set(np.asarray(rank)) == {0, 1}
    d = np.asarray(crowding_distance(jnp.asarray(F), rank))
    # both fronts have identical geometry: same crowding pattern
    np.testing.assert_allclose(d[:3][np.isfinite(d[:3])],
                               d[3:][np.isfinite(d[3:])], rtol=1e-6)


def test_hypervolume_2d_unit_square():
    # single point at origin dominates the whole [0, 1]^2 box
    F = np.array([[0.0, 0.0]], np.float32)
    hv = float(hypervolume_2d(jnp.asarray(F), jnp.array([1.0, 1.0])))
    assert hv == pytest.approx(1.0)


def test_hypervolume_2d_staircase():
    F = np.array([[0.0, 0.5], [0.5, 0.0]], np.float32)
    hv = float(hypervolume_2d(jnp.asarray(F), jnp.array([1.0, 1.0])))
    # two rectangles 1x0.5 + 0.5x0.5 overlap region counted once = 0.75
    assert hv == pytest.approx(0.75)
