"""Session workloads + cache-affinity routing: generator invariants, the
affinity decision py/jnp oracle pair, monitor prefix state, JAX/DES
prefix-cache equivalence, and the router's affinity mode + re-fit."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft optional dep

from conftest import shared_arrays

from repro.cluster.monitor import ClusterMonitor
from repro.cluster.simulator import ClusterSimulator
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.policy import (AFFINITY_DEFAULTS, SLO_DEFAULTS,
                               decide_pair_affinity_jnp,
                               decide_pair_affinity_py)
from repro.core.router import RequestRouter

# ``session_trace`` and ``cluster`` now come from conftest.py.


# ---------------------------------------------------------------------------
# generator invariants
# ---------------------------------------------------------------------------
def test_session_prompts_extend_and_arrivals_sorted(session_trace):
    tr = session_trace
    assert tr.has_sessions and tr.has_arrivals
    assert (np.diff(tr.arrival_time) >= 0).all()
    latest = {}
    for r in tr.requests:
        prev = latest.get(r.session_id)
        if prev is not None:
            assert r.text.startswith(prev.text), \
                "turn prompt must extend the previous turn verbatim"
            assert r.turn == prev.turn + 1
            assert r.prompt_tokens > prev.prompt_tokens
        latest[r.session_id] = r
    # agent sharing: sessions with the same sys_id share the system prefix
    by_sys = {}
    for r in tr.requests:
        if r.turn == 0 and r.sys_id >= 0:
            by_sys.setdefault(r.sys_id, []).append(r.text)
    for sid, texts in by_sys.items():
        if len(texts) >= 2:
            a, b = texts[0], texts[1]
            common = 0
            for ca, cb in zip(a, b):
                if ca != cb:
                    break
                common += 1
            assert common >= 40, "shared system prompt must be a real prefix"


def test_session_trace_arrays_match_requests(session_trace):
    tr = session_trace
    assert tr.group_id.shape == (tr.n_requests,)
    for i, r in enumerate(tr.requests):
        assert tr.group_id[i] == r.session_id
        assert tr.sys_id[i] == r.sys_id
        assert tr.sys_tokens[i] == r.sys_tokens


# ---------------------------------------------------------------------------
# affinity decision: numpy oracle == jnp implementation
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_affinity_decision_py_jnp_agree(seed):
    arrays = shared_arrays()
    rng = np.random.default_rng(seed)
    n_pairs, n_nodes = arrays.n_pairs, arrays.n_nodes
    genome = rng.uniform([0.3, 0, 0], [1.1, 20, 4]).astype(np.float32)
    kw = dict(
        ttft_deadline=float(rng.uniform(0.1, 5.0)),
        tpot_deadline=float(rng.uniform(0.05, 1.0)),
        up=rng.uniform(0, 1, n_pairs).astype(np.float32),
        prefill=rng.uniform(0, 2, n_pairs).astype(np.float32),
        tpot=rng.uniform(0.04, 0.3, n_pairs).astype(np.float32),
        cost=rng.uniform(0, 1e-3, n_pairs).astype(np.float32),
        prompt_cost=rng.uniform(0, 5e-4, n_pairs).astype(np.float32),
        hit_frac=rng.uniform(0, 1, n_pairs).astype(np.float32),
        queue_len=rng.integers(0, 10, n_nodes))
    want = decide_pair_affinity_py(genome, arrays=arrays, **kw)
    got = int(decide_pair_affinity_jnp(
        jnp.asarray(genome), arrays=arrays,
        **{k: (jnp.asarray(v) if not np.isscalar(v) else jnp.float32(v))
           for k, v in kw.items()}))
    assert want == got


def test_affinity_hit_discount_changes_decision(cluster):
    """A full cache hit on an edge node must beat an empty cloud pair when
    the undiscounted prefill would miss the deadline."""
    arrays = cluster.to_arrays()
    n_pairs = arrays.n_pairs
    pair_is_edge = np.asarray(arrays.pair_is_edge)
    prefill = np.where(pair_is_edge, 2.0, 0.05).astype(np.float32)
    cost = np.where(pair_is_edge, 1e-5, 1e-3).astype(np.float32)
    hit = np.where(pair_is_edge, 0.9, 0.0).astype(np.float32)
    kw = dict(ttft_deadline=0.5, tpot_deadline=1.0,
              up=np.zeros(n_pairs, np.float32), prefill=prefill,
              tpot=np.full(n_pairs, 0.05, np.float32), cost=cost,
              prompt_cost=(cost * 0.5).astype(np.float32),
              queue_len=np.zeros(arrays.n_nodes, np.int64), arrays=arrays)
    blind = decide_pair_affinity_py(
        AFFINITY_DEFAULTS, hit_frac=np.zeros(n_pairs, np.float32), **kw)
    aware = decide_pair_affinity_py(AFFINITY_DEFAULTS, hit_frac=hit, **kw)
    assert not pair_is_edge[blind]     # uncached edge prefill infeasible
    assert pair_is_edge[aware]         # cached edge is feasible and cheaper


# ---------------------------------------------------------------------------
# monitor prefix state
# ---------------------------------------------------------------------------
def test_monitor_prefix_state_and_hit_fractions():
    mon = ClusterMonitor(3)
    mon.record_prefix(1, ("sess", 7), 32)
    mon.record_prefix(1, ("sess", 7), 16)     # monotone max, never shrinks
    mon.record_prefix(2, ("sys", 0), 48)
    assert mon.cached_tokens(1, ("sess", 7)) == 32
    # session hit on node 1; system-prompt hit on node 2; nothing on node 0
    hf = mon.hit_fractions(session=7, sys=0, prompt_tokens=64,
                           sys_tokens=50, block=16)
    assert hf[0] == 0.0
    assert hf[1] == pytest.approx(32 / 64)
    assert hf[2] == pytest.approx(48 / 64)
    mon.drop_prefixes(1)
    assert mon.hit_fractions(7, 0, 64, 50, block=16)[1] == 0.0


# ---------------------------------------------------------------------------
# JAX evaluator vs DES oracles with the prefix-cache model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["affinity", "slo", "direct"])
def test_prefix_cache_jax_des_equivalence(session_trace, cluster, policy):
    ev = TraceEvaluator(session_trace, cluster,
                        EvalConfig(mode="open", prefix_cache=True))
    if policy == "affinity":
        res = ev.run_affinity_policy(AFFINITY_DEFAULTS)
    elif policy == "slo":
        res = ev.run_slo_policy(SLO_DEFAULTS)
    else:
        rng = np.random.default_rng(0)
        res = ev.run_assignment(
            jnp.asarray(rng.integers(0, ev.arrays.n_pairs,
                                     session_trace.n_requests)))
    assign = np.asarray(res.assign)
    sim = ClusterSimulator(session_trace, cluster, prefix_cache=True)
    for sr in (sim.run(assign), sim.run_event_heap(assign)):
        np.testing.assert_array_equal(assign, sr.assign)
        for f in ("q", "cost", "rt", "ttft", "hit"):
            np.testing.assert_allclose(np.asarray(getattr(res, f)),
                                       getattr(sr, f), rtol=1e-4, atol=1e-5,
                                       err_msg=f)
    assert float(np.asarray(res.hit).mean()) > 0.0


def test_prefix_cache_discounts_vs_cache_blind_run(session_trace, cluster):
    """Same assignment with and without the cache model: hits can only
    shorten prefill (ttft) and reduce cost, never the reverse."""
    ev_on = TraceEvaluator(session_trace, cluster,
                           EvalConfig(mode="open", prefix_cache=True))
    ev_off = TraceEvaluator(session_trace, cluster, EvalConfig(mode="open"))
    assign = jnp.asarray(
        np.asarray(ev_on.run_affinity_policy(AFFINITY_DEFAULTS).assign))
    on = ev_on.run_assignment(assign)
    off = ev_off.run_assignment(assign)
    assert float(jnp.mean(on.hit)) > 0.1
    assert np.all(np.asarray(on.cost) <= np.asarray(off.cost) + 1e-9)
    assert np.all(np.asarray(on.ttft) <= np.asarray(off.ttft) + 1e-6)
    assert float(jnp.mean(on.rt)) <= float(jnp.mean(off.rt)) + 1e-6


def test_prefix_cache_requires_open_loop():
    with pytest.raises(AssertionError):
        EvalConfig(mode="queued", prefix_cache=True)


# ---------------------------------------------------------------------------
# router affinity mode
# ---------------------------------------------------------------------------
def test_router_affinity_mode_sticks_to_cached_node(session_trace, cluster):
    router = RequestRouter(cluster, np.zeros(6), mode="affinity")
    # serve each session's first turn, recording prefix residency like the
    # cluster scheduler does on dispatch
    placed = {}
    for req in session_trace.requests:
        d = router.route(req)
        blk = router.cache_block
        router.monitor.record_prefix(d.node, ("sess", req.session_id),
                                     req.prompt_tokens // blk * blk)
        if req.sys_id >= 0:
            router.monitor.record_prefix(d.node, ("sys", req.sys_id),
                                         req.sys_tokens // blk * blk)
        if req.turn > 0 and req.session_id in placed:
            # later turns overwhelmingly land where the session's KV lives
            placed.setdefault("later", []).append(
                d.node == placed[req.session_id])
        placed[req.session_id] = d.node
    later = placed.get("later", [])
    assert later and np.mean(later) >= 0.7, np.mean(later)


def test_router_affinity_reoptimize_installs_genome(session_trace, cluster):
    """The rolling-horizon re-fit must search the [γ, κ, ρ] affinity genome
    (with the cache modeled, since the recorded window carries sessions +
    arrivals) and install the selected parameters."""
    router = RequestRouter(cluster, np.zeros(6), mode="affinity")
    ev = TraceEvaluator(session_trace, cluster,
                        EvalConfig(mode="open", prefix_cache=True))
    res = ev.run_affinity_policy(AFFINITY_DEFAULTS)
    q = np.asarray(res.q); c = np.asarray(res.cost); rt = np.asarray(res.rt)
    for i, req in enumerate(session_trace.requests):
        d = router.route(req)
        router.record(req, d, quality=float(q[i]), cost=float(c[i]),
                      rt=float(rt[i]),
                      now=float(session_trace.arrival_time[i]),
                      ttft_deadline=float(session_trace.ttft_deadline[i]),
                      tpot_deadline=float(session_trace.tpot_deadline[i]))
    params = router.maybe_reoptimize(force=True, window=64, generations=3,
                                     pop_size=8, seed=0)
    assert params is not None and params.shape == (3,)
    assert np.array_equal(params, router.affinity_params)
