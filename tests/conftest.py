"""Shared test fixtures/helpers (deduplicated from the per-file copies).

Two access styles, because pytest fixtures cannot feed module-level
constants or ``@pytest.mark.parametrize`` expressions:

* **importable helpers** — ``from conftest import shared_cluster, ...`` for
  module scope (the testbed is built once per process via ``lru_cache``);
* **fixtures** — ``cluster`` / ``arrays`` / ``session_trace`` / ``rng`` for
  ordinary per-test injection.
"""
from __future__ import annotations

import functools

import numpy as np
import pytest

from repro.cluster.spec import paper_testbed


@functools.lru_cache(maxsize=None)
def shared_cluster():
    """The paper's 4-node testbed, built once per test process."""
    return paper_testbed()


@functools.lru_cache(maxsize=None)
def shared_arrays():
    """`shared_cluster().to_arrays()`, cached (device constants)."""
    return shared_cluster().to_arrays()


def make_session_trace(n_requests=None, seed=1, n_sessions=10,
                       mean_turns=3.0, tightness=2.0):
    """Multi-turn session trace with SLOs attached — the shared workload of
    the policy/online/session test modules."""
    from repro.workload.sessions import SessionConfig, build_session_trace
    from repro.workload.slo import attach_slos

    tr = build_session_trace(
        SessionConfig(n_sessions=n_sessions, mean_turns=mean_turns),
        seed=seed, n_requests=n_requests)
    attach_slos(tr, tightness=tightness, seed=seed)
    return tr


@pytest.fixture(scope="session")
def cluster():
    return shared_cluster()


@pytest.fixture(scope="session")
def arrays():
    return shared_arrays()


@pytest.fixture(scope="session")
def session_trace():
    """The historical test_sessions workload (n_sessions=10, seed=3)."""
    return make_session_trace(seed=3, tightness=2.0)


@pytest.fixture
def make_trace():
    """Factory fixture: build session traces with explicit sizes/seeds."""
    return make_session_trace


@pytest.fixture
def rng():
    """Deterministic per-test RNG."""
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# deterministic fault-schedule presets (shared by the chaos/fault tests)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def crash_storm_schedule(n_nodes=4, seed=0, horizon=60.0, spare=1):
    """Seeded repeated-crash preset (node 0..spare-1 never crash)."""
    from repro.faults import FaultSchedule
    return FaultSchedule.crash_storm(n_nodes, seed=seed, horizon=horizon,
                                     spare=spare)


@functools.lru_cache(maxsize=None)
def link_flap_schedule(seed=0, horizon=60.0, factor=20.0):
    """Seeded KV-link degradation preset."""
    from repro.faults import FaultSchedule
    return FaultSchedule.link_flap(seed=seed, horizon=horizon, factor=factor)


@functools.lru_cache(maxsize=None)
def straggler_schedule(n_nodes=4, seed=0, horizon=60.0, factor=4.0):
    """Seeded straggler-slowdown preset."""
    from repro.faults import FaultSchedule
    return FaultSchedule.straggler_storm(n_nodes, seed=seed, horizon=horizon,
                                         factor=factor)


@functools.lru_cache(maxsize=None)
def targeted_crash_schedule(node, start=1.0, end=10.0 ** 9):
    """Deterministic single-node crash window (endpoint-death scenarios)."""
    from repro.faults import CrashWindow, FaultSchedule
    return FaultSchedule(crashes=(CrashWindow(node, start, end),))


@pytest.fixture
def fault_schedule():
    """Factory fixture over the shared presets: ``fault_schedule("crash")``,
    ``("flap")``, ``("straggler")`` — deterministic per (kind, seed)."""
    def make(kind="crash", **kw):
        if kind == "crash":
            return crash_storm_schedule(**kw)
        if kind == "flap":
            return link_flap_schedule(**kw)
        if kind == "straggler":
            return straggler_schedule(**kw)
        raise ValueError(f"unknown fault preset {kind!r}")
    return make
