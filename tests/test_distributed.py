"""Distribution layer: sharding rules, mesh construction, and a reduced
multi-device dry-run — run in subprocesses so the 8 fabricated host devices
never leak into the main test process."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.models import lm
from repro.models import sharding as shard

REPO = Path(__file__).resolve().parent.parent


def _run_subprocess(code: str) -> str:
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900, check=False).stdout


# ---------------------------------------------------------------------------
# sharding rules (pure functions, no devices needed)
# ---------------------------------------------------------------------------
def _fake_mesh():
    # an abstract mesh object is enough for spec derivation; the
    # AbstractMesh signature changed across jax releases (axis_sizes +
    # axis_names vs a tuple of (name, size) pairs), so accept either
    try:
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))


def test_param_specs_cover_all_leaves_and_divide():
    mesh = _fake_mesh()
    for arch in ("qwen3-4b", "dbrx-132b", "jamba-v0.1-52b", "xlstm-1.3b",
                 "whisper-tiny"):
        cfg = get(arch).config()
        params = jax.eval_shape(lambda k, c=cfg: lm.init(k, c),
                                jax.random.key(0))
        specs = shard.param_specs(cfg, params, mesh, mode="train")
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        assert len(flat_p) == len(flat_s), arch
        for leaf, spec in zip(flat_p, flat_s):
            for dim, axis in zip(leaf.shape, tuple(spec)):
                if axis is None:
                    continue
                size = (np.prod([mesh.shape[a] for a in axis])
                        if isinstance(axis, tuple) else mesh.shape[axis])
                assert dim % size == 0, (arch, leaf.shape, spec)


def test_serve_specs_replicate_fsdp_for_small_archs():
    mesh = _fake_mesh()
    cfg = get("qwen3-1.7b").config()   # 2B: serving replicates over data
    params = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.key(0))
    specs = shard.param_specs(cfg, params, mesh, mode="serve")
    for spec in jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)):
        assert "data" not in [a for a in tuple(spec) if a is not None]


def test_big_arch_serve_specs_keep_fsdp():
    mesh = _fake_mesh()
    cfg = get("dbrx-132b").config()    # 132B: must shard over data too
    params = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.key(0))
    specs = shard.param_specs(cfg, params, mesh, mode="serve")
    axes = set()
    for spec in jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)):
        axes.update(a for a in tuple(spec) if a is not None)
    assert "data" in axes


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shard.constrain(x, "dp", None) is x


# ---------------------------------------------------------------------------
# multi-device (8 fabricated devices, subprocess)
# ---------------------------------------------------------------------------
def test_make_production_mesh_shapes():
    out = _run_subprocess("""
        import jax
        from repro.launch.mesh import make_production_mesh
        # reduced: 8 devices -> (4, 2) and (2, 2, 2)
        m = jax.make_mesh((4, 2), ("data", "model"))
        print(dict(m.shape))  # dict(): repr is stable across jax versions
        m2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        print(dict(m2.shape))
    """)
    assert "'data': 4" in out and "'model': 2" in out
    assert "'pod': 2" in out


def test_sharded_train_step_compiles_and_runs_8dev():
    """End-to-end: jit train step with FSDP×TP specs on 8 devices,
    numerically matching the single-device step."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get
        from repro.models import lm, sharding as shard
        from repro.training.optim import adamw, OptConfig

        cfg = get("stablelm-3b").smoke()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = lm.init(jax.random.key(0), cfg)
        opt = adamw(OptConfig(lr=1e-3))
        state = opt.init(params)
        tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}

        def step(p, s, b):
            def loss(pp):
                l, _ = lm.loss_fn(pp, cfg, b)
                return l
            l, g = jax.value_and_grad(loss)(p)
            np_, ns = opt.update(g, s, p)
            return np_, ns, l

        # single device reference
        p1, s1, l1 = jax.jit(step)(params, state, batch)

        # sharded
        pspecs = shard.param_specs(cfg, params, mesh, mode="train")
        psh = shard.to_shardings(mesh, pspecs)
        params_sh = jax.device_put(params, psh)
        with shard.activation_mesh(mesh):
            p2, s2, l2 = jax.jit(step)(params_sh, state, batch)
        print("loss_single", float(l1))
        print("loss_sharded", float(l2))
        assert abs(float(l1) - float(l2)) < 5e-2, (float(l1), float(l2))
        print("OK")
    """)
    assert "OK" in out, out


def test_dryrun_cell_reduced_mesh():
    """The dry-run machinery end-to-end on a small fabricated mesh."""
    out = _run_subprocess("""
        import jax, dataclasses
        from repro.configs import get
        from repro.launch import dryrun
        from repro.models import sharding as shard

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get("qwen3-1.7b").config(), n_layers=4)
        fn, args, outsh, extra = dryrun.build_cell(cfg, "train_4k", mesh,
                                                   unroll=False)
        with shard.activation_mesh(mesh), mesh:
            jitted = jax.jit(fn, out_shardings=outsh)
            compiled = jitted.lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax returns [dict]
            cost = cost[0]
        print("flops", cost.get("flops", 0) > 0)
        coll = dryrun.collective_bytes(compiled.as_text())
        print("has_collectives", coll["total_bytes"] > 0)
    """)
    assert "flops True" in out, out
    assert "has_collectives True" in out, out


def test_grad_compression_psum_8dev():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.training.grad_compress import init_residual, psum_compressed
        try:
            shard_map = jax.shard_map
        except AttributeError:  # older jax keeps it in experimental
            from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ("pod",))
        grads = {"w": jnp.arange(512, dtype=jnp.float32).reshape(2, 256) / 77}
        res = init_residual(grads)

        @partial(shard_map, mesh=mesh, in_specs=(), out_specs=P())
        def reduce_plain():
            return jax.tree.map(lambda g: jax.lax.psum(g, "pod") / 8, grads)

        @partial(shard_map, mesh=mesh, in_specs=(), out_specs=P())
        def reduce_q():
            m, r = psum_compressed(grads, res, "pod", method="int8")
            return m

        a = reduce_plain()
        b = reduce_q()
        err = float(jnp.max(jnp.abs(a["w"] - b["w"])))
        rel = err / float(jnp.max(jnp.abs(a["w"])))
        print("rel_err", rel)
        assert rel < 0.02
        print("OK")
    """)
    assert "OK" in out, out
