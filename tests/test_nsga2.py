"""Tests for the vectorized NSGA-II engine (operators + convergence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # soft optional dep

from repro.core.nsga2 import (NSGA2, NSGA2Config, binary_tournament,
                              polynomial_mutation, reassignment_mutation,
                              sbx_crossover, survival_select,
                              uniform_swap_crossover)


def test_sbx_respects_bounds_and_prob_zero_identity():
    key = jax.random.key(0)
    lo, hi = jnp.zeros(8), jnp.ones(8)
    p1 = jax.random.uniform(jax.random.key(1), (16, 8))
    p2 = jax.random.uniform(jax.random.key(2), (16, 8))
    c1, c2 = sbx_crossover(key, p1, p2, lo, hi, pc=0.0, eta=15.0)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(p2))
    c1, c2 = sbx_crossover(key, p1, p2, lo, hi, pc=1.0, eta=15.0)
    for c in (c1, c2):
        assert (np.asarray(c) >= 0).all() and (np.asarray(c) <= 1).all()


def test_sbx_preserves_parent_mean_per_gene():
    # SBX children are symmetric around the parent mean where applied
    key = jax.random.key(3)
    lo, hi = jnp.full(4, -10.0), jnp.full(4, 10.0)
    p1 = jnp.array([[1.0, 2.0, 3.0, 4.0]])
    p2 = jnp.array([[2.0, 1.0, 5.0, 0.0]])
    c1, c2 = sbx_crossover(key, p1, p2, lo, hi, pc=1.0, eta=20.0)
    np.testing.assert_allclose(np.asarray(c1 + c2), np.asarray(p1 + p2),
                               rtol=1e-5)


@given(st.integers(0, 10 ** 6), st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_polynomial_mutation_bounds(seed, pm):
    key = jax.random.key(seed)
    x = jax.random.uniform(jax.random.key(seed + 1), (10, 5))
    out = polynomial_mutation(key, x, jnp.zeros(5), jnp.ones(5), pm, 20.0)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) <= 1).all()


def test_uniform_swap_is_permutation_of_genes():
    key = jax.random.key(0)
    p1 = jnp.arange(12, dtype=jnp.int32).reshape(2, 6)
    p2 = p1 + 100
    c1, c2 = uniform_swap_crossover(key, p1, p2, pc=1.0)
    # at every gene position the multiset {c1, c2} == {p1, p2}
    same = (jnp.minimum(c1, c2) == jnp.minimum(p1, p2)) & \
           (jnp.maximum(c1, c2) == jnp.maximum(p1, p2))
    assert bool(same.all())


def test_reassignment_mutation_stays_in_range():
    key = jax.random.key(0)
    x = jnp.zeros((8, 20), jnp.int32)
    out = reassignment_mutation(key, x, pm=1.0, n_choices=7)
    o = np.asarray(out)
    assert (o >= 0).all() and (o < 7).all()


def test_binary_tournament_prefers_better_rank():
    rank = jnp.array([0, 5], jnp.int32)
    crowd = jnp.array([1.0, 1.0])
    winners = binary_tournament(jax.random.key(0), rank, crowd, 256)
    # index 0 strictly better: it must win every tournament it appears in;
    # expected win share is 3/4 (wins unless both draws are index 1)
    share = float(jnp.mean((winners == 0).astype(jnp.float32)))
    assert share > 0.6


def test_survival_select_keeps_nondominated():
    # 4 points where 2 dominate the other 2 -> survivors must be the dominators
    F = jnp.array([[0.1, 0.1], [0.2, 0.2], [0.9, 0.9], [1.0, 1.0]])
    sel, rank, crowd = survival_select(F, 2)
    assert set(np.asarray(sel).tolist()) == {0, 1}


def _zdt1_fitness(genomes, key):
    f1 = genomes[:, 0]
    g = 1 + 9 * jnp.mean(genomes[:, 1:], axis=1)
    f2 = g * (1 - jnp.sqrt(f1 / g))
    return jnp.stack([f1, f2], axis=1), jnp.zeros(genomes.shape[0])


def test_nsga2_converges_on_zdt1():
    # 90 generations: 60 leaves g.mean ≈ 1.5 (marginal) on this jax version's
    # RNG stream; 90 converges decisively (g.mean ≈ 1.09)
    D = 8
    cfg = NSGA2Config(pop_size=48, n_generations=90, lo=jnp.zeros(D),
                      hi=jnp.ones(D))
    opt = NSGA2(_zdt1_fitness, cfg)
    state = opt.evolve_scan(jax.random.key(0), 90)
    g = 1 + 9 * np.mean(np.asarray(state.genomes)[:, 1:], axis=1)
    assert g.mean() < 1.5  # optimum g = 1
    # front should span f1 (diversity via crowding)
    front = np.asarray(state.F_raw)[np.asarray(state.rank) == 0]
    assert front[:, 0].max() - front[:, 0].min() > 0.5


def test_nsga2_penalty_excludes_infeasible():
    # violation > 0 on half the space: survivors should be feasible
    def fit(genomes, key):
        F = jnp.stack([genomes[:, 0], 1 - genomes[:, 0]], axis=1)
        viol = jnp.where(genomes[:, 1] > 0.5, genomes[:, 1], 0.0)
        return F, viol

    cfg = NSGA2Config(pop_size=32, n_generations=30, lo=jnp.zeros(2),
                      hi=jnp.ones(2))
    opt = NSGA2(fit, cfg)
    state = opt.evolve_scan(jax.random.key(1), 30)
    genomes, front = opt.pareto_front(state)
    assert front.shape[0] > 0
    assert (np.asarray(genomes)[:, 1] <= 0.5 + 1e-6).all()


def test_pallas_dominance_flag_matches_reference():
    """use_pallas_dominance must produce the exact same evolution as the jnp
    reference sort (the flag was stored-but-dead before; interpret-mode
    kernel on CPU)."""
    D = 6
    cfg = NSGA2Config(pop_size=16, n_generations=6, lo=jnp.zeros(D),
                      hi=jnp.ones(D))
    ref = NSGA2(_zdt1_fitness, cfg).evolve_scan(jax.random.key(0), 6)
    pal = NSGA2(_zdt1_fitness, cfg,
                use_pallas_dominance=True).evolve_scan(jax.random.key(0), 6)
    np.testing.assert_allclose(np.asarray(ref.F_raw), np.asarray(pal.F_raw),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref.rank), np.asarray(pal.rank))
    np.testing.assert_allclose(np.asarray(ref.genomes),
                               np.asarray(pal.genomes), rtol=1e-6)


def _discrete_fitness(genomes, key):
    m = jnp.mean(genomes.astype(jnp.float32), axis=1)
    return jnp.stack([m, -m], axis=1), jnp.zeros(genomes.shape[0])


def test_discrete_default_init_uses_genome_length():
    """Regression: the default discrete init hardcoded D=1, silently
    optimizing a single gene per individual."""
    n_requests = 53
    cfg = NSGA2Config(pop_size=8, n_generations=2, genome="discrete",
                      n_choices=7, genome_length=n_requests)
    opt = NSGA2(_discrete_fitness, cfg)
    state = opt.init(jax.random.key(0))
    assert state.genomes.shape == (8, n_requests)
    g = np.asarray(state.genomes)
    assert (g >= 0).all() and (g < 7).all()
    # and the genes are not all identical within an individual (D>1 entropy)
    assert any(len(np.unique(g[i])) > 1 for i in range(8))
    # evolution preserves the shape
    state = opt.evolve_scan(jax.random.key(0), 2)
    assert state.genomes.shape == (8, n_requests)


def test_discrete_init_without_length_raises():
    cfg = NSGA2Config(pop_size=4, n_generations=1, genome="discrete",
                      n_choices=3)
    with pytest.raises(AssertionError):
        NSGA2(_discrete_fitness, cfg).init(jax.random.key(0))


def test_evolve_matches_evolve_scan():
    D = 4
    cfg = NSGA2Config(pop_size=16, n_generations=5, lo=jnp.zeros(D),
                      hi=jnp.ones(D))
    opt = NSGA2(_zdt1_fitness, cfg)
    s1 = opt.evolve(jax.random.key(7), 5)
    s2 = opt.evolve_scan(jax.random.key(7), 5)
    np.testing.assert_allclose(np.asarray(s1.F_raw), np.asarray(s2.F_raw),
                               rtol=1e-5, atol=1e-6)
