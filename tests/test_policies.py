"""RoutingPolicy registry tests: registry-wide jnp≡py decision equivalence,
the error/deprecation surface, grep-enforced absence of string dispatch in
the consumer layers, masked-tail invariance + NSGA-II fit + router re-fit
for every registered policy (including the two shipped through the registry:
p2c-hedge and budget), and the compile-once regression (one ``_run_trace``
trace per policy identity across re-fit windows)."""
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # soft optional dep
from conftest import make_session_trace, shared_arrays, shared_cluster

from repro.core import nsga2 as nsga2_mod
from repro.core.fitness import EvalConfig, TraceEvaluator, _run_trace
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.policies import (PolicyInputs, get_policy, list_policies,
                                 runtime_policies)
from repro.core.policies.budget import WINDOW_S, BudgetPolicy
from repro.core.router import RequestRouter
from repro.workload.slo import attach_slos
from repro.workload.trace import build_trace

CLUSTER = shared_cluster()
ARRAYS = shared_arrays()
REPO = Path(__file__).resolve().parent.parent


def _random_inputs(rng, n_genes_direct=32, index=None):
    n_pairs, n_nodes = ARRAYS.n_pairs, ARRAYS.n_nodes
    i = int(rng.integers(0, n_genes_direct)) if index is None else index
    return PolicyInputs(
        index=np.int32(i), now=np.float32(rng.uniform(0.0, 200.0)),
        complexity=np.float32(rng.random()),
        pred_category=np.int32(rng.integers(0, 3)),
        pred_conf=np.float32(rng.random()),
        ttft_deadline=np.float32(rng.uniform(0.1, 5.0)),
        tpot_deadline=np.float32(rng.uniform(0.05, 1.0)),
        prompt_tokens=np.float32(rng.integers(8, 512)),
        up=rng.uniform(0, 1, n_pairs).astype(np.float32),
        prefill=rng.uniform(0, 2, n_pairs).astype(np.float32),
        tpot=rng.uniform(0.04, 0.3, n_pairs).astype(np.float32),
        cost=rng.uniform(0, 1e-3, n_pairs).astype(np.float32),
        prompt_cost=rng.uniform(0, 5e-4, n_pairs).astype(np.float32),
        hit_frac=rng.uniform(0, 1, n_pairs).astype(np.float32),
        queue_len=rng.integers(0, 10, n_nodes),
        kv_bytes=np.float32(rng.uniform(0.0, 2e6)),
        quality=rng.uniform(0, 1, n_pairs).astype(np.float32),
        unc=rng.uniform(0, 1, n_pairs).astype(np.float32))


def _random_genome(pol, rng, n_genes_direct=32):
    spec = pol.genome_spec
    if spec.per_request:
        return rng.integers(0, ARRAYS.n_pairs,
                            n_genes_direct).astype(np.int32)
    return rng.uniform(spec.lo, spec.hi).astype(np.float32)


def _random_state(pol, rng):
    if pol.state_size == 0:
        return pol.init_state()
    # exercise both fresh-window and in-window ledgers
    return np.array([float(rng.integers(-1, 6)),
                     float(rng.uniform(0, 0.05))], np.float32)[:pol.state_size]


# ---------------------------------------------------------------------------
# registry-wide decision equivalence: decide_jnp == decide_py for EVERY
# registered policy on randomized inputs (new policies get this for free via
# the parametrization over list_policies())
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", list_policies())
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_decide_jnp_matches_py_for_every_policy(policy, seed):
    pol = get_policy(policy)
    rng = np.random.default_rng(seed)
    genome = _random_genome(pol, rng)
    state = _random_state(pol, rng)
    inp = _random_inputs(rng)
    want = pol.decide_py(genome, inp, ARRAYS, state)
    jnp_inp = PolicyInputs(*(jnp.asarray(v) for v in inp))
    got = int(pol.decide_jnp(jnp.asarray(genome), jnp_inp, ARRAYS,
                             jnp.asarray(state, jnp.float32)))
    assert want == got
    # route-valued policies index the route table, pair-valued the pair table
    n_out = ARRAYS.n_routes if pol.decides == "route" else ARRAYS.n_pairs
    assert 0 <= got < n_out


@pytest.mark.parametrize("policy", list_policies())
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_update_jnp_matches_py_for_every_policy(policy, seed):
    """State transitions must agree too (exactly, in float32)."""
    pol = get_policy(policy)
    rng = np.random.default_rng(seed)
    genome = _random_genome(pol, rng)
    state = _random_state(pol, rng)
    inp = _random_inputs(rng)
    pair = int(rng.integers(0, ARRAYS.n_pairs))
    cost = float(rng.uniform(0, 1e-3))
    want = np.asarray(pol.update_py(genome, state, inp, pair, cost),
                      np.float32)
    jnp_inp = PolicyInputs(*(jnp.asarray(v) for v in inp))
    got = np.asarray(pol.update_jnp(jnp.asarray(genome),
                                    jnp.asarray(state, jnp.float32),
                                    jnp_inp, jnp.int32(pair),
                                    jnp.float32(cost)), np.float32)
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("policy", list_policies())
def test_decide_jnp_matches_py_fixed_seeds(policy):
    """Deterministic mini-sweep of the same property (runs even without
    hypothesis installed)."""
    pol = get_policy(policy)
    for seed in range(8):
        rng = np.random.default_rng(seed)
        genome = _random_genome(pol, rng)
        state = _random_state(pol, rng)
        inp = _random_inputs(rng)
        want = pol.decide_py(genome, inp, ARRAYS, state)
        jnp_inp = PolicyInputs(*(jnp.asarray(v) for v in inp))
        got = int(pol.decide_jnp(jnp.asarray(genome), jnp_inp, ARRAYS,
                                 jnp.asarray(state, jnp.float32)))
        assert want == got, (policy, seed)


# ---------------------------------------------------------------------------
# error surface + legacy-name removal
# ---------------------------------------------------------------------------
def test_unknown_policy_raises_value_error_listing_names():
    tr = build_trace(8, seed=0)
    ev = TraceEvaluator(tr, CLUSTER)
    with pytest.raises(ValueError) as ei:
        ev.make_fitness("no-such-policy")
    for name in list_policies():
        assert name in str(ei.value)
    with pytest.raises(ValueError) as ei:
        RequestRouter(CLUSTER, mode="no-such-mode")
    assert "threshold" in str(ei.value)
    with pytest.raises(ValueError):
        ev.run_policy("no-such-policy", np.zeros(3))


def test_per_request_policy_rejected_by_router():
    with pytest.raises(ValueError) as ei:
        RequestRouter(CLUSTER, mode="direct")
    assert "per-request" in str(ei.value)
    assert "p2c-hedge" in str(ei.value)   # runtime-capable set is listed


def test_legacy_genome_strings_are_gone():
    """The "continuous"/"discrete" alias shims are removed: legacy names
    fail like any other unknown policy (ValueError listing the registry),
    and canonical names resolve warning-free."""
    tr = build_trace(8, seed=0)
    attach_slos(tr, seed=0)
    ev = TraceEvaluator(tr, CLUSTER)
    for legacy in ("continuous", "discrete"):
        with pytest.raises(ValueError) as ei:
            ev.make_fitness(legacy)
        assert "threshold" in str(ei.value)   # registry names are listed
        with pytest.raises(ValueError):
            RequestRouter(CLUSTER, mode=legacy)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ev.make_fitness("slo")
        RequestRouter(CLUSTER, mode="slo")


# ---------------------------------------------------------------------------
# grep-enforced: no string-dispatch branches remain in the consumer layers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("relpath", [
    "src/repro/core/fitness.py",
    "src/repro/core/router.py",
    "src/repro/cluster/simulator.py",
])
def test_no_policy_string_dispatch_in_consumer_layers(relpath):
    text = (REPO / relpath).read_text()
    hits = re.findall(r".*(?:policy|mode|genome)\s*==\s*[\"'].*", text)
    assert not hits, (f"{relpath} still string-dispatches on policy/mode: "
                      f"{hits}")


# ---------------------------------------------------------------------------
# masked-tail invariance (bucketed eval) for every registered policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", list_policies())
def test_masked_tail_invariance_every_policy(policy):
    pol = get_policy(policy)
    tr = make_session_trace(n_requests=50, seed=2)
    cfg = EvalConfig(mode="open", prefix_cache=True,
                     disaggregated=pol.decides == "route")
    plain = TraceEvaluator(tr, CLUSTER, cfg)
    padded = TraceEvaluator(tr, CLUSTER, cfg, bucket="pow2")
    genome = _random_genome(pol, np.random.default_rng(0),
                            n_genes_direct=tr.n_requests)
    a = plain.run_policy(policy, genome)
    b = padded.run_policy(policy, genome)
    assert (np.asarray(a.assign) == np.asarray(b.assign)).all()
    for f in ("q", "cost", "rt", "ttft", "hit", "transfer"):
        np.testing.assert_allclose(np.asarray(getattr(a, f)),
                                   np.asarray(getattr(b, f)), err_msg=f)
    np.testing.assert_allclose(float(a.violation), float(b.violation))


# ---------------------------------------------------------------------------
# NSGA-II end-to-end through the registry-derived genome spec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["p2c-hedge", "budget"])
def test_new_policies_nsga2_fit_end_to_end(policy):
    """The two policies shipped through the registry must be searchable with
    a config derived from their GenomeSpec and runnable end-to-end."""
    pol = get_policy(policy)
    tr = make_session_trace(n_requests=48, seed=3)
    ev = TraceEvaluator(tr, CLUSTER,
                        EvalConfig(mode="open", prefix_cache=True),
                        bucket="pow2")
    cfg = NSGA2Config.from_policy(pol, pop_size=8, n_generations=3)
    assert cfg.n_genes == pol.genome_spec.length
    opt = NSGA2(ev.make_fitness(policy, objectives="qoe"), cfg)
    state = opt.evolve_scan(jax.random.key(0), 3)
    genome, F = opt.select_by_weights(state, jnp.full((4,), 0.25))
    lo, hi = pol.genome_spec.lo, pol.genome_spec.hi
    g = np.asarray(genome)
    assert g.shape == (pol.genome_spec.length,)
    assert (g >= lo - 1e-6).all() and (g <= hi + 1e-6).all()
    res = ev.run_policy(policy, genome)
    assert np.asarray(res.assign).shape == (tr.n_requests,)


def test_from_policy_derives_bounds_and_length():
    cfg = NSGA2Config.from_policy("slo", pop_size=8, n_generations=2)
    assert cfg.n_genes == 2
    np.testing.assert_allclose(np.asarray(cfg.lo),
                               get_policy("slo").genome_spec.lo)
    cfg = NSGA2Config.from_policy("direct", pop_size=8, n_generations=2,
                                  genome_length=40,
                                  n_choices=ARRAYS.n_pairs)
    assert cfg.genome == "discrete" and cfg.n_genes == 40


# ---------------------------------------------------------------------------
# router: every runtime policy routes, fails over, and re-fits
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", runtime_policies())
def test_router_reoptimize_installs_registry_genome(policy):
    pol = get_policy(policy)
    tr = make_session_trace(n_requests=64, seed=4)
    router = RequestRouter(CLUSTER, mode=policy)
    for i, req in enumerate(tr.requests):
        d = router.route(req)
        router.record(req, d, quality=0.5, cost=1e-4, rt=1.0,
                      now=float(tr.arrival_time[i]),
                      ttft_deadline=float(tr.ttft_deadline[i]),
                      tpot_deadline=float(tr.tpot_deadline[i]))
    params = router.maybe_reoptimize(force=True, window=64, generations=3,
                                     pop_size=8, seed=0)
    assert params is not None
    assert params.shape == (pol.genome_spec.length,)
    np.testing.assert_array_equal(params, router.params)
    lo, hi = pol.genome_spec.lo, pol.genome_spec.hi
    assert (params >= lo - 1e-6).all() and (params <= hi + 1e-6).all()


# ---------------------------------------------------------------------------
# compile-once: re-fits across windows add no new _run_trace traces beyond
# one per policy identity
# ---------------------------------------------------------------------------
def test_refit_one_trace_per_policy_identity():
    cfgs = {p: NSGA2Config.from_policy(p, pop_size=8, n_generations=2)
            for p in ("slo", "p2c-hedge", "budget")}

    def refit(policy, n, seed):
        tr = build_trace(n, seed=seed)
        attach_slos(tr, seed=seed)
        ev = TraceEvaluator(tr, CLUSTER, EvalConfig(concurrency=4),
                            bucket="pow2")
        opt = NSGA2(ev.make_fitness(policy, objectives="qoe"), cfgs[policy])
        return jax.block_until_ready(
            opt.evolve_scan(jax.random.key(seed), 2).genomes)

    for p in cfgs:
        refit(p, 70, 0)                     # first re-fit per policy compiles
    traces_before = _run_trace._cache_size()
    runs_before = nsga2_mod._nsga2_run._cache_size()
    for p in cfgs:                          # new windows, same pow2 bucket
        refit(p, 90, 1)
        refit(p, 100, 2)
    assert _run_trace._cache_size() == traces_before, \
        "re-fit across windows retraced _run_trace for an existing policy"
    assert nsga2_mod._nsga2_run._cache_size() == runs_before, \
        "re-fit across windows retraced the NSGA-II run"


# ---------------------------------------------------------------------------
# behavioural checks for the two new policies
# ---------------------------------------------------------------------------
def test_budget_ledger_windows_and_resets():
    pol = BudgetPolicy()
    rng = np.random.default_rng(0)
    genome = np.asarray(pol.genome_spec.defaults)
    state = pol.init_state()
    inp0 = _random_inputs(rng)._replace(now=np.float32(1.0))
    s1 = pol.update_py(genome, state, inp0, 2, 0.0)
    assert s1[0] == 0.0 and s1[1] == np.float32(inp0.cost[2])
    # same window accumulates
    inp1 = inp0._replace(now=np.float32(WINDOW_S - 1.0))
    s2 = pol.update_py(genome, s1, inp1, 3, 0.0)
    assert s2[1] == np.float32(s1[1] + np.float32(inp1.cost[3]))
    # next window resets the ledger
    inp2 = inp0._replace(now=np.float32(WINDOW_S + 1.0))
    s3 = pol.update_py(genome, s2, inp2, 3, 0.0)
    assert s3[0] == 1.0 and s3[1] == np.float32(inp2.cost[3])


def test_budget_cap_reduces_spend_vs_loose_budget():
    tr = make_session_trace(n_requests=80, seed=5)
    ev = TraceEvaluator(tr, CLUSTER,
                        EvalConfig(mode="open", prefix_cache=True))
    tight = ev.run_policy("budget", [1e-4, 0.9, 3.0])
    loose = ev.run_policy("budget", [10.0, 0.9, 3.0])
    assert float(jnp.sum(tight.cost)) < float(jnp.sum(loose.cost))
    # exhausted ledger falls back to the globally cheapest pair, so tight
    # budgets concentrate on the cheapest pairs rather than dropping traffic
    assert np.asarray(tight.assign).shape == (tr.n_requests,)


def test_des_policy_run_conserves_node_busy_time():
    """Regression: the policy-decided DES path must accumulate
    node_busy_time exactly like the fixed-assignment path (the in-loop
    busy-slot probe must not clobber the accumulator)."""
    from repro.cluster.simulator import ClusterSimulator
    tr = make_session_trace(n_requests=50, seed=8)
    sim = ClusterSimulator(tr, CLUSTER, prefix_cache=True)
    g = get_policy("slo").genome_spec.defaults
    by_policy = sim.run(policy="slo", genome=g)
    replay = sim.run(assign=by_policy.assign)
    np.testing.assert_allclose(by_policy.node_busy_time,
                               replay.node_busy_time)
    assert by_policy.node_busy_time.sum() > 0


def test_router_budget_ledger_bills_failover_pair():
    """Regression: with the policy-chosen node down, the spend ledger must
    bill the pair actually dispatched after failover, not the dead one."""
    router = RequestRouter(CLUSTER, mode="budget")
    req = build_trace(4, seed=0).requests[0]
    d0 = router.route(req, now=0.0)        # healthy: establishes baseline
    assert router._pstate[1] > 0
    router2 = RequestRouter(CLUSTER, mode="budget")
    router2.monitor.mark_down(d0.node)     # kill the chosen node
    d1 = router2.route(req, now=0.0)
    assert d1.node != d0.node
    # ledger reflects the dispatched pair's cost row, not the dead pair's
    from repro.core.fitness import request_pair_estimates
    cost = request_pair_estimates(req.prompt_tokens, req.resp_tokens_mean,
                                  req.query_bytes, router2._np_arrays)["cost"]
    assert router2._pstate[1] == np.float32(cost[d1.pair])


def test_p2c_spreads_load_and_is_deterministic():
    tr = make_session_trace(n_requests=80, seed=6)
    ev = TraceEvaluator(tr, CLUSTER,
                        EvalConfig(mode="open", prefix_cache=True))
    g = get_policy("p2c-hedge").genome_spec.defaults
    a = np.asarray(ev.run_policy("p2c-hedge", g).assign)
    b = np.asarray(ev.run_policy("p2c-hedge", g).assign)
    np.testing.assert_array_equal(a, b)
    # two-choice sampling over the pair table must actually spread traffic
    assert len(np.unique(a)) >= 3
