"""Serving substrate: continuous-batching engine exactness + cluster server
fault tolerance (failover, hedging) with real tiny models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.spec import paper_testbed
from repro.configs import get
from repro.core.policy import PAPER_DEFAULTS
from repro.models import lm
from repro.serving import ClusterServer, EngineConfig, LLMEngine, ServeRequest
from repro.workload.trace import build_trace


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get("stablelm-3b").smoke()
    params = lm.init(jax.random.key(0), cfg)
    return cfg, params


def _greedy_reference(cfg, params, tokens, n_new):
    """Offline greedy generation via repeated full forward passes."""
    toks = list(tokens)
    out = []
    for _ in range(n_new):
        logits, _ = lm.train_logits(params, cfg,
                                    {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_offline_greedy(tiny_model):
    cfg, params = tiny_model
    eng = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                              max_new_tokens=6))
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, size=8 + i) for i in range(2)}
    for i, p in prompts.items():
        eng.submit(i, p, max_new_tokens=6)
    results = eng.run_to_completion()
    for i, p in prompts.items():
        want = _greedy_reference(cfg, params, p, 6)
        assert results[i]["tokens"] == want, i


def test_engine_continuous_batching_admits_from_queue(tiny_model):
    cfg, params = tiny_model
    eng = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                              max_new_tokens=4))
    rng = np.random.default_rng(1)
    for i in range(6):  # 6 requests through 2 slots
        eng.submit(i, rng.integers(0, cfg.vocab, size=6))
    assert eng.active_count == 2 and eng.queue_len == 6
    results = eng.run_to_completion()
    assert sorted(results) == list(range(6))
    assert all(len(r["tokens"]) == 4 for r in results.values())


def test_engine_ragged_lengths_independent(tiny_model):
    """A long-prompt slot must not perturb a short-prompt slot's output."""
    cfg, params = tiny_model
    rng = np.random.default_rng(2)
    short = rng.integers(0, cfg.vocab, size=5)
    long = rng.integers(0, cfg.vocab, size=37)

    solo = LLMEngine(cfg, params, EngineConfig(max_slots=1, max_seq=64,
                                               max_new_tokens=5))
    solo.submit(0, short)
    want = solo.run_to_completion()[0]["tokens"]

    both = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                               max_new_tokens=5))
    both.submit(0, short)
    both.submit(1, long)
    got = both.run_to_completion()[0]["tokens"]
    assert got == want


# ---------------------------------------------------------------------------
# cluster server
# ---------------------------------------------------------------------------
def _builders():
    """Tiny real models standing in for the testbed's 4 model types."""
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    kb = jax.random.key(0)
    ks = jax.random.key(1)
    pb = lm.init(kb, big)
    ps = lm.init(ks, small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


@pytest.fixture(scope="module")
def server_parts():
    return paper_testbed(), _builders(), build_trace(24, seed=5)


def test_cluster_server_serves_all(server_parts):
    cluster, builders, trace = server_parts
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=3))
    for i, r in enumerate(trace.requests[:12]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    done = srv.run()
    assert sorted(done) == list(range(12))
    assert all(len(d["tokens"]) == 3 for d in done.values())


def test_cluster_server_failover_requeues(server_parts):
    cluster, builders, trace = server_parts
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=4))
    for i, r in enumerate(trace.requests[:8]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=4))
    # crash every edge node mid-flight: requests must finish on the cloud
    for node in (1, 2, 3):
        srv.fail_node(node)
    done = srv.run()
    assert sorted(done) == list(range(8))
    assert srv.stats()["reroutes"] >= 1


def test_cluster_server_hedges_stragglers(server_parts):
    cluster, builders, trace = server_parts
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=1, max_seq=48,
                                     max_new_tokens=2),
                        hedge_after=1)  # aggressive hedging
    for i, r in enumerate(trace.requests[:6]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=2))
    srv.run()
    assert srv.stats()["hedges"] >= 1
    assert len(srv.done) == 6


def test_hedging_accounting_drains_to_zero(server_parts):
    """Regression: the losing hedged duplicate used to leave `outstanding`
    inflated forever, skewing every later queue-based routing decision."""
    cluster, builders, trace = server_parts
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=1, max_seq=48,
                                     max_new_tokens=3),
                        hedge_after=1)
    for i, r in enumerate(trace.requests[:8]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=3))
    srv.run()
    stats = srv.stats()
    assert stats["hedges"] >= 1
    assert stats["cancelled"] >= 1          # losers were actually cancelled
    assert all(q == 0 for q in stats["queue_lengths"]), stats
    # conservation: every dispatch is closed as complete/failed/cancelled
    for s in srv.monitor.stats.values():
        assert (s.total_dispatched
                == s.total_completed + s.total_failed + s.total_cancelled)


def test_cluster_server_affinity_prefix_reuse_end_to_end(server_parts):
    """Session traffic through the affinity router into prefix-cached
    engines: the prefix-stable tokenizer + paged KV must produce real cache
    hits (strictly fewer prefill tokens run than submitted)."""
    from repro.workload.sessions import SessionConfig, build_session_trace
    cluster, builders, _ = server_parts
    tr = build_session_trace(SessionConfig(n_sessions=4, mean_turns=3.0),
                             seed=2)
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=2, prefix_cache=True,
                                     block_size=8, cache_blocks=32),
                        router_kwargs={"mode": "affinity"})
    reqs = tr.requests[:10]
    for i, r in enumerate(reqs):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=2))
    done = srv.run()
    assert sorted(done) == list(range(len(reqs)))
    stats = [e.cache_stats() for e in srv.engines.values()]
    assert sum(s["hits"] for s in stats) >= 1
    assert (sum(s["prefill_tokens_run"] for s in stats)
            < sum(s["prefill_tokens_total"] for s in stats))
    # the monitor's residency view was populated on dispatch
    assert any(ns.cached_prefixes for ns in srv.monitor.stats.values())

    # a crashed node restarts with empty caches: both the monitor's
    # residency view and its engines' paged pools must flush, or affinity
    # routing keeps crediting KV that did not survive
    node = next(n for n, ns in srv.monitor.stats.items()
                if ns.cached_prefixes)
    srv.fail_node(node)
    assert not srv.monitor.stats[node].cached_prefixes
    pair_node = np.asarray(srv.router.arrays.pair_node)
    for p, eng in srv.engines.items():
        if int(pair_node[p]) == node:
            assert eng.kv.cache.pool.n_free == eng.ecfg.cache_blocks


def test_tokenize_is_stable_and_prefix_preserving(server_parts):
    """Regression: `abs(hash(text))` was salted per process (PYTHONHASHSEED),
    so served token streams — and every prefix-cache hit — were
    irreproducible across runs. crc32 word hashing is stable and maps an
    extending prompt to an extending token stream."""
    cluster, builders, trace = server_parts
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=1, max_seq=48,
                                     max_new_tokens=2))
    req = trace.requests[0]
    toks = srv._tokenize(req, vocab=1000)
    # stable: recomputing (and any other process) yields identical streams
    np.testing.assert_array_equal(toks, srv._tokenize(req, vocab=1000))
    import dataclasses as _dc
    import zlib as _zlib
    assert toks[0] == _zlib.crc32(req.text.split()[0].encode()) % 1000
    # prefix-preserving: an extended prompt extends the token stream
    longer = _dc.replace(req, text=req.text + " extra tail words here",
                         prompt_tokens=req.prompt_tokens + 4)
    toks2 = srv._tokenize(longer, vocab=1000, cap=64)
    toks1 = srv._tokenize(req, vocab=1000, cap=64)
    np.testing.assert_array_equal(toks2[:len(toks1)], toks1)


def test_recover_node_uses_simulated_clock(server_parts):
    """Regression: recover_node injected wall-clock time.monotonic() into
    the monitor's simulated timeline."""
    cluster, builders, trace = server_parts
    srv = ClusterServer(cluster, builders, PAPER_DEFAULTS,
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=2))
    for i, r in enumerate(trace.requests[:4]):
        srv.submit(ServeRequest(request_id=i, req=r, max_new_tokens=2))
    srv.fail_node(1)
    srv.step()
    srv.recover_node(1)
    hb = srv.monitor.stats[1].last_heartbeat
    assert hb == srv.ticks            # scheduler ticks, not time.monotonic()
    assert srv.monitor.healthy_mask()[1]
    srv.run()
