"""repro.learn tests: jnp≡py estimator-update parity (property-style over
both estimator kinds), convergence-to-truth under stationary synthetic
observations, the cold-start contract (learned=True routes byte-identically
to the static-prior baseline before any observation — and, fault-free, for
the whole run), and the live serving loop (router -> monitor estimator ->
record feedback)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # soft optional dep
from conftest import make_session_trace, shared_cluster

from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.policies import get_policy
from repro.core.router import RequestRouter
from repro.learn import (FEAT_DIM, N_CATEGORIES, N_SIGNALS, LearnConfig,
                         OnlineEstimator, features, init_state, predict_jnp,
                         predict_np, state_size, update_jnp, update_np)

CLUSTER = shared_cluster()
N_NODES = 4
CONC = np.array([8, 4, 4, 4], np.int64)   # paper testbed concurrency
KINDS = ["ewma", "blr"]


def _rand_obs(rng):
    """One synthetic (category, nodes, features, targets) observation."""
    cat = int(rng.integers(0, N_CATEGORIES))
    node_p = int(rng.integers(0, N_NODES))
    node_q = int(rng.integers(0, N_NODES))
    pt = float(rng.integers(8, 512))
    cx = float(rng.random())
    queue = rng.integers(0, 10, N_NODES).astype(np.int64)
    ys = rng.normal(0.0, 0.5, 3).astype(np.float32)
    return cat, node_p, node_q, pt, cx, queue, ys


# ---------------------------------------------------------------------------
# jnp ≡ py update/predict parity, property-style over both estimator kinds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_update_and_predict_jnp_matches_np(kind, seed):
    """The same update rule runs inside the JAX scan carry and the DES event
    loops: states and predictions must stay *bitwise* equal through a chain
    of randomized observations (argmax tie-breaks downstream depend on it)."""
    cfg = LearnConfig(kind=kind)
    rng = np.random.default_rng(seed)
    s_np = init_state(cfg, N_NODES)
    s_j = jnp.asarray(s_np)
    for _ in range(6):
        cat, node_p, node_q, pt, cx, queue, ys = _rand_obs(rng)
        x1, x2, x3 = features(np, pt, cx, queue, CONC)
        s_np = update_np(cfg, s_np, N_NODES, cat, node_p, node_q,
                         x1, x2, x3, *ys)
        x1j, x2j, x3j = features(jnp, jnp.float32(pt), jnp.float32(cx),
                                 jnp.asarray(queue), jnp.asarray(CONC))
        s_j = update_jnp(cfg, s_j, N_NODES, cat, node_p, node_q,
                         x1j, x2j, x3j, *(jnp.float32(y) for y in ys))
        np.testing.assert_array_equal(s_np, np.asarray(s_j))
        want = predict_np(cfg, s_np, N_NODES, cat, x1, x2, x3)
        got = predict_jnp(cfg, s_j, N_NODES, cat, x1j, x2j, x3j)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w, np.float32),
                                          np.asarray(g))


@pytest.mark.parametrize("kind", KINDS)
def test_state_layout_and_neutral_seed(kind):
    cfg = LearnConfig(kind=kind)
    s = init_state(cfg, N_NODES)
    assert s.shape == (state_size(cfg, N_NODES),)
    assert s.dtype == np.float32
    # neutral seed: zero residuals and (for BLR) prior-scaled identity A⁻¹
    d_p, d_t, d_q, unc = predict_np(cfg, s, N_NODES, 0, np.float32(0.25),
                                    np.float32(0.5),
                                    np.zeros(N_NODES, np.float32))
    np.testing.assert_array_equal(d_p, 0.0)
    np.testing.assert_array_equal(d_t, 0.0)
    np.testing.assert_array_equal(d_q, 0.0)
    assert (np.asarray(unc) > 0).all()
    if kind == "blr":
        s4 = s.reshape(N_NODES, N_CATEGORIES, N_SIGNALS, cfg.slot)
        A = s4[0, 0, 0, :FEAT_DIM * FEAT_DIM].reshape(FEAT_DIM, FEAT_DIM)
        np.testing.assert_allclose(A, np.eye(FEAT_DIM) / cfg.prior)


# ---------------------------------------------------------------------------
# convergence to truth under stationary synthetic observations
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_estimator_converges_to_stationary_truth(kind):
    """Feeding a constant residual (node 2 runs 1.8x slower than its static
    table, quality 0.1 above its prior) plus noise must converge the
    prediction to the truth, shrink uncertainty on observed slots, and leave
    unobserved nodes exactly neutral."""
    cfg = LearnConfig(kind=kind)
    rng = np.random.default_rng(0)
    truth_lat, truth_q = 0.8, 0.1
    s0 = init_state(cfg, N_NODES)
    s = s0
    for _ in range(300):
        queue = rng.integers(0, 4, N_NODES).astype(np.int64)
        x1, x2, x3 = features(np, float(rng.integers(64, 256)),
                              float(rng.random()), queue, CONC)
        y = np.float32(truth_lat + rng.normal(0.0, 0.05))
        s = update_np(cfg, s, N_NODES, 1, 2, 2, x1, x2, x3, y, y,
                      np.float32(truth_q + rng.normal(0.0, 0.02)))
    x3q = np.zeros(N_NODES, np.float32)
    d_p, d_t, d_q, unc = predict_np(cfg, s, N_NODES, 1, np.float32(0.25),
                                    np.float32(0.5), x3q)
    assert abs(float(d_p[2]) - truth_lat) < 0.15
    assert abs(float(d_t[2]) - truth_lat) < 0.15
    assert abs(float(d_q[2]) - truth_q) < 0.05
    # unobserved (node, category) slots stay exactly on the static tables
    assert float(d_p[0]) == 0.0 and float(d_t[3]) == 0.0
    unc0 = predict_np(cfg, s0, N_NODES, 1, np.float32(0.25), np.float32(0.5),
                      x3q)[3]
    assert float(unc[2]) < float(unc0[2])
    # other categories of the same node are independent slots
    assert float(predict_np(cfg, s, N_NODES, 0, np.float32(0.25),
                            np.float32(0.5), x3q)[0][2]) == 0.0


# ---------------------------------------------------------------------------
# cold-start contract: learned=True ≡ static-prior baseline pre-observation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_fault_free_learned_run_matches_static_baseline(kind):
    """Fault-free, the latency observations are exactly zero (x/x ratios),
    so a whole learned run stays byte-identical to the static baseline for
    estimate-consuming policies — the strongest form of the cold-start
    seeding requirement."""
    tr = make_session_trace(n_requests=60, seed=11)
    g = get_policy("slo").genome_spec.defaults
    cfg = EvalConfig(mode="open", prefix_cache=True)
    base = TraceEvaluator(tr, CLUSTER, cfg).run_policy("slo", g)
    lrn = TraceEvaluator(
        tr, CLUSTER, dataclasses.replace(cfg, learned=True,
                                         learner=LearnConfig(kind=kind))
    ).run_policy("slo", g)
    np.testing.assert_array_equal(np.asarray(base.assign),
                                  np.asarray(lrn.assign))
    for f in ("q", "cost", "rt", "ttft", "tpot"):
        np.testing.assert_array_equal(np.asarray(getattr(base, f)),
                                      np.asarray(getattr(lrn, f)), err_msg=f)


@pytest.mark.parametrize("kind", KINDS)
def test_bandit_first_decision_matches_static_prior(kind):
    """The bandit's exploration bonus is constant across pairs at cold start
    (neutral state, empty queues), so its *first* decision pre-observation is
    byte-identical with learned=True vs False."""
    tr = make_session_trace(n_requests=40, seed=13)
    g = get_policy("bandit").genome_spec.defaults
    cfg = EvalConfig(mode="open", prefix_cache=True)
    base = TraceEvaluator(tr, CLUSTER, cfg).run_policy("bandit", g)
    lrn = TraceEvaluator(
        tr, CLUSTER, dataclasses.replace(cfg, learned=True,
                                         learner=LearnConfig(kind=kind))
    ).run_policy("bandit", g)
    assert int(np.asarray(base.assign)[0]) == int(np.asarray(lrn.assign)[0])


@pytest.mark.parametrize("mode", ["slo", "bandit"])
@pytest.mark.parametrize("kind", KINDS)
def test_router_cold_start_first_decision_matches_static(mode, kind):
    """Live-path twin of the cold-start contract: the first RequestRouter
    decision with learned=True matches the static router byte-for-byte."""
    req = make_session_trace(n_requests=4, seed=17).requests[0]
    d0 = RequestRouter(CLUSTER, mode=mode).route(req)
    d1 = RequestRouter(CLUSTER, mode=mode, learned=True,
                       learner=LearnConfig(kind=kind)).route(req)
    assert (d0.pair, d0.node) == (d1.pair, d1.node)


# ---------------------------------------------------------------------------
# live serving loop: router estimates -> record() feedback -> corrections
# ---------------------------------------------------------------------------
def test_router_record_feeds_estimator_and_corrects_estimates():
    tr = make_session_trace(n_requests=40, seed=19)
    router = RequestRouter(CLUSTER, mode="bandit", learned=True)
    est = router.monitor.estimator
    assert isinstance(est, OnlineEstimator)
    for req in tr.requests:
        d = router.route(req)
        assert d.est_ttft > 0.0 and d.est_tpot > 0.0
        # realized latencies consistently 2x the estimates
        router.record(req, d, quality=0.8, cost=d.est_cost, rt=1.0,
                      ttft=2.0 * d.est_ttft, tpot=2.0 * d.est_tpot)
    assert est.n_obs == tr.n_requests
    d_p, d_t, _, _ = est.predict(0, 128, 0.5, np.zeros(N_NODES, np.int64),
                                 CONC)
    # the 2x slowdown shows up as a ~+1.0 multiplicative residual on at
    # least the node the bandit kept routing to
    assert float(np.max(d_p)) > 0.5 and float(np.max(d_t)) > 0.5


def test_record_without_latency_feedback_leaves_estimator_neutral():
    tr = make_session_trace(n_requests=8, seed=23)
    router = RequestRouter(CLUSTER, mode="slo", learned=True)
    for req in tr.requests:
        d = router.route(req)
        router.record(req, d, quality=0.5, cost=1e-4, rt=1.0)  # no ttft/tpot
    assert router.monitor.estimator.n_obs == 0


def test_monitor_feed_estimator_noop_without_estimator():
    from repro.cluster.monitor import ClusterMonitor
    mon = ClusterMonitor(2)
    mon.feed_estimator(0, 0, 0, 128, 0.5, 0.2, 0.1)   # must not raise
    mon2 = ClusterMonitor(2)
    mon2.estimator = OnlineEstimator(LearnConfig(), 2)
    mon2.feed_estimator(0, 0, 1, 128, 0.5, 0.2, 0.1)
    assert mon2.estimator.n_obs == 1


def test_online_estimator_ratio_contract():
    assert OnlineEstimator.ratio(0.0, 5.0) == 0.0       # unobservable
    assert OnlineEstimator.ratio(2.0, 2.0) == 0.0       # on-estimate
    assert OnlineEstimator.ratio(2.0, 4.0) == pytest.approx(1.0)
    assert OnlineEstimator.ratio(2.0, 1.0) == pytest.approx(-0.5)
