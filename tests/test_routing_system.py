"""System behaviour tests for the routing reproduction layer:
policy decode (Algorithm 2), fitness evaluator vs. discrete-event oracle,
baselines, runtime router failover, and end-to-end NSGA-II routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # soft optional dep
from conftest import shared_arrays, shared_cluster

from repro.cluster.simulator import ClusterSimulator
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.objectives import overall_scores
from repro.core.policies import runtime_policies
from repro.core.policy import (BOUNDS_HI, BOUNDS_LO, PAPER_DEFAULTS,
                               decide_pair_jnp, decide_pair_py)
from repro.core.router import RequestRouter
from repro.workload.trace import build_trace

CLUSTER = shared_cluster()
TRACE = build_trace(120, seed=3)


@pytest.fixture(scope="module")
def evaluator():
    return TraceEvaluator(TRACE, CLUSTER, EvalConfig(concurrency=1))


# ---------------------------------------------------------------------------
# Algorithm 2: jnp decode == python oracle
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=60, deadline=None)
def test_decide_pair_jnp_matches_python_oracle(seed):
    rng = np.random.default_rng(seed)
    arrays = shared_arrays()
    genome = BOUNDS_LO + rng.random(6).astype(np.float32) * (BOUNDS_HI - BOUNDS_LO)
    complexity = float(rng.random())
    pred_cat = int(rng.integers(0, 3))
    conf = float(rng.random())
    queue = rng.integers(0, 12, size=arrays.n_nodes)
    got = int(decide_pair_jnp(jnp.asarray(genome),
                              complexity=jnp.float32(complexity),
                              pred_category=jnp.int32(pred_cat),
                              pred_conf=jnp.float32(conf),
                              queue_len=jnp.asarray(queue), arrays=arrays))
    want = decide_pair_py(genome, complexity=complexity,
                          pred_category=pred_cat, pred_conf=conf,
                          queue_len=queue, arrays=arrays)
    assert got == want


def test_paper_default_thresholds_route_easy_to_edge():
    arrays = shared_arrays()
    # trivially easy request, empty queues -> must go to an edge pair
    p = decide_pair_py(PAPER_DEFAULTS, complexity=0.05, pred_category=2,
                       pred_conf=0.9, queue_len=[0, 0, 0, 0], arrays=arrays)
    assert bool(np.asarray(arrays.pair_is_edge)[p])
    # very complex request -> cloud fallback
    p = decide_pair_py(PAPER_DEFAULTS, complexity=0.95, pred_category=0,
                       pred_conf=0.9, queue_len=[0, 0, 0, 0], arrays=arrays)
    assert p == int(arrays.cloud_fallback_pair)
    # easy but all edge queues above theta_q -> cloud fallback
    p = decide_pair_py(PAPER_DEFAULTS, complexity=0.05, pred_category=2,
                       pred_conf=0.9, queue_len=[0, 9, 9, 9], arrays=arrays)
    assert p == int(arrays.cloud_fallback_pair)


def test_confident_code_prediction_selects_coder_model():
    arrays = shared_arrays()
    p = decide_pair_py(PAPER_DEFAULTS, complexity=0.1, pred_category=0,
                       pred_conf=0.95, queue_len=[0, 0, 0, 0], arrays=arrays)
    from repro.cluster.spec import MODEL_TYPE_INDEX
    assert int(np.asarray(arrays.pair_model_type)[p]) == MODEL_TYPE_INDEX["coder"]
    # low confidence -> instruct
    p = decide_pair_py(PAPER_DEFAULTS, complexity=0.1, pred_category=0,
                       pred_conf=0.4, queue_len=[0, 0, 0, 0], arrays=arrays)
    assert int(np.asarray(arrays.pair_model_type)[p]) == MODEL_TYPE_INDEX["instruct"]


# ---------------------------------------------------------------------------
# JAX evaluator == discrete-event simulator (independent implementations)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("concurrency", [1, 4, 10])
def test_jax_evaluator_matches_des_oracle(concurrency):
    rng = np.random.default_rng(0)
    assign = rng.integers(0, CLUSTER.n_pairs, TRACE.n_requests).astype(np.int32)
    ev = TraceEvaluator(TRACE, CLUSTER, EvalConfig(concurrency=concurrency))
    res = ev.run_assignment(jnp.asarray(assign))
    sim = ClusterSimulator(TRACE, CLUSTER).run(assign, concurrency=concurrency)
    np.testing.assert_allclose(np.asarray(res.rt), sim.rt, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.q), sim.q, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res.cost), sim.cost, rtol=1e-5)


def test_des_heap_variant_agrees_at_conc1():
    rng = np.random.default_rng(1)
    assign = rng.integers(0, CLUSTER.n_pairs, TRACE.n_requests)
    sim = ClusterSimulator(TRACE, CLUSTER)
    a = sim.run(assign, concurrency=1)
    b = sim.run_event_heap(assign, concurrency=1)
    np.testing.assert_allclose(a.rt, b.rt, rtol=1e-9)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_queueing_invariants(seed, conc):
    """Properties: waits are non-negative; at concurrency 1 there is no wait;
    rt >= net + service always; busy time conserved."""
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, CLUSTER.n_pairs, TRACE.n_requests)
    sim = ClusterSimulator(TRACE, CLUSTER)
    r = sim.run(assign, concurrency=conc)
    assert (r.wait >= -1e-9).all()
    if conc == 1:
        np.testing.assert_allclose(r.wait, 0.0, atol=1e-9)
    service = sim.service[np.arange(len(assign)), assign]
    net = sim.up[np.arange(len(assign)), assign] + \
        sim.down[np.arange(len(assign)), assign]
    # float32 tables: allow small absolute+relative slack
    assert (r.rt >= (service + net) * (1 - 1e-5) - 1e-4).all()
    np.testing.assert_allclose(r.node_busy_time.sum(), service.sum(), rtol=1e-5)


def test_concurrency_increases_mean_rt():
    assign = baselines.edge_only(TRACE, CLUSTER)
    sim = ClusterSimulator(TRACE, CLUSTER)
    rt1 = sim.run(assign, concurrency=1).rt.mean()
    rt10 = sim.run(assign, concurrency=10).rt.mean()
    assert rt10 >= rt1  # contention can only hurt mean latency here


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------
def test_baseline_assignments_valid_and_shaped():
    arrays = shared_arrays()
    for fn in (baselines.cloud_only, baselines.edge_only,
               baselines.round_robin):
        a = fn(TRACE, CLUSTER)
        assert a.shape == (TRACE.n_requests,)
        assert (a >= 0).all() and (a < CLUSTER.n_pairs).all()
    a = baselines.random_router(TRACE, CLUSTER)
    assert (a >= 0).all() and (a < CLUSTER.n_pairs).all()


def test_cloud_only_all_cloud_edge_only_all_edge():
    arrays = shared_arrays()
    is_edge = np.asarray(arrays.pair_is_edge)
    assert not is_edge[baselines.cloud_only(TRACE, CLUSTER)].any()
    assert is_edge[baselines.edge_only(TRACE, CLUSTER)].all()


def test_round_robin_half_cloud():
    a = baselines.round_robin(TRACE, CLUSTER)
    is_edge = np.asarray(shared_arrays().pair_is_edge)
    share = is_edge[a].mean()
    assert 0.45 <= share <= 0.55


def test_edge_only_model_matches_task_type():
    from repro.cluster.spec import MODEL_TYPE_INDEX
    a = baselines.edge_only(TRACE, CLUSTER)
    ptype = np.asarray(shared_arrays().pair_model_type)
    for i in range(TRACE.n_requests):
        task = int(TRACE.task[i])
        want = {0: "coder", 1: "math", 2: "instruct", 3: "instruct"}[task]
        assert ptype[a[i]] == MODEL_TYPE_INDEX[want]


# ---------------------------------------------------------------------------
# End-to-end: NSGA-II beats naive baselines on the composite score
# ---------------------------------------------------------------------------
def test_nsga2_router_beats_naive_baselines(evaluator):
    cfg = NSGA2Config(pop_size=32, n_generations=30,
                      lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
    opt = NSGA2(evaluator.make_fitness("threshold"), cfg)
    state = opt.evolve_scan(jax.random.key(0), 30)
    genome, _ = opt.select_by_weights(state, jnp.array([1 / 3, 1 / 3, 1 / 3]))
    rows = {}
    for name, a in [("cloud", baselines.cloud_only(TRACE, CLUSTER)),
                    ("edge", baselines.edge_only(TRACE, CLUSTER)),
                    ("random", baselines.random_router(TRACE, CLUSTER)),
                    ("rr", baselines.round_robin(TRACE, CLUSTER))]:
        rows[name] = evaluator.summarize(evaluator.run_assignment(jnp.asarray(a)))
    rows["proposed"] = evaluator.summarize(evaluator.run_thresholds(genome))
    names = list(rows)
    ov = overall_scores(np.array([rows[n]["avg_quality"] for n in names]),
                        np.array([rows[n]["avg_response_time"] for n in names]),
                        np.array([rows[n]["avg_cost"] for n in names]))
    scores = dict(zip(names, ov))
    assert scores["proposed"] >= scores["random"]
    assert scores["proposed"] >= scores["rr"]
    assert scores["proposed"] >= scores["edge"]


# ---------------------------------------------------------------------------
# Runtime router: failover + hedging (every runtime-capable registry policy
# must survive node failure, not just the paper's threshold rule)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", runtime_policies())
def test_router_failover_avoids_dead_edge_nodes(policy):
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS, mode=policy)
    # easy request normally goes to edge-0 (node 1) under the paper defaults
    req = TRACE.requests[2]
    if policy == "threshold":
        d0 = router.route(req)
        assert d0.go_edge
    # kill every edge node: routing must fall back to cloud
    for j in (1, 2, 3):
        router.monitor.mark_down(j)
    d1 = router.route(req)
    assert d1.node == 0 and not d1.go_edge


@pytest.mark.parametrize("policy", runtime_policies())
def test_router_failover_cloud_down_picks_healthy_edge(policy):
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS, mode=policy)
    router.monitor.mark_down(0)
    # complex request would go to cloud; must fail over to a healthy node
    hard = max(TRACE.requests, key=lambda r: r.prompt_tokens)
    d = router.route(hard)
    assert d.node != 0


@pytest.mark.parametrize("policy", runtime_policies())
def test_router_no_healthy_nodes_raises(policy):
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS, mode=policy)
    for j in range(4):
        router.monitor.mark_down(j)
    with pytest.raises(RuntimeError):
        router.route(TRACE.requests[0])


@pytest.mark.parametrize("policy", runtime_policies())
def test_router_backup_pair_on_different_node(policy):
    router = RequestRouter(CLUSTER, PAPER_DEFAULTS, mode=policy)
    d = router.route(TRACE.requests[0], want_backup=True)
    assert d.backup_pair is not None
    pn = np.asarray(shared_arrays().pair_node)
    assert pn[d.backup_pair] != d.node


def test_des_failure_injection_reroutes_to_cloud():
    assign = baselines.edge_only(TRACE, CLUSTER)
    sim = ClusterSimulator(TRACE, CLUSTER)
    res = sim.run(assign, concurrency=1,
                  down_nodes={1: (0.0, float("inf"))})
    # no request may have executed on node 1
    pn = np.asarray(shared_arrays().pair_node)
    assert (pn[res.assign] != 1).all()
