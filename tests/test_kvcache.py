"""Paged KV-cache subsystem: block-pool invariants, radix longest-prefix
correctness, LRU eviction safety, and engine-level prefix-reuse exactness
(paged-with-reuse output tokens must be byte-identical to the contiguous
non-caching engine while running strictly fewer prefill tokens)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft optional dep

from repro.configs import get
from repro.models import lm
from repro.serving import EngineConfig, LLMEngine
from repro.serving.kvcache import BlockPool, PagedKVCache, RadixIndex


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------
def _random_pool_workload(seed: int, n_blocks: int, n_ops: int):
    """Drive a BlockPool through a random alloc/acquire/release schedule and
    check invariants after every op."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(n_blocks)
    held = []          # (block, cached) pins we own
    cached = set()     # blocks the fake index would report as cached
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        if op == 0:
            b = pool.take_free()
            if b is None:
                b = pool.pop_evictable(lambda blk: True)
                if b is not None:
                    cached.discard(b)
            if b is not None:
                if rng.random() < 0.5:
                    cached.add(b)
                held.append(b)
        elif op == 1 and held:
            b = held[int(rng.integers(0, len(held)))]
            pool.acquire(b)
            held.append(b)
        elif op == 2 and held:
            b = held.pop(int(rng.integers(0, len(held))))
            pool.release(b, cached=b in cached)
        pool.check_invariants()
    for b in held:
        pool.release(b, cached=b in cached)
    pool.check_invariants()
    assert pool.n_free + pool.n_evictable == n_blocks


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 12), st.integers(1, 120))
@settings(max_examples=40, deadline=None)
def test_block_pool_invariants_property(seed, n_blocks, n_ops):
    _random_pool_workload(seed, n_blocks, n_ops)


def test_block_pool_invariants_deterministic():
    for seed in range(8):
        _random_pool_workload(seed, 6, 80)


def test_block_pool_never_evicts_referenced():
    pool = BlockPool(2)
    a = pool.take_free()
    b = pool.take_free()
    assert pool.take_free() is None
    # both referenced: nothing evictable even if the index would allow it
    assert pool.pop_evictable(lambda blk: True) is None
    pool.release(a, cached=True)           # a becomes evictable
    got = pool.pop_evictable(lambda blk: True)
    assert got == a and pool.ref[b] == 1
    pool.release(b, cached=False)
    pool.release(got, cached=False)
    pool.check_invariants()

    with pytest.raises(AssertionError):
        pool.release(a, cached=False)      # refcount would go negative


# ---------------------------------------------------------------------------
# radix index
# ---------------------------------------------------------------------------
def _brute_longest_prefix(entries, tokens, bs):
    """Longest whole-block prefix of ``tokens`` present among ``entries``."""
    best = 0
    for ent in entries:
        m = 0
        while (m + bs <= min(len(ent), len(tokens))
               and ent[m:m + bs] == tokens[m:m + bs]):
            m += bs
        best = max(best, m)
    return best


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_radix_longest_prefix_matches_bruteforce(seed, bs, n_entries):
    rng = np.random.default_rng(seed)
    idx = RadixIndex(bs)
    entries = []
    next_block = 0
    for _ in range(n_entries):
        if entries and rng.random() < 0.5:  # extend an existing entry
            base = list(entries[int(rng.integers(0, len(entries)))])
        else:
            base = []
        toks = base + list(rng.integers(0, 5, size=int(rng.integers(1, 20))))
        blocks = idx.match(toks)
        n_new = len(toks) // bs - len(blocks)
        new = list(range(next_block, next_block + n_new))
        next_block += n_new
        idx.insert(toks, blocks + new)
        entries.append(toks)
    for _ in range(10):
        if entries and rng.random() < 0.7:
            probe = list(entries[int(rng.integers(0, len(entries)))])
            cut = int(rng.integers(0, len(probe) + 1))
            probe = probe[:cut] + list(rng.integers(0, 5, size=6))
        else:
            probe = list(rng.integers(0, 5, size=int(rng.integers(0, 25))))
        want = _brute_longest_prefix(entries, probe, bs)
        assert len(idx.match(probe)) * bs == want


def test_radix_only_leaves_evictable():
    idx = RadixIndex(2)
    toks = [1, 2, 3, 4, 5, 6]
    idx.insert(toks, [0, 1, 2])
    assert not idx.is_evictable(0) and not idx.is_evictable(1)
    assert idx.is_evictable(2)
    idx.remove(2)
    assert idx.is_evictable(1)
    assert idx.match(toks) == [0, 1]          # surviving prefix still matches


def test_paged_cache_eviction_reclaims_lru_leaf():
    kvc = PagedKVCache(n_blocks=2, block_size=2)
    t1, t2 = [1, 2, 3], [4, 5, 6]
    b1 = kvc.allocate()
    kvc.commit(t1, [b1])
    kvc.release([b1])                       # cached + unreferenced
    b2 = kvc.allocate()
    kvc.commit(t2, [b2])
    kvc.release([b2])
    kvc.check_invariants()
    # pool is full of evictable cached blocks; a new allocation evicts b1
    # (least recently used) and its index entry disappears with it
    b3 = kvc.allocate()
    assert b3 == b1
    assert kvc.match(t1) == []
    assert kvc.match(t2) == [b2]
    kvc.release([b3])
    kvc.check_invariants()
    assert kvc.stats.evictions == 1


# ---------------------------------------------------------------------------
# engine-level exactness
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_model():
    cfg = get("stablelm-3b").smoke()
    params = lm.init(jax.random.key(0), cfg)
    return cfg, params


def _session_prompts(vocab: int, seed: int = 0):
    """A 3-turn session + an agent sharing its system prefix."""
    rng = np.random.default_rng(seed)
    sys_prefix = rng.integers(0, vocab, size=16)
    t1 = np.concatenate([sys_prefix, rng.integers(0, vocab, size=6)])
    t2 = np.concatenate([t1, rng.integers(0, vocab, size=9)])
    t3 = np.concatenate([t2, rng.integers(0, vocab, size=5)])
    other = np.concatenate([sys_prefix, rng.integers(0, vocab, size=7)])
    return [t1, t2, t3, other]


def test_paged_engine_matches_contiguous_and_prefills_less(tiny_model):
    cfg, params = tiny_model
    prompts = _session_prompts(cfg.vocab)

    ref = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                              max_new_tokens=4))
    for i, p in enumerate(prompts):
        ref.submit(i, p, max_new_tokens=4)
    want = ref.run_to_completion()

    pag = LLMEngine(cfg, params,
                    EngineConfig(max_slots=2, max_seq=64, max_new_tokens=4,
                                 prefix_cache=True, block_size=8,
                                 cache_blocks=24))
    for i, p in enumerate(prompts):
        pag.submit(i, p, max_new_tokens=4)
        pag.run_to_completion()            # serialize turns so reuse can hit
    got = pag.results

    for i in range(len(prompts)):
        assert got[i]["tokens"] == want[i]["tokens"], i
    st = pag.cache_stats()
    total = sum(len(p) for p in prompts)
    assert st["prefill_tokens_total"] == total
    assert st["prefill_tokens_run"] < total          # strictly fewer prefills
    assert st["hits"] >= 2                           # turns 2, 3 + the agent
    pag.kv.cache.check_invariants()


def test_paged_engine_under_eviction_pressure_stays_exact(tiny_model):
    """A pool far smaller than the working set must still be exact."""
    cfg, params = tiny_model
    prompts = _session_prompts(cfg.vocab, seed=3)

    ref = LLMEngine(cfg, params, EngineConfig(max_slots=1, max_seq=64,
                                              max_new_tokens=3))
    pag = LLMEngine(cfg, params,
                    EngineConfig(max_slots=1, max_seq=64, max_new_tokens=3,
                                 prefix_cache=True, block_size=8,
                                 cache_blocks=3))
    for i, p in enumerate(prompts):
        ref.submit(i, p, max_new_tokens=3)
        pag.submit(i, p, max_new_tokens=3)
    want = ref.run_to_completion()
    got = pag.run_to_completion()
    for i in range(len(prompts)):
        assert got[i]["tokens"] == want[i]["tokens"], i
    pag.kv.cache.check_invariants()


def test_resubmitting_fully_cached_prompt_allocates_nothing(tiny_model):
    """Regression: a prompt whose whole-block path is already indexed used
    to allocate (evicting live cached leaves under a full pool) a duplicate
    block for the chunk match() capped off, only for commit() to discard
    it."""
    cfg, params = tiny_model
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=16)      # exactly 2 full blocks
    eng = LLMEngine(cfg, params,
                    EngineConfig(max_slots=1, max_seq=48, max_new_tokens=2,
                                 prefix_cache=True, block_size=8,
                                 cache_blocks=2))     # pool exactly fits it
    eng.submit(0, prompt, max_new_tokens=2)
    first = eng.run_to_completion()[0]["tokens"]
    eng.submit(1, prompt, max_new_tokens=2)
    again = eng.run_to_completion()[1]["tokens"]
    assert again == first
    assert eng.cache_stats()["evictions"] == 0
    assert eng.kv.cache.pool.n_evictable == 2         # both blocks survive
    eng.kv.cache.check_invariants()


def test_retired_slot_zeroes_kv_len(tiny_model):
    """Regression: retiring/cancelling a slot used to leave ``cache.kv_len``
    at its old value, so ``decode_step`` kept attending over the dead slot's
    KV until the slot was reused."""
    cfg, params = tiny_model
    eng = LLMEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64,
                                              max_new_tokens=3))
    rng = np.random.default_rng(0)
    eng.submit(0, rng.integers(0, cfg.vocab, size=8), max_new_tokens=3)
    eng.submit(1, rng.integers(0, cfg.vocab, size=12), max_new_tokens=6)
    while 0 not in eng.results:
        eng.step()
    assert int(eng.cache.kv_len[0]) == 0      # retired slot zeroed
    assert int(eng.cache.kv_len[1]) > 0       # active slot untouched

    eng.cancel(1)
    assert int(eng.cache.kv_len[1]) == 0      # cancelled slot zeroed too
