"""Train a reduced-config model for a few hundred steps on CPU with the full
production stack: AdamW, microbatching, deterministic sharded data, periodic
checkpoints, and a simulated crash + resume halfway through.

    PYTHONPATH=src python examples/train_small.py [--arch stablelm-3b]
"""
import argparse
import shutil
import tempfile
from pathlib import Path

from repro.configs import get
from repro.training.optim import OptConfig
from repro.training.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckdir = Path(tempfile.mkdtemp()) / "ck"
    cfg = get(args.arch).smoke()
    print(f"training {cfg.name}: "
          f"{cfg.param_counts()['total'] / 1e6:.1f}M params")
    common = dict(seq_len=128, global_batch=8, microbatches=2,
                  checkpoint_dir=str(ckdir), checkpoint_every=50,
                  log_every=20, data_vocab=64, data_chains=2, data_branch=4,
                  opt=OptConfig(lr=3e-3))

    half = args.steps // 2
    print(f"\n-- phase 1: steps 0..{half}, then simulated crash --")
    Trainer(cfg, TrainConfig(steps=half, **common)).run(
        resume=False,
        callback=lambda s, m: print(f"  step {s:4d} nll {m['nll']:.4f} "
                                    f"tok/s {m['tokens_per_s']:.0f}"))

    print(f"\n-- phase 2: restart from checkpoint, steps {half}.."
          f"{args.steps} --")
    t = Trainer(cfg, TrainConfig(steps=args.steps, **common))
    print(f"  resuming from step {t.ckpt.latest_step()}")
    _, _, hist = t.run(
        resume=True,
        callback=lambda s, m: print(f"  step {s:4d} nll {m['nll']:.4f} "
                                    f"tok/s {m['tokens_per_s']:.0f}"))
    print(f"\nfinal nll: {hist[-1]['nll']:.4f} (started ~{hist[0]['nll']:.2f}"
          " — loss decreases on the Markov-mixture corpus)")
    shutil.rmtree(ckdir.parent, ignore_errors=True)


if __name__ == "__main__":
    main()
