"""Quickstart: the paper in 60 seconds.

Builds the §V-C cloud-edge testbed, evaluates the four baselines, runs the
NSGA-II router optimization (100 pop × 60 gens, vectorized in JAX), and
prints the Table-II-style comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core import baselines
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.objectives import overall_scores
from repro.core.policy import BOUNDS_HI, BOUNDS_LO, THRESHOLD_NAMES
from repro.workload.trace import build_trace


def main():
    trace = build_trace(500, seed=0)
    cluster = paper_testbed()
    print(cluster.describe())
    ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=1))

    rows = {}
    for name, assign in [
            ("Cloud Only", baselines.cloud_only(trace, cluster)),
            ("Edge Only", baselines.edge_only(trace, cluster)),
            ("Random Router", baselines.random_router(trace, cluster)),
            ("Round Robin Router", baselines.round_robin(trace, cluster))]:
        rows[name] = ev.summarize(ev.run_assignment(jnp.asarray(assign)))

    print("\nevolving routing policies (NSGA-II, pop=100) ...")
    cfg = NSGA2Config(pop_size=100, n_generations=60,
                      lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("threshold"), cfg)
    t0 = time.time()
    state = opt.evolve_scan(jax.random.key(42), 60)
    dt = time.time() - t0
    genome, _ = opt.select_by_weights(state, jnp.array([1 / 3, 1 / 3, 1 / 3]))
    rows["Proposed Router"] = ev.summarize(ev.run_thresholds(genome))
    print(f"  {60 * 100 * 2} policy evaluations over a 500-request trace "
          f"in {dt:.1f}s")
    print("  thresholds: " + ", ".join(
        f"{n}={float(v):.3f}" for n, v in zip(THRESHOLD_NAMES, genome)))

    names = list(rows)
    ov = overall_scores(
        np.array([rows[n]["avg_quality"] for n in names]),
        np.array([rows[n]["avg_response_time"] for n in names]),
        np.array([rows[n]["avg_cost"] for n in names]))
    print(f"\n{'Router':22s} {'quality↑':>9s} {'time(s)↓':>9s} "
          f"{'cost($)↓':>11s} {'overall↑':>9s}")
    for n, o in zip(names, ov):
        r = rows[n]
        print(f"{n:22s} {r['avg_quality']:9.4f} "
              f"{r['avg_response_time']:9.4f} {r['avg_cost']:11.3e} {o:9.4f}")


if __name__ == "__main__":
    main()
