"""Explore the router's quality/cost/latency Pareto front (paper Fig. 3).

Runs NSGA-II, prints the front, and shows how the Eq. (1) weights pick
different operating points (low-latency vs low-cost deployments).

    PYTHONPATH=src python examples/pareto_explorer.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.spec import paper_testbed
from repro.core.fitness import EvalConfig, TraceEvaluator
from repro.core.nsga2 import NSGA2, NSGA2Config
from repro.core.pareto import hypervolume_mc
from repro.core.policy import BOUNDS_HI, BOUNDS_LO


def main():
    from repro.workload.trace import build_trace
    trace = build_trace(300, seed=1)
    ev = TraceEvaluator(trace, paper_testbed(), EvalConfig(concurrency=1))
    cfg = NSGA2Config(pop_size=64, n_generations=60,
                      lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
    opt = NSGA2(ev.make_fitness("threshold"), cfg)
    state = opt.evolve_scan(jax.random.key(3), 60)
    genomes, F = opt.pareto_front(state)
    F = np.asarray(F)
    order = np.argsort(F[:, 2])
    print(f"Pareto front: {len(F)} policies  (RQ=1-quality, C=$, RT=s)")
    print(f"{'RQ':>8s} {'C':>11s} {'RT':>8s}")
    seen = set()
    for i in order:
        key = tuple(np.round(F[i], 4))
        if key in seen:
            continue
        seen.add(key)
        print(f"{F[i, 0]:8.4f} {F[i, 1]:11.3e} {F[i, 2]:8.4f}")

    ref = jnp.asarray(F.max(0) * 1.1)
    ideal = jnp.asarray(F.min(0))
    hv = hypervolume_mc(jnp.asarray(F), ref, ideal, jax.random.key(0))
    print(f"\nhypervolume (MC, ref=1.1·nadir): {float(hv):.3e}")

    for name, w in [("latency-first", (0.2, 0.1, 0.7)),
                    ("balanced", (1 / 3, 1 / 3, 1 / 3)),
                    ("cost-first", (0.2, 0.7, 0.1))]:
        g, f = opt.select_by_weights(state, jnp.asarray(w))
        print(f"{name:14s} ω={w}:  quality={1 - float(f[0]):.4f} "
              f"cost={float(f[1]):.3e}  rt={float(f[2]):.4f}")


if __name__ == "__main__":
    main()
