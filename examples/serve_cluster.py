"""End-to-end serving driver (the paper's kind of system): batched requests
routed by the NSGA-II policy across real JAX model instances standing in for
the cloud/edge testbed, with continuous batching, a mid-run node failure,
and straggler hedging.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import subprocess
import sys

if __name__ == "__main__":
    # the launcher is the real entry point; this example drives it with a
    # failure injection so the fault-tolerance path is exercised visibly
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve",
         "--requests", "24", "--optimize-router",
         "--fail-node", "1", "--fail-at", "8"],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}))
