"""Open-loop arrival processes for dynamic workloads (beyond-paper axis).

The paper evaluates a single static closed-loop trace (§V-C); its §IV-B.6
claim — "periodic small-scale NSGA-II re-optimization" adapting the routing
policy to workload dynamics — is only testable under an **open-loop** request
process whose statistics drift over time. This module generates such
processes:

* :func:`poisson_arrivals` — homogeneous Poisson at a fixed rate λ;
* :func:`onoff_arrivals` — bursty on/off (interrupted Poisson): alternating
  high-rate bursts and quiet periods, the classic edge-traffic pattern;
* :func:`mmpp_arrivals` — Markov-modulated Poisson over a cycle of
  :class:`PhaseSpec` phases (a deterministic-dwell MMPP, i.e. a diurnal
  profile: night / ramp / peak phases with different rates).

Each :class:`PhaseSpec` also carries a **workload-mix drift**: a category mix
over the four datasets and a prompt/response length scale, so the request
*content* drifts together with the arrival rate.  :func:`build_open_loop_trace`
stitches arrivals + per-phase request generation into a ``Trace`` with
``arrival_time`` set; both cluster oracles (``cluster.simulator``) and the
JAX evaluator (``core.fitness`` with ``mode="open"``) replay it identically —
the equivalence property test extends to this regime.

Everything is deterministic given ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import datasets as ds
from .trace import Trace, trace_from_requests


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One phase of a piecewise-stationary workload.

    rate        — arrival rate λ (requests/second) while the phase is active;
    duration    — dwell time in seconds before moving to the next phase;
    mix         — category mix over ``datasets.DATASETS`` order
                  (mbpp, gsm8k, squad, hellaswag); None = uniform;
    length_scale — multiplier on generated prompt/response lengths (drifting
                  prompt-length distribution).
    """

    rate: float
    duration: float
    mix: Optional[Tuple[float, float, float, float]] = None
    length_scale: float = 1.0

    def __post_init__(self):
        assert self.rate > 0 and self.duration > 0
        if self.mix is not None:
            assert len(self.mix) == len(ds.DATASETS)
            assert abs(sum(self.mix) - 1.0) < 1e-6, "mix must sum to 1"


def _exp_stream(rng: np.random.Generator, rate: float, t0: float, t1: float,
                limit: Optional[int] = None) -> List[float]:
    """Poisson arrival instants in [t0, t1) at rate ``rate``; at most
    ``limit`` of them (so an effectively-infinite dwell stays O(limit))."""
    out = []
    t = t0
    while limit is None or len(out) < limit:
        t += rng.exponential(1.0 / rate)
        if t >= t1:
            break
        out.append(t)
    return out


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """(n,) sorted float32 timestamps of a homogeneous Poisson process."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 11]))
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps).astype(np.float32)


def onoff_arrivals(n: int, rate_on: float, rate_off: float, on_s: float,
                   off_s: float, seed: int = 0) -> np.ndarray:
    """(n,) timestamps of a bursty on/off (interrupted Poisson) process."""
    phases = (PhaseSpec(rate=rate_on, duration=on_s),
              PhaseSpec(rate=rate_off, duration=off_s))
    times, _ = mmpp_arrivals(n, phases, seed=seed)
    return times


def mmpp_arrivals(n: int, phases: Sequence[PhaseSpec], seed: int = 0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic-dwell MMPP: cycle through ``phases`` until n arrivals.

    Returns (timestamps (n,) float32 sorted, phase_id (n,) int32) — the phase
    each request was generated in, which drives the per-phase workload mix.
    """
    assert phases, "need at least one phase"
    rng = np.random.default_rng(np.random.SeedSequence([seed, 13]))
    times: List[float] = []
    ids: List[int] = []
    t = 0.0
    k = 0
    while len(times) < n:
        ph = phases[k % len(phases)]
        seg = _exp_stream(rng, ph.rate, t, t + ph.duration,
                          limit=n - len(times))
        times.extend(seg)
        ids.extend([k % len(phases)] * len(seg))
        t += ph.duration
        k += 1
    return (np.asarray(times[:n], np.float32),
            np.asarray(ids[:n], np.int32))


def _scale_request(r: ds.Request, scale: float) -> ds.Request:
    """Apply a prompt/response length scale to a generated request.

    Text is repeated (never truncated mid-token) so the tokenizer-derived
    observables stay consistent with the content the classifier sees.
    """
    if abs(scale - 1.0) < 1e-9:
        return r
    reps = max(1, int(round(scale)))
    text = " ".join([r.text] * reps) if reps > 1 else r.text
    return dataclasses.replace(
        r, text=text,
        prompt_tokens=max(1, int(round(r.prompt_tokens * scale))),
        query_bytes=max(1, int(round(r.query_bytes * scale))),
        resp_tokens_mean=float(r.resp_tokens_mean * scale),
        sentence_count=max(1, int(round(r.sentence_count * scale))))


def build_open_loop_trace(n_requests: int, phases: Sequence[PhaseSpec],
                          seed: int = 0) -> Trace:
    """Open-loop trace whose mix/lengths drift with the MMPP phase cycle.

    Each arrival draws its dataset from the active phase's category mix and
    scales its lengths by the phase's ``length_scale``; the returned trace
    carries ``arrival_time`` so the simulators replay it open-loop.
    """
    times, phase_id = mmpp_arrivals(n_requests, phases, seed=seed)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 17]))
    # oversized per-dataset pools so any mix can be satisfied
    pools = {name: ds.generate(name, n_requests, seed=seed)
             for name in ds.DATASETS}
    cursors = {name: 0 for name in ds.DATASETS}
    uniform = np.full(len(ds.DATASETS), 1.0 / len(ds.DATASETS))

    reqs: List[ds.Request] = []
    for i in range(n_requests):
        ph = phases[int(phase_id[i])]
        mix = uniform if ph.mix is None else np.asarray(ph.mix, np.float64)
        name = ds.DATASETS[int(rng.choice(len(ds.DATASETS), p=mix))]
        reqs.append(_scale_request(pools[name][cursors[name]],
                                   ph.length_scale))
        cursors[name] += 1
    trace = trace_from_requests(reqs, seed=seed, arrival_time=times)
    trace.phase_id = phase_id
    return trace
