"""Synthetic generators statistically matching the paper's four datasets.

The paper mixes MBPP (code generation), GSM8K (grade-school math), SQuAD
(extractive QA) and HellaSwag (commonsense MC completion) into one 500-request
trace (§V-B/C). The real datasets are not shipped in this container, so each
generator emits *synthetic requests with real text* whose statistics (prompt
token length, response length, task phrasing, constraint phrases, difficulty
spread) match the published datasets. All downstream machinery — tokenizer,
feature extraction, classifier, cost/latency accounting — operates on the
generated text exactly as it would on the originals.

Each generated request carries a latent ``difficulty`` in [0, 1] (used by the
quality model only — the router never sees it, it must infer difficulty from
observable features, which correlate by construction: harder problems have
longer, more clause-heavy prompts).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .tokenizer import count_tokens, text_bytes

DATASETS = ("mbpp", "gsm8k", "squad", "hellaswag")

_NOUNS = ("list", "string", "matrix", "graph", "tree", "array", "number",
          "interval", "sequence", "dictionary", "window", "queue", "stack",
          "polygon", "vector", "substring", "digit", "prime", "factor", "path")
_VERBS = ("compute", "return", "find", "merge", "sort", "count", "reverse",
          "partition", "validate", "transform", "encode", "filter", "rotate",
          "flatten", "search")
_TOPICS = ("the river festival", "a school fundraiser", "the bake sale",
           "a train journey", "the orchard harvest", "a paint job",
           "the reading challenge", "a cycling trip", "the garden fence",
           "a grocery run")
_ENTITIES = ("the Amazon basin", "the 1896 Olympics", "photosynthesis",
             "the printing press", "plate tectonics", "the Roman senate",
             "migratory birds", "the telegraph", "alpine glaciers",
             "the cotton trade")
_SCENES = ("a man is waxing a car", "a woman ties her climbing harness",
           "two chefs plate a dessert", "a child stacks wooden blocks",
           "a runner stretches at the track", "a barista steams milk",
           "a violinist tunes her strings", "a diver checks his gauge")

_CONSTRAINTS = ("You must output only the final answer.",
                "Output must be a single integer.",
                "Only return the function body.",
                "The answer must be given in meters.",
                "You must respond with the letter of the ending only.")


@dataclasses.dataclass
class Request:
    """One inference request r_i with its observable and latent attributes."""

    dataset: str
    index: int
    text: str
    prompt_tokens: int
    query_bytes: int
    resp_tokens_mean: float   # task-typical response length (model-agnostic)
    difficulty: float         # latent, drives realized quality
    sentence_count: int
    has_constraint: bool
    # multi-turn session identity (workload.sessions); -1/0 = single-shot.
    # Turn t+1's text extends turn t's, so a node that served the previous
    # turn holds that prompt's KV prefix; sys_id groups sessions sharing the
    # same system prompt (sys_tokens of it) across sessions.
    session_id: int = -1
    turn: int = 0
    sys_id: int = -1
    sys_tokens: int = 0

    @property
    def task_id(self) -> int:
        return DATASETS.index(self.dataset)


def _sentences(rng: np.random.Generator, n: int, maker) -> str:
    return " ".join(maker(rng) for _ in range(n))


def _mbpp(rng: np.random.Generator, i: int) -> Request:
    difficulty = float(rng.beta(2.6, 2.4))
    n_clauses = 1 + int(round(difficulty * 4)) + int(rng.integers(0, 2))
    body = []
    for _ in range(n_clauses):
        body.append(f"The function should {rng.choice(_VERBS)} the "
                    f"{rng.choice(_NOUNS)} of a given {rng.choice(_NOUNS)}.")
    has_constraint = bool(rng.random() < 0.55)
    text = (f"Write a python function to {rng.choice(_VERBS)} a "
            f"{rng.choice(_NOUNS)}. " + " ".join(body)
            + (" " + str(rng.choice(_CONSTRAINTS)) if has_constraint else "")
            + " Your code should pass these tests: assert f(" +
            ", ".join(str(int(rng.integers(0, 99))) for _ in range(3)) + ")")
    resp = 20 + 16 * difficulty
    return _pack("mbpp", i, text, resp, difficulty)


def _gsm8k(rng: np.random.Generator, i: int) -> Request:
    difficulty = float(rng.beta(3.0, 2.2))  # skews harder
    steps = 2 + int(round(difficulty * 5))
    topic = rng.choice(_TOPICS)
    body = [f"For {topic}, Maya buys {int(rng.integers(2, 60))} items at "
            f"{int(rng.integers(1, 15))} dollars each."]
    for _ in range(steps - 1):
        body.append(f"Then she {rng.choice(['sells', 'adds', 'returns', 'splits'])} "
                    f"{int(rng.integers(1, 40))} of them with "
                    f"{int(rng.integers(2, 9))} friends.")
    has_constraint = bool(rng.random() < 0.35)
    text = (" ".join(body) + " How many does she have left?"
            + (" " + str(rng.choice(_CONSTRAINTS)) if has_constraint else ""))
    resp = 18 + 14 * difficulty  # concise worked solutions
    return _pack("gsm8k", i, text, resp, difficulty)


def _squad(rng: np.random.Generator, i: int) -> Request:
    difficulty = float(rng.beta(2.0, 3.2))  # skews easier
    ctx_sent = 3 + int(round(difficulty * 6)) + int(rng.integers(0, 3))
    ent = rng.choice(_ENTITIES)
    ctx = []
    for _ in range(ctx_sent):
        ctx.append(f"Historians note that {ent} influenced "
                   f"{rng.choice(_ENTITIES)} during the period of "
                   f"{int(rng.integers(1700, 1990))}.")
    has_constraint = bool(rng.random() < 0.2)
    text = ("Context: " + " ".join(ctx) +
            f" Question: When did {ent} influence the region?"
            + (" " + str(rng.choice(_CONSTRAINTS)) if has_constraint else ""))
    resp = 7 + 8 * difficulty  # extractive short answers
    return _pack("squad", i, text, resp, difficulty)


def _hellaswag(rng: np.random.Generator, i: int) -> Request:
    difficulty = float(rng.beta(2.5, 2.5))
    scene = rng.choice(_SCENES)
    endings = [f"({c}) then {rng.choice(_SCENES)}." for c in "ABCD"]
    has_constraint = bool(rng.random() < 0.6)
    text = (f"Complete the scenario: {scene}. Choose the most plausible "
            "ending: " + " ".join(endings)
            + (" " + str(rng.choice(_CONSTRAINTS)) if has_constraint else ""))
    resp = 3 + 3 * difficulty  # a letter + short justification
    return _pack("hellaswag", i, text, resp, difficulty)


def _pack(ds: str, i: int, text: str, resp_mean: float, difficulty: float
          ) -> Request:
    return Request(
        dataset=ds, index=i, text=text,
        prompt_tokens=count_tokens(text), query_bytes=text_bytes(text),
        resp_tokens_mean=float(resp_mean), difficulty=difficulty,
        sentence_count=max(1, text.count(".") + text.count("?")),
        has_constraint=any(k in text for k in ("must", "only", "Output", "output")),
    )


_GENERATORS = {"mbpp": _mbpp, "gsm8k": _gsm8k, "squad": _squad,
               "hellaswag": _hellaswag}


def generate(dataset: str, n: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, DATASETS.index(dataset)]))
    return [_GENERATORS[dataset](rng, i) for i in range(n)]
