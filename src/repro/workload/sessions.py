"""Multi-turn session workloads (beyond-paper axis: conversational traffic).

The paper's trace is 500 independent single-shot requests; production
traffic is dominated by **sessions** — chat conversations and agent loops
whose turn *t+1* prompt is turn *t*'s prompt plus the assistant reply and
the next user message, and **agent fleets** whose sessions all share one of
a few long system prompts. Both shapes are exactly what a prefix cache
(``serving.kvcache``) and cache-affinity routing exploit: the shared prefix
of a later turn is already resident on whichever node served the earlier
one.

:func:`build_session_trace` generates such a workload as an open-loop
``Trace`` (composable with ``workload.arrivals``-style replay — sessions
start at Poisson instants and turns follow after exponential think times):

* turn prompts **extend** earlier turns verbatim (``text`` is a strict
  string prefix of the next turn's, so token streams share prefixes under
  any prefix-stable tokenizer);
* each session draws its task from the standard dataset mix; the *latest*
  user message determines category/difficulty/response length (the earlier
  turns are context);
* every request carries ``session_id`` / ``turn`` / ``sys_id`` /
  ``sys_tokens``, lifted into ``Trace.group_id`` / ``sys_id`` /
  ``sys_tokens`` for the analytical cache model in ``core.fitness`` and the
  DES oracles.

Everything is deterministic given ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import datasets as ds
from .tokenizer import count_tokens, text_bytes
from .trace import Trace, trace_from_requests

_SYS_TOPICS = ("inventory triage", "travel planning", "code review",
               "incident response", "literature search", "budget audits")
_ASSISTANT_FILLER = (
    "Here is a step by step answer with the key quantities worked out.",
    "The result follows from the stated constraints applied in order.",
    "I verified each intermediate value before composing the final reply.",
    "The answer accounts for every clause in the request above.",
)
_FOLLOWUPS = ("Now also handle the edge case where the input is empty.",
              "Can you redo that with the second quantity doubled?",
              "Explain the same result but more concisely.",
              "Apply the identical procedure to the next example.",
              "What changes if the last constraint is dropped?")


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Shape of the session workload.

    n_sessions / mean_turns — how many conversations and their geometric
    mean length (1.0 degenerates to single-shot traffic);
    session_rate — Poisson rate (sessions/s) of new-session starts;
    think_time_s — mean exponential gap between a session's turns;
    n_system_prompts — size of the shared system-prompt pool (agent
    workloads: many sessions reuse the same long preamble); 0 disables;
    system_prompt_sentences — length of each shared preamble.
    """

    n_sessions: int = 16
    mean_turns: float = 3.0
    session_rate: float = 0.5
    think_time_s: float = 4.0
    n_system_prompts: int = 2
    system_prompt_sentences: int = 6

    def __post_init__(self):
        assert self.n_sessions > 0 and self.mean_turns >= 1.0
        assert self.session_rate > 0 and self.think_time_s > 0


def _system_prompts(cfg: SessionConfig,
                    rng: np.random.Generator) -> List[str]:
    out = []
    for k in range(cfg.n_system_prompts):
        topic = _SYS_TOPICS[k % len(_SYS_TOPICS)]
        body = " ".join(
            f"Rule {j + 1}: when assisting with {topic}, respond with "
            f"{int(rng.integers(1, 9))} numbered points and cite the "
            "relevant clause." for j in range(cfg.system_prompt_sentences))
        out.append(f"System: you are agent {k} for {topic}. {body}")
    return out


def _turn_request(base: ds.Request, text: str, sid: int, turn: int,
                  sys_id: int, sys_tok: int) -> ds.Request:
    return dataclasses.replace(
        base, text=text, prompt_tokens=count_tokens(text),
        query_bytes=text_bytes(text),
        sentence_count=max(1, text.count(".") + text.count("?")),
        session_id=sid, turn=turn, sys_id=sys_id, sys_tokens=sys_tok)


def session_requests(cfg: SessionConfig, seed: int = 0
                     ) -> List[Tuple[float, ds.Request]]:
    """(arrival_time, request) pairs, unsorted (sessions interleave)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 23]))
    sys_prompts = _system_prompts(cfg, rng)
    # oversized per-dataset pools: each turn consumes one base request
    pool = {name: ds.generate(name, cfg.n_sessions * 8, seed=seed)
            for name in ds.DATASETS}
    cursor = {name: 0 for name in ds.DATASETS}

    out: List[Tuple[float, ds.Request]] = []
    start = 0.0
    p_more = 1.0 - 1.0 / cfg.mean_turns    # geometric continuation
    for sid in range(cfg.n_sessions):
        start += float(rng.exponential(1.0 / cfg.session_rate))
        sys_id = (int(rng.integers(0, len(sys_prompts)))
                  if sys_prompts else -1)
        sys_text = sys_prompts[sys_id] if sys_prompts else ""
        sys_tok = count_tokens(sys_text) if sys_text else 0
        name = ds.DATASETS[sid % len(ds.DATASETS)]

        context = sys_text
        t = start
        turn = 0
        while True:
            base = pool[name][cursor[name]]
            cursor[name] += 1
            user = (base.text if turn == 0
                    else f"{base.text} {_FOLLOWUPS[int(rng.integers(0, len(_FOLLOWUPS)))]}")
            context = (context + " " + user).strip()
            out.append((t, _turn_request(base, context, sid, turn,
                                         sys_id, sys_tok)))
            if rng.random() >= p_more:
                break
            # the assistant reply becomes carried context for the next turn
            context += " Assistant: " + str(rng.choice(_ASSISTANT_FILLER))
            t += float(rng.exponential(cfg.think_time_s))
            turn += 1
    return out


def build_session_trace(cfg: SessionConfig = SessionConfig(), seed: int = 0,
                        n_requests: Optional[int] = None) -> Trace:
    """Open-loop session trace, sorted by arrival, with session arrays set.

    ``n_requests`` truncates (sessions cut mid-way keep their early turns —
    prefix structure is preserved).
    """
    items = sorted(session_requests(cfg, seed=seed), key=lambda it: it[0])
    if n_requests is not None:
        items = items[:n_requests]
    assert items, "session workload generated no requests"
    times = np.asarray([t for t, _ in items], np.float32)
    return trace_from_requests([r for _, r in items], seed=seed,
                               arrival_time=times)
