"""Request feature extraction (paper §IV-B.6, "Feature Extraction").

The router's feature vector f_i = (c_i, t_i, q_j):

* ``c_i`` — complexity score: weighted combination of prompt token length,
  sentence count, task type and presence of output constraints, normalized to
  [0, 1]. Weights are "empirically tuned based on correlations between
  features and inference time" — we tune them on generated training traces
  (see workload/calibration.py) and freeze them here.
* ``t_i`` — task category + confidence, from workload.classifier.
* ``q_j`` — live node queue length, supplied by the monitor at decision time.
"""
from __future__ import annotations

import numpy as np

from .classifier import CATEGORY_INDEX
from .datasets import Request

# feature weights (sum to 1): token_len, sentence_count, task_type, constraint
W_TOKENS = 0.45
W_SENTENCES = 0.25
W_TASK = 0.20
W_CONSTRAINT = 0.10

# normalization caps (p95 of the generated corpora)
TOKENS_CAP = 260.0
SENTENCES_CAP = 12.0

# task-type prior complexity: code/math are heavier per token than QA/MC
_TASK_WEIGHT = {"code": 0.9, "math": 0.8, "general": 0.35}


def complexity_score(req: Request, pred_category: int) -> float:
    """c_i ∈ [0, 1], computed from *observable* prompt features only."""
    cat = list(CATEGORY_INDEX)[pred_category]
    f_tok = min(req.prompt_tokens / TOKENS_CAP, 1.0)
    f_sent = min(req.sentence_count / SENTENCES_CAP, 1.0)
    f_task = _TASK_WEIGHT[cat]
    f_con = 1.0 if req.has_constraint else 0.0
    c = (W_TOKENS * f_tok + W_SENTENCES * f_sent + W_TASK * f_task
         + W_CONSTRAINT * f_con)
    return float(np.clip(c, 0.0, 1.0))
