"""Simulated SetFit task classifier (paper §IV-B.6, footnote 2).

The paper trains a SetFit classifier on samples of the four datasets to
predict a request's *task category* ('code', 'math', 'general') plus a
confidence score p_t. We reproduce it as a deterministic keyword/statistics
classifier with a calibrated confusion profile matching what a small SetFit
model achieves on these four corpora (high-90s accuracy on MBPP/GSM8K, near
perfect on SQuAD/HellaSwag), so routing sees realistic (t_i, p_t) features.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .datasets import Request

CATEGORIES = ("code", "math", "general")
CATEGORY_INDEX = {c: i for i, c in enumerate(CATEGORIES)}

# dataset -> true category
DATASET_CATEGORY = {"mbpp": "code", "gsm8k": "math", "squad": "general",
                    "hellaswag": "general"}

_CODE_KEYS = ("python", "function", "assert", "code", "return")
_MATH_KEYS = ("how many", "dollars", "left?", "friends", "each")


def classify(req: Request, rng: np.random.Generator) -> Tuple[int, float]:
    """Return (predicted category index, confidence p_t).

    Keyword evidence drives the score; a small noise floor creates the
    occasional low-confidence / wrong prediction the thresholds θ_t guard
    against.
    """
    t = req.text.lower()
    code_score = sum(k in t for k in _CODE_KEYS) / len(_CODE_KEYS)
    math_score = sum(k in t for k in _MATH_KEYS) / len(_MATH_KEYS)
    gen_score = 0.35 + 0.1 * ("context:" in t or "scenario" in t)
    logits = np.array([code_score * 2.2, math_score * 2.2, gen_score * 2.0])
    logits = logits + rng.normal(0.0, 0.18, size=3)  # SetFit-like uncertainty
    e = np.exp(logits - logits.max())
    p = e / e.sum()
    pred = int(np.argmax(p))
    return pred, float(p[pred])
