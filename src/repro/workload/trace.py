"""Mixed-dataset request trace (paper §V-C).

"A test script mixes problems from MBPP, GSM8K, SQuAD, and HellaSwag, sending
500 requests in total with a round-robin order (e.g., MBPP, GSM8K, HellaSwag,
SQuAD, repeating). The requests are evenly distributed across the four
datasets, with 125 requests per dataset."

``Trace`` is the array-of-structs view consumed by the JAX fitness evaluator
and the discrete-event simulator. Everything is deterministic given ``seed``.

Two trace regimes:

* **closed-loop** (the paper's test script): no timestamps — G clients issue
  their next request on completion of the previous one;
* **open-loop** (dynamic-workload extension): ``arrival_time`` carries one
  timestamp per request and the simulators release requests at those instants
  regardless of completions. Open-loop traces are produced by
  ``workload.arrivals`` (Poisson / bursty on-off / diurnal MMPP with drifting
  category mix) and by the runtime router when it re-fits on its observed
  history window (``trace_from_requests``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from . import datasets as ds
from .classifier import classify
from .features import complexity_score

# round-robin order used by the paper's test script
ORDER = ("mbpp", "gsm8k", "hellaswag", "squad")


@dataclasses.dataclass
class Trace:
    """I requests with observable features + latent difficulty (numpy)."""

    requests: List[ds.Request]
    task: np.ndarray            # (I,) int32 dataset id (ds.DATASETS order)
    pred_category: np.ndarray   # (I,) int32 into classifier.CATEGORIES
    pred_conf: np.ndarray       # (I,) float32
    complexity: np.ndarray      # (I,) float32 — c_i
    prompt_tokens: np.ndarray   # (I,) int32
    resp_tokens_mean: np.ndarray  # (I,) float32
    difficulty: np.ndarray      # (I,) float32 latent
    query_bytes: np.ndarray     # (I,) float32
    # Optional QoE contract (see workload.slo.attach_slos). None = no SLOs.
    ttft_deadline: Optional[np.ndarray] = None   # (I,) float32 seconds
    tpot_deadline: Optional[np.ndarray] = None   # (I,) float32 s/token
    slo_interactive: Optional[np.ndarray] = None  # (I,) bool deadline class
    # Optional open-loop arrival timestamps (sorted ascending, seconds).
    # None = closed-loop trace.
    arrival_time: Optional[np.ndarray] = None    # (I,) float32
    phase_id: Optional[np.ndarray] = None        # (I,) int32 workload phase
    # Optional multi-turn session identity (workload.sessions): session id
    # (-1 = single-shot), shared-system-prompt class, and the token length of
    # that shared prefix — what the prefix-cache model in core.fitness /
    # cluster.simulator keys hit state on.
    group_id: Optional[np.ndarray] = None        # (I,) int32 session id
    sys_id: Optional[np.ndarray] = None          # (I,) int32 system-prompt id
    sys_tokens: Optional[np.ndarray] = None      # (I,) float32

    @property
    def n_requests(self) -> int:
        return self.task.shape[0]

    @property
    def has_slos(self) -> bool:
        return self.ttft_deadline is not None and self.tpot_deadline is not None

    @property
    def has_arrivals(self) -> bool:
        return self.arrival_time is not None

    @property
    def has_sessions(self) -> bool:
        return self.group_id is not None


def trace_from_requests(reqs: List[ds.Request], seed: int = 0,
                        arrival_time: Optional[np.ndarray] = None) -> Trace:
    """Build the array-of-structs view over an explicit request list.

    Shared by ``build_trace`` (round-robin closed loop), the open-loop
    generators in ``workload.arrivals``, and the runtime router's rolling-
    horizon re-fit over its recorded history window.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, 1234]))
    I = len(reqs)
    task = np.zeros(I, np.int32)
    pred_cat = np.zeros(I, np.int32)
    pred_conf = np.zeros(I, np.float32)
    complexity = np.zeros(I, np.float32)
    prompt_tokens = np.zeros(I, np.int32)
    resp_mean = np.zeros(I, np.float32)
    difficulty = np.zeros(I, np.float32)
    qbytes = np.zeros(I, np.float32)
    for i, r in enumerate(reqs):
        task[i] = r.task_id
        pc, conf = classify(r, rng)
        pred_cat[i] = pc
        pred_conf[i] = conf
        complexity[i] = complexity_score(r, pc)
        prompt_tokens[i] = r.prompt_tokens
        resp_mean[i] = r.resp_tokens_mean
        difficulty[i] = r.difficulty
        qbytes[i] = r.query_bytes

    if arrival_time is not None:
        arrival_time = np.asarray(arrival_time, np.float32)
        assert arrival_time.shape == (I,), "one timestamp per request"
        assert (np.diff(arrival_time) >= 0).all(), \
            "open-loop arrival times must be sorted ascending"

    trace = Trace(requests=reqs, task=task, pred_category=pred_cat,
                  pred_conf=pred_conf, complexity=complexity,
                  prompt_tokens=prompt_tokens, resp_tokens_mean=resp_mean,
                  difficulty=difficulty, query_bytes=qbytes,
                  arrival_time=arrival_time)
    # requests generated by workload.sessions carry session identity; lift it
    # into trace arrays so the prefix-cache model (fitness/simulator) and the
    # router's history re-fit see it without a separate side channel
    if any(getattr(r, "session_id", -1) >= 0
           or getattr(r, "sys_id", -1) >= 0 for r in reqs):
        trace.group_id = np.asarray(
            [getattr(r, "session_id", -1) for r in reqs], np.int32)
        trace.sys_id = np.asarray(
            [getattr(r, "sys_id", -1) for r in reqs], np.int32)
        trace.sys_tokens = np.asarray(
            [getattr(r, "sys_tokens", 0) for r in reqs], np.float32)
    return trace


def build_trace(n_requests: int = 500, seed: int = 0) -> Trace:
    per = (n_requests + len(ORDER) - 1) // len(ORDER)
    pools = {name: ds.generate(name, per, seed=seed) for name in ORDER}
    cursors = {name: 0 for name in ORDER}

    reqs: List[ds.Request] = []
    for i in range(n_requests):
        name = ORDER[i % len(ORDER)]
        reqs.append(pools[name][cursors[name]])
        cursors[name] += 1
    return trace_from_requests(reqs, seed=seed)
