"""Mixed-dataset request trace (paper §V-C).

"A test script mixes problems from MBPP, GSM8K, SQuAD, and HellaSwag, sending
500 requests in total with a round-robin order (e.g., MBPP, GSM8K, HellaSwag,
SQuAD, repeating). The requests are evenly distributed across the four
datasets, with 125 requests per dataset."

``Trace`` is the array-of-structs view consumed by the JAX fitness evaluator
and the discrete-event simulator. Everything is deterministic given ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from . import datasets as ds
from .classifier import classify
from .features import complexity_score

# round-robin order used by the paper's test script
ORDER = ("mbpp", "gsm8k", "hellaswag", "squad")


@dataclasses.dataclass
class Trace:
    """I requests with observable features + latent difficulty (numpy)."""

    requests: List[ds.Request]
    task: np.ndarray            # (I,) int32 dataset id (ds.DATASETS order)
    pred_category: np.ndarray   # (I,) int32 into classifier.CATEGORIES
    pred_conf: np.ndarray       # (I,) float32
    complexity: np.ndarray      # (I,) float32 — c_i
    prompt_tokens: np.ndarray   # (I,) int32
    resp_tokens_mean: np.ndarray  # (I,) float32
    difficulty: np.ndarray      # (I,) float32 latent
    query_bytes: np.ndarray     # (I,) float32
    # Optional QoE contract (see workload.slo.attach_slos). None = no SLOs.
    ttft_deadline: Optional[np.ndarray] = None   # (I,) float32 seconds
    tpot_deadline: Optional[np.ndarray] = None   # (I,) float32 s/token
    slo_interactive: Optional[np.ndarray] = None  # (I,) bool deadline class

    @property
    def n_requests(self) -> int:
        return self.task.shape[0]

    @property
    def has_slos(self) -> bool:
        return self.ttft_deadline is not None and self.tpot_deadline is not None


def build_trace(n_requests: int = 500, seed: int = 0) -> Trace:
    per = (n_requests + len(ORDER) - 1) // len(ORDER)
    pools = {name: ds.generate(name, per, seed=seed) for name in ORDER}
    cursors = {name: 0 for name in ORDER}
    rng = np.random.default_rng(np.random.SeedSequence([seed, 1234]))

    reqs: List[ds.Request] = []
    for i in range(n_requests):
        name = ORDER[i % len(ORDER)]
        reqs.append(pools[name][cursors[name]])
        cursors[name] += 1

    I = len(reqs)
    task = np.zeros(I, np.int32)
    pred_cat = np.zeros(I, np.int32)
    pred_conf = np.zeros(I, np.float32)
    complexity = np.zeros(I, np.float32)
    prompt_tokens = np.zeros(I, np.int32)
    resp_mean = np.zeros(I, np.float32)
    difficulty = np.zeros(I, np.float32)
    qbytes = np.zeros(I, np.float32)
    for i, r in enumerate(reqs):
        task[i] = r.task_id
        pc, conf = classify(r, rng)
        pred_cat[i] = pc
        pred_conf[i] = conf
        complexity[i] = complexity_score(r, pc)
        prompt_tokens[i] = r.prompt_tokens
        resp_mean[i] = r.resp_tokens_mean
        difficulty[i] = r.difficulty
        qbytes[i] = r.query_bytes

    return Trace(requests=reqs, task=task, pred_category=pred_cat,
                 pred_conf=pred_conf, complexity=complexity,
                 prompt_tokens=prompt_tokens, resp_tokens_mean=resp_mean,
                 difficulty=difficulty, query_bytes=qbytes)
