from .arrivals import (PhaseSpec, build_open_loop_trace, mmpp_arrivals,
                       onoff_arrivals, poisson_arrivals)
from .sessions import SessionConfig, build_session_trace, session_requests
from .trace import Trace, build_trace, trace_from_requests
from .tokenizer import count_tokens

__all__ = ["Trace", "build_trace", "trace_from_requests", "count_tokens",
           "PhaseSpec", "build_open_loop_trace", "mmpp_arrivals",
           "onoff_arrivals", "poisson_arrivals", "SessionConfig",
           "build_session_trace", "session_requests"]
