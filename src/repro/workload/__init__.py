from .trace import Trace, build_trace
from .tokenizer import count_tokens

__all__ = ["Trace", "build_trace", "count_tokens"]
