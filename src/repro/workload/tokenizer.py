"""Deterministic tokenizer for request accounting.

The paper uses tiktoken purely for *token counting* (cost Eq. 3 and prompt
length features). We reproduce that role with a deterministic, dependency-free
approximation of a BPE tokenizer: whitespace words are split into sub-word
units of ~4 characters, punctuation and digits tokenize individually. On
typical English/benchmark text this lands within a few percent of cl100k_base
counts, which is all the routing features and cost metric need.
"""
from __future__ import annotations

import re

_WORD_RE = re.compile(r"[A-Za-z]+|\d|[^\sA-Za-z\d]")

# average characters per BPE token for alphabetic words (cl100k-ish)
_CHARS_PER_SUBWORD = 4


def count_tokens(text: str) -> int:
    """Approximate BPE token count, deterministic."""
    n = 0
    for piece in _WORD_RE.findall(text):
        if piece.isalpha():
            n += max(1, (len(piece) + _CHARS_PER_SUBWORD - 1) // _CHARS_PER_SUBWORD)
        else:
            n += 1
    return n


def text_bytes(text: str) -> int:
    return len(text.encode("utf-8"))
