"""Per-category latency SLOs for QoE-aware routing (beyond-paper axis).

The paper collapses latency into one scalar RT (Eq. 4). Production serving
stacks differentiate the two phases of a streamed response:

* **TTFT** (time to first token) — upload + queue wait + prefill; what an
  interactive user perceives as "responsiveness";
* **TPOT** (time per output token) — the decode-phase streaming rate.

A request's QoE contract is the pair of deadlines (TTFT_max, TPOT_max). This
module defines a per-category SLO table plus a deadline-class mix
(interactive vs batch clients), and attaches per-request deadline arrays to a
``Trace``. Deadline heterogeneity is the new scenario axis the SLO-aware
router (``repro.core.policy.decide_pair_slo_*``) and the attainment objective
(``repro.core.objectives.slo_attainment``) optimize over.

Deadlines are calibrated to the §V-C testbed: cloud decode ≈ 19 tok/s (TPOT
0.053 s) vs edge ≈ 5.2 tok/s (TPOT 0.192 s), so an interactive TPOT budget is
only attainable on the cloud pair while batch budgets admit edge pairs —
exactly the tension phase-split routing has to arbitrate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from .classifier import CATEGORIES


@dataclasses.dataclass(frozen=True)
class CategorySLO:
    """Base deadlines (seconds) for one request category."""

    ttft_s: float
    tpot_s: float


# Base per-category contract at tightness 1.0. Code requests tolerate a
# slower first token (editors batch completions) but want fast streaming;
# general chat wants a snappy first token.
DEFAULT_SLO_TABLE: Dict[str, CategorySLO] = {
    "code": CategorySLO(ttft_s=1.40, tpot_s=0.16),
    "math": CategorySLO(ttft_s=1.10, tpot_s=0.14),
    "general": CategorySLO(ttft_s=0.80, tpot_s=0.12),
}

# Deadline classes: interactive clients shrink the budget, batch clients
# relax it enough that edge decode (0.192 s/tok) qualifies.
INTERACTIVE_SCALE = 0.55
BATCH_SCALE = 4.0


def slo_arrays(table: Dict[str, CategorySLO] = DEFAULT_SLO_TABLE
               ) -> Tuple[np.ndarray, np.ndarray]:
    """(n_categories,) base deadline vectors in classifier category order."""
    ttft = np.array([table[c].ttft_s for c in CATEGORIES], np.float32)
    tpot = np.array([table[c].tpot_s for c in CATEGORIES], np.float32)
    return ttft, tpot


def attach_slos(trace, tightness: float = 1.0,
                interactive_frac: float = 0.5, seed: int = 0,
                table: Dict[str, CategorySLO] = DEFAULT_SLO_TABLE):
    """Attach per-request (ttft_deadline, tpot_deadline) arrays to ``trace``.

    Each request draws a deadline class (interactive with probability
    ``interactive_frac``, else batch) and scales its category's base contract
    by the class scale × global ``tightness``. Returns the trace (mutated in
    place) for chaining. Deterministic given ``seed``.
    """
    base_ttft, base_tpot = slo_arrays(table)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 4242]))
    I = trace.n_requests
    interactive = rng.random(I) < interactive_frac
    scale = np.where(interactive, INTERACTIVE_SCALE, BATCH_SCALE)
    scale = scale.astype(np.float32) * np.float32(tightness)
    cat = trace.pred_category
    trace.ttft_deadline = (base_ttft[cat] * scale).astype(np.float32)
    trace.tpot_deadline = (base_tpot[cat] * scale).astype(np.float32)
    trace.slo_interactive = interactive
    return trace
