"""Model configuration for the assigned architecture zoo.

Every architecture is expressed as a repeating *period* of blocks; each block
is (mixer, ffn) where

    mixer ∈ {"attn", "attn_bidir", "attn_cross", "cross", "mamba",
             "mlstm", "slstm"}
    ffn   ∈ {"dense", "moe", "none"}

The LM stacks ``n_layers // len(pattern)`` periods and runs them with
``lax.scan`` (per-position params stacked over periods) so HLO size is O(1)
in depth. Heterogeneous families:

* dense LMs            — pattern [("attn", "dense")]
* dbrx (all-MoE)       — [("attn", "moe")]
* llama4 (interleaved) — [("attn", "moe"), ("attn", "dense")]
* jamba (1:7 + MoE/2)  — period 8, attn at index 4, MoE on even indices
* xLSTM [7:1]          — 7×("mlstm", "none") + ("slstm", "none")
* llama-3.2-vision     — period 5, ("cross", "dense") at index 0
* whisper decoder      — [("attn_cross", "dense")], plus an encoder stack

The modality frontends of [audio]/[vlm] archs are stubs per the assignment:
``input_specs`` hands the model precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

Block = Tuple[str, str]

MIXERS = ("attn", "attn_bidir", "attn_cross", "cross", "mamba", "mlstm",
          "slstm")
FFNS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden width
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    """Whisper-style encoder over a stubbed conv frontend."""
    n_layers: int
    n_frames: int = 1504          # 1500 rounded up to 32-multiple for tiling


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128              # selective-scan chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    proj_factor_m: float = 2.0    # mLSTM up-projection
    proj_factor_s: float = 1.3333  # sLSTM FFN factor
    chunk: int = 64               # mLSTM chunkwise-parallel length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | audio | ssm | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[Block, ...]
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    activation: str = "swiglu"    # swiglu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    encoder: Optional[EncoderCfg] = None
    ssm: Optional[SSMCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    cross_kv_tokens: int = 0      # VLM patch tokens / audio frames for cross
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # which serving shapes apply (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False
    # False: recurrent-dense blocks (xLSTM) gain nothing from tensor
    # parallelism — shard batch + params over the flattened (data, model)
    # axes instead (pure FSDP/ZeRO-3); see sharding.py
    tp_friendly: bool = True

    def __post_init__(self):
        assert self.family in ("dense", "moe", "audio", "ssm", "vlm", "hybrid")
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, len(self.pattern))
        for mixer, ffn in self.pattern:
            assert mixer in MIXERS and ffn in FFNS
        if any(f == "moe" for _, f in self.pattern):
            assert self.moe is not None
        assert self.n_heads % self.n_kv_heads == 0

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def block_at(self, layer: int) -> Block:
        return self.pattern[layer % len(self.pattern)]

    # -- parameter accounting (drives ModelCards & roofline "useful FLOPs") --
    def param_counts(self) -> Dict[str, float]:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * (Hq + 2 * Hkv) + Hq * hd * d
        if self.qk_norm:
            attn += 2 * hd
        dense_ffn = 3 * d * ff
        counts = {"embed": V * d, "head": 0 if self.tie_embeddings else V * d}
        total = counts["embed"] + counts["head"]
        active = total
        for li in range(self.n_layers):
            mixer, ffn = self.block_at(li)
            if mixer in ("attn", "attn_bidir", "cross"):
                m = attn
            elif mixer == "attn_cross":
                m = 2 * attn
            elif mixer == "mamba":
                di = self.ssm.expand * d
                m = (2 * d * di + di * self.ssm.d_conv
                     + di * (2 * self.ssm.d_state + 2) + di * d)
            elif mixer == "mlstm":
                di = int(self.xlstm.proj_factor_m * d)
                dh = di // self.n_heads
                # up + block-diagonal qkv + gates + down
                m = (2 * d * di + 3 * di * dh
                     + 2 * di * self.n_heads + di * d)
            elif mixer == "slstm":
                m = 4 * d * d + int(self.xlstm.proj_factor_s * d) * d * 2
            else:
                m = 0
            if ffn == "dense":
                f_tot = f_act = dense_ffn
            elif ffn == "moe":
                f_tot = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
                f_act = 3 * d * self.moe.d_ff * self.moe.top_k + d * self.moe.n_experts
            else:
                f_tot = f_act = 0
            total += m + f_tot + 2 * d     # + norms
            active += m + f_act + 2 * d
        counts["total"] = float(total)
        counts["active"] = float(active)
        if self.encoder is not None:
            enc = self.encoder.n_layers * (attn + dense_ffn + 2 * d)
            counts["encoder"] = float(enc)
            counts["total"] += enc
            counts["active"] += enc
        return counts

    def model_flops_per_token(self, train: bool = True) -> float:
        """6·N_active per trained token; 2·N_active per decoded token."""
        n = self.param_counts()["active"] - self.param_counts()["embed"]
        return (6.0 if train else 2.0) * n


# ---------------------------------------------------------------------------
# Input shape cells (assignment): every LM arch pairs with these four
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason) — long_500k only for sub-quadratic archs."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 512k-context decode requires "
                       "sub-quadratic attention (run for SSM/hybrid only)")
    return True, ""
