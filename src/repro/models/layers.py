"""Parameter initialization + core layer math (pure functional JAX).

Conventions: params are nested dicts of jnp arrays; every layer is an
``init(key, ...) -> params`` / ``apply(params, x, ...) -> y`` pair; compute
dtype is bf16 with f32 accumulation (``preferred_element_type``), norms and
softmax in f32. No framework dependency (flax is not available here), which
also keeps the pytree paths stable for the sharding-rule matcher.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..kernels import ops as kops
from .config import ModelConfig
from .sharding import accum_dot, constrain

Params = Dict


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(dtype)}


def norm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def layernorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# norms / rope / embeddings
# ---------------------------------------------------------------------------

def rms_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["g"]).astype(x.dtype)


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"] + p["b"]).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p, x):
    return (rms_norm(p, x, cfg.norm_eps) if cfg.norm == "rmsnorm"
            else layer_norm(p, x, cfg.norm_eps))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p, tokens):
    out = jnp.take(p["table"], tokens, axis=0)
    return constrain(out, *(("dp",) + (None,) * (out.ndim - 1)))


def unembed(p, x):
    out = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                     p["table"].astype(jnp.float32))
    nd = out.ndim
    return constrain(out, *((("dp",) + (None,) * (nd - 2)) + ("model",)))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dt),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dt,
                         scale=1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qk_norm:
        p["qnorm"] = norm_init(cfg.hd)
        p["knorm"] = norm_init(cfg.hd)
    return p


def _qkv(p, cfg: ModelConfig, x, kv_x):
    B = x.shape[0]
    q = jnp.einsum("bsd,de->bse", x, p["wq"]["w"])
    k = jnp.einsum("bsd,de->bse", kv_x, p["wk"]["w"])
    v = jnp.einsum("bsd,de->bse", kv_x, p["wv"]["w"])
    q = constrain(q.reshape(B, -1, cfg.n_heads, cfg.hd),
                  "dp", None, "model", None)
    k = constrain(k.reshape(B, -1, cfg.n_kv_heads, cfg.hd),
                  "dp", None, "model", None)
    v = constrain(v.reshape(B, -1, cfg.n_kv_heads, cfg.hd),
                  "dp", None, "model", None)
    if cfg.qk_norm:
        q = rms_norm(p["qnorm"], q, cfg.norm_eps)
        k = rms_norm(p["knorm"], k, cfg.norm_eps)
    return q, k, v


_CHUNKED_THRESHOLD = 4096
_Q_CHUNK = 512


def _xla_attention(q, k, v, causal: bool, q_offset: int = 0,
                   mask=None) -> jax.Array:
    """(B, S, H, D) attention via XLA einsums; q-chunked beyond threshold so
    the (B, H, Sq, Sk) score tensor never exceeds ~chunk×S per head.
    ``q_offset`` is the global position of query row 0 (prefix-extension
    prefill attends suffix queries over prefix+suffix keys).
    ``mask`` optionally supplies an explicit (Sq, Sk) boolean admission mask
    (True = attend) that *replaces* the index-based causal mask — used by the
    bucketed prefix-extension path whose key layout carries padding (mask
    values may be dynamic; shapes stay static)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    scale = D ** -0.5
    qh = jnp.swapaxes(q, 1, 2) * scale                     # (B, Hq, Sq, D)
    kh = jnp.swapaxes(k, 1, 2)                             # (B, Hkv, Sk, D)
    vh = jnp.swapaxes(v, 1, 2)
    Sk = kh.shape[2]
    qh = qh.reshape(B, Hkv, group, Sq, D)

    def block(q_blk, q_off):
        # f32 accumulation without materializing f32 copies of K/V
        s = accum_dot("bhgqd,bhkd->bhgqk", q_blk, kh)
        if mask is not None:
            m = jax.lax.dynamic_slice_in_dim(mask, q_off - q_offset,
                                             q_blk.shape[3], axis=0)
            s = jnp.where(m[None, None, None], s, -jnp.inf)
        elif causal:
            qi = q_off + jnp.arange(q_blk.shape[3])
            cm = qi[:, None] >= jnp.arange(Sk)[None, :]
            s = jnp.where(cm[None, None, None], s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        return accum_dot("bhgqk,bhkd->bhgqd", w.astype(vh.dtype), vh)

    if Sq <= _CHUNKED_THRESHOLD:
        out = block(qh, q_offset)
    else:
        n = Sq // _Q_CHUNK
        qc = qh.reshape(B, Hkv, group, n, _Q_CHUNK, D)

        def body(i, acc):
            o = block(jax.lax.dynamic_index_in_dim(qc, i, axis=3,
                                                   keepdims=False),
                      q_offset + i * _Q_CHUNK)
            return jax.lax.dynamic_update_index_in_dim(acc, o, i, axis=3)

        acc0 = jnp.zeros((B, Hkv, group, n, _Q_CHUNK, D), jnp.float32)
        out = jax.lax.fori_loop(0, n, body, acc0)
        out = out.reshape(B, Hkv, group, Sq, D)
    out = out.reshape(B, Hq, Sq, D)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def attention(p, cfg: ModelConfig, x, *, positions, causal: bool = True,
              kv_x=None, use_pallas: str = "auto") -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    kv_in = x if kv_x is None else kv_x
    q, k, v = _qkv(p, cfg, x, kv_in)
    if kv_x is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if use_pallas in ("pallas", "interpret") or (
            use_pallas == "auto" and jax.default_backend() == "tpu"):
        out = kops.flash_attention(jnp.swapaxes(q, 1, 2),
                                   jnp.swapaxes(k, 1, 2),
                                   jnp.swapaxes(v, 1, 2), causal=causal,
                                   mode=use_pallas)
        out = jnp.swapaxes(out, 1, 2)
    else:
        out = _xla_attention(q, k, v, causal)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.q_dim)
    return constrain(jnp.einsum("bse,ed->bsd", out, p["wo"]["w"]),
                     "dp", None, None)


def attention_prefill_cache(p, cfg: ModelConfig, x, positions
                            ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Like attention() but also returns the (k, v) cache (B, S, Hkv, D)."""
    q, k, v = _qkv(p, cfg, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = _xla_attention(q, k, v, causal=True)
    B, S = x.shape[:2]
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, cfg.q_dim), p["wo"]["w"])
    return y, (k, v)


def attention_prefill_extend(p, cfg: ModelConfig, x, positions, prefix_kv,
                             prefix_len=None
                             ) -> Tuple[jax.Array,
                                        Tuple[jax.Array, jax.Array]]:
    """Prefill the suffix of a prompt whose prefix K/V is already cached.

    x: (B, S_new, d) suffix activations; positions: (1, S_new) absolute
    positions starting at the prefix length; prefix_kv: (k, v) each
    (B, S_pre, Hkv, D). Returns (y, (k_full, v_full)) where the cache covers
    prefix + suffix. Exactness: suffix rows see bitwise the same keys/values
    and causal mask a full-prompt ``attention_prefill_cache`` would compute,
    so prefix reuse cannot perturb the sampled tokens.

    ``prefix_len`` switches to the **bucketed** layout (compile-once
    admission): the prefix buffer is padded to its static S_pre and only the
    first ``prefix_len`` (dynamic) rows are real; suffix rows may be padded
    past their true length too. The explicit mask admits real-prefix columns
    plus index-causal suffix columns (padded *query* rows produce garbage
    that callers discard; padded *key* columns are only reachable from
    padded query rows). Returns (y, (k, v)) with the **suffix-only** K/V —
    the caller assembles the contiguous cache at the dynamic offset.
    """
    k_pre, v_pre = prefix_kv
    S_pre = k_pre.shape[1]
    q, k, v = _qkv(p, cfg, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    k_full = jnp.concatenate([k_pre, k], axis=1)
    v_full = jnp.concatenate([v_pre, v], axis=1)
    B, S = x.shape[:2]
    if prefix_len is None:
        out = _xla_attention(q, k_full, v_full, causal=True, q_offset=S_pre)
        y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, cfg.q_dim),
                       p["wo"]["w"])
        return y, (k_full, v_full)
    col = jnp.arange(S_pre + S)[None, :]
    row = jnp.arange(S)[:, None]
    mask = jnp.where(col < S_pre, col < prefix_len, (col - S_pre) <= row)
    out = _xla_attention(q, k_full, v_full, causal=False, mask=mask)
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, cfg.q_dim), p["wo"]["w"])
    return y, (k, v)


def attention_decode(p, cfg: ModelConfig, x, cache, pos,
                     use_pallas: str = "auto"):
    """One-token decode. x: (B, 1, d); cache: (k, v) each (B, Smax, Hkv, D);
    pos: (B,) current lengths. Returns (y, new_cache).

    Sharding: the KV cache is head_dim-sharded over 'model' (Hkv rarely
    divides the axis), so q/k/v here are constrained to the SAME hd sharding
    — otherwise the q·k dot partitioner cannot co-locate the contraction and
    falls back to all-gathering the entire cache per layer (measured: 1 GiB
    per layer per step before this constraint; the score all-reduce it buys
    is 16 MiB)."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(p, cfg, x, x)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k_new = rope(k_new, pos[:, None], cfg.rope_theta)
    q = constrain(q, "dp", None, None, "model")
    k_new = constrain(k_new, "dp", None, None, "model")
    v_new = constrain(v_new, "dp", None, None, "model")
    k_cache, v_cache = cache
    # write at pos (per batch row)
    k_cache = jax.vmap(
        lambda c, kn, i: jax.lax.dynamic_update_slice_in_dim(c, kn, i, 0)
    )(k_cache, k_new, pos)
    v_cache = jax.vmap(
        lambda c, vn, i: jax.lax.dynamic_update_slice_in_dim(c, vn, i, 0)
    )(v_cache, v_new, pos)
    kv_len = pos + 1

    if use_pallas in ("pallas", "interpret") or (
            use_pallas == "auto" and jax.default_backend() == "tpu"):
        out = kops.gqa_decode_attention(
            q[:, 0].reshape(B, cfg.n_heads, cfg.hd),
            jnp.transpose(k_cache, (0, 2, 1, 3)),
            jnp.transpose(v_cache, (0, 2, 1, 3)), kv_len, mode=use_pallas)
        out = out.reshape(B, 1, cfg.q_dim)
    else:
        from ..kernels import ref as kref
        out = kref.gqa_decode(
            q[:, 0].reshape(B, cfg.n_heads, cfg.hd),
            jnp.transpose(k_cache, (0, 2, 1, 3)),
            jnp.transpose(v_cache, (0, 2, 1, 3)), kv_len)
        out = out.reshape(B, 1, cfg.q_dim)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"]["w"])
    return y, (k_cache, v_cache)


def cross_attention_cached(p, cfg: ModelConfig, x, kv_cache):
    """Cross-attn against precomputed encoder/vision (k, v): (B, T, Hkv, D)."""
    B, S = x.shape[:2]
    q = jnp.einsum("bsd,de->bse", x, p["wq"]["w"]).reshape(
        B, S, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(p["qnorm"], q, cfg.norm_eps)
    k, v = kv_cache
    out = _xla_attention(q, k, v, causal=False)
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, S, cfg.q_dim), p["wo"]["w"])
    return y


def cross_kv(p, cfg: ModelConfig, kv_x):
    """Precompute cross-attention K/V from encoder output / patch embeds."""
    B, T = kv_x.shape[:2]
    k = jnp.einsum("btd,de->bte", kv_x, p["wk"]["w"]).reshape(
        B, T, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("btd,de->bte", kv_x, p["wv"]["w"]).reshape(
        B, T, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(p["knorm"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    dt = _dtype(cfg)
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], cfg.d_model, ff, dt),
         "wo": dense_init(ks[2], ff, cfg.d_model, dt,
                          scale=1.0 / math.sqrt(ff))}
    if cfg.activation == "swiglu":
        p["wg"] = dense_init(ks[1], cfg.d_model, ff, dt)
    return p


def ffn_apply(p, x, activation: str = "swiglu"):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"]["w"])
    if activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"]["w"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:  # gelu MLP (whisper)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    # named for selective remat: saving the ffn hidden skips recomputing the
    # two widest matmuls of each layer in the backward pass
    h = checkpoint_name(h, "ffn_hidden")
    h = constrain(h, "dp", None, "model")
    return constrain(jnp.einsum("bsf,fd->bsd", h, p["wo"]["w"]),
                     "dp", None, None)
