"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan), composing the xlstm-1.3b arch in
a [7:1] mLSTM:sLSTM pattern.

TPU adaptation of mLSTM: the paper presents a recurrent form; materializing
the (B, H, Dh, Dh) matrix state per timestep is hopeless, so we use the
equivalent chunkwise linear-attention form (the mLSTM *is* gated linear
attention): within a chunk the contribution is a (Lc, Lc) masked score
matrix — MXU work — and across chunks a (B, H, Dh, Dh) running state C plus
normalizer n and log-scale stabilizer m are carried by a ``lax.scan``.
Exponential input gates are stabilized by tracking the running max log-gate m
exactly as Appendix A of the paper prescribes; all gate math in f32.

sLSTM keeps the true sequential recurrence (its state is only (B, H, Dh));
one ``lax.scan`` step per token. It exists in the architecture for its
state-tracking abilities, not throughput — the [7:1] ratio keeps it off the
critical path.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dtype, dense_init, norm_init, rms_norm
from .sharding import accum_dot, constrain

Params = Dict


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def m_dims(cfg: ModelConfig) -> Tuple[int, int]:
    di = int(cfg.xlstm.proj_factor_m * cfg.d_model)
    H = cfg.n_heads
    assert di % H == 0
    return di, di // H


def mlstm_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    di, dh = m_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    dh = di // H

    def blockdiag(k):
        # xLSTM uses block-diagonal q/k/v projections (one dh x dh block per
        # head) — fewer params and no cross-head mixing
        return {"w": (jax.random.normal(k, (H, dh, dh), jnp.float32)
                      * (dh ** -0.5)).astype(dt)}

    return {
        "up": dense_init(ks[0], d, 2 * di, dt),          # branch + gate
        "wq": blockdiag(ks[1]),
        "wk": blockdiag(ks[2]),
        "wv": blockdiag(ks[3]),
        "wi": dense_init(ks[4], di, cfg.n_heads, jnp.float32),
        "wf": dense_init(ks[5], di, cfg.n_heads, jnp.float32),
        "norm": norm_init(di),
        "down": dense_init(ks[6], di, d, dt),
    }


def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunk. q/k/v: (B, H, Lc, Dh) f32; li/lf: (B, H, Lc) log gates.
    state: (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)). Returns (h, new_state)."""
    B, H, Lc, Dh = q.shape
    C_prev, n_prev, m_prev = state
    F = jnp.cumsum(lf, axis=-1)                         # (B, H, Lc) inclusive
    # stabilizer: m_i = max( F_i + m_prev, max_{j<=i} (F_i - F_j + li_j) )
    g = li - F                                          # (B, H, Lc)
    g_run = jax.lax.associative_scan(jnp.maximum, g, axis=-1)
    m_loc = F + g_run
    m_cross = F + m_prev[..., None]
    m = jnp.maximum(m_loc, m_cross)                     # (B, H, Lc)

    scale = Dh ** -0.5
    s = jnp.einsum("bhid,bhjd->bhij", q * scale, k)     # (B, H, Lc, Lc)
    decay = F[..., :, None] - F[..., None, :] + li[..., None, :] - m[..., :, None]
    mask = jnp.tril(jnp.ones((Lc, Lc), bool))
    dmat = jnp.where(mask, jnp.exp(decay), 0.0)
    s = s * dmat

    cross_scale = jnp.exp(F + m_prev[..., None] - m)    # (B, H, Lc)
    num = (jnp.einsum("bhij,bhjd->bhid", s, v)
           + jnp.einsum("bhid,bhde->bhie", q * scale, C_prev)
           * cross_scale[..., None])
    den = (jnp.sum(s, axis=-1)
           + jnp.einsum("bhid,bhd->bhi", q * scale, n_prev) * cross_scale)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

    # state update to chunk end
    FL = F[..., -1:]                                    # (B, H, 1)
    m_new = jnp.maximum(m_prev + FL[..., 0],
                        jnp.max(FL - F + li, axis=-1))
    w = jnp.exp(FL - F + li - m_new[..., None])         # (B, H, Lc)
    C_new = (C_prev * jnp.exp(m_prev + FL[..., 0] - m_new)[..., None, None]
             + jnp.einsum("bhj,bhjd,bhje->bhde", w, k, v))
    n_new = (n_prev * jnp.exp(m_prev + FL[..., 0] - m_new)[..., None]
             + jnp.einsum("bhj,bhjd->bhd", w, k))
    return h, (C_new, n_new, m_new)


def mlstm_forward(p, cfg: ModelConfig, x, state=None):
    """x: (B, L, d) -> (y, state). Chunkwise-parallel over cfg.xlstm.chunk.

    L pads to a chunk multiple with state-neutral steps (log f = 0,
    log i = -inf), so the carried state is exact at position L."""
    B, L0, d = x.shape
    chunk0 = min(cfg.xlstm.chunk, L0)
    pad = (-L0) % chunk0
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    B, L, d = x.shape
    di, dh = m_dims(cfg)
    H = cfg.n_heads
    up = jnp.einsum("bld,de->ble", x, p["up"]["w"])
    xi, z = jnp.split(up, 2, axis=-1)

    xh = xi.reshape(B, L, H, dh)

    def heads(w):
        out = accum_dot("blhd,hde->blhe", xh, w)
        return constrain(out.transpose(0, 2, 1, 3), "dp", None, None, None)

    q, k, v = heads(p["wq"]["w"]), heads(p["wk"]["w"]), heads(p["wv"]["w"])
    li = jnp.einsum("ble,eh->blh", xi.astype(jnp.float32),
                    p["wi"]["w"]).transpose(0, 2, 1)          # log input gate
    lf = jax.nn.log_sigmoid(
        jnp.einsum("ble,eh->blh", xi.astype(jnp.float32),
                   p["wf"]["w"])).transpose(0, 2, 1)
    if pad:
        valid = (jnp.arange(L) < L0)[None, None, :]
        li = jnp.where(valid, li, -1e30)   # no writes on pad steps
        lf = jnp.where(valid, lf, 0.0)     # no decay on pad steps

    if state is None:
        state = init_mlstm_state(cfg, B)
    Lc = min(cfg.xlstm.chunk, L)
    assert L % Lc == 0
    n = L // Lc

    def step(st, args):
        qc, kc, vc, lic, lfc = args
        h, st2 = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
        return st2, h

    def split(a):  # (B, H, L, ...) -> (n, B, H, Lc, ...)
        return jnp.moveaxis(a.reshape(B, H, n, Lc, *a.shape[3:]), 2, 0)

    state, hs = jax.lax.scan(
        step, state,
        (split(q), split(k), split(v), split(li), split(lf)))
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, L, dh)
    h = h.transpose(0, 2, 1, 3).reshape(B, L, di)
    h = rms_norm(p["norm"], h.astype(_dtype(cfg)), cfg.norm_eps)
    y = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    out = constrain(jnp.einsum("ble,ed->bld", y, p["down"]["w"]),
                    "dp", None, None)
    if pad:
        out = out[:, :L0]
    return out, state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    di, dh = m_dims(cfg)
    H = cfg.n_heads
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def s_dims(cfg: ModelConfig) -> Tuple[int, int]:
    H = cfg.n_kv_heads
    assert cfg.d_model % H == 0
    return cfg.d_model, cfg.d_model // H


def slstm_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    d, dh = s_dims(cfg)
    H = cfg.n_kv_heads
    ks = jax.random.split(key, 7)
    dff = int(cfg.xlstm.proj_factor_s * d)
    r_scale = 1.0 / math.sqrt(dh)

    def rmat(k):
        return (jax.random.normal(k, (H, dh, dh), jnp.float32) * r_scale)

    return {
        "wx": dense_init(ks[0], d, 4 * d, dt),        # z, i, f, o pre-acts
        "rz": rmat(ks[1]), "ri": rmat(ks[2]),
        "rf": rmat(ks[3]), "ro": rmat(ks[4]),
        "norm": norm_init(d),
        "ff_up": dense_init(ks[5], d, dff, dt),
        "ff_down": dense_init(ks[6], dff, d, dt),
    }


def slstm_forward(p, cfg: ModelConfig, x, state=None):
    """Sequential scan over time. x: (B, L, d)."""
    B, L, d = x.shape
    H = cfg.n_kv_heads
    dh = d // H
    pre = jnp.einsum("bld,de->ble", x, p["wx"]["w"]).astype(jnp.float32)
    pre = pre.reshape(B, L, 4, H, dh)

    if state is None:
        state = init_slstm_state(cfg, B)

    def step(carry, pre_t):
        c, n, h, m = carry                             # (B, H, dh) ... (B, H, dh)?
        rec = lambda R: jnp.einsum("bhd,hde->bhe", h, R)
        z_t = jnp.tanh(pre_t[:, 0] + rec(p["rz"]))
        i_t = pre_t[:, 1] + rec(p["ri"])               # log-space
        f_t = jax.nn.log_sigmoid(pre_t[:, 2] + rec(p["rf"]))
        o_t = jax.nn.sigmoid(pre_t[:, 3] + rec(p["ro"]))
        m_new = jnp.maximum(f_t + m, i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(f_t + m - m_new)
        c_new = f_e * c + i_e * z_t
        n_new = f_e * n + i_e
        h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, L, d).astype(x.dtype)
    y = rms_norm(p["norm"], y, cfg.norm_eps)
    ff = jnp.einsum("bld,df->blf", y, p["ff_up"]["w"])
    ff = jax.nn.gelu(ff.astype(jnp.float32)).astype(ff.dtype)
    return jnp.einsum("blf,fd->bld", ff, p["ff_down"]["w"]), state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d, dh = s_dims(cfg)
    H = cfg.n_kv_heads
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, z, jnp.full((batch, H, dh), -1e30, jnp.float32))
