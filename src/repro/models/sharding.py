"""Sharding rules: parameter/cache/batch PartitionSpecs per (arch × mode).

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.
DP axis = ('pod', 'data') when the pod axis exists (pure data parallelism
across pods — only gradient all-reduce crosses pod links).

Training (2-D sharding, MaxText-style):
  * column-parallel weights (d_in, d_out): P('data', 'model') — TP over the
    output features, FSDP (ZeRO-3) over the input features;
  * row-parallel weights: P('model', 'data');
  * MoE experts (E, d, ff): P('model', 'data', None) — expert parallelism on
    the TP axis + FSDP on d;
  * embeddings (V, d): P('model', 'data') (vocab-parallel);
  * optimizer state inherits the param spec (sharded identically).

Serving: TP only for ≤40 B params (weights replicated over 'data' so each
data-parallel serving group holds a full replica); weights also FSDP-shard
over 'data' for the ≥100 B archs (dbrx, llama4) — an all-gather per layer is
the price of fitting 16 GB/chip.

KV caches (B, S, Hkv, hd): batch over DP and **head_dim over 'model'** —
Hkv (6–8) does not divide the 16-wide model axis, and sharding S would make
the decode dynamic-update-slice a cross-shard write. hd is 128 (64 whisper),
always divisible; the q·k contraction over the sharded hd produces a cheap
(B, H, S)-score all-reduce that the roofline table prices out (§Perf
iterates on exactly this choice).

Rules match on parameter *path* (dict keys joined by '/'), falling back to
replication whenever an axis does not divide the dimension (whisper's 6
heads, xlstm's 4, …).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

BIG_MODEL_B = 60e9  # params above this FSDP-shard even for serving

# ---------------------------------------------------------------------------
# activation sharding constraints
#
# GSPMD propagation alone is not enough: without explicit activation
# constraints the partitioner happily un-shards the batch dimension around
# the loss / attention contractions (measured: 37 GiB full-batch logits and
# 16 GiB full-batch attention scores per device on qwen3-1.7b train_4k).
# The model code calls ``constrain(x, axes...)``; it is a no-op unless a
# mesh has been installed via ``activation_mesh`` (done by the dry-run and
# the distributed trainer — CPU unit tests never see it).
# ---------------------------------------------------------------------------

_ACT_MESH: Optional[Mesh] = None
_ACT_FULL_DP: bool = False


class activation_mesh:
    """Context manager installing the mesh used by ``constrain``.

    full_dp=True (tp_friendly=False archs): 'dp' expands to include the
    'model' axis too — the whole mesh is data-parallel (pure FSDP)."""

    def __init__(self, mesh: Optional[Mesh], full_dp: bool = False):
        self.mesh = mesh
        self.full_dp = full_dp
        self.prev = None

    def __enter__(self):
        global _ACT_MESH, _ACT_FULL_DP
        self.prev = (_ACT_MESH, _ACT_FULL_DP)
        _ACT_MESH = self.mesh
        _ACT_FULL_DP = self.full_dp
        return self.mesh

    def __exit__(self, *exc):
        global _ACT_MESH, _ACT_FULL_DP
        _ACT_MESH, _ACT_FULL_DP = self.prev
        return False


def lowering_mode() -> bool:
    """True while lowering for the production mesh (dry-run / distributed
    trainer). Model code uses this to pick bf16-in/f32-accum einsums —
    which the XLA *CPU runtime* cannot execute (DotThunk: BF16 x BF16 = F32
    unsupported) but TPU prefers over materialized f32 operand copies."""
    return _ACT_MESH is not None


def accum_dot(subscripts: str, a, b):
    """einsum with f32 accumulation: preferred_element_type under lowering
    (no f32 operand copies), explicit casts on the CPU execution path."""
    if lowering_mode():
        return jnp.einsum(subscripts, a, b,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, a.astype(jnp.float32),
                      b.astype(jnp.float32))


def best_dp_prefix(mesh: Mesh, dim: int, full_dp: bool):
    """Longest data-parallel axis tuple whose product divides ``dim``."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if full_dp and "model" in mesh.axis_names:
        axes.append("model")
    while axes and dim % _axis_size(mesh, tuple(axes)) != 0:
        axes.pop()
    return tuple(axes) if axes else None


def constrain(x, *axes):
    """with_sharding_constraint(x, P(*axes)) against the installed mesh.

    'dp' expands to the data-parallel axes (longest prefix that divides the
    dimension); other axes that do not divide are dropped. No-op when no
    mesh is installed.
    """
    if _ACT_MESH is None:
        return x
    mesh = _ACT_MESH
    resolved = []
    for dim, a in zip(x.shape, axes + (None,) * (x.ndim - len(axes))):
        if a == "dp":
            resolved.append(best_dp_prefix(mesh, dim, _ACT_FULL_DP))
        elif a == "model" and _ACT_FULL_DP:
            resolved.append(None)   # 'model' belongs to DP in full-dp mode
        else:
            resolved.append(a)
    spec = _fit(mesh, P(*resolved), x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(mesh: Mesh, spec: P, shape) -> P:
    """Drop axes that do not divide their dimension (replicate instead)."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# path-regex -> (train spec, serve spec); {fsdp} substituted with 'data' or None
_COL = P("data", "model")     # (d_in, d_out) column-parallel
_ROW = P("model", "data")     # row-parallel
_RULES = [
    # attention / generic dense
    (r".*(wq|wk|wv|wi|wg|up|in_proj|x_proj|ff_up|wx)/w$", _COL),
    (r".*(wo|down|out_proj|ff_down|dt_proj)/w$", _ROW),
    # MoE experts: E over model (EP), d over data (FSDP)
    (r".*ffn/(wi|wg)/w$", P("model", "data", None)),
    (r".*ffn/wo/w$", P("model", None, "data")),
    (r".*router/w$", P("data", None)),
    # embeddings
    (r".*(embed|head)/table$", P("model", "data")),
    (r".*encoder/pos$", P(None, "data")),
    # ssm / xlstm specials
    (r".*A_log$", P("model", None)),
    (r".*mixer/D$", P("model",)),
    (r".*conv/w$", P(None, "model")),
    (r".*conv/b$", P("model",)),
    (r".*(rz|ri|rf|ro)$", P(None, None, None)),
    # norms & biases: replicated
    (r".*", P()),
]


def _match(path: str, stacked: bool, mesh: Mesh, shape,
           serve_replicate_fsdp: bool) -> P:
    for pat, spec in _RULES:
        if re.fullmatch(pat, path):
            axes = tuple(spec)
            if serve_replicate_fsdp:
                axes = tuple(None if a == "data" else a for a in axes)
            if stacked:
                axes = (None,) + axes  # leading period axis
            return _fit(mesh, P(*axes), shape)
    raise AssertionError(f"no rule for {path}")


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, f"{prefix}/{k}" if prefix else k)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _fsdp_only_spec(mesh: Mesh, shape, stacked: bool, serve_rep: bool) -> P:
    """Pure ZeRO-3: shard the first dimension (after any stacked period
    axis) that the flattened (data, model) axes divide; replicate the rest.
    Used for tp_friendly=False archs whose blocks gain nothing from TP."""
    axes_full = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    if serve_rep:
        axes_full = tuple(a for a in axes_full if a != "data")
    start = 1 if stacked else 0
    out = [None] * len(shape)
    for cand in (axes_full, axes_full[:1]):
        if not cand:
            continue
        size = _axis_size(mesh, cand)
        for i in range(start, len(shape)):
            if shape[i] % size == 0 and shape[i] >= size:
                out[i] = cand if len(cand) > 1 else cand[0]
                return P(*out)
    return P(*out)


def param_specs(cfg: ModelConfig, params, mesh: Mesh, mode: str = "train"):
    """PartitionSpec pytree matching ``params``. mode: train|serve."""
    serve_rep = (mode == "serve"
                 and cfg.param_counts()["total"] < BIG_MODEL_B)
    fsdp_only = not cfg.tp_friendly

    def walk(tree, prefix="", stacked=False):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k,
                            stacked or k == "blocks") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(walk(v, f"{prefix}/{i}", stacked)
                     for i, v in enumerate(tree))
        # leaf
        path = re.sub(r"/\d+(/|$)", r"\1", prefix)  # strip list indices
        is_stacked = "blocks" in prefix.split("/")
        shape = tree.shape
        if fsdp_only:
            return _fsdp_only_spec(mesh, shape, is_stacked, serve_rep)
        return _match(path, is_stacked, mesh, shape, serve_rep)

    return walk(params)


def cache_specs(cfg: ModelConfig, cache, mesh: Mesh):
    """Specs for a Cache pytree: batch over DP; KV caches are
    **sequence-sharded** over 'model' (Hkv rarely divides the axis, and
    sequence sharding beats head_dim sharding 11× on decode collectives:
    softmax over the sharded S needs only (B, H, G)-sized max/sum
    all-reduces, vs 16 MiB score all-reduces with hd sharding — §Perf
    iteration 3; the one-token cache write lowers to an owner-masked
    dynamic-update-slice with a 0.5 MiB new-token all-gather).
    Recurrent-state inner dims shard over 'model'."""
    dp = dp_axes(mesh)

    def leaf_spec(x):
        shape = x.shape
        if x.ndim == 5:          # (P_rep, B, S, Hkv, hd) KV cache
            spec = _fit(mesh, P(None, dp, "model", None, None), shape)
            if tuple(spec)[2] is None:   # S not divisible: fall back to hd
                spec = _fit(mesh, P(None, dp, None, None, "model"), shape)
            return spec
        if x.ndim == 4:          # mlstm C (P_rep? B H D D) / conv windows
            return _fit(mesh, P(None, dp, None, "model"), shape)
        if x.ndim == 3:          # (P_rep, B, d) style states
            return _fit(mesh, P(None, dp, "model"), shape)
        if x.ndim in (1, 2):
            return _fit(mesh, P(dp), shape) if shape and shape[0] > 1 else P()
        return P()

    return jax.tree.map(leaf_spec, cache)


def batch_specs(cfg: ModelConfig, batch, mesh: Mesh):
    dp = dp_axes(mesh)

    def leaf_spec(x):
        nd = getattr(x, "ndim", None) or len(x.shape)
        return _fit(mesh, P(*((dp,) + (None,) * (nd - 1))), x.shape)

    return jax.tree.map(leaf_spec, batch)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))
