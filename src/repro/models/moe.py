"""Token-choice top-k Mixture-of-Experts with capacity (GShard-style drop).

TPU/GSPMD adaptation: instead of the GShard one-hot dispatch einsum (whose
(T, E, C) combine tensor is ~5·10⁹ elements for the llama4 train cell) we use
the sort-based ragged dispatch used by production JAX MoE stacks:

  1. router top-k → (T, k) expert ids + weights,
  2. flat (T·k,) expert ids argsorted → tokens grouped by expert,
  3. position-in-expert from the sorted order; slots ≥ capacity dropped,
  4. gather tokens into an (E, C, d) buffer, batched expert GEMMs
     (E sharded over the 'model' axis = expert parallelism; the gather from
     data-sharded tokens into expert-sharded buffers is the EP all-to-all,
     visible in the dry-run collective bytes),
  5. combine by gathering each token's (expert, slot) output × router weight.

Capacity C = ceil(T·k·capacity_factor / E), padded to a multiple of 8 for
TPU sublane alignment.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, _dtype
from .sharding import constrain

Params = Dict


def moe_init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, m.d_ff, m.n_experts
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(ff)
    return {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wi": {"w": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                     * scale_in).astype(dt)},
        "wg": {"w": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
                     * scale_in).astype(dt)},
        "wo": {"w": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                     * scale_out).astype(dt)},
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_apply(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Load-balancing aux loss is the standard
    Switch/GShard  E · Σ_e f_e · p_e  term."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    C = capacity(cfg, T)
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_w, top_e = jax.lax.top_k(probs, k)                       # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # aux loss (fraction routed vs router prob mass)
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)        # (T, k, E)
    f = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)               # (E,)
    aux = E * jnp.sum(f * jnp.mean(probs, axis=0))

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = top_e.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(flat_e)                                  # stable
    sorted_e = flat_e[order]
    # position within expert group = index - first index of that expert
    counts = jnp.bincount(sorted_e, length=E)                    # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(T * k) - starts[sorted_e]            # (T*k,)
    keep = pos_sorted < C
    # scatter (expert, slot) <- flat token index; dropped slots point at T
    slot_token = jnp.full((E * C,), T, jnp.int32)
    dst = sorted_e * C + pos_sorted.astype(jnp.int32)
    src_token = (order // k).astype(jnp.int32)
    slot_token = slot_token.at[jnp.where(keep, dst, E * C)].set(
        src_token, mode="drop")
    slot_token = slot_token.reshape(E, C)

    # gather tokens (padded row T = zeros) -> expert buffers
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = constrain(xpad[slot_token], "model", None, None)        # (E, C, d)

    # ---- expert GEMMs (E sharded over 'model') -------------------------------
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"]["w"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"]["w"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    ye = constrain(jnp.einsum("ecf,efd->ecd", h, p["wo"]["w"]),
                   "model", None, None)                          # (E, C, d)

    # ---- combine -------------------------------------------------------------
    # token's k-th choice lives at (expert=top_e, slot): recover slot by
    # inverting the scatter through the sorted order
    slot_flat = jnp.full((T * k,), C, jnp.int32)                 # C = dropped
    slot_flat = slot_flat.at[order].set(
        jnp.where(keep, pos_sorted, C).astype(jnp.int32))
    slot = slot_flat.reshape(T, k)
    ypad = jnp.concatenate(
        [ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)            # slot C = 0
    gathered = ypad[top_e, slot]                                 # (T, k, d)
    y = jnp.sum(gathered.astype(jnp.float32)
                * top_w[..., None], axis=1).astype(x.dtype)
    return y.reshape(B, S, d), aux
