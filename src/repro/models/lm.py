"""The language-model zoo: one functional LM covering all 10 assigned
architectures via the block-pattern config (dense / MoE / SSM / xLSTM /
hybrid / enc-dec / VLM).

Layer stacking uses ``lax.scan`` over *periods* (the repeating block pattern)
with per-position parameters stacked across periods, so the HLO is O(period)
regardless of depth; each period is wrapped in ``jax.checkpoint`` with a
configurable policy for training.

Public entry points (all pure):
    init(key, cfg)                                  -> params
    loss_fn(params, cfg, batch, use_pallas)         -> (loss, aux)
    train_logits(params, cfg, batch)                -> logits
    prefill(params, cfg, batch)                     -> (last_logits, Cache)
    decode_step(params, cfg, token, cache)          -> (logits, Cache)
    make_cache(cfg, batch, max_seq)                 -> empty Cache (decode-only
                                                       dry-runs)

``batch`` is a dict: tokens (B, S) int32, and for the stub-frontend archs
"frames" (audio) / "patches" (vlm): (B, T, d_model) precomputed embeddings.
Decode state is a ``Cache`` pytree whose leaves are stacked over periods.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from . import ssm as S
from . import xlstm as X
from .config import ModelConfig
from .sharding import constrain

Params = Dict


class Cache(NamedTuple):
    """Decode state. Per-pattern-position dict entries, each stacked over
    periods on axis 0. ``pos`` is the shared decode cursor (synchronized
    continuous batching keeps rows aligned; per-row fill lives in kv_len)."""
    layer: Tuple                     # tuple over pattern positions
    cross: Tuple                     # cross-attn K/V per position ((), if none)
    enc: Optional[jax.Array]         # encoder output (whisper), else None
    kv_len: jax.Array                # (B,) valid lengths
    pos: jax.Array                   # scalar int32 cursor


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig):
    return (L.norm_init(cfg.d_model) if cfg.norm == "rmsnorm"
            else L.layernorm_init(cfg.d_model))


def _block_init(key, cfg: ModelConfig, mixer: str, ffn: str) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": _norm_init(cfg)}
    if mixer in ("attn", "attn_bidir"):
        p["mixer"] = L.attn_init(ks[0], cfg)
    elif mixer == "cross":
        p["mixer"] = L.attn_init(ks[0], cfg, cross=True)
    elif mixer == "attn_cross":
        p["mixer"] = L.attn_init(ks[0], cfg)
        p["mixer2"] = L.attn_init(ks[3], cfg, cross=True)
        p["norm1b"] = _norm_init(cfg)
    elif mixer == "mamba":
        p["mixer"] = S.mamba_init(ks[0], cfg)
    elif mixer == "mlstm":
        p["mixer"] = X.mlstm_init(ks[0], cfg)
    elif mixer == "slstm":
        p["mixer"] = X.slstm_init(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if ffn == "dense":
        p["norm2"] = _norm_init(cfg)
        p["ffn"] = L.ffn_init(ks[1], cfg)
    elif ffn == "moe":
        p["norm2"] = _norm_init(cfg)
        p["ffn"] = M.moe_init(ks[1], cfg)
    return p


def _stacked_block_init(key, cfg: ModelConfig, mixer: str, ffn: str,
                        n: int) -> Params:
    keys = jax.random.split(key, n)
    ps = [_block_init(k, cfg, mixer, ffn) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, len(cfg.pattern) + 4)
    params: Params = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, L._dtype(cfg)),
        "final_norm": (L.norm_init(cfg.d_model) if cfg.norm == "rmsnorm"
                       else L.layernorm_init(cfg.d_model)),
        "blocks": [
            _stacked_block_init(ks[2 + i], cfg, mixer, ffn, cfg.n_periods)
            for i, (mixer, ffn) in enumerate(cfg.pattern)],
    }
    if not cfg.tie_embeddings:
        params["head"] = L.embed_init(ks[1], cfg.vocab, cfg.d_model,
                                      L._dtype(cfg))
    if cfg.encoder is not None:
        enc_cfg = cfg
        ke = jax.random.split(ks[-1], cfg.encoder.n_layers + 2)
        params["encoder"] = {
            "pos": (jax.random.normal(ke[0], (cfg.encoder.n_frames,
                                              cfg.d_model), jnp.float32)
                    * 0.02).astype(L._dtype(cfg)),
            "blocks": _stacked_block_init(ke[1], enc_cfg, "attn_bidir",
                                          "dense", cfg.encoder.n_layers),
            "final_norm": L.layernorm_init(cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# block application (full-sequence path)
# ---------------------------------------------------------------------------

def _apply_block_full(p, cfg: ModelConfig, mixer: str, ffn: str, x, *,
                      positions, cross_x, causal: bool, use_pallas: str,
                      collect_cache: bool):
    """Returns (x, cache_entry, aux)."""
    aux = jnp.float32(0.0)
    cache = ()
    h = L.apply_norm(cfg, p["norm1"], x)
    if mixer in ("attn", "attn_bidir"):
        if collect_cache:
            y, kv = L.attention_prefill_cache(p["mixer"], cfg, h, positions)
            cache = kv
        else:
            y = L.attention(p["mixer"], cfg, h, positions=positions,
                            causal=causal and mixer == "attn",
                            use_pallas=use_pallas)
        x = x + y
    elif mixer == "cross":
        kv = L.cross_kv(p["mixer"], cfg, cross_x)
        y = L.cross_attention_cached(p["mixer"], cfg, h, kv)
        if collect_cache:
            cache = kv
        x = x + y
    elif mixer == "attn_cross":
        if collect_cache:
            y, kv = L.attention_prefill_cache(p["mixer"], cfg, h, positions)
        else:
            y = L.attention(p["mixer"], cfg, h, positions=positions,
                            causal=True, use_pallas=use_pallas)
            kv = None
        x = x + y
        h2 = L.apply_norm(cfg, p["norm1b"], x)
        ckv = L.cross_kv(p["mixer2"], cfg, cross_x)
        x = x + L.cross_attention_cached(p["mixer2"], cfg, h2, ckv)
        if collect_cache:
            cache = (kv, ckv)
    elif mixer == "mamba":
        y, st = S.mamba_forward(p["mixer"], cfg, h)
        if collect_cache:
            cache = st
        x = x + y
    elif mixer == "mlstm":
        y, st = X.mlstm_forward(p["mixer"], cfg, h)
        if collect_cache:
            cache = st
        x = x + y
    elif mixer == "slstm":
        y, st = X.slstm_forward(p["mixer"], cfg, h)
        if collect_cache:
            cache = st
        x = x + y

    if ffn == "dense":
        x = x + L.ffn_apply(p["ffn"], L.apply_norm(cfg, p["norm2"], x),
                            activation=cfg.activation)
    elif ffn == "moe":
        y, aux = M.moe_apply(p["ffn"], cfg, L.apply_norm(cfg, p["norm2"], x))
        x = x + y
    return x, cache, aux


REMAT_POLICIES = {
    "full": None,   # save only period boundaries; recompute everything
    # save the per-layer FFN hidden activations: ~60% of the remat
    # recompute FLOPs for (B·S·d_ff/TP) bf16 per layer of memory
    "save_ffn_hidden": "ffn_hidden",
}


def _backbone_full(params, cfg: ModelConfig, x, *, positions, cross_x,
                   causal=True, use_pallas="auto", collect_cache=False,
                   remat=True, unroll=False, remat_policy="full"):
    """Run the block pattern over periods: ``lax.scan`` by default (O(1) HLO
    in depth), or a Python loop with ``unroll=True`` — used by the dry-run so
    ``cost_analysis`` sees every period (XLA counts while bodies once).
    Returns (x, caches, aux_sum)."""

    def period_body(x, stacked_slice):
        caches = []
        aux = jnp.float32(0.0)
        x = constrain(x, "dp", None, None)
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, c, a = _apply_block_full(
                stacked_slice[i], cfg, mixer, ffn, x, positions=positions,
                cross_x=cross_x, causal=causal, use_pallas=use_pallas,
                collect_cache=collect_cache)
            caches.append(c)
            aux = aux + a
        return x, (tuple(caches), aux)

    if remat:
        name = REMAT_POLICIES.get(remat_policy)
        policy = (jax.checkpoint_policies.save_only_these_names(name)
                  if name else None)
        body = jax.checkpoint(period_body, policy=policy)
    else:
        body = period_body
    if unroll:
        caches_list, aux_sum = [], jnp.float32(0.0)
        for pi in range(cfg.n_periods):
            sl = jax.tree.map(lambda a: a[pi], params["blocks"])
            x, (caches, aux) = body(x, sl)
            caches_list.append(caches)
            aux_sum = aux_sum + aux
        if caches_list and any(c != () for c in caches_list[0]):
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_list)
        else:
            caches = caches_list[0] if caches_list else ()
        return x, caches, aux_sum
    x, (caches, aux) = jax.lax.scan(
        lambda carry, sl: body(carry, sl), x, params["blocks"])
    return x, caches, jnp.sum(aux)


def _encode(params, cfg: ModelConfig, frames, unroll=False):
    """Whisper encoder over stubbed conv-frontend output (B, T, d)."""
    enc = params["encoder"]
    T = frames.shape[1]
    x = frames + enc["pos"][None, :T]
    positions = jnp.arange(T)[None, :]

    def body(x, p):
        x, _, _ = _apply_block_full(p, cfg, "attn_bidir", "dense", x,
                                    positions=positions, cross_x=None,
                                    causal=False, use_pallas="auto",
                                    collect_cache=False)
        return x, ()

    if unroll:
        for li in range(cfg.encoder.n_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[li], enc["blocks"]))
    else:
        x, _ = jax.lax.scan(lambda c, p: body(c, p), x, enc["blocks"])
    return L.layer_norm(enc["final_norm"], x, cfg.norm_eps)


def _cross_input(params, cfg: ModelConfig, batch, unroll=False):
    if cfg.family == "audio":
        return _encode(params, cfg, batch["frames"], unroll=unroll)
    if cfg.family == "vlm":
        return batch["patches"]
    return None


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def train_logits(params, cfg: ModelConfig, batch, use_pallas="auto",
                 remat=True, unroll=False, remat_policy="full"):
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(Sq)[None, :]
    cross_x = _cross_input(params, cfg, batch, unroll=unroll)
    x, _, aux = _backbone_full(params, cfg, x, positions=positions,
                               cross_x=cross_x, use_pallas=use_pallas,
                               remat=remat, unroll=unroll,
                               remat_policy=remat_policy)
    x = L.apply_norm(cfg, params["final_norm"], x) \
        if cfg.norm == "rmsnorm" else L.layer_norm(params["final_norm"], x,
                                                   cfg.norm_eps)
    head = params.get("head", params["embed"])
    return L.unembed(head, x), aux


def loss_fn(params, cfg: ModelConfig, batch, use_pallas="auto", remat=True,
            aux_weight: float = 0.01, unroll=False, remat_policy="full"):
    logits, aux = train_logits(params, cfg, batch, use_pallas, remat, unroll,
                               remat_policy)
    labels = batch["labels"]
    # sharding-friendly cross-entropy: logsumexp reduces over the (possibly
    # vocab-sharded) last axis; the label logit comes from a mask-select
    # rather than a gather so no cross-shard index arithmetic is needed.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    V = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    nll = constrain(lse - label_logit, "dp", None)
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, max_seq: Optional[int] = None,
            use_pallas="auto", unroll=False,
            length: Optional[jax.Array] = None):
    """Run the prompt, return (last-token logits, Cache). KV caches are
    allocated at ``max_seq`` (default: prompt length) and prefixed with the
    prompt's K/V.

    ``length`` enables **bucketed prefill**: ``tokens`` may be padded past
    the real prompt (to a compile-size bucket) and ``length`` is the dynamic
    true length — logits are read at row ``length - 1`` and the cache's
    ``kv_len``/``pos`` marks only the real prompt as valid, so the padded
    tail (whose K/V rows land beyond ``kv_len`` and get overwritten by
    decode) cannot perturb outputs. Exact for pure-attention *dense*
    patterns (causal masking keeps rows independent); recurrent mixers
    integrate every token and MoE capacity lets padding displace real
    tokens from expert slots, so callers must not pad those (the serving
    engine gates on the block pattern)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    max_seq = max_seq or Sq
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(Sq)[None, :]
    cross_x = _cross_input(params, cfg, batch, unroll=unroll)
    x, caches, _ = _backbone_full(params, cfg, x, positions=positions,
                                  cross_x=cross_x, use_pallas=use_pallas,
                                  collect_cache=True, remat=False,
                                  unroll=unroll)
    x = (L.apply_norm(cfg, params["final_norm"], x) if cfg.norm == "rmsnorm"
         else L.layer_norm(params["final_norm"], x, cfg.norm_eps))
    head = params.get("head", params["embed"])
    if length is None:
        last = x[:, -1:]
        kv_fill, pos_fill = Sq, jnp.int32(Sq)
    else:
        length = jnp.asarray(length, jnp.int32)
        last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        kv_fill, pos_fill = length, length
    logits = L.unembed(head, last)[:, 0]

    layer_caches, cross_caches = [], []
    for (mixer, _), c in zip(cfg.pattern, caches):
        if mixer in ("attn", "attn_bidir"):
            k, v = c
            layer_caches.append((_pad_cache(k, max_seq),
                                 _pad_cache(v, max_seq)))
            cross_caches.append(())
        elif mixer == "attn_cross":
            (k, v), ckv = c
            layer_caches.append((_pad_cache(k, max_seq),
                                 _pad_cache(v, max_seq)))
            cross_caches.append(ckv)
        elif mixer == "cross":
            layer_caches.append(())
            cross_caches.append(c)
        else:  # recurrent state
            layer_caches.append(c)
            cross_caches.append(())
    cache = Cache(layer=tuple(layer_caches), cross=tuple(cross_caches),
                  enc=None, kv_len=jnp.full((B,), kv_fill, jnp.int32),
                  pos=jnp.asarray(pos_fill, jnp.int32))
    return logits, cache


def prefill_extend(params, cfg: ModelConfig, batch, prefix,
                   max_seq: Optional[int] = None,
                   prefix_len: Optional[jax.Array] = None,
                   length: Optional[jax.Array] = None):
    """Prefill only the uncached suffix of a prompt (paged prefix reuse).

    ``batch["tokens"]`` holds the (B, S_new) suffix; ``prefix`` is a tuple
    over pattern positions of (k, v), each (P, B, S_pre, Hkv, D) — the cached
    whole-block prefix gathered by ``serving.kvcache.PagedKVStore``. Returns
    (last-token logits, Cache) covering prefix + suffix, exactly as
    ``prefill`` on the concatenated prompt would (suffix queries attend the
    cached keys under the same causal mask, so outputs are bit-identical).

    **Bucketed mode** (compile-once admission): with ``prefix_len`` given,
    the prefix buffer is padded to a fixed block budget (only the first
    ``prefix_len`` dynamic rows are real) and the suffix tokens may be
    padded to a length bucket with ``length`` as the true suffix length —
    one executable then serves every (matched-blocks, suffix-length)
    combination in the bucket. The returned cache stays contiguous: suffix
    K/V is written at the dynamic ``prefix_len`` offset of a max_seq buffer.

    Pure-attention patterns only: recurrent mixers carry no position-sliceable
    prefix state (the serving engine gates paged mode on the same predicate).
    """
    assert all(mixer == "attn" for mixer, _ in cfg.pattern), \
        "prefill_extend supports pure-attention block patterns"
    tokens = batch["tokens"]
    B, Sn = tokens.shape
    S_pre = prefix[0][0].shape[2]
    bucketed = prefix_len is not None
    if bucketed:
        prefix_len = jnp.asarray(prefix_len, jnp.int32)
        suffix_len = (jnp.asarray(length, jnp.int32) if length is not None
                      else jnp.int32(Sn))
        total = prefix_len + suffix_len
        assert max_seq is not None and Sn <= max_seq, \
            "bucketed extend needs an explicit max_seq >= the padded suffix"
        positions = prefix_len + jnp.arange(Sn)[None, :]
    else:
        total = S_pre + Sn
        positions = S_pre + jnp.arange(Sn)[None, :]
    max_seq = max_seq or (S_pre + Sn)
    x = L.embed(params["embed"], tokens)

    def period_body(x, sl):
        stacked, pref = sl
        new_kv = []
        aux = jnp.float32(0.0)
        x = constrain(x, "dp", None, None)
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            p = stacked[i]
            h = L.apply_norm(cfg, p["norm1"], x)
            y, kv = L.attention_prefill_extend(
                p["mixer"], cfg, h, positions, pref[i],
                prefix_len=prefix_len if bucketed else None)
            x = x + y
            new_kv.append(kv)
            if ffn == "dense":
                x = x + L.ffn_apply(p["ffn"], L.apply_norm(cfg, p["norm2"], x),
                                    activation=cfg.activation)
            elif ffn == "moe":
                y2, aux2 = M.moe_apply(p["ffn"], cfg,
                                       L.apply_norm(cfg, p["norm2"], x))
                x = x + y2
                aux = aux + aux2
        return x, tuple(new_kv)

    x, caches = jax.lax.scan(lambda c, sl: period_body(c, sl), x,
                             (params["blocks"], prefix))
    x = (L.apply_norm(cfg, params["final_norm"], x) if cfg.norm == "rmsnorm"
         else L.layer_norm(params["final_norm"], x, cfg.norm_eps))
    head = params.get("head", params["embed"])
    if bucketed:
        last = jax.lax.dynamic_slice_in_dim(x, suffix_len - 1, 1, axis=1)
    else:
        last = x[:, -1:]
    logits = L.unembed(head, last)[:, 0]

    if bucketed:
        # contiguous cache: prefix buffer padded to max_seq, suffix K/V
        # written at the dynamic prefix_len offset (real rows [0, total) —
        # anything beyond is masked by kv_len and overwritten by decode)
        def assemble(pre, suf):
            base = _pad_cache(pre, max_seq)
            return jax.lax.dynamic_update_slice(
                base, suf.astype(base.dtype),
                (0, 0, prefix_len, 0, 0))

        layer_caches = tuple(
            (assemble(pre_k, k), assemble(pre_v, v))
            for (pre_k, pre_v), (k, v) in zip(prefix, caches))
    else:
        layer_caches = tuple((_pad_cache(k, max_seq), _pad_cache(v, max_seq))
                             for k, v in caches)
    cache = Cache(layer=layer_caches,
                  cross=tuple(() for _ in cfg.pattern), enc=None,
                  kv_len=jnp.full((B,), total, jnp.int32),
                  pos=jnp.asarray(total, jnp.int32))
    return logits, cache


def _pad_cache(k, max_seq):
    """(P_rep, B, S, H, D) -> padded to max_seq along S."""
    pad = max_seq - k.shape[2]
    if pad <= 0:
        return k
    return jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))


# ---------------------------------------------------------------------------
# serving: decode
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               kv_len: Optional[jax.Array] = None,
               cross_tokens: Optional[int] = None) -> Cache:
    """Empty (or logically-filled) decode cache for decode-only dry-runs:
    allocates the same buffers prefill would, with kv_len marking the fill."""
    dt = L._dtype(cfg)
    P_rep = cfg.n_periods
    layer, cross = [], []
    for mixer, _ in cfg.pattern:
        if mixer in ("attn", "attn_bidir", "attn_cross"):
            shp = (P_rep, batch_size, max_seq, cfg.n_kv_heads, cfg.hd)
            layer.append((jnp.zeros(shp, dt), jnp.zeros(shp, dt)))
            if mixer == "attn_cross":
                t = cross_tokens or cfg.cross_kv_tokens
                cshp = (P_rep, batch_size, t, cfg.n_kv_heads, cfg.hd)
                cross.append((jnp.zeros(cshp, dt), jnp.zeros(cshp, dt)))
            else:
                cross.append(())
        elif mixer == "cross":
            layer.append(())
            t = cross_tokens or cfg.cross_kv_tokens
            cshp = (P_rep, batch_size, t, cfg.n_kv_heads, cfg.hd)
            cross.append((jnp.zeros(cshp, dt), jnp.zeros(cshp, dt)))
        elif mixer == "mamba":
            h, cw = S.init_state(cfg, batch_size)
            layer.append((_rep(h, P_rep), _rep(cw, P_rep)))
            cross.append(())
        elif mixer == "mlstm":
            st = X.init_mlstm_state(cfg, batch_size)
            layer.append(tuple(_rep(s, P_rep) for s in st))
            cross.append(())
        elif mixer == "slstm":
            st = X.init_slstm_state(cfg, batch_size)
            layer.append(tuple(_rep(s, P_rep) for s in st))
            cross.append(())
    kv_len = (jnp.zeros((batch_size,), jnp.int32) if kv_len is None
              else kv_len)
    return Cache(layer=tuple(layer), cross=tuple(cross), enc=None,
                 kv_len=kv_len, pos=jnp.max(kv_len).astype(jnp.int32))


def _rep(x, n):
    return jnp.broadcast_to(x[None], (n,) + x.shape)


def _apply_block_decode(p, cfg: ModelConfig, mixer: str, ffn: str, x,
                        cache_entry, cross_entry, kv_len, pos, use_pallas):
    """x: (B, 1, d). Returns (x, new_cache_entry)."""
    h = L.apply_norm(cfg, p["norm1"], x)
    new_entry = cache_entry
    if mixer in ("attn", "attn_bidir", "attn_cross"):
        k, v = cache_entry
        y, (k, v) = L.attention_decode(p["mixer"], cfg, h, (k, v), kv_len,
                                       use_pallas=use_pallas)
        new_entry = (k, v)
        x = x + y
        if mixer == "attn_cross":
            h2 = L.apply_norm(cfg, p["norm1b"], x)
            x = x + L.cross_attention_cached(p["mixer2"], cfg, h2, cross_entry)
    elif mixer == "cross":
        x = x + L.cross_attention_cached(p["mixer"], cfg, h, cross_entry)
    elif mixer == "mamba":
        y, st = S.mamba_decode(p["mixer"], cfg, h, cache_entry)
        new_entry = st
        x = x + y
    elif mixer == "mlstm":
        y, st = X.mlstm_forward(p["mixer"], cfg, h, state=cache_entry)
        new_entry = st
        x = x + y
    elif mixer == "slstm":
        y, st = X.slstm_forward(p["mixer"], cfg, h, state=cache_entry)
        new_entry = st
        x = x + y

    if ffn == "dense":
        x = x + L.ffn_apply(p["ffn"], L.apply_norm(cfg, p["norm2"], x),
                            activation=cfg.activation)
    elif ffn == "moe":
        y, _ = M.moe_apply(p["ffn"], cfg, L.apply_norm(cfg, p["norm2"], x))
        x = x + y
    return x, new_entry


def decode_step(params, cfg: ModelConfig, token, cache: Cache,
                use_pallas="auto", unroll=False):
    """token: (B, 1) int32. Returns (logits (B, vocab), new Cache)."""
    B = token.shape[0]
    x = L.embed(params["embed"], token)

    def period_body(x, sl):
        stacked, layer_c, cross_c = sl
        new_cs = []
        x = constrain(x, "dp", None, None)
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, nc = _apply_block_decode(
                stacked[i], cfg, mixer, ffn, x, layer_c[i], cross_c[i],
                cache.kv_len, cache.pos, use_pallas)
            new_cs.append(nc)
        return x, tuple(new_cs)

    if unroll:
        new_per_period = []
        for pi in range(cfg.n_periods):
            sl = jax.tree.map(lambda a: a[pi],
                              (params["blocks"], cache.layer, cache.cross))
            x, ncs = period_body(x, sl)
            new_per_period.append(ncs)
        new_layer = jax.tree.map(lambda *xs: jnp.stack(xs), *new_per_period)
    else:
        x, new_layer = jax.lax.scan(
            lambda c, sl: period_body(c, sl), x,
            (params["blocks"], cache.layer, cache.cross))
    x = (L.apply_norm(cfg, params["final_norm"], x) if cfg.norm == "rmsnorm"
         else L.layer_norm(params["final_norm"], x, cfg.norm_eps))
    head = params.get("head", params["embed"])
    logits = L.unembed(head, x)[:, 0]
    new_cache = cache._replace(layer=new_layer, kv_len=cache.kv_len + 1,
                               pos=cache.pos + 1)
    return logits, new_cache
