"""Model zoo: the 10 assigned architectures as one pattern-configured LM."""
from . import lm
from .config import SHAPES, ModelConfig, ShapeCell, cell_applicable

__all__ = ["lm", "ModelConfig", "SHAPES", "ShapeCell", "cell_applicable"]
