"""Mamba selective-SSM block (for the Jamba hybrid arch).

Selective scan with diagonal state transition (Mamba-1, arXiv:2312.00752),
adapted for TPU:

* The recurrence h_t = a_t ⊙ h_{t-1} + b_t (a_t = exp(Δ_t·A)) is evaluated
  **chunkwise**: sequential ``lax.scan`` over chunks of ``cfg.ssm.chunk``
  tokens carrying the (B, d_inner, d_state) boundary state, with a parallel
  ``associative_scan`` inside each chunk. This bounds the live scan tensor to
  (B, chunk, d_inner, d_state) — sharded over 'model' on d_inner — instead of
  the full-sequence (B, L, d_inner, d_state) a naive associative scan would
  materialize (17 GB/device at the jamba train cell).
* d_inner (= expand·d_model) is the tensor-parallel axis throughout: in_proj
  column-parallel, out_proj row-parallel, conv/dt/B/C all elementwise or
  row-local in d_inner — one all-reduce per block, Megatron-style.

Decode is the O(1) recurrent step; its state is (h, conv window).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dtype, dense_init
from .sharding import constrain

Params = Dict


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def mamba_init(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    dt = _dtype(cfg)
    di, dr, ds = d_inner(cfg), dt_rank(cfg), s.d_state
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * di, dt),
        "conv": {"w": (jax.random.normal(ks[1], (s.d_conv, di), jnp.float32)
                       / math.sqrt(s.d_conv)).astype(dt),
                 "b": jnp.zeros((di,), jnp.float32)},
        "x_proj": dense_init(ks[2], di, dr + 2 * ds, dt),
        "dt_proj": {"w": (jax.random.normal(ks[3], (dr, di), jnp.float32)
                          * (dr ** -0.5)).astype(dt),
                    "b": jnp.full((di,), -4.6, jnp.float32)},  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, cfg.d_model, dt),
    }


def _ssm_params(p, cfg: ModelConfig, xc):
    """xc: (B, L, di) post-conv activations -> (dtv, Bv, Cv) f32."""
    ds = cfg.ssm.d_state
    dr = dt_rank(cfg)
    proj = jnp.einsum("bld,de->ble", xc, p["x_proj"]["w"]).astype(jnp.float32)
    dt_in, Bv, Cv = jnp.split(proj, [dr, dr + ds], axis=-1)
    dtv = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_in, p["dt_proj"]["w"].astype(jnp.float32))
        + p["dt_proj"]["b"])
    return dtv, Bv, Cv


def _scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t over axis 1, chunked. a, b: (B, L, di, ds)."""
    B, L, di, ds = a.shape
    n = L // chunk
    a = a.reshape(B, n, chunk, di, ds)
    b = b.reshape(B, n, chunk, di, ds)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, ab):
        ac, bc = ab                                  # (B, chunk, di, ds)
        A_cum, B_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = A_cum * h[:, None] + B_cum              # states at every t
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, L, di, ds)
    return h_last, hs


def mamba_forward(p, cfg: ModelConfig, x, state=None
                  ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence forward. x: (B, L, d). Returns (y, (h, conv_win)).

    L is padded up to a chunk multiple with *state-neutral* steps
    (Δt = 0 ⇒ a = 1, b = 0), so the returned state is exact at position L.
    """
    s = cfg.ssm
    B, L0, _ = x.shape
    chunk0 = min(s.chunk, L0)
    pad = (-L0) % chunk0
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    B, L, _ = x.shape
    di = d_inner(cfg)
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"]["w"])
    xi, z = jnp.split(xz, 2, axis=-1)                # (B, L, di)
    xi = constrain(xi, "dp", None, "model")
    z = constrain(z, "dp", None, "model")

    # causal depthwise conv (window d_conv)
    if state is not None:
        conv_win = state[1]                          # (B, d_conv-1, di)
        xpad = jnp.concatenate([conv_win, xi], axis=1)
    else:
        xpad = jnp.pad(xi, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + L] * p["conv"]["w"][i] for i in range(s.d_conv))
    xc = jax.nn.silu((xc + p["conv"]["b"]).astype(jnp.float32)).astype(x.dtype)
    new_conv_win = jax.lax.dynamic_slice_in_dim(xpad, L0, s.d_conv - 1, 1)

    dtv, Bv, Cv = _ssm_params(p, cfg, xc)            # f32
    if pad:
        valid = (jnp.arange(L) < L0)[None, :, None]
        dtv = jnp.where(valid, dtv, 0.0)             # a=1, b=0 on pad steps
    A = -jnp.exp(p["A_log"])                         # (di, ds)
    a = jnp.exp(dtv[..., None] * A[None, None])      # (B, L, di, ds)
    bterm = (dtv * xc.astype(jnp.float32))[..., None] * Bv[:, :, None, :]

    h0 = (state[0] if state is not None
          else jnp.zeros((B, di, s.d_state), jnp.float32))
    chunk = min(s.chunk, L)
    assert L % chunk == 0, (L, chunk)
    h_last, hs = _scan_chunked(a, bterm, h0, chunk)

    y = jnp.einsum("blds,bls->bld", hs, Cv) + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = constrain(jnp.einsum("bld,de->ble", y, p["out_proj"]["w"]),
                    "dp", None, None)
    if pad:
        out = out[:, :L0]
    return out, (h_last, new_conv_win)


def mamba_decode(p, cfg: ModelConfig, x, state
                 ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token step. x: (B, 1, d); state: (h (B, di, ds), conv_win)."""
    return mamba_forward(p, cfg, x, state=state)


def init_state(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = d_inner(cfg)
    return (jnp.zeros((batch, di, s.d_state), jnp.float32),
            jnp.zeros((batch, s.d_conv - 1, di),
                      _dtype(cfg)))
