"""xlstm-1.3b [ssm]: 48 blocks d=2048, mLSTM:sLSTM 7:1, no separate FFN
(d_ff=0; blocks carry their own projections). [arXiv:2405.04517; unverified]

mLSTM: 4 heads over a 2x up-projection (d_inner 4096, head dim 1024),
chunkwise-parallel linear-attention form. sLSTM: 4 heads at d_model with
recurrent gate matrices + 4/3x FFN. Sub-quadratic: runs the long_500k cell.
"""
from ..models.config import ModelConfig, XLSTMCfg
from ._base import make_card

NAME = "xlstm-1.3b"

_PATTERN = tuple([("mlstm", "none")] * 7 + [("slstm", "none")])


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="ssm", n_layers=48, d_model=2048, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab=50304, pattern=_PATTERN,
        xlstm=XLSTMCfg(), tie_embeddings=True, supports_long_context=True,
        tp_friendly=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="ssm", n_layers=8, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab=256, pattern=_PATTERN,
        xlstm=XLSTMCfg(chunk=16), tie_embeddings=True,
        supports_long_context=True, tp_friendly=False)


def card():
    return make_card(NAME, config())
