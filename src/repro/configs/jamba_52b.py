"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) ff=14336 V=65536,
Mamba:attention 1:7 interleave, MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]

Jamba block = period 8: one attention layer (index 4), seven Mamba layers;
MoE replaces the dense MLP on every second layer. Sub-quadratic overall:
runs the long_500k cell (the 4 attention layers keep KV caches; Mamba
layers carry O(1) state).
"""
from ..models.config import MoECfg, ModelConfig, SSMCfg
from ._base import make_card

NAME = "jamba-v0.1-52b"

_PATTERN = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 0 else "dense")
    for i in range(8))


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="hybrid", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=65536, head_dim=128,
        pattern=_PATTERN, moe=MoECfg(n_experts=16, top_k=2, d_ff=14336),
        ssm=SSMCfg(), supports_long_context=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="hybrid", n_layers=8, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
        pattern=_PATTERN, moe=MoECfg(n_experts=4, top_k=2, d_ff=256),
        ssm=SSMCfg(d_state=8, chunk=16), supports_long_context=True)


def card():
    return make_card(NAME, config())
