"""stablelm-3b [dense]: 32L d=2560 32H (kv=32) ff=6912 V=50304.

[hf:stabilityai/stablelm-2-1_6b family; unverified] Full multi-head attention
(kv=32 == heads), SwiGLU, RMSNorm, untied head.
"""
from ..models.config import ModelConfig
from ._base import make_card

NAME = "stablelm-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="dense", n_layers=32, d_model=2560, n_heads=32,
        n_kv_heads=32, d_ff=6912, vocab=50304, pattern=(("attn", "dense"),),
        rope_theta=1e4)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=352, vocab=512,
        pattern=(("attn", "dense"),))


def card():
    return make_card(NAME, config())
