"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) ff=9728 V=151936, qk_norm.

[hf:Qwen/Qwen3-8B family; hf] Qwen3 uses head_dim=128 (q_dim 4096 != d_model)
and per-head RMS q/k norms.
"""
from ..models.config import ModelConfig
from ._base import make_card

NAME = "qwen3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="dense", n_layers=36, d_model=2560, n_heads=32,
        n_kv_heads=8, d_ff=9728, vocab=151936, pattern=(("attn", "dense"),),
        head_dim=128, qk_norm=True, rope_theta=1e6)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
        qk_norm=True, pattern=(("attn", "dense"),))


def card():
    return make_card(NAME, config())
