"""Shared helpers for architecture config modules."""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelCard:
    """Registers an architecture as a routable model for the cluster layer.

    decode_tps / prefill_tps are *per v5e-chip* roofline-derived estimates
    (filled by benchmarks/roofline.py after the dry-run; the defaults here are
    analytic 2·N_active/HBM-bw bounds). price is a Together.ai-style $/Mtok
    proxy scaled by active parameters.
    """
    arch: str
    params_b: float
    active_params_b: float
    model_type: str = "general"
    price_per_mtok: float = 0.0
    decode_tps: float = 0.0
    prefill_tps: float = 0.0


def make_card(name: str, cfg: ModelConfig, model_type: str = "general"
              ) -> ModelCard:
    counts = cfg.param_counts()
    nb = counts["total"] / 1e9
    na = counts["active"] / 1e9
    # analytic single-chip bounds (819 GB/s HBM, bf16): decode is
    # memory-bound at N_active bytes/token; prefill compute-bound at
    # 197 TFLOP/s / 2·N_active.
    decode_tps = 819e9 / max(2e9 * na, 1e6)
    prefill_tps = 197e12 / max(2e9 * na, 1e6)
    price = 0.06 + 0.09 * na  # $/Mtok, roughly Together.ai's size scaling
    return ModelCard(arch=name, params_b=nb, active_params_b=na,
                     model_type=model_type, price_per_mtok=price,
                     decode_tps=decode_tps, prefill_tps=prefill_tps)
