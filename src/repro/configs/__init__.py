"""Architecture registry: one module per assigned architecture.

Each module exposes ``config()`` (the full published config), ``smoke()``
(a reduced same-family config for CPU tests) and ``card()`` (the ModelCard
that registers the arch as a routable model in the cluster substrate).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "stablelm_3b", "qwen3_4b", "stablelm_12b", "qwen3_1p7b", "dbrx_132b",
    "llama4_maverick_400b", "whisper_tiny", "xlstm_1p3b",
    "llama32_vision_11b", "jamba_52b",
]

# CLI ids (assignment spelling) -> module names
ALIASES: Dict[str, str] = {
    "stablelm-3b": "stablelm_3b",
    "qwen3-4b": "qwen3_4b",
    "stablelm-12b": "stablelm_12b",
    "qwen3-1.7b": "qwen3_1p7b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "whisper-tiny": "whisper_tiny",
    "xlstm-1.3b": "xlstm_1p3b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "jamba-v0.1-52b": "jamba_52b",
}


def get(name: str):
    mod = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod}")


def all_ids() -> List[str]:
    return list(ALIASES.keys())
