"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) ff=6144 V=151936, qk_norm.

[hf:Qwen/Qwen3-8B family; hf]
"""
from ..models.config import ModelConfig
from ._base import make_card

NAME = "qwen3-1.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="dense", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=6144, vocab=151936, pattern=(("attn", "dense"),),
        head_dim=128, qk_norm=True, rope_theta=1e6)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
        qk_norm=True, pattern=(("attn", "dense"),))


def card():
    return make_card(NAME, config())
