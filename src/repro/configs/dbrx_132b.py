"""dbrx-132b [moe]: 40L d=6144 48H (GQA kv=8) ff=10752 V=100352,
MoE 16 experts top-4 (fine-grained). [hf:databricks/dbrx-base; unverified]

Every layer is MoE. 16e x 3 x 6144 x 10752 x 40 = 127B expert params
+ attention/embeddings ~= 132B total, ~36B active (top-4).
"""
from ..models.config import MoECfg, ModelConfig
from ._base import make_card

NAME = "dbrx-132b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="moe", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab=100352, head_dim=128,
        pattern=(("attn", "moe"),),
        moe=MoECfg(n_experts=16, top_k=4, d_ff=10752), rope_theta=5e5)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, head_dim=32,
        pattern=(("attn", "moe"),),
        moe=MoECfg(n_experts=4, top_k=2, d_ff=256))


def card():
    return make_card(NAME, config())
