"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) ff=13824 V=100352.

[hf:stabilityai/stablelm-2-12b; hf]
"""
from ..models.config import ModelConfig
from ._base import make_card

NAME = "stablelm-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="dense", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, d_ff=13824, vocab=100352, pattern=(("attn", "dense"),),
        rope_theta=1e4)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="dense", n_layers=4, d_model=160,
        n_heads=4, n_kv_heads=1, d_ff=448, vocab=512,
        pattern=(("attn", "dense"),))


def card():
    return make_card(NAME, config())
