"""whisper-tiny [audio]: enc-dec, 4L d=384 6H ff=1536 V=51865.

[arXiv:2212.04356; unverified] Conv frontend is a STUB per the assignment:
input_specs provide precomputed frame embeddings (B, 1504, 384) — 1500 mel
frames rounded to a 32 multiple. Decoder blocks carry self- AND cross-attn
(attn_cross); LayerNorm + GELU MLPs per the original. The 32k/500k shape
cells exceed Whisper's real 448-token decoder context; they exercise the
backbone mechanically and are marked synthetic in EXPERIMENTS.md.
"""
from ..models.config import EncoderCfg, ModelConfig
from ._base import make_card

NAME = "whisper-tiny"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="audio", n_layers=4, d_model=384, n_heads=6,
        n_kv_heads=6, d_ff=1536, vocab=51865,
        pattern=(("attn_cross", "dense"),),
        encoder=EncoderCfg(n_layers=4, n_frames=1504),
        cross_kv_tokens=1504, norm="layernorm", activation="gelu",
        tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
        pattern=(("attn_cross", "dense"),),
        encoder=EncoderCfg(n_layers=2, n_frames=64),
        cross_kv_tokens=64, norm="layernorm", activation="gelu",
        tie_embeddings=True)


def card():
    return make_card(NAME, config())
