"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) ff=8192
V=202048, MoE 128 experts top-1, interleaved every other layer (as Maverick:
dense FFN on odd layers). [hf:meta-llama/Llama-4-Scout-17B-16E family;
unverified]

Param math: 24 MoE layers x 128e x 3 x 5120 x 8192 = 386B expert
+ 24 dense-FFN layers (3 x 5120 x 16384) + attention + 202k vocab ~= 400B
total, ~17B active (top-1 + dense path), matching the -400b-a17b name.
"""
from ..models.config import MoECfg, ModelConfig
from ._base import make_card

NAME = "llama4-maverick-400b-a17b"


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="moe", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=16384, vocab=202048, head_dim=128,
        pattern=(("attn", "moe"), ("attn", "dense")),
        moe=MoECfg(n_experts=128, top_k=1, d_ff=8192), rope_theta=5e5)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="moe", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=320, vocab=512, head_dim=32,
        pattern=(("attn", "moe"), ("attn", "dense")),
        moe=MoECfg(n_experts=8, top_k=1, d_ff=160))


def card():
    return make_card(NAME, config())
