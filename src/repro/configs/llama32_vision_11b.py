"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H (GQA kv=8) ff=14336 V=128256,
cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB per the assignment: input_specs provide
precomputed patch embeddings (B, 1600, 4096); each cross layer computes its
own K/V from them (cached at prefill).
"""
from ..models.config import ModelConfig
from ._base import make_card

NAME = "llama-3.2-vision-11b"

_PATTERN = tuple([("cross", "dense")] + [("attn", "dense")] * 4)


def config() -> ModelConfig:
    return ModelConfig(
        name=NAME, family="vlm", n_layers=40, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=128256, pattern=_PATTERN,
        cross_kv_tokens=1600, rope_theta=5e5)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=NAME + "-smoke", family="vlm", n_layers=5, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, pattern=_PATTERN,
        cross_kv_tokens=32)


def card():
    return make_card(NAME, config())
