"""Per-(node, category) online estimators for TTFT, TPOT and quality.

Residual parametrization (the cold-start contract)
--------------------------------------------------
The estimators never re-learn the static pair tables — they learn *residuals*
against them, and the corrected estimate a policy sees is

    prefill' = prefill_table · (1 + d_p)        (multiplicative)
    tpot'    = tpot_table    · (1 + d_t)        (multiplicative)
    quality' = clip(quality_mean_table + d_q, 0, 1)   (additive)

with all residuals seeded at **zero**. Seeding the residuals at zero *is*
seeding the estimators from the static pair tables: pre-observation,
``x · (1 + 0.0)`` and ``q + 0.0`` are bitwise identity in float32, so
cold-start routing is byte-identical to the static-prior baseline (the
regression test in tests/test_learn.py asserts exactly this).

Two estimator kinds, selected by ``LearnConfig.kind``:

* ``"ewma"`` — per-(node, category, signal) scalar residual EWMA
  ``r ← r + α (y − r)`` plus an observation count; uncertainty is
  ``1/√(1+n)`` (unexplored slots keep a high exploration bonus).
* ``"blr"`` — per-(node, category, signal) Bayesian linear regression of the
  residual over request features ``x = [1, prompt/512, complexity,
  min(queue/conc, 4)]``. The posterior is maintained via Sherman–Morrison
  rank-1 updates of A⁻¹ (A = λI + Σ x xᵀ, b = Σ x y, weights w = A⁻¹ b);
  uncertainty is the LinUCB width ``√(xᵀ A⁻¹ x)``.

Numerical discipline: every update/prediction is written as **explicit
fixed-association float32 expression trees** (no ``linalg``/BLAS reductions),
shared verbatim between the numpy and jnp twins — so the same rule running
inside the JAX scan carry and inside the DES event loops produces
bit-identical states, and argmin/argmax tie-breaking downstream cannot
diverge between layers. tests/test_learn.py property-checks this parity.

Observation contract (analytic layers): the latency signals are *speed
ratios* computed from shared float32 table values — ``y = (static · slow) /
static − 1`` — so a fault-free run observes exactly 0 and the learned state
stays neutral (learned=True ≡ learned=False without faults), while straggler
regimes (repro.faults) are what the estimators actually capture. The quality
signal is the realized-minus-expected delta (zero-mean classifier/sampling
noise when the tables are stationary). The live serving path
(:class:`OnlineEstimator`) instead observes realized-vs-estimated ratios in
the caller's own clock domain — the multiplicative residual absorbs the
model-seconds→scheduler-ticks scale, which is the point of an online
calibrator; the repo's enforced 3-way equivalence is among the three
analytic layers (JAX scan + both DES oracles).

Clock/feature contract: updates happen at dispatch in request order with
greedily-computed realized values (the same greedy-at-issue convention as
policy scan state); features are decision-time features (queue depth at
arrival). Disaggregated routes attribute the prefill residual to the
prefill node and the TPOT/quality residuals to the decode node.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: signal indices within a (node, category) slot group
N_SIGNALS = 3           # 0 = prefill ratio, 1 = tpot ratio, 2 = quality delta
#: pred_category cardinality (workload.classifier.CATEGORIES)
N_CATEGORIES = 3
#: BLR feature vector [1, prompt_norm, complexity, queue_norm]
FEAT_DIM = 4

_PROMPT_NORM = np.float32(512.0)   # prompt-token feature scale
_QUEUE_CAP = np.float32(4.0)       # queue/conc feature cap (masks DEAD_QUEUE)
_EPS = np.float32(1e-6)
_ONE = np.float32(1.0)
_ZERO = np.float32(0.0)

_EWMA_SLOT = 2                     # [residual, count]
_BLR_SLOT = FEAT_DIM * FEAT_DIM + FEAT_DIM   # [A⁻¹ (16), b (4)]


@dataclasses.dataclass(frozen=True)
class LearnConfig:
    """Hashable estimator configuration (part of the ``EvalConfig`` jit key).

    kind: "ewma" | "blr". alpha: EWMA step size. prior: BLR prior precision
    λ (A⁻¹ seeded at I/λ; larger = slower to move off the static tables).
    rel_clip: upper clip of the multiplicative residuals (lower clip is
    -0.9 so corrected times stay positive; quality deltas clip to ±1).
    """

    kind: str = "ewma"
    alpha: float = 0.25
    prior: float = 25.0
    rel_clip: float = 4.0

    def __post_init__(self):
        assert self.kind in ("ewma", "blr"), self.kind
        assert 0.0 < self.alpha <= 1.0
        assert self.prior > 0.0 and self.rel_clip > 0.0

    @property
    def slot(self) -> int:
        return _EWMA_SLOT if self.kind == "ewma" else _BLR_SLOT


def state_size(cfg: LearnConfig, n_nodes: int) -> int:
    """Flat float32 state length (lives in the scan carry)."""
    return n_nodes * N_CATEGORIES * N_SIGNALS * cfg.slot


def init_state(cfg: LearnConfig, n_nodes: int) -> np.ndarray:
    """Neutral (static-table-seeded) state: zero residuals everywhere.

    EWMA slots start at [r=0, n=0]; BLR slots at [A⁻¹=I/λ, b=0] whose
    posterior mean is the zero vector — either way the first prediction is
    a zero residual and corrected estimates equal the static tables bitwise.
    """
    s = np.zeros((n_nodes, N_CATEGORIES, N_SIGNALS, cfg.slot), np.float32)
    if cfg.kind == "blr":
        eye = (np.eye(FEAT_DIM, dtype=np.float32)
               / np.float32(cfg.prior)).reshape(-1)
        s[..., :FEAT_DIM * FEAT_DIM] = eye
    return s.reshape(-1)


def features(xp, prompt_tokens, complexity, queue_len, node_conc):
    """Decision-time feature triple (x1 scalar, x2 scalar, x3 per-node).

    ``queue_len`` is the policy-visible (possibly fault-masked) busy-slot
    vector; the cap at ``_QUEUE_CAP`` keeps DEAD_QUEUE sentinels from
    poisoning the regression features. Identical float32 expression for the
    numpy and jnp callers (``xp`` ∈ {numpy, jax.numpy}).
    """
    x1 = xp.float32(prompt_tokens) / _PROMPT_NORM if xp is np \
        else prompt_tokens / _PROMPT_NORM
    x2 = xp.float32(complexity) if xp is np else complexity
    load = queue_len.astype(xp.float32) / node_conc.astype(xp.float32)
    x3 = xp.minimum(load, _QUEUE_CAP)
    return x1, x2, x3


def _blr_matvec(A, v0, v1, v2, v3):
    """A (…, 4, 4) · v, unrolled with fixed association (bit-stable)."""
    u0 = (A[..., 0, 0] * v0 + A[..., 0, 1] * v1) + \
         (A[..., 0, 2] * v2 + A[..., 0, 3] * v3)
    u1 = (A[..., 1, 0] * v0 + A[..., 1, 1] * v1) + \
         (A[..., 1, 2] * v2 + A[..., 1, 3] * v3)
    u2 = (A[..., 2, 0] * v0 + A[..., 2, 1] * v1) + \
         (A[..., 2, 2] * v2 + A[..., 2, 3] * v3)
    u3 = (A[..., 3, 0] * v0 + A[..., 3, 1] * v1) + \
         (A[..., 3, 2] * v2 + A[..., 3, 3] * v3)
    return u0, u1, u2, u3


def _dot4(a0, a1, a2, a3, b0, b1, b2, b3):
    return (a0 * b0 + a1 * b1) + (a2 * b2 + a3 * b3)


def _predict(xp, cfg: LearnConfig, state, n_nodes: int, cat, x1, x2, x3):
    """(d_prefill, d_tpot, d_quality, unc), each (n_nodes,) float32."""
    s4 = state.reshape(n_nodes, N_CATEGORIES, N_SIGNALS, cfg.slot)
    sl = s4[:, cat]                               # (n_nodes, 3, slot)
    if cfg.kind == "ewma":
        d_p, d_t, d_q = sl[:, 0, 0], sl[:, 1, 0], sl[:, 2, 0]
        unc = _ONE / xp.sqrt(_ONE + sl[:, 2, 1])
    else:
        ds = []
        for sig in range(N_SIGNALS):
            A = sl[:, sig, :FEAT_DIM * FEAT_DIM].reshape(n_nodes, FEAT_DIM,
                                                         FEAT_DIM)
            b = sl[:, sig, FEAT_DIM * FEAT_DIM:]
            w0, w1, w2, w3 = _blr_matvec(A, b[:, 0], b[:, 1], b[:, 2],
                                         b[:, 3])
            ds.append(_dot4(w0, w1, w2, w3, _ONE, x1, x2, x3))
        d_p, d_t, d_q = ds
        Aq = sl[:, 2, :FEAT_DIM * FEAT_DIM].reshape(n_nodes, FEAT_DIM,
                                                    FEAT_DIM)
        u0, u1, u2, u3 = _blr_matvec(Aq, _ONE, x1, x2, x3)
        unc = xp.sqrt(xp.maximum(_dot4(u0, u1, u2, u3, _ONE, x1, x2, x3),
                                 _ZERO))
    lo, hi = np.float32(-0.9), np.float32(cfg.rel_clip)
    return (xp.clip(d_p, lo, hi), xp.clip(d_t, lo, hi),
            xp.clip(d_q, -_ONE, _ONE), unc)


def predict_np(cfg: LearnConfig, state, n_nodes: int, cat, x1, x2, x3):
    return _predict(np, cfg, state, n_nodes, int(cat), np.float32(x1),
                    np.float32(x2), np.asarray(x3, np.float32))


def predict_jnp(cfg: LearnConfig, state, n_nodes: int, cat, x1, x2, x3):
    import jax.numpy as jnp
    return _predict(jnp, cfg, state, n_nodes, cat, x1, x2, x3)


def _slot_update(xp, cfg: LearnConfig, slot, x1, x2, x3, y):
    """Next value of one (node, category, signal) slot after observing y."""
    if cfg.kind == "ewma":
        a = np.float32(cfg.alpha)
        r, n = slot[0], slot[1]
        return xp.stack([r + a * (y - r), n + _ONE])
    A = slot[:FEAT_DIM * FEAT_DIM].reshape(FEAT_DIM, FEAT_DIM)
    b = slot[FEAT_DIM * FEAT_DIM:]
    u0, u1, u2, u3 = _blr_matvec(A, _ONE, x1, x2, x3)
    inv = _ONE / (_ONE + _dot4(u0, u1, u2, u3, _ONE, x1, x2, x3))
    u = xp.stack([u0, u1, u2, u3])
    A_new = A - (u[:, None] * u[None, :]) * inv          # Sherman–Morrison
    b_new = b + xp.stack([_ONE * y, x1 * y, x2 * y, x3 * y])
    return xp.concatenate([A_new.reshape(FEAT_DIM * FEAT_DIM), b_new])


#: (signal, which node observes it): prefill on the prefill node, tpot and
#: quality on the decode node (identical nodes on colocated routes)
_SIGNAL_NODES = ((0, "p"), (1, "q"), (2, "q"))


def update_np(cfg: LearnConfig, state, n_nodes: int, cat, node_p, node_q,
              x1, x2, x3, y_p, y_t, y_q) -> np.ndarray:
    """Numpy twin of the scan-carry update (returns a fresh state array)."""
    s4 = np.array(state, np.float32).reshape(n_nodes, N_CATEGORIES,
                                             N_SIGNALS, cfg.slot)
    cat = int(cat)
    ys = (np.float32(y_p), np.float32(y_t), np.float32(y_q))
    x3 = np.asarray(x3, np.float32)
    for sig, leg in _SIGNAL_NODES:
        node = int(node_p) if leg == "p" else int(node_q)
        s4[node, cat, sig] = _slot_update(np, cfg, s4[node, cat, sig],
                                          np.float32(x1), np.float32(x2),
                                          x3[node], ys[sig])
    return s4.reshape(-1)


def update_jnp(cfg: LearnConfig, state, n_nodes: int, cat, node_p, node_q,
               x1, x2, x3, y_p, y_t, y_q):
    """jnp twin of :func:`update_np` (scan-traceable, functional update)."""
    import jax.numpy as jnp
    s4 = state.reshape(n_nodes, N_CATEGORIES, N_SIGNALS, cfg.slot)
    ys = (y_p, y_t, y_q)
    for sig, leg in _SIGNAL_NODES:
        node = node_p if leg == "p" else node_q
        s4 = s4.at[node, cat, sig].set(
            _slot_update(jnp, cfg, s4[node, cat, sig], x1, x2, x3[node],
                         ys[sig]))
    return s4.reshape(-1)


def observations(xp, prefill_static, slow_p, tpot_static, slow_q, q_real,
                 q_mean):
    """(y_p, y_t, y_q) residual targets from shared float32 table values.

    Latency signals are speed ratios of the *full* static phase time —
    ``(static · slow)/static − 1`` — so the known cache discount never
    enters and a fault-free run observes exactly zero; the quality signal
    is realized minus expected. Same expression tree for both layers.
    """
    y_p = xp.where(prefill_static > _EPS,
                   (prefill_static * slow_p)
                   / xp.maximum(prefill_static, _EPS) - _ONE, _ZERO)
    y_t = xp.where(tpot_static > _EPS,
                   (tpot_static * slow_q)
                   / xp.maximum(tpot_static, _EPS) - _ONE, _ZERO)
    return y_p, y_t, q_real - q_mean


def corrected_rows(xp, prefill_row, tpot_row, quality_row, d_p, d_t, d_q,
                   unc, pair_node):
    """Apply per-node residuals to the per-pair estimate rows.

    Zero residuals reproduce the inputs bitwise (×1.0 and +0.0 are float32
    identities) — the cold-start contract policies rely on.
    """
    prefill_c = prefill_row * (_ONE + d_p[pair_node])
    tpot_c = tpot_row * (_ONE + d_t[pair_node])
    quality_c = xp.clip(quality_row + d_q[pair_node], _ZERO, _ONE)
    return prefill_c, tpot_c, quality_c, unc[pair_node]


class OnlineEstimator:
    """Live (serving/runtime) numpy estimator held by ``ClusterMonitor``.

    The stateful counterpart of the functional twins above: the router
    applies :meth:`predict` corrections on its hot path and the completion/
    retire path feeds :meth:`observe` with realized-vs-estimated ratios in
    the caller's own clock domain (scheduler ticks or simulated seconds —
    the multiplicative residual absorbs the unit scale).
    """

    def __init__(self, cfg: LearnConfig = LearnConfig(), n_nodes: int = 0,
                 node_conc=None):
        assert n_nodes > 0, "OnlineEstimator needs the cluster's node count"
        self.cfg = cfg
        self.n_nodes = n_nodes
        # per-node concurrency for the queue-load feature (ones when the
        # caller never provides queue context)
        self.node_conc = (np.ones(n_nodes, np.int64) if node_conc is None
                          else np.asarray(node_conc, np.int64))
        self.state = init_state(cfg, n_nodes)
        self.n_obs = 0

    def predict(self, cat, prompt_tokens, complexity, queue_len, node_conc):
        """(d_prefill, d_tpot, d_quality, unc) per node for one request."""
        x1, x2, x3 = features(np, prompt_tokens, complexity,
                              np.asarray(queue_len, np.int64),
                              np.asarray(node_conc))
        return predict_np(self.cfg, self.state, self.n_nodes, cat, x1, x2,
                          x3)

    @staticmethod
    def ratio(expected: float, realized: float) -> float:
        """Residual target ``realized/expected − 1`` (0 when unobservable)."""
        e = float(expected)
        if e <= 1e-6:
            return 0.0
        return float(np.float32(realized) / np.float32(e) - _ONE)

    def observe(self, cat, node_p, node_q, prompt_tokens, complexity,
                queue_len, node_conc, y_prefill, y_tpot,
                y_quality=0.0) -> None:
        """Feed one completed request's residual targets (completion path)."""
        x1, x2, x3 = features(np, prompt_tokens, complexity,
                              np.asarray(queue_len, np.int64),
                              np.asarray(node_conc))
        self.state = update_np(self.cfg, self.state, self.n_nodes, cat,
                               node_p, node_q, x1, x2, x3, y_prefill, y_tpot,
                               y_quality)
        self.n_obs += 1
