"""Online-learned latency/quality estimators (jnp/numpy twins).

Closes the loop from observed completions back into routing decisions: the
static pair tables every policy routes on are corrected by per-(node,
category) residual estimators updated online from realized (prefill, TPOT,
quality) observations. One update rule, three execution layers:

* inside the JAX fitness scan carry (``core.fitness``,
  ``EvalConfig(learned=True)``),
* inside both DES oracles (``cluster.simulator``), mirrored op-for-op in
  float32 so the JAX/DES equivalence property extends to learned runs,
* in the live ``ClusterMonitor`` (an :class:`OnlineEstimator` fed from the
  serving scheduler's completion/retire path and ``RequestRouter.record``).

See :mod:`repro.learn.estimators` for the residual parametrization (why
cold-start estimates are byte-identical to the static tables) and the
EWMA / Bayesian-linear-regression update rules.
"""
from .estimators import (FEAT_DIM, N_CATEGORIES, N_SIGNALS,  # noqa: F401
                         LearnConfig, OnlineEstimator, corrected_rows,
                         features, init_state, observations, predict_jnp,
                         predict_np, state_size, update_jnp, update_np)

__all__ = ["LearnConfig", "OnlineEstimator", "state_size", "init_state",
           "features", "predict_np", "predict_jnp", "update_np",
           "update_jnp", "observations", "corrected_rows", "N_SIGNALS",
           "N_CATEGORIES", "FEAT_DIM"]
