"""Optimizers (pure JAX, optax-style init/update pairs).

Three memory tiers, selected per architecture size so the ≥100 B archs fit
16 GB/chip on the production mesh (§Dry-run memory table):

* ``adamw``     — fp32 m+v (8 bytes/param state). Default for ≤15 B archs.
* ``adamw8bit`` — block-wise dynamic-quantized int8 m+v (2 bytes/param +
  fp32 per-block scales). The distributed-optimization trick for dbrx-132b.
* ``adafactor`` — factored second moment, no first moment (≈0 bytes/param
  beyond factored vectors). Used for llama4-maverick-400b.

All states are sharded exactly like their parameters (the dry-run passes the
param PartitionSpec tree for the state too), i.e. ZeRO-3 via the FSDP axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256  # int8 quantization block


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (new_params, new_state)
    name: str


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def _tree_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _clipped(grads, clip):
    gnorm = _tree_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(cfg: OptConfig = OptConfig()) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32),
                "gnorm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        grads, gnorm = _clipped(grads, cfg.grad_clip)
        step = state["step"] + 1
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            new_p = (p.astype(jnp.float32)
                     - cfg.lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                 + cfg.weight_decay * p.astype(jnp.float32)))
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step,
                            "gnorm": gnorm}

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# 8-bit AdamW (block-wise dynamic quantization, Dettmers-style)
# ---------------------------------------------------------------------------

def _q8(x):
    """Blockwise-quantize f32 along the LAST axis -> (int8 same shape,
    scales (..., n_blocks)). Blocking the last axis (not a flat view) keeps
    q shaped exactly like the parameter, so q shards with the parameter's
    PartitionSpec and scales with its leading dims — required for the
    dry-run's honest per-device memory accounting."""
    last = x.shape[-1]
    block = min(QBLOCK, last)
    pad = (-last) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = xp.shape[-1] // block
    xb = xp.reshape(x.shape[:-1] + (nb, block))
    s = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    s = jnp.where(s == 0, 1.0, s)
    q = jnp.clip(jnp.round(xb / s[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :last]
    return q, s


def _dq8(q, s, shape):
    last = shape[-1]
    block = min(QBLOCK, last)
    pad = (-last) % block
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    nb = qp.shape[-1] // block
    xb = qp.reshape(shape[:-1] + (nb, block)).astype(jnp.float32)
    xf = xb * s[..., None]
    return xf.reshape(qp.shape)[..., :last]


def adamw8bit(cfg: OptConfig = OptConfig()) -> Optimizer:
    def init(params):
        def zq(p):
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return {"m": jax.tree.map(zq, params),
                "v": jax.tree.map(zq, params),
                "step": jnp.zeros((), jnp.int32),
                "gnorm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        grads, gnorm = _clipped(grads, cfg.grad_clip)
        step = state["step"] + 1
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, mq, vq):
            m = cfg.b1 * _dq8(mq["q"], mq["s"], p.shape) + (1 - cfg.b1) * g
            v = cfg.b2 * _dq8(vq["q"], vq["s"], p.shape) + (1 - cfg.b2) * g * g
            v = jnp.maximum(v, 0.0)
            new_p = (p.astype(jnp.float32)
                     - cfg.lr * ((m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
                                 + cfg.weight_decay * p.astype(jnp.float32)))
            qm, sm = _q8(m)
            qv, sv = _q8(v)
            return new_p.astype(p.dtype), {"q": qm, "s": sm}, {"q": qv, "s": sv}

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v):
            a, b, c = upd(p, g, m, v)
            new_p.append(a)
            new_m.append(b)
            new_v.append(c)
        return (jax.tree.unflatten(treedef, new_p),
                {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step, "gnorm": gnorm})

    return Optimizer(init, update, "adamw8bit")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------

def adafactor(cfg: OptConfig = OptConfig()) -> Optimizer:
    def init(params):
        def fac(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(fac, params,
                                  is_leaf=lambda x: hasattr(x, "ndim")),
                "step": jnp.zeros((), jnp.int32),
                "gnorm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        grads, gnorm = _clipped(grads, cfg.grad_clip)
        step = state["step"] + 1
        decay = 1.0 - step.astype(jnp.float32) ** -0.8

        def upd(p, g, f):
            g2 = g * g + 1e-30
            if p.ndim >= 2:
                vr = decay * f["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * f["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)
                                       [..., None], 1e-30))
                upd_ = g * jax.lax.rsqrt(denom + 1e-30)
                newf = {"vr": vr, "vc": vc}
            else:
                v = decay * f["v"] + (1 - decay) * g2
                upd_ = g * jax.lax.rsqrt(v + 1e-30)
                newf = {"v": v}
            # relative update clipping (Adafactor's d=1.0)
            rms = jnp.sqrt(jnp.mean(upd_ * upd_) + 1e-30)
            upd_ = upd_ / jnp.maximum(1.0, rms)
            new_p = (p.astype(jnp.float32) - cfg.lr * upd_
                     - cfg.lr * cfg.weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), newf

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_f = treedef.flatten_up_to(state["f"])
        new_p, new_f = [], []
        for p, g, f in zip(leaves_p, leaves_g, leaves_f):
            a, b = upd(p, g, f)
            new_p.append(a)
            new_f.append(b)
        return (jax.tree.unflatten(treedef, new_p),
                {"f": jax.tree.unflatten(treedef, new_f), "step": step,
                 "gnorm": gnorm})

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, cfg: OptConfig = OptConfig()) -> Optimizer:
    return {"adamw": adamw, "adamw8bit": adamw8bit,
            "adafactor": adafactor}[name](cfg)


def optimizer_for_arch(total_params: float) -> str:
    """Memory-tier policy (see module docstring)."""
    if total_params > 200e9:
        return "adafactor"
    if total_params > 60e9:
        return "adamw8bit"
    return "adamw"
