"""Gradient compression for the cross-pod data-parallel all-reduce.

At 2+ pods the gradient all-reduce crosses DCN (slow vs ICI), so the trainer
can compress the pod-axis reduction:

* ``int8`` — error-feedback blockwise-int8: quantize (grad + residual),
  all-reduce the int8 payload (4× less DCN traffic than f32), keep the
  quantization error as residual for the next step (Seide et al. / 1-bit
  Adam lineage — EF makes the bias telescoping, preserving convergence).
* ``topk`` — error-feedback magnitude top-k per tensor (k as a fraction),
  exchanged dense-masked (simple, deterministic shapes; a production DCN
  implementation would exchange (indices, values) pairs).

Both are pure functions usable inside jit/shard_map; state is a residual
pytree shaped like the gradients.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    last = x.shape[-1] if x.ndim else 1
    block = min(QBLOCK, max(last, 1))
    pad = (-last) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if x.ndim else x
    xb = xp.reshape(x.shape[:-1] + (-1, block)) if x.ndim else xp
    s = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    s = jnp.where(s == 0, 1.0, s)
    q = jnp.clip(jnp.round(xb / s), -127, 127).astype(jnp.int8)
    return q, s


def _dequantize_int8(q, s, shape):
    xf = q.astype(jnp.float32) * s
    xf = xf.reshape(shape[:-1] + (-1,))[..., :shape[-1]] if shape else xf
    return xf


def compress_int8(grads, residual):
    """Returns (payload int8 pytree to reduce, scales, new_residual_fn).

    new residual is computed against the *local* quantization (standard EF)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = _quantize_int8(gf)
        deq = _dequantize_int8(q, s, gf.shape)
        return q, s, gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    qs, ss, rs = zip(*(one(g, r) for g, r in zip(flat_g, flat_r)))
    return (jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, rs))


def decompress_int8(payload, scales, grads_template):
    return jax.tree.map(
        lambda q, s, g: _dequantize_int8(q, s, g.shape).astype(jnp.float32),
        payload, scales, grads_template)


def compress_topk(grads, residual, frac: float = 0.05):
    """EF top-|frac| sparsification (dense-masked)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(gf) >= thresh).astype(jnp.float32)
        sent = gf * mask
        return sent, gf - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    sents, rs = zip(*(one(g, r) for g, r in zip(flat_g, flat_r)))
    return jax.tree.unflatten(treedef, sents), jax.tree.unflatten(treedef, rs)


def init_residual(grads_or_params):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_or_params)


def psum_compressed(grads, residual, axis_name: str, method: str = "int8"):
    """All-reduce ``grads`` over ``axis_name`` with EF compression.

    Use inside shard_map/pmap-style code where ``axis_name`` is bound.
    Returns (mean_grads_f32, new_residual).
    """
    n = jax.lax.psum(1, axis_name)
    if method == "int8":
        q, s, new_res = compress_int8(grads, residual)
        # int8 payloads summed in int32 to avoid overflow across replicas
        summed = jax.tree.map(
            lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
        s_sum = jax.tree.map(lambda ss: jax.lax.psum(ss, axis_name) / n, s)
        mean = jax.tree.map(
            lambda qq, ss, g: _dequantize_int8(qq.astype(jnp.float32) / n,
                                               ss, g.shape),
            summed, s_sum, grads)
        return mean, new_res
    if method == "topk":
        sent, new_res = compress_topk(grads, residual)
        mean = jax.tree.map(lambda x: jax.lax.psum(x, axis_name) / n, sent)
        return mean, new_res
    # no compression
    mean = jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n, grads)
    return mean, residual
