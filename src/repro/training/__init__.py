from .optim import adafactor, adamw, adamw8bit, make_optimizer

__all__ = ["adamw", "adamw8bit", "adafactor", "make_optimizer"]
