"""Distributed training loop.

Composes the substrates: model zoo (scan-over-layers + remat), sharding rules
(FSDP×TP×DP), optimizers (memory-tiered), gradient accumulation
(microbatching via ``lax.scan``), optional cross-pod gradient compression,
deterministic data pipeline, and fault-tolerant checkpointing
(checkpoint/restart → the trainer resumes from the latest committed step).

The same Trainer drives the CPU examples (tiny smoke configs on a (1, 1)
mesh) and the production dry-run path (it is what ``launch/train.py`` runs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import CheckpointManager
from ..data import DataConfig, SyntheticLMData
from ..models import lm
from ..models import sharding as shard
from ..models.config import ModelConfig
from .optim import OptConfig, make_optimizer, optimizer_for_arch


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 128
    global_batch: int = 8
    microbatches: int = 1          # gradient accumulation steps
    steps: int = 100
    optimizer: Optional[str] = None  # default: by model size
    opt: OptConfig = OptConfig()
    remat: bool = True
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep_checkpoints: int = 2
    log_every: int = 10
    seed: int = 0
    # synthetic-corpus difficulty (tests/examples use an easy setting so the
    # loss visibly decreases within ~100 CPU steps)
    data_vocab: Optional[int] = None   # tokens drawn from [0, data_vocab)
    data_chains: int = 8
    data_branch: int = 32


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.opt_name = tcfg.optimizer or optimizer_for_arch(
            cfg.param_counts()["total"])
        self.opt = make_optimizer(self.opt_name, tcfg.opt)
        self.data = SyntheticLMData(DataConfig(
            vocab=min(tcfg.data_vocab or cfg.vocab, cfg.vocab),
            seq_len=tcfg.seq_len, global_batch=tcfg.global_batch,
            seed=tcfg.seed, n_chains=tcfg.data_chains,
            branch=tcfg.data_branch))
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir,
                                       keep=tcfg.keep_checkpoints,
                                       save_interval_steps=tcfg.checkpoint_every)
                     if tcfg.checkpoint_dir else None)
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, tcfg = self.cfg, self.tcfg
        assert tcfg.global_batch % tcfg.microbatches == 0
        mb = tcfg.global_batch // tcfg.microbatches

        def loss_fn(params, batch):
            l, aux = lm.loss_fn(params, cfg, batch, remat=tcfg.remat)
            return l, aux

        def train_step(params, opt_state, batch):
            if tcfg.microbatches == 1:
                (l, aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                def micro(carry, mb_batch):
                    acc = carry
                    (l, aux), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb_batch)
                    acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), acc, g)
                    return acc, (l, aux["nll"])

                split = jax.tree.map(
                    lambda x: x.reshape(tcfg.microbatches, mb, *x.shape[1:]),
                    batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, (ls, nlls) = jax.lax.scan(micro, zeros, split)
                grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
                l = jnp.mean(ls)
                aux = {"nll": jnp.mean(nlls), "aux": jnp.float32(0)}
            new_params, new_state = self.opt.update(grads, opt_state, params)
            metrics = {"loss": l, "nll": aux["nll"],
                       "gnorm": new_state["gnorm"]}
            return new_params, new_state, metrics

        if self.mesh is not None:
            params_abs = jax.eval_shape(lambda k: lm.init(k, cfg),
                                        jax.random.key(tcfg.seed))
            self.pspecs = shard.param_specs(cfg, params_abs, self.mesh)
            from ..launch.dryrun import opt_state_specs
            self.sspecs = opt_state_specs(self.opt_name, params_abs,
                                          self.pspecs)
            psh = shard.to_shardings(self.mesh, self.pspecs)
            ssh = shard.to_shardings(self.mesh, self.sspecs)
            self._step = jax.jit(train_step,
                                 out_shardings=(psh, ssh, None),
                                 donate_argnums=(0, 1))
        else:
            self.pspecs = self.sspecs = None
            self._step = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_state(self, resume: bool = True):
        key = jax.random.key(self.tcfg.seed)
        params = lm.init(key, self.cfg)
        opt_state = self.opt.init(params)
        start = 0
        if self.ckpt and resume and self.ckpt.latest_step() is not None:
            (params, opt_state), manifest = self.ckpt.restore(
                (params, opt_state), mesh=self.mesh,
                specs=(self.pspecs, self.sspecs) if self.pspecs else None)
            start = manifest["step"]
        return params, opt_state, start

    def run(self, steps: Optional[int] = None, resume: bool = True,
            callback: Optional[Callable[[int, Dict], None]] = None):
        steps = steps or self.tcfg.steps
        params, opt_state, start = self.init_state(resume=resume)
        history = []
        it = self.data.iterator(start_step=start)
        t0 = time.time()
        for step in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt_state, metrics = self._step(params, opt_state, batch)
            if step % self.tcfg.log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["tokens_per_s"] = (self.tcfg.global_batch * self.tcfg.seq_len
                                     * (step - start + 1) / (time.time() - t0))
                history.append(m)
                if callback:
                    callback(step, m)
            if self.ckpt and self.ckpt.should_save(step):
                self.ckpt.save(step, (params, opt_state),
                               specs=((self.pspecs, self.sspecs)
                                      if self.pspecs else None),
                               metadata={"arch": self.cfg.name})
        if self.ckpt:
            self.ckpt.save(steps, (params, opt_state),
                           specs=((self.pspecs, self.sspecs)
                                  if self.pspecs else None),
                           metadata={"arch": self.cfg.name}, blocking=True)
            self.ckpt.wait()
        return params, opt_state, history
