"""Public jit'd wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas kernels run natively; elsewhere (this CPU container, tests)
they run through ``interpret=True`` or fall back to the jnp reference —
controlled by ``mode``:

* "auto"      — Pallas on TPU, reference otherwise (the model zoo default,
                 so dry-runs lower the XLA path and real TPUs get kernels);
* "pallas"    — force the kernel (native);
* "interpret" — force the kernel in interpret mode (kernel-correctness tests);
* "ref"       — force the jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import dominance as _dom
from . import flash_attention as _fa
from . import paged_attention as _paged
from . import ref

_MODES = ("auto", "pallas", "interpret", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    assert mode in _MODES, mode
    if mode == "auto":
        return "pallas" if _on_tpu() else "ref"
    return mode


def dominance_matrix(F: jax.Array, mode: str = "auto") -> jax.Array:
    m = _resolve(mode)
    if m == "ref":
        return ref.dominance_matrix(F)
    out = _dom.dominance_matrix_pallas(F, interpret=(m == "interpret"))
    return out.astype(bool)


def dominance_counts(F: jax.Array, mode: str = "auto") -> jax.Array:
    m = _resolve(mode)
    if m == "ref":
        return ref.dominance_counts(F)
    return _dom.dominance_counts_pallas(F, interpret=(m == "interpret"))


def flash_attention(q, k, v, causal: bool = True, mode: str = "auto",
                    block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K) -> jax.Array:
    m = _resolve(mode)
    S = q.shape[2]
    if m == "ref" or S % min(block_q, S) or S % min(block_k, S):
        return ref.mha_prefill(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=(m == "interpret"))


def gqa_decode_attention(q, k_cache, v_cache, kv_len, mode: str = "auto",
                         block_k: int = _dec.DEFAULT_BLOCK_K) -> jax.Array:
    m = _resolve(mode)
    Smax = k_cache.shape[2]
    if m == "ref" or Smax % min(block_k, Smax):
        return ref.gqa_decode(q, k_cache, v_cache, kv_len)
    return _dec.gqa_decode_attention(q, k_cache, v_cache, kv_len,
                                     block_k=block_k,
                                     interpret=(m == "interpret"))


def paged_gqa_decode_attention(q, k_pool, v_pool, block_table, kv_len,
                               mode: str = "auto") -> jax.Array:
    """Decode attention over a paged KV pool addressed by a per-request
    block table (see ``serving.kvcache``). Pool-resident decode path for
    prefix-reuse serving on TPU; jnp gather+reference elsewhere."""
    m = _resolve(mode)
    if m == "ref":
        return ref.paged_gqa_decode(q, k_pool, v_pool, block_table, kv_len)
    return _paged.paged_gqa_decode_attention(q, k_pool, v_pool, block_table,
                                             kv_len,
                                             interpret=(m == "interpret"))
