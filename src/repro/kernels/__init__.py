"""Pallas TPU kernels for the framework's compute hot spots.

The paper's own contribution is control-plane (routing), but the serving
substrate it routes onto has three kernel-level hot spots we optimize for
TPU: the NSGA-II dominance matrix (VPU/bandwidth), prefill flash attention
(MXU), and GQA decode attention over long KV caches (HBM-bandwidth).
All validated against the jnp oracles in ref.py via interpret mode on CPU.

Public API lives in :mod:`repro.kernels.ops` (backend-dispatching wrappers);
kernel modules keep their own names (flash_attention.py, decode_attention.py,
dominance.py) and are intentionally *not* re-exported here to avoid
function/submodule name shadowing.
"""
from . import ops, ref  # noqa: F401

__all__ = ["ops", "ref"]
