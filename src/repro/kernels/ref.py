"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here. They are also the execution
path used on non-TPU backends (the dry-run lowers the models with these, so
roofline FLOPs/bytes come from XLA's un-fused reference implementation —
conservative for the kernels' benefit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# NSGA-II Pareto dominance
# ---------------------------------------------------------------------------

def dominance_matrix(F: jax.Array) -> jax.Array:
    """(P, M) objectives -> (P, P) bool, D[i, j] = i dominates j (minimize)."""
    le = jnp.all(F[:, None, :] <= F[None, :, :], axis=-1)
    lt = jnp.any(F[:, None, :] < F[None, :, :], axis=-1)
    return le & lt


def dominance_counts(F: jax.Array) -> jax.Array:
    """(P,) int32: number of individuals dominating each column j."""
    return jnp.sum(dominance_matrix(F), axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def mha_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                causal: bool = True, scale: float | None = None) -> jax.Array:
    """Grouped-query attention, full materialized reference.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0.
    Returns (B, Hq, S, D) in q.dtype; math in f32.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
    return out.astype(q.dtype)


def paged_gqa_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_table: jax.Array, kv_len: jax.Array,
                     scale: float | None = None) -> jax.Array:
    """Paged decode attention oracle: gather the block table back into a
    contiguous cache, then run :func:`gqa_decode`.

    q: (B, Hq, D); k/v_pool: (n_blocks, Hkv, block_size, D); block_table:
    (B, max_blocks) int32 (pad entries may be any valid id); kv_len: (B,).
    """
    bt = jnp.maximum(block_table.astype(jnp.int32), 0)

    def gather(pool):
        g = jnp.take(pool, bt, axis=0)          # (B, nb, Hkv, bs, D)
        B, nb, Hkv, bs, D = g.shape
        return jnp.transpose(g, (0, 2, 1, 3, 4)).reshape(B, Hkv, nb * bs, D)

    return gqa_decode(q, gather(k_pool), gather(v_pool), kv_len, scale=scale)


def gqa_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               kv_len: jax.Array, scale: float | None = None) -> jax.Array:
    """Single-token decode attention over a (possibly padded) KV cache.

    q: (B, Hq, D); k_cache, v_cache: (B, Hkv, Smax, D); kv_len: (B,) valid
    prefix lengths. Positions >= kv_len are masked. Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    from ..models.sharding import accum_dot
    qf = q.reshape(B, Hkv, group, D)
    # no input casts under lowering: a .astype(f32) on the cache would
    # materialize a full-size f32 copy (2x HBM)
    scores = accum_dot("bhgd,bhsd->bhgs", qf, k_cache) * scale
    pos = jnp.arange(Smax)[None, None, None, :]
    mask = pos < kv_len[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = accum_dot("bhgs,bhsd->bhgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, Hq, D).astype(q.dtype)
