"""Pallas TPU kernel: paged GQA decode attention (vLLM-style block tables).

The paged-KV serving path (``serving.kvcache``) stores K/V in fixed-size
blocks shared between requests; a slot's logical cache is the sequence of
physical blocks named by its **block table**. This kernel runs the decode
attention of ``decode_attention.py`` directly over the pool — no host-side
gather into a contiguous cache — by resolving the physical block id *in the
BlockSpec index map* via scalar prefetch: the block table and ``kv_len``
ride in SMEM, so each grid cell's K/V DMA is issued straight from
``pool[block_table[b, ib]]``.

Same online-softmax/GQA-folding scheme as the contiguous kernel (one
(G, D) × (D, BS) MXU pass per block, K/V tile loaded once per KV group);
fully-dead blocks (``ib * block_size >= kv_len``) are skipped before their
DMA is issued, so a mostly-empty block table costs nothing. Parity-tested in
interpret mode against both the jnp oracle and the contiguous kernel on a
gathered cache (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(kv_len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, block_size: int, n_b: int,
                  scale: float):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kv_len_ref[b]
    k_start = ib * block_size

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (BS, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, BS)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ib == n_b - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gqa_decode_attention(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_table: jax.Array,
                               kv_len: jax.Array, *,
                               interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v_pool: (n_blocks, Hkv, block_size, D);
    block_table: (B, max_blocks) int32 physical block per logical position
    (entries past ``ceil(kv_len / block_size)`` may hold any valid id — their
    scores are masked); kv_len: (B,) int32 valid lengths.
    """
    B, Hq, D = q.shape
    Hkv, block_size = k_pool.shape[1], k_pool.shape[2]
    n_b = block_table.shape[1]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    bt = jnp.maximum(block_table.astype(jnp.int32), 0)  # pad slots -> block 0
    kernel = functools.partial(_paged_kernel, block_size=block_size, n_b=n_b,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, ib, kv_len, bt: (b, h, 0, 0)),
            # the paged gather: physical block id resolved in the index map
            pl.BlockSpec((1, 1, block_size, D),
                         lambda b, h, ib, kv_len, bt: (bt[b, ib], h, 0, 0)),
            pl.BlockSpec((1, 1, block_size, D),
                         lambda b, h, ib, kv_len, bt: (bt[b, ib], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ib, kv_len, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), bt, qg, k_pool, v_pool)
    return out.reshape(B, Hq, D)
