"""Pallas TPU kernel: GQA decode attention (one new token vs a long KV cache).

The decode-phase hot spot for the ``decode_32k`` / ``long_500k`` serving
shapes: a single query token attends over S cached keys. This op is
**memory-bound** (arithmetic intensity ≈ 1 FLOP/byte — every K/V byte is
touched once), so the kernel's job is to stream the cache through VMEM at
full HBM bandwidth while keeping the softmax online.

TPU adaptation:
* For GQA we fold the query heads of one KV group into the matmul M-dim:
  q is viewed as (B, Hkv, G, D) and each grid cell computes a (G, BK)
  score tile via one (G, D) × (D, BK) MXU pass — the CUDA equivalent keeps
  one warp per head; here the group shares a single systolic pass and the
  K/V tile is loaded **once per group** instead of once per head (G× less
  HBM traffic than the naive lowering — the entire point of GQA decode).
* The cache-position loop is the innermost grid dimension with running
  (m, l, acc) in VMEM scratch, identical online-softmax scheme to the
  prefill kernel.
* Variable cache fill: ``kv_len`` rides in SMEM via
  ``PrefetchScalarGridSpec`` so fully-dead tiles (k_start >= kv_len) are
  skipped before their DMA is issued — with a 512k-slot cache at 32k fill
  this skips 15/16 of the streaming.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_k: int, n_k: int,
                   scale: float):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kv_len_ref[b]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, BK)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def gqa_decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         kv_len: jax.Array, *,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v_cache: (B, Hkv, Smax, D); kv_len: (B,) int32.

    Smax must be a multiple of block_k (cache slabs are allocated in
    block_k-sized pages by the serving engine).
    """
    B, Hq, D = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    block_k = min(block_k, Smax)
    assert Smax % block_k == 0, (Smax, block_k)
    n_k = Smax // block_k
    scale = D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_decode_kernel, block_k=block_k, n_k=n_k,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik, kv_len: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, kv_len: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, kv_len: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ik, kv_len: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
