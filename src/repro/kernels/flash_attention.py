"""Pallas TPU kernel: causal GQA flash attention (prefill path).

Online-softmax attention tiled for VMEM, the serving engine's prefill
hot spot. TPU adaptation (vs the CUDA FlashAttention-2 schedule):

* Tiles are MXU-aligned: BQ = BK = 128 rows/cols, head_dim D stays whole
  (128 for every assigned arch), so each (BQ, D) × (D, BK) product maps onto
  128×128 MXU passes with no fragmentation.
* The K loop is a *grid dimension* (innermost), not an in-kernel loop:
  q/o blocks are revisited across the nK steps while running max ``m``,
  normalizer ``l`` and accumulator ``acc`` live in VMEM scratch. The Mosaic
  pipeliner overlaps the next K/V tile's HBM→VMEM DMA with the current tile's
  compute — the overlap a CUDA kernel gets from cp.async, expressed
  structurally instead of with explicit pipelining code.
* Causal skipping is a `pl.when` guard on whole (BQ, BK) tiles above the
  diagonal — those grid steps issue no DMA and no FLOPs.
* GQA is handled in the k/v index_map (head h reads kv head h // group):
  no repeated-KV materialization in HBM, which is the main memory-roofline
  win over the naive XLA lowering at 8:1 GQA ratios.

VMEM budget per grid cell (BQ=BK=128, D=128, f32 compute):
q 64 KiB + k 64 + v 64 + o 64 + acc 64 + m/l ~1 ≈ 321 KiB  « 16 MiB VMEM,
leaving the pipeliner room for double-buffering (×2 on k/v).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: tile is fully masked iff k_start > q_end
    q_end = (iq + 1) * block_q - 1
    k_start = ik * block_k
    live = (not causal) or (k_start <= q_end)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)               # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qi = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kj = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qi >= kj, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """GQA flash attention. q: (B, Hq, S, D); k, v: (B, Hkv, S, D).

    S must be a multiple of max(block_q, block_k) — the model layer pads
    sequences to the tile size (all assigned shapes are powers of two).
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k
    scale = D ** -0.5

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
