"""Pallas TPU kernel: blocked Pareto-dominance matrix for NSGA-II.

The O(P²·M) all-pairs comparison is the non-dominated-sort hot spot at large
population sizes (P ≥ 4k when the router is re-optimized over long traces
with direct-assignment genomes). The MXU offers nothing for boolean
domination, so this is a **VPU/bandwidth kernel**: each grid cell loads two
objective slabs — F_i (BI, M) and F_j (BJ, M) — into VMEM and writes one
(BI, BJ) int8 tile of the dominance matrix.

TPU adaptation notes (vs a CUDA port):
* tiles are (128, 128) to match the VPU lane layout (8×128 vregs; the BI
  dimension vectorizes over sublanes, BJ over lanes);
* the M objective axis (≤ 8 in practice) stays resident: both slabs together
  occupy 2·128·M·4 B ≤ 8 KiB — far under VMEM, so the kernel is bound by the
  (BI·BJ) output-tile write, exactly what a roofline for a boolean all-pairs
  op predicts;
* output is int8 (0/1): TPU stores would waste 4× on an int32 mask and bool
  stores pack awkwardly across lanes.

``dominance_counts_kernel`` fuses the column reduction (dominator counts used
by front peeling) so the P×P matrix never hits HBM: grid is (j_blocks,
i_blocks) with i innermost, accumulating counts into the same (BJ,) output
block across i steps — the standard Pallas revisiting-output accumulation
pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _dominance_tile_kernel(fi_ref, fj_ref, out_ref):
    """One (BI, BJ) tile: D[i, j] = all(Fi <= Fj) & any(Fi < Fj)."""
    fi = fi_ref[...].astype(jnp.float32)          # (BI, M)
    fj = fj_ref[...].astype(jnp.float32)          # (BJ, M)
    le = jnp.all(fi[:, None, :] <= fj[None, :, :], axis=-1)
    lt = jnp.any(fi[:, None, :] < fj[None, :, :], axis=-1)
    out_ref[...] = (le & lt).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dominance_matrix_pallas(F: jax.Array, *, block: int = DEFAULT_BLOCK,
                            interpret: bool = False) -> jax.Array:
    """(P, M) -> (P, P) int8 dominance matrix. P padded to ``block``."""
    P, M = F.shape
    Pp = ((P + block - 1) // block) * block
    # +inf padding: a padded row never dominates (le fails vs any real row on
    # all objectives? no — +inf <= +inf) ... pad with +inf and slice: padded
    # rows may relate to each other but the (P, P) slice is unaffected because
    # +inf rows dominate no real row (inf <= x is false) and real rows'
    # domination of padded columns lands outside the slice.
    Fp = jnp.pad(F.astype(jnp.float32), ((0, Pp - P), (0, 0)),
                 constant_values=jnp.inf)
    grid = (Pp // block, Pp // block)
    out = pl.pallas_call(
        _dominance_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, M), lambda i, j: (i, 0)),
            pl.BlockSpec((block, M), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Pp, Pp), jnp.int8),
        interpret=interpret,
    )(Fp, Fp)
    return out[:P, :P]


def _dominance_counts_kernel(fj_ref, fi_ref, out_ref):
    """Accumulate dominator counts for one (BJ,) column block over i steps."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    fi = fi_ref[...].astype(jnp.float32)          # (BI, M) dominators
    fj = fj_ref[...].astype(jnp.float32)          # (BJ, M) dominated
    le = jnp.all(fi[:, None, :] <= fj[None, :, :], axis=-1)
    lt = jnp.any(fi[:, None, :] < fj[None, :, :], axis=-1)
    out_ref[...] += jnp.sum((le & lt).astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dominance_counts_pallas(F: jax.Array, *, block: int = DEFAULT_BLOCK,
                            interpret: bool = False) -> jax.Array:
    """(P, M) -> (P,) int32 dominator counts, P×P matrix never materialized."""
    P, M = F.shape
    Pp = ((P + block - 1) // block) * block
    Fp = jnp.pad(F.astype(jnp.float32), ((0, Pp - P), (0, 0)),
                 constant_values=jnp.inf)
    nb = Pp // block
    out = pl.pallas_call(
        _dominance_counts_kernel,
        grid=(nb, nb),          # (j, i) with i innermost -> accumulation
        in_specs=[
            pl.BlockSpec((block, M), lambda j, i: (j, 0)),
            pl.BlockSpec((block, M), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.int32),
        interpret=interpret,
    )(Fp, Fp)
    return out[:P]
