"""Production mesh definitions.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
XLA_FLAGS before any jax import to fabricate 512 host devices.

Target hardware: TPU v5e pods — 256 chips/pod in a (16, 16) 2-D ICI torus;
multi-pod spans 2 pods over DCN. Axis roles:
  pod   — pure data parallelism across pods (gradient all-reduce over DCN)
  data  — data parallel + FSDP/ZeRO-3 parameter sharding (intra-pod ICI)
  model — tensor / expert parallelism (intra-pod ICI)
"""
from __future__ import annotations

import jax

# v5e roofline constants (per chip) — used by benchmarks/roofline.py
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s/link (~)
ICI_LINKS_2D = 4              # 2-D torus: 4 links/chip on v5e


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2, data: int = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = data or n // model
    return jax.make_mesh((data, model), ("data", "model"))
