import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (including any
# `from repro...`) — jax locks the device count at first initialization.

__doc__ = """Multi-pod dry-run (deliverable e).

For every (architecture × input-shape × mesh) cell: AOT-lower and compile the
appropriate step function (train_step / prefill_step / decode_step) against
ShapeDtypeStruct inputs on the production mesh, then record

  * memory_analysis()  — per-device argument/output/temp/peak bytes,
  * cost_analysis()    — HLO FLOPs / bytes accessed,
  * collective bytes   — parsed from the post-SPMD optimized HLO text,

into results/dryrun/<arch>__<shape>__<mesh>.json. These JSONs are the sole
input to benchmarks/roofline.py (§Roofline) and EXPERIMENTS.md §Dry-run.

NOTE the import order above: XLA_FLAGS must be set before jax initializes,
and only in this entrypoint — tests and benches see the real single device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import all_ids, get
from ..models import lm
from ..models import sharding as shard
from ..models.config import SHAPES, ModelConfig, cell_applicable
from ..training.optim import make_optimizer, optimizer_for_arch
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str, mesh) -> dict:
    """Abstract model inputs for a shape cell, with shardings attached."""
    cell = SHAPES[shape_name]
    B = cell.global_batch
    S = cell.seq_len
    # tp_friendly=False archs are pure-DP: batch shards over the whole mesh
    dp = shard.best_dp_prefix(mesh, B, full_dp=not cfg.tp_friendly)

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, spec))

    if cell.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32, P(dp, None)),
                 "labels": sds((B, S), jnp.int32, P(dp, None))}
    elif cell.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32, P(dp, None))}
    else:  # decode: one new token against an S-deep cache
        batch = {"token": sds((B, 1), jnp.int32, P(dp, None))}
    if cfg.family == "audio" and cell.kind != "decode":
        batch["frames"] = sds((B, cfg.encoder.n_frames, cfg.d_model),
                              jnp.bfloat16, P(dp, None, None))
    if cfg.family == "vlm" and cell.kind != "decode":
        batch["patches"] = sds((B, cfg.cross_kv_tokens, cfg.d_model),
                               jnp.bfloat16, P(dp, None, None))
    return batch


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _with_sharding(tree, specs, mesh):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def opt_state_specs(opt_name: str, params_abs, param_specs):
    """PartitionSpecs for optimizer state, derived from param specs."""
    P0 = P()

    def last_drop(spec, p):
        axes = tuple(spec)[:max(0, p.ndim - 1)]
        return P(*axes)

    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs, "step": P0, "gnorm": P0}
    if opt_name == "adamw8bit":
        qspec = jax.tree.map(
            lambda s, p: {"q": s, "s": last_drop(s, p)},
            param_specs, params_abs,
            is_leaf=lambda s: isinstance(s, P))
        return {"m": qspec, "v": qspec, "step": P0, "gnorm": P0}
    if opt_name == "adafactor":
        def fac(s, p):
            axes = tuple(s) + (None,) * (p.ndim - len(tuple(s)))
            if p.ndim >= 2:
                return {"vr": P(*axes[:-1]),
                        "vc": P(*(axes[:-2] + (axes[-1],)))}
            return {"v": P(*axes)}
        return {"f": jax.tree.map(fac, param_specs, params_abs,
                                  is_leaf=lambda s: isinstance(s, P)),
                "step": P0, "gnorm": P0}
    raise ValueError(opt_name)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape_name: str, mesh, unroll: bool = True):
    """Returns (fn, example_args (abstract, sharded), out_shardings, extra).

    unroll=True gives exact cost_analysis (every period materialized in HLO;
    XLA counts while bodies once — verified empirically); unroll=False is the
    production scan form whose memory_analysis reflects real loop buffer
    reuse. run_cell compiles both and records cost from the former, memory
    from the latter.
    """
    cell = SHAPES[shape_name]
    params_abs = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.key(0))
    pspecs_train = shard.param_specs(cfg, params_abs, mesh, mode="train")
    pspecs_serve = shard.param_specs(cfg, params_abs, mesh, mode="serve")
    batch = input_specs(cfg, shape_name, mesh)

    if cell.kind == "train":
        opt_name = optimizer_for_arch(cfg.param_counts()["total"])
        opt = make_optimizer(opt_name)
        state_abs = jax.eval_shape(opt.init, params_abs)
        sspecs = opt_state_specs(opt_name, params_abs, pspecs_train)

        def train_step(params, opt_state, batch):
            def loss(p):
                l, aux = lm.loss_fn(p, cfg, batch, unroll=unroll)
                return l, aux
            (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, {"loss": l, **aux}

        args = (_with_sharding(params_abs, pspecs_train, mesh),
                _with_sharding(state_abs, sspecs, mesh), batch)
        out_shardings = (shard.to_shardings(mesh, pspecs_train),
                         shard.to_shardings(mesh, sspecs), None)
        return train_step, args, out_shardings, {"optimizer": opt_name}

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            logits, cache = lm.prefill(params, cfg, batch, unroll=unroll)
            return logits, cache

        args = (_with_sharding(params_abs, pspecs_serve, mesh), batch)
        # let GSPMD choose cache/logit layouts from propagation
        return prefill_step, args, None, {}

    # decode
    B = cell.global_batch
    cache_abs = jax.eval_shape(
        lambda: lm.make_cache(cfg, B, cell.seq_len,
                              kv_len=jnp.full((B,), cell.seq_len - 1,
                                              jnp.int32)))
    cspecs = shard.cache_specs(cfg, cache_abs, mesh)

    def decode_step(params, cache, batch):
        return lm.decode_step(params, cfg, batch["token"], cache,
                              unroll=unroll)

    args = (_with_sharding(params_abs, pspecs_serve, mesh),
            _with_sharding(cache_abs, cspecs, mesh), batch)
    out_shardings = (None, shard.to_shardings(mesh, cspecs))
    return decode_step, args, out_shardings, {}


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in post-SPMD optimized HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    # lines like:  %x = bf16[16,4096,320]{...} all-gather(...)
    shape_re = re.compile(r"\b(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|u64)"
                          r"\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
                      + r")\b", stripped)
        if not m:
            continue
        op = m.group(1)
        # result shapes approximate payload (operands ~= result for these ops)
        sm = shape_re.search(stripped)
        if sm is None:
            continue
        dtype, dims = sm.groups()
        bytes_per = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4,
                     "u32": 4, "bf16": 2, "f16": 2, "s8": 1, "u8": 1,
                     "pred": 1}[dtype]
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] += float(n * bytes_per)
        count[op] += 1
    return {"bytes": out, "count": count,
            "total_bytes": float(sum(out.values()))}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             force: bool = False) -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get(arch).config()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "family": cfg.family,
           "params_total": cfg.param_counts()["total"],
           "params_active": cfg.param_counts()["active"],
           "time": None, "status": None}

    ok, reason = cell_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    try:
        import dataclasses as _dc
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        n_chips = int(np.prod(list(mesh.shape.values())))

        def compile_variant(cfg_v, unroll):
            fn, args, out_shardings, extra = build_cell(
                cfg_v, shape_name, mesh, unroll=unroll)
            rec.update(extra)
            with shard.activation_mesh(
                    mesh, full_dp=not cfg.tp_friendly), mesh:
                jitted = (jax.jit(fn, out_shardings=out_shardings)
                          if out_shardings is not None else jax.jit(fn))
                return jitted.lower(*args).compile()

        def costs_of(compiled):
            c = compiled.cost_analysis() or {}
            coll = collective_bytes(compiled.as_text())
            return {"flops": float(c.get("flops", 0.0)),
                    "bytes": float(c.get("bytes accessed", 0.0)),
                    "coll": coll}

        # 1) production scan form, full depth: memory analysis (loop
        #    buffers are reused, matching real execution)
        compiled_scan = compile_variant(cfg, False)
        mem = compiled_scan.memory_analysis()

        # 2) cost analysis: XLA counts while bodies once, so costs come from
        #    *unrolled* programs. Unrolling the full depth is prohibitive for
        #    the big MoE archs, but periods are homogeneous, so costs are
        #    exactly linear in the period count: compile unrolled 2- and
        #    4-period variants and extrapolate
        #        total(n) = c2 + (n - 2) · (c4 - c2) / 2.
        plen = len(cfg.pattern)
        n_per = cfg.n_periods
        if n_per <= 4:
            cu = costs_of(compile_variant(cfg, True))
            flops, bytes_acc = cu["flops"], cu["bytes"]
            coll = cu["coll"]
        else:
            c2 = costs_of(compile_variant(
                _dc.replace(cfg, n_layers=2 * plen), True))
            c4 = costs_of(compile_variant(
                _dc.replace(cfg, n_layers=4 * plen), True))
            # guard: XLA occasionally optimizes the 4-period program below
            # the 2-period one (cross-period CSE); clamp the per-period slope
            # at zero so the extrapolation never goes negative
            lin = lambda a2, a4: a2 + (n_per - 2) * max((a4 - a2) / 2.0, 0.0)
            flops = lin(c2["flops"], c4["flops"])
            bytes_acc = lin(c2["bytes"], c4["bytes"])
            coll = {
                "bytes": {k: lin(c2["coll"]["bytes"][k], c4["coll"]["bytes"][k])
                          for k in c2["coll"]["bytes"]},
                "count": {k: int(lin(c2["coll"]["count"][k],
                                     c4["coll"]["count"][k]))
                          for k in c2["coll"]["count"]},
                "total_bytes": lin(c2["coll"]["total_bytes"],
                                   c4["coll"]["total_bytes"]),
            }
        rec.update(
            status="ok",
            n_chips=n_chips,
            flops=flops,
            bytes_accessed=bytes_acc,
            flops_scan=float((compiled_scan.cost_analysis() or {})
                             .get("flops", -1.0)),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            collectives=coll,
            time=time.time() - t0,
        )
        print(mem)
        print({"flops": flops, "bytes accessed": bytes_acc})
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:],
                   time=time.time() - t0)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = all_ids() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = (["single", "multipod"] if args.mesh == "both"
              else [args.mesh])

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape_name, mesh_kind, force=args.force)
                status = rec["status"]
                msg = rec.get("error", rec.get("reason", ""))[:100]
                t = rec.get("time")
                print(f"[{status:7s}] {arch:28s} {shape_name:12s} "
                      f"{mesh_kind:8s} {t and f'{t:6.1f}s' or '':8s} {msg}",
                      flush=True)
                failures += status == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
