"""Serving launcher: the paper's cloud-edge cluster with real (reduced)
models on this host, NSGA-II-optimized routing, continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --requests 32 \
        [--optimize-router] [--fail-node 1 --fail-at 5]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..cluster.spec import paper_testbed
from ..configs import get
from ..core.fitness import EvalConfig, TraceEvaluator
from ..core.nsga2 import NSGA2, NSGA2Config
from ..core.policy import BOUNDS_HI, BOUNDS_LO, PAPER_DEFAULTS
from ..models import lm
from ..serving import ClusterServer, EngineConfig, ServeRequest
from ..workload.trace import build_trace


def build_models():
    big = get("stablelm-3b").smoke()
    small = get("qwen3-1.7b").smoke()
    pb = lm.init(jax.random.key(0), big)
    ps = lm.init(jax.random.key(1), small)
    return {"gemma3:27b": (big, pb),
            "qwen2.5:1.5b-instruct": (small, ps),
            "qwen2.5-coder:1.5b-instruct": (small, ps),
            "qwen2.5-math:1.5b-instruct": (small, ps)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--optimize-router", action="store_true")
    ap.add_argument("--generations", type=int, default=30)
    ap.add_argument("--fail-node", type=int, default=None)
    ap.add_argument("--fail-at", type=int, default=5)
    ap.add_argument("--max-new-tokens", type=int, default=4)
    args = ap.parse_args()

    cluster = paper_testbed()
    trace = build_trace(max(args.requests, 64), seed=0)

    thresholds = PAPER_DEFAULTS
    if args.optimize_router:
        print("optimizing router thresholds with NSGA-II ...")
        ev = TraceEvaluator(trace, cluster, EvalConfig(concurrency=4))
        cfg = NSGA2Config(pop_size=48, n_generations=args.generations,
                          lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
        opt = NSGA2(ev.make_fitness("threshold"), cfg)
        state = opt.evolve_scan(jax.random.key(0), args.generations)
        thresholds, F = opt.select_by_weights(
            state, jnp.array([1 / 3, 1 / 3, 1 / 3]))
        print("selected thresholds:", [round(float(x), 3) for x in thresholds],
              "objectives (RQ, C, RT):", [float(x) for x in F])

    print("building cluster server (4 nodes, 10 routable pairs) ...")
    srv = ClusterServer(cluster, build_models(), thresholds,
                        EngineConfig(max_slots=2, max_seq=48,
                                     max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    for i, r in enumerate(trace.requests[:args.requests]):
        srv.submit(ServeRequest(request_id=i, req=r,
                                max_new_tokens=args.max_new_tokens))
        if args.fail_node is not None and i == args.fail_at:
            print(f"!! injecting failure of node {args.fail_node}")
            srv.fail_node(args.fail_node)
    done = srv.run()
    dt = time.time() - t0
    print(f"served {len(done)} requests in {dt:.2f}s "
          f"({len(done) / dt:.1f} req/s on CPU)")
    print("stats:", srv.stats())


if __name__ == "__main__":
    main()
