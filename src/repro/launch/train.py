"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --smoke --steps 50 --checkpoint-dir /tmp/ck

``--smoke`` runs the reduced config on local devices (this container);
without it the full config is used — on real hardware you would launch one
process per host (jax.distributed.initialize) against the production mesh
from launch.mesh. ``--dry-run`` AOT-compiles the train step instead of
executing (see launch.dryrun for the full sweep tooling).
"""
from __future__ import annotations

import argparse

import jax

from ..configs import all_ids, get
from ..training.optim import OptConfig
from ..training.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=all_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default=None,
                    choices=[None, "adamw", "adamw8bit", "adafactor"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    mod = get(args.arch)
    cfg = mod.smoke() if args.smoke else mod.config()
    print(f"arch={cfg.name} params={cfg.param_counts()['total'] / 1e6:.1f}M "
          f"devices={len(jax.devices())}")

    tcfg = TrainConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        microbatches=args.microbatches, steps=args.steps,
        optimizer=args.optimizer, opt=OptConfig(lr=args.lr),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    trainer = Trainer(cfg, tcfg)
    _, _, hist = trainer.run(
        resume=not args.no_resume,
        callback=lambda step, m: print(
            f"step {step:5d} loss {m['loss']:.4f} nll {m['nll']:.4f} "
            f"gnorm {m['gnorm']:.3f} tok/s {m['tokens_per_s']:.0f}",
            flush=True))
    print("final:", hist[-1])


if __name__ == "__main__":
    main()
