from .spec import (ClusterArrays, ClusterSpec, LinkSpec, ModelSpec, NodeSpec,
                   paper_testbed)

__all__ = ["ClusterSpec", "NodeSpec", "ModelSpec", "LinkSpec", "ClusterArrays",
           "paper_testbed"]
