"""Discrete-event simulator of the cloud-edge cluster.

This is the *oracle* counterpart of the JAX evaluator in
``repro.core.fitness``: a classic heap-based event loop with explicit client
and slot entities. The two implementations are developed independently and a
property test (tests/test_fitness_equivalence.py) asserts they agree on random
traces/policies — the standard way to de-risk a vectorized rewrite.

It also powers failure-injection experiments that the fixed-shape JAX scan
does not model: node crash/recovery events, hedged requests, and reroute-on-
failure, used by the serving scheduler tests.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..workload.trace import Trace
from .spec import ClusterSpec


@dataclasses.dataclass
class SimResult:
    q: np.ndarray
    cost: np.ndarray
    rt: np.ndarray
    assign: np.ndarray
    wait: np.ndarray
    node_busy_time: np.ndarray
    # phase split (QoE accounting, mirrors fitness.EvalResult); optional so
    # externally-constructed pre-QoE SimResults keep working
    ttft: Optional[np.ndarray] = None   # upload + queue wait + prefill
    tpot: Optional[np.ndarray] = None   # decode seconds per output token

    def summary(self) -> Dict[str, float]:
        out = {"avg_quality": float(self.q.mean()),
               "avg_response_time": float(self.rt.mean()),
               "avg_cost": float(self.cost.mean())}
        if self.ttft is not None:
            out["avg_ttft"] = float(self.ttft.mean())
            out["avg_tpot"] = float(self.tpot.mean())
        return out

    def slo_attainment(self, ttft_deadline: np.ndarray,
                       tpot_deadline: np.ndarray) -> float:
        """Fraction of requests meeting both phase deadlines.

        Deliberately re-implements the attainment predicate rather than
        calling objectives.slo_ok: this class is the independent oracle the
        JAX path is validated against (tests/test_slo.py), so sharing the
        expression would defeat the cross-check.
        """
        assert self.ttft is not None, "result carries no phase accounting"
        ok = (self.ttft <= ttft_deadline) & (self.tpot <= tpot_deadline)
        return float(ok.mean())


class ClusterSimulator:
    """Trace execution with per-node slots: closed-loop (G clients) or
    open-loop (requests released at explicit ``arrivals`` timestamps)."""

    def __init__(self, trace: Trace, cluster: ClusterSpec, seed: int = 0):
        self.trace = trace
        self.cluster = cluster
        # reuse the same static tables as the JAX path so quality/cost/
        # service-time definitions are shared; only queueing is independent
        from ..core.fitness import build_tables
        tables, arrays = build_tables(trace, cluster, seed=seed)
        self.quality = np.asarray(tables.quality)
        self.cost = np.asarray(tables.cost)
        self.service = np.asarray(tables.service)
        self.up = np.asarray(tables.up_time)
        self.down = np.asarray(tables.down_time)
        self.prefill = np.asarray(tables.prefill_time)
        self.tpot_pair = np.asarray(tables.tpot)
        self.pair_node = np.asarray(arrays.pair_node)
        self.node_conc = np.asarray(arrays.node_conc)
        self.arrays = arrays

    def run(self, assign: Sequence[int], concurrency: int = 1,
            down_nodes: Optional[Dict[int, Tuple[float, float]]] = None,
            on_failure: Optional[Callable[[int, int], int]] = None,
            arrivals: Optional[Sequence[float]] = None) -> SimResult:
        """Execute the trace under assignment ``assign``.

        down_nodes: {node: (t_down, t_up)} crash windows. A request dispatched
        to a crashed node invokes ``on_failure(request, node) -> new_pair``
        (default: retry on the cloud fallback), modeling the reroute-on-
        failure behaviour of the runtime router.

        arrivals: optional (I,) sorted timestamps — **open-loop** mode:
        request i enters the system at ``arrivals[i]`` regardless of earlier
        completions (``concurrency`` is ignored; node capacity still queues).
        Defaults to the trace's own ``arrival_time`` when it carries one.
        """
        I = self.trace.n_requests
        G = concurrency
        n_nodes = len(self.cluster.nodes)
        down_nodes = down_nodes or {}
        if arrivals is None and self.trace.has_arrivals:
            arrivals = self.trace.arrival_time
        if arrivals is not None:
            arrivals = np.asarray(arrivals, np.float64)
            assert arrivals.shape == (I,)
            # index order must equal time order or this loop oracle would
            # silently disagree with the event-heap oracle
            assert (np.diff(arrivals) >= 0).all(), "arrivals must be sorted"

        # slot free-times per node (the capacity C_j resource)
        slots: List[List[float]] = [
            [0.0] * int(self.node_conc[n]) for n in range(n_nodes)]
        client_ready = [0.0] * G

        q = np.zeros(I)
        cost = np.zeros(I)
        rt = np.zeros(I)
        wait = np.zeros(I)
        ttft = np.zeros(I)
        tpot = np.zeros(I)
        out_assign = np.zeros(I, np.int64)
        busy = np.zeros(n_nodes)

        for i in range(I):
            c = i % G
            arrival = (float(arrivals[i]) if arrivals is not None
                       else client_ready[c])
            pair = int(assign[i])
            node = int(self.pair_node[pair])

            if node in down_nodes:
                t_down, t_up = down_nodes[node]
                if t_down <= arrival < t_up:
                    pair = (on_failure(i, node) if on_failure is not None
                            else int(self.arrays.cloud_fallback_pair))
                    node = int(self.pair_node[pair])

            ready = arrival + self.up[i, pair]
            s = int(np.argmin(slots[node]))
            start = max(ready, slots[node][s])
            finish = start + self.service[i, pair]
            completion = finish + self.down[i, pair]
            slots[node][s] = finish
            client_ready[c] = completion

            q[i] = self.quality[i, pair]
            cost[i] = self.cost[i, pair]
            rt[i] = completion - arrival
            wait[i] = start - ready
            # first token leaves prefill at start + prefill_time
            ttft[i] = (start + self.prefill[i, pair]) - arrival
            tpot[i] = self.tpot_pair[pair]
            out_assign[i] = pair
            busy[node] += self.service[i, pair]

        return SimResult(q=q, cost=cost, rt=rt, assign=out_assign, wait=wait,
                         node_busy_time=busy, ttft=ttft, tpot=tpot)

    # -- event-heap variant -------------------------------------------------
    def run_event_heap(self, assign: Sequence[int], concurrency: int = 1,
                       arrivals: Optional[Sequence[float]] = None
                       ) -> SimResult:
        """Same semantics via an explicit event heap (belt-and-braces oracle:
        two independent queueing implementations must agree). With
        ``arrivals`` (or a trace carrying ``arrival_time``) every request's
        issue event is scheduled at its own timestamp — open-loop mode."""
        I = self.trace.n_requests
        G = concurrency
        n_nodes = len(self.cluster.nodes)
        if arrivals is None and self.trace.has_arrivals:
            arrivals = self.trace.arrival_time

        q = np.zeros(I); cost = np.zeros(I); rt = np.zeros(I)
        wait = np.zeros(I); out_assign = np.zeros(I, np.int64)
        ttft = np.zeros(I); tpot = np.zeros(I)
        busy = np.zeros(n_nodes)

        # events: (time, seq, kind, payload)
        heap: List[Tuple[float, int, str, tuple]] = []
        seq = 0
        node_free: List[List[float]] = [
            [0.0] * int(self.node_conc[n]) for n in range(n_nodes)]
        if arrivals is not None:
            arrivals = np.asarray(arrivals, np.float64)
            assert arrivals.shape == (I,)
            assert (np.diff(arrivals) >= 0).all(), "arrivals must be sorted"
            for i in range(I):
                heapq.heappush(heap, (float(arrivals[i]), seq, "issue",
                                      (i, None))); seq += 1
            issued = I
        else:
            next_req = [c for c in range(min(G, I))]
            for c, i in enumerate(next_req):
                heapq.heappush(heap, (0.0, seq, "issue", (i, c))); seq += 1
            issued = min(G, I)

        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if kind == "issue":
                i, c = payload
                pair = int(assign[i]); node = int(self.pair_node[pair])
                ready = t + self.up[i, pair]
                s = int(np.argmin(node_free[node]))
                start = max(ready, node_free[node][s])
                finish = start + self.service[i, pair]
                node_free[node][s] = finish
                completion = finish + self.down[i, pair]
                q[i] = self.quality[i, pair]; cost[i] = self.cost[i, pair]
                rt[i] = completion - t; wait[i] = start - ready
                ttft[i] = (start + self.prefill[i, pair]) - t
                tpot[i] = self.tpot_pair[pair]
                out_assign[i] = pair; busy[node] += self.service[i, pair]
                heapq.heappush(heap, (completion, seq, "done", (i, c))); seq += 1
            else:  # done -> closed-loop client issues its next request
                _, c = payload
                if c is not None and issued < I:
                    heapq.heappush(heap, (t, seq, "issue", (issued, c)))
                    seq += 1; issued += 1

        return SimResult(q=q, cost=cost, rt=rt, assign=out_assign, wait=wait,
                         node_busy_time=busy, ttft=ttft, tpot=tpot)
