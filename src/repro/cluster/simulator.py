"""Discrete-event simulator of the cloud-edge cluster.

This is the *oracle* counterpart of the JAX evaluator in
``repro.core.fitness``: a classic heap-based event loop with explicit client
and slot entities. The two implementations are developed independently and a
property test (tests/test_fitness_equivalence.py) asserts they agree on random
traces/policies — the standard way to de-risk a vectorized rewrite.

It also powers failure-injection experiments that the fixed-shape JAX scan
does not model: node crash/recovery events, hedged requests, and reroute-on-
failure, used by the serving scheduler tests.

With ``prefix_cache=True`` (session traces from ``workload.sessions``, open
loop) both oracles mirror the JAX evaluator's prefix-cache model: a served
prompt's whole-block prefix stays resident on its node, and a later request
of the same session (or sharing the same system prompt) on that node pays
only the uncached prefill fraction plus a discounted price for cached prompt
tokens — the equivalence property extends to this regime.

Both oracles can also make the routing decision *themselves*: pass
``policy=<registry name>, genome=...`` instead of ``assign`` and every
dispatch builds the same ``PolicyInputs`` bundle the JAX scan builds (busy
slots at arrival, per-pair cache hit fractions, deadline contract, float32
estimate rows) and calls ``RoutingPolicy.decide_py`` through the registry —
no per-policy mirroring here, so new policy modules get DES-oracle coverage
(and the JAX/DES equivalence property, tests/test_online.py) for free.
Per-policy decision state (e.g. the budget spend ledger) threads through
``RoutingPolicy.update_py`` in dispatch order, exactly like the scan carry.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.policies import PolicyInputs, get_policy
from ..faults import (FaultSchedule, link_slowdown_np, node_available_np,
                      node_slowdown_np, transient_delay_np)
from ..learn import LearnConfig
from ..learn import estimators as learn_est
from ..obs.trace import NOOP_TRACER
from ..workload.trace import Trace
from .spec import ClusterSpec


@dataclasses.dataclass
class SimResult:
    q: np.ndarray
    cost: np.ndarray
    rt: np.ndarray
    assign: np.ndarray
    wait: np.ndarray
    node_busy_time: np.ndarray
    # phase split (QoE accounting, mirrors fitness.EvalResult); optional so
    # externally-constructed pre-QoE SimResults keep working
    ttft: Optional[np.ndarray] = None   # upload + queue wait + prefill
    tpot: Optional[np.ndarray] = None   # decode seconds per output token
    hit: Optional[np.ndarray] = None    # realized cached-prefix fraction
    # KV-transfer seconds between prefill and decode (disaggregated runs;
    # exactly 0 on colocated routes)
    transfer: Optional[np.ndarray] = None
    # learned-estimator accounting (ClusterSimulator(learned=True) runs):
    # per-request decision-time estimates vs. realized values of the phase
    # times the estimators correct (full-prompt prefill seconds and decode
    # s/token, both including straggler stretch), and the final estimator
    # state — reseedable into the next window via run(learn_state=)
    est_prefill: Optional[np.ndarray] = None
    est_tpot: Optional[np.ndarray] = None
    real_prefill: Optional[np.ndarray] = None
    real_tpot: Optional[np.ndarray] = None
    learn_state: Optional[np.ndarray] = None

    def summary(self) -> Dict[str, float]:
        out = {"avg_quality": float(self.q.mean()),
               "avg_response_time": float(self.rt.mean()),
               "avg_cost": float(self.cost.mean())}
        if self.ttft is not None:
            out["avg_ttft"] = float(self.ttft.mean())
            out["avg_tpot"] = float(self.tpot.mean())
        return out

    def slo_attainment(self, ttft_deadline: np.ndarray,
                       tpot_deadline: np.ndarray) -> float:
        """Fraction of requests meeting both phase deadlines.

        Deliberately re-implements the attainment predicate rather than
        calling objectives.slo_ok: this class is the independent oracle the
        JAX path is validated against (tests/test_slo.py), so sharing the
        expression would defeat the cross-check.
        """
        assert self.ttft is not None, "result carries no phase accounting"
        ok = (self.ttft <= ttft_deadline) & (self.tpot <= tpot_deadline)
        return float(ok.mean())


class ClusterSimulator:
    """Trace execution with per-node slots: closed-loop (G clients) or
    open-loop (requests released at explicit ``arrivals`` timestamps)."""

    def __init__(self, trace: Trace, cluster: ClusterSpec, seed: int = 0,
                 prefix_cache: bool = False, cache_block: int = 16,
                 disaggregated: bool = False, faults=None,
                 learned: bool = False, learner: LearnConfig = LearnConfig()):
        if prefix_cache:
            assert trace.has_sessions and trace.has_arrivals, \
                "prefix_cache needs an open-loop session trace"
        self.trace = trace
        self.cluster = cluster
        self.prefix_cache = prefix_cache
        self.cache_block = cache_block
        self.disaggregated = disaggregated
        # online-learned estimators (repro.learn): the DES twin of the JAX
        # scan's EvalConfig(learned=True) — corrected PolicyInputs rows at
        # decision time, residual updates at dispatch, float32 op-for-op
        self.learned = learned
        self.learner = learner
        # reuse the same static tables as the JAX path so quality/cost/
        # service-time definitions are shared; only queueing is independent
        from ..core.fitness import build_tables
        tables, arrays = build_tables(trace, cluster, seed=seed)
        self.quality = np.asarray(tables.quality)
        self.quality_mean = np.asarray(tables.quality_mean)
        self.cost = np.asarray(tables.cost)
        self.service = np.asarray(tables.service)
        self.up = np.asarray(tables.up_time)
        self.down = np.asarray(tables.down_time)
        self.prefill = np.asarray(tables.prefill_time)
        self.tpot_pair = np.asarray(tables.tpot)
        self.prompt_cost = np.asarray(tables.prompt_cost)
        self.arrays = arrays
        # host-side view for per-dispatch policy decisions (no device
        # transfers inside the event loop)
        self.np_arrays = arrays.numpy()
        self.pair_node = self.np_arrays.pair_node
        self.node_conc = self.np_arrays.node_conc
        # colocated route per pair (disaggregated fault fallback)
        self._colo_route = {
            int(p): r for r, (p, q_) in enumerate(
                zip(self.np_arrays.route_prefill,
                    self.np_arrays.route_decode)) if p == q_}
        # deterministic fault injection (repro.faults): a FaultSchedule (or
        # pre-compiled FaultTables) mirrored op-for-op against the JAX
        # scan's EvalConfig(faulty=True) branches
        if isinstance(faults, FaultSchedule):
            faults = faults.compile(len(cluster.nodes))
        self.faults = faults

    # -- fault-injection mirror ----------------------------------------------
    def _fault_ctx(self, i: int, arrival: float):
        """(t_eff, avail, slow, linkf, delay) at request ``i``'s effective
        arrival — the DES twin of the scan's fault context — or None when
        no schedule is attached. Float32 arithmetic like the scan."""
        if self.faults is None:
            return None
        ft = self.faults
        delay = float(transient_delay_np(ft, i))
        t_eff = float(np.float32(arrival) + np.float32(delay))
        return (t_eff, node_available_np(ft, t_eff),
                node_slowdown_np(ft, t_eff),
                float(link_slowdown_np(ft, t_eff)), delay)

    def _fault_failover(self, decided: int, avail) -> Tuple[int, bool]:
        """The scan's deterministic failover: if the decision lands on a
        crashed node, prefer the lowest-index alive cloud pair (alive
        colocated route when disaggregated), else the lowest alive index."""
        a = self.np_arrays
        if self.disaggregated:
            rp, rq = a.route_prefill, a.route_decode
            dead = (~avail[self.pair_node[rp]]) | (~avail[self.pair_node[rq]])
            if not dead[decided]:
                return decided, False
            rank = ((rp != rq).astype(np.float32) * np.float32(1e6)
                    + np.arange(len(rp), dtype=np.float32))
        else:
            dead = ~avail[self.pair_node]
            if not dead[decided]:
                return decided, False
            rank = (a.pair_is_edge.astype(np.float32) * np.float32(1e6)
                    + np.arange(len(dead), dtype=np.float32))
        return int(np.argmin(np.where(dead, np.inf, rank))), True

    # -- prefix-cache mirror (independent of the JAX carry implementation) ----
    def _cache_state(self):
        return {} if self.prefix_cache else None

    def _cache_hit(self, state, i: int, node: int) -> float:
        """Cached fraction of request i's prompt on ``node`` (0 when the
        model is off), from the per-(node, session/system-prompt) state."""
        if state is None:
            return 0.0
        tr = self.trace
        P = float(tr.prompt_tokens[i])
        blk = self.cache_block
        g = int(tr.group_id[i])
        y = int(tr.sys_id[i]) if tr.sys_id is not None else -1
        hit = 0.0
        if g >= 0:
            hit = min(state.get((node, "sess", g), 0.0),
                      float(int(P) // blk * blk))
        if y >= 0:
            sys_tok = float(tr.sys_tokens[i])
            hit = max(hit, min(state.get((node, "sys", y), 0.0),
                               float(int(sys_tok) // blk * blk)))
        return hit / max(P, 1.0)

    def _cache_admit(self, state, i: int, node: int) -> None:
        if state is None:
            return
        tr = self.trace
        blk = self.cache_block
        g = int(tr.group_id[i])
        y = int(tr.sys_id[i]) if tr.sys_id is not None else -1
        if g >= 0:
            key = (node, "sess", g)
            state[key] = max(state.get(key, 0.0),
                             float(int(tr.prompt_tokens[i]) // blk * blk))
        if y >= 0:
            key = (node, "sys", y)
            state[key] = max(state.get(key, 0.0),
                             float(int(tr.sys_tokens[i]) // blk * blk))

    def _discounted(self, state, i: int, pair: int):
        """(hit_frac, service_eff, prefill_eff, cost_eff) for request i."""
        from ..core.policy import CACHED_TOKEN_PRICE_FACTOR
        node = int(self.pair_node[pair])
        hf = self._cache_hit(state, i, node)
        service = self.service[i, pair] - hf * self.prefill[i, pair]
        prefill = self.prefill[i, pair] * (1.0 - hf)
        cost = (self.cost[i, pair]
                - hf * (1.0 - CACHED_TOKEN_PRICE_FACTOR)
                * self.prompt_cost[i, pair])
        return hf, service, prefill, cost

    # -- registry-driven in-loop decisions -----------------------------------
    def _resolve_policy(self, policy, genome, assign):
        """Validate the (policy, genome) / assign alternative and return
        (RoutingPolicy | None, cast genome, init decision state)."""
        if policy is None:
            assert assign is not None, "need either assign or policy+genome"
            return None, None, None
        pol = get_policy(policy)        # ValueError lists registered names
        assert genome is not None, f"policy {pol.name!r} needs a genome"
        if not pol.genome_spec.per_request:
            want = "route" if self.disaggregated else "pair"
            assert pol.decides == want, \
                (f"policy {pol.name!r} decides over {pol.decides!r} indices "
                 f"but the simulator was built with "
                 f"disaggregated={self.disaggregated}")
        g = np.asarray(genome,
                       np.int32 if pol.genome_spec.discrete else np.float32)
        return pol, g, pol.init_state()

    def _policy_inputs(self, i: int, busy, cache, now: float,
                       avail=None, lstate=None) -> PolicyInputs:
        """The DES twin of the JAX scan's decision context: same float32
        table rows, busy-slot counts at arrival, whole-block cache hit
        fractions, and deadline contract (+inf without SLOs). ``avail``
        (fault injection) masks crashed nodes out of the policy's view with
        the router's sentinels (queue_len -> 1e6, up -> 1e9)."""
        from ..core.fitness import DEAD_QUEUE, DEAD_UP
        tr = self.trace
        n_nodes = len(self.cluster.nodes)
        up_row = self.up[i]
        queue = np.asarray(busy, np.int64)
        if avail is not None:
            queue = np.where(avail, queue, DEAD_QUEUE)
            up_row = np.where(avail[self.pair_node], up_row,
                              np.float32(DEAD_UP)).astype(np.float32)
        if cache is not None:
            hit_node = np.asarray(
                [self._cache_hit(cache, i, n) for n in range(n_nodes)],
                np.float32)
            hit = hit_node[self.pair_node]
        else:
            hit = np.zeros(len(self.pair_node), np.float32)
        has_slos = tr.has_slos
        if self.disaggregated:
            blk = float(self.cache_block)
            kv_blk = np.float32(np.floor(
                np.float32(tr.prompt_tokens[i]) / np.float32(blk)) * blk)
            kv_bytes = (kv_blk * np.asarray(
                self.np_arrays.pair_kv_bytes_per_token,
                np.float32)).astype(np.float32)
        else:
            kv_bytes = np.zeros(len(self.pair_node), np.float32)
        if self.learned and lstate is not None:
            # learned-estimator correction, mirroring the scan op-for-op:
            # residual posteriors override the prefill/tpot estimate rows
            # and fill the quality/unc rows (neutral state -> bitwise the
            # static rows)
            x1, x2, x3 = learn_est.features(
                np, np.float32(tr.prompt_tokens[i]),
                np.float32(tr.complexity[i]), queue,
                np.asarray(self.node_conc))
            d_p, d_t, d_q, unc_n = learn_est.predict_np(
                self.learner, lstate, n_nodes, int(tr.pred_category[i]),
                x1, x2, x3)
            prefill_row, tpot_row, quality_row, unc_row = \
                learn_est.corrected_rows(
                    np, np.asarray(self.prefill[i], np.float32),
                    np.asarray(self.tpot_pair, np.float32),
                    np.asarray(self.quality_mean[i], np.float32),
                    d_p, d_t, d_q, unc_n, np.asarray(self.pair_node))
        else:
            prefill_row = self.prefill[i]
            tpot_row = self.tpot_pair
            quality_row = self.quality_mean[i]
            unc_row = np.zeros(len(self.pair_node), np.float32)
        return PolicyInputs(
            index=np.int32(i), now=np.float32(now),
            complexity=np.float32(tr.complexity[i]),
            pred_category=np.int32(tr.pred_category[i]),
            pred_conf=np.float32(tr.pred_conf[i]),
            ttft_deadline=np.float32(tr.ttft_deadline[i] if has_slos
                                     else np.inf),
            tpot_deadline=np.float32(tr.tpot_deadline[i] if has_slos
                                     else np.inf),
            prompt_tokens=np.float32(tr.prompt_tokens[i]),
            up=up_row, prefill=prefill_row, tpot=tpot_row,
            cost=self.cost[i], prompt_cost=self.prompt_cost[i],
            hit_frac=hit, queue_len=queue,
            kv_bytes=kv_bytes, quality=quality_row, unc=unc_row)

    # -- learned-estimator feedback (shared by both oracles) ------------------
    def _learn_observe(self, lstate, i: int, inp: PolicyInputs, pair_p: int,
                       pair_q: int, node_p: int, node_q: int, slow_p: float,
                       slow_q: float) -> np.ndarray:
        """Feed the dispatched request's residual targets into the estimator
        state: the scan's update mirror (prefill residual on the prefill
        node, tpot + quality on the decode node; fault-free observations are
        exact zeros for the latency signals)."""
        x1, x2, x3 = learn_est.features(
            np, inp.prompt_tokens, inp.complexity,
            np.asarray(inp.queue_len, np.int64), np.asarray(self.node_conc))
        y_p, y_t, y_q = learn_est.observations(
            np, np.float32(self.prefill[i, pair_p]), np.float32(slow_p),
            np.float32(self.tpot_pair[pair_q]), np.float32(slow_q),
            np.float32(self.quality[i, pair_q]),
            np.float32(self.quality_mean[i, pair_q]))
        return learn_est.update_np(
            self.learner, lstate, len(self.node_conc),
            int(inp.pred_category), node_p, node_q, x1, x2, x3, y_p, y_t,
            y_q)

    def _learn_after_colo(self, lstate, i: int, inp, pair: int, node: int,
                          slow_n: float, est_p, est_t, real_p, real_t):
        """Record est-vs-realized phase times and update the state after a
        colocated dispatch (realized = full static phase × straggler)."""
        est_p[i] = float(inp.prefill[pair])
        est_t[i] = float(inp.tpot[pair])
        real_p[i] = float(self.prefill[i, pair]) * slow_n
        real_t[i] = float(self.tpot_pair[pair]) * slow_n
        return self._learn_observe(lstate, i, inp, pair, pair, node, node,
                                   slow_n, slow_n)

    def _learn_after_disagg(self, lstate, i: int, inp, row, fc, est_p,
                            est_t, real_p, real_t):
        """Disaggregated twin of :meth:`_learn_after_colo`: prefill leg
        attributed to the prefill node, tpot/quality to the decode node."""
        pp, qd = row["pp"], row["pair"]
        lp, lq = row["lp"], row["lq"]
        slow_p = 1.0 if fc is None else float(fc[2][lp])
        slow_q = 1.0 if fc is None else float(fc[2][lq])
        est_p[i] = float(inp.prefill[pp])
        est_t[i] = float(inp.tpot[qd])
        real_p[i] = float(self.prefill[i, pp]) * slow_p
        real_t[i] = float(self.tpot_pair[qd]) * slow_q
        return self._learn_observe(lstate, i, inp, pp, qd, lp, lq, slow_p,
                                   slow_q)

    def _learn_init(self, pol, learn_state):
        """Initial estimator state for a run (None when learning is off)."""
        if not self.learned:
            return None
        assert pol is not None, \
            "learned=True needs in-loop policy= decisions (not assign=)"
        if learn_state is not None:
            return np.asarray(learn_state, np.float32).copy()
        return learn_est.init_state(self.learner, len(self.node_conc))

    # -- observability emission (shared by both oracles, so the span and
    # audit streams are identical by construction) ----------------------------
    def _trace_issue(self, tracer, audit, i: int, now: float, pol, g, inp,
                     raw: int, decided: int,
                     failover: Optional[str] = None) -> None:
        """Open request i's span and log its routing decision. ``decided``
        is a pair index (colocated mode) or a route index (disaggregated);
        ``raw`` is the policy output before any down-node failover."""
        if tracer.enabled:
            tracer.begin(i, now,
                         category=int(self.trace.pred_category[i]))
            tracer.event(i, "route-decision", now, decision=int(decided),
                         raw=int(raw), failover=failover)
        if audit is not None and pol is not None:
            if self.disaggregated:
                a = self.np_arrays
                pair = int(a.route_decode[decided])
                prefill_pair = int(a.route_prefill[decided])
            else:
                pair = int(decided)
                prefill_pair = None
            audit.record(
                i, now, pol.name, pol.decides, g, raw, pair,
                int(self.pair_node[pair]), prefill_pair=prefill_pair,
                failover=failover, queue=inp.queue_len,
                category=int(inp.pred_category), up=inp.up,
                prefill=inp.prefill, tpot=inp.tpot, cost=inp.cost,
                hit=inp.hit_frac, est_cost=float(inp.cost[pair]))

    def _trace_colo(self, tracer, i: int, arrival: float, pair: int,
                    node: int, wait_i: float, prefill_i: float,
                    decode_i: float, completion: float) -> None:
        """Phase timeline of a colocated execution; the five phase
        durations sum to ``completion - arrival`` (span conservation)."""
        if not tracer.enabled:
            return
        up_i = float(self.up[i, pair])
        down_i = float(self.down[i, pair])
        ready = arrival + up_i
        start = ready + wait_i
        tracer.event(i, "dispatch", arrival, node=node, pair=int(pair))
        tracer.phase(i, "upload", arrival, up_i, node)
        tracer.phase(i, "queue-wait", ready, wait_i, node)
        tracer.phase(i, "prefill", start, prefill_i, node)
        tracer.phase(i, "decode", start + prefill_i, decode_i, node)
        tracer.phase(i, "download", start + prefill_i + decode_i, down_i,
                     node)
        tracer.event(i, "complete", completion, node=node)
        tracer.end(i, completion, "completed")

    def _record_metrics(self, metrics, res: "SimResult") -> None:
        """Vectorized post-run ingest of a SimResult into the registry
        (per-(node, category) labels from the realized assignment)."""
        if metrics is None:
            return
        if self.disaggregated:
            nodes = self.pair_node[
                np.asarray(self.np_arrays.route_decode)[res.assign]]
        else:
            nodes = self.pair_node[res.assign]
        cats = np.asarray(self.trace.pred_category)
        metrics.observe_by("ttft", res.ttft, nodes, cats)
        metrics.observe_by("tpot", res.tpot, nodes, cats)
        metrics.observe_by("queue_wait", res.wait, nodes, cats)
        metrics.observe_by("transfer", res.transfer, nodes, cats)
        metrics.observe_by("cache_hit_frac", res.hit, nodes, cats)
        metrics.observe_by("spend", res.cost, nodes, cats)
        metrics.observe_by("latency", res.rt, nodes, cats)

    # -- disaggregated execution (shared by both oracles) --------------------
    def _disagg_exec(self, cache, i: int, route: int, slots, arrival: float,
                     tracer=NOOP_TRACER, fc=None):
        """Greedy-at-issue execution of one request over route ``route``:
        prefill leg, KV transfer (0 on colocated routes), decode leg.
        Mirrors the JAX scan's disaggregated arithmetic op-for-op; mutates
        ``slots`` and the cache state, returns the accounting row."""
        from ..core.policy import CACHED_TOKEN_PRICE_FACTOR
        a = self.np_arrays
        p = int(a.route_prefill[route])
        qd = int(a.route_decode[route])
        node_p = int(self.pair_node[p])
        node_q = int(self.pair_node[qd])
        colo = p == qd
        blk = float(self.cache_block)
        kv_blk = float(np.floor(float(self.trace.prompt_tokens[i]) / blk)
                       * blk)
        kv_b = kv_blk * float(a.pair_kv_bytes_per_token[p])
        hf = self._cache_hit(cache, i, node_p)
        prefill_eff = self.prefill[i, p] * (1.0 - hf)
        decode_t = self.service[i, qd] - self.prefill[i, qd]
        tt = (float(a.kv_lat[node_p, node_q])
              + kv_b * float(a.kv_inv_bw[node_p, node_q]))
        cost_i = (self.prompt_cost[i, p]
                  * (1.0 - hf * (1.0 - CACHED_TOKEN_PRICE_FACTOR))
                  + (self.cost[i, qd] - self.prompt_cost[i, qd])
                  + kv_b * float(a.kv_egress[node_p, node_q]))
        slow_q = 1.0
        if fc is not None:
            # straggler factors per leg, link flap on the transfer, transient
            # delay shifting the effective arrival (scan mirror)
            t_eff, _, slow, linkf, _ = fc
            prefill_eff = prefill_eff * float(slow[node_p])
            slow_q = float(slow[node_q])
            decode_t = decode_t * slow_q
            tt = tt * linkf
            ready = t_eff + self.up[i, p]
        else:
            ready = arrival + self.up[i, p]
        s_p = int(np.argmin(slots[node_p]))
        start_p = max(ready, slots[node_p][s_p])
        wait_p = start_p - ready
        finish_p = start_p + prefill_eff
        # colocated: one slot holds the whole service; split: the prefill
        # slot frees at finish_p and the decode leg queues on node_q
        slots[node_p][s_p] = finish_p + decode_t if colo else finish_p
        if colo:
            finish_d = finish_p + decode_t
            wait_d = 0.0
            transfer = 0.0
        else:
            ready_d = finish_p + tt
            s_q = int(np.argmin(slots[node_q]))
            start_d = max(ready_d, slots[node_q][s_q])
            wait_d = start_d - ready_d
            finish_d = start_d + decode_t
            slots[node_q][s_q] = finish_d
            transfer = tt
        completion = finish_d + self.down[i, qd]
        self._cache_admit(cache, i, node_p)
        self._cache_admit(cache, i, node_q)
        if tracer.enabled:
            # phase durations sum to completion - arrival exactly: upload,
            # prefill queue-wait, prefill, (transfer, decode queue-wait),
            # decode, download (span conservation, tests/test_obs.py)
            tracer.event(i, "dispatch", arrival, node=node_p, pair=p)
            tracer.phase(i, "upload", arrival, float(self.up[i, p]), node_p)
            tracer.phase(i, "queue-wait", ready, wait_p, node_p)
            tracer.phase(i, "prefill", start_p, prefill_eff, node_p)
            if not colo:
                tracer.event(i, "handoff-start", finish_p, node=node_p,
                             decode_node=node_q)
                tracer.phase(i, "kv-transfer", finish_p, tt, node_q)
                tracer.phase(i, "queue-wait-decode", finish_p + tt, wait_d,
                             node_q)
            tracer.phase(i, "decode", finish_d - decode_t, decode_t, node_q)
            tracer.phase(i, "download", finish_d, float(self.down[i, qd]),
                         node_q)
            tracer.event(i, "complete", completion, node=node_q)
            tracer.end(i, completion, "completed")
        return {"pair": qd, "pp": p, "lp": node_p, "lq": node_q,
                "hf": hf, "cost": cost_i,
                "wait": wait_p + wait_d,
                "ttft": (start_p + prefill_eff) - arrival,
                "transfer": transfer, "completion": completion,
                "q": self.quality[i, qd],
                "tpot": self.tpot_pair[qd] * slow_q,
                "busy": ((node_p, prefill_eff), (node_q, decode_t))}

    def run(self, assign: Optional[Sequence[int]] = None,
            concurrency: int = 1,
            down_nodes: Optional[Dict[int, Tuple[float, float]]] = None,
            on_failure: Optional[Callable[[int, int], int]] = None,
            arrivals: Optional[Sequence[float]] = None,
            policy: Optional[str] = None, genome=None,
            tracer=None, audit=None, metrics=None,
            learn_state=None) -> SimResult:
        """Execute the trace under assignment ``assign``, or — with
        ``policy=``/``genome=`` — decide each request in-loop through the
        RoutingPolicy registry (the DES twin of the JAX scan's in-scan
        decisions).

        down_nodes: {node: (t_down, t_up)} crash windows. A request dispatched
        to a crashed node invokes ``on_failure(request, node) -> new_pair``
        (default: retry on the cloud fallback), modeling the reroute-on-
        failure behaviour of the runtime router.

        arrivals: optional (I,) sorted timestamps — **open-loop** mode:
        request i enters the system at ``arrivals[i]`` regardless of earlier
        completions (``concurrency`` is ignored; node capacity still queues).
        Defaults to the trace's own ``arrival_time`` when it carries one.

        tracer/audit/metrics: optional ``repro.obs`` sinks — per-request
        lifecycle spans (simulated-seconds clock), per-decision audit
        records, and a vectorized post-run metrics ingest. All default to
        zero-overhead no-ops.

        learn_state: optional estimator state (``ClusterSimulator(
        learned=True)`` only) carried in from a previous window's
        ``SimResult.learn_state`` — cold-starts neutral when omitted.
        """
        I = self.trace.n_requests
        G = concurrency
        n_nodes = len(self.cluster.nodes)
        down_nodes = down_nodes or {}
        tracer = NOOP_TRACER if tracer is None else tracer
        pol, g, pstate = self._resolve_policy(policy, genome, assign)
        lstate = self._learn_init(pol, learn_state)
        est_p = np.zeros(I); est_t = np.zeros(I)
        real_p = np.zeros(I); real_t = np.zeros(I)
        if arrivals is None and self.trace.has_arrivals:
            arrivals = self.trace.arrival_time
        if arrivals is not None:
            arrivals = np.asarray(arrivals, np.float64)
            assert arrivals.shape == (I,)
            # index order must equal time order or this loop oracle would
            # silently disagree with the event-heap oracle
            assert (np.diff(arrivals) >= 0).all(), "arrivals must be sorted"

        # slot free-times per node (the capacity C_j resource)
        slots: List[List[float]] = [
            [0.0] * int(self.node_conc[n]) for n in range(n_nodes)]
        client_ready = [0.0] * G

        q = np.zeros(I)
        cost = np.zeros(I)
        rt = np.zeros(I)
        wait = np.zeros(I)
        ttft = np.zeros(I)
        tpot = np.zeros(I)
        hit = np.zeros(I)
        transfer = np.zeros(I)
        out_assign = np.zeros(I, np.int64)
        busy = np.zeros(n_nodes)
        cache = self._cache_state()

        for i in range(I):
            c = i % G
            arrival = (float(arrivals[i]) if arrivals is not None
                       else client_ready[c])
            fc = self._fault_ctx(i, arrival)
            t_dec = arrival if fc is None else fc[0]
            if pol is not None:
                busy_slots = [sum(1 for f in slots[n] if f > t_dec)
                              for n in range(n_nodes)]
                inp = self._policy_inputs(
                    i, busy_slots, cache, t_dec,
                    avail=None if fc is None else fc[1], lstate=lstate)
                pair = int(pol.decide_py(g, inp, self.np_arrays, pstate))
            else:
                inp = None
                pair = int(assign[i])
            raw = pair
            fault_failover = None
            if fc is not None:
                pair, fo = self._fault_failover(pair, fc[1])
                if fo:
                    fault_failover = "fault-node-down"

            if self.disaggregated:
                # ``pair`` is a route index here; crash windows on either
                # endpoint fall back to a colocated route
                route = pair
                failover = fault_failover
                a_ = self.np_arrays
                ends = {int(self.pair_node[a_.route_prefill[route]]),
                        int(self.pair_node[a_.route_decode[route]])}
                for nd in sorted(ends):
                    if nd in down_nodes:
                        t_down, t_up = down_nodes[nd]
                        if t_down <= arrival < t_up:
                            fb = (on_failure(i, nd)
                                  if on_failure is not None
                                  else int(self.arrays.cloud_fallback_pair))
                            route = self._colo_route.get(int(fb), route)
                            failover = "route-endpoint-down"
                            break
                self._trace_issue(tracer, audit, i, arrival, pol, g, inp,
                                  raw, route, failover)
                row = self._disagg_exec(cache, i, route, slots, arrival,
                                        tracer=tracer, fc=fc)
                client_ready[c] = row["completion"]
                if pol is not None:
                    pstate = pol.update_py(g, pstate, inp, row["pair"],
                                           row["cost"])
                if lstate is not None:
                    lstate = self._learn_after_disagg(
                        lstate, i, inp, row, fc, est_p, est_t, real_p,
                        real_t)
                q[i] = row["q"]; cost[i] = row["cost"]
                rt[i] = row["completion"] - arrival
                wait[i] = row["wait"]; ttft[i] = row["ttft"]
                tpot[i] = row["tpot"]; hit[i] = row["hf"]
                transfer[i] = row["transfer"]
                out_assign[i] = route
                for nd, dur in row["busy"]:
                    busy[nd] += dur
                continue
            node = int(self.pair_node[pair])

            failover = fault_failover
            if node in down_nodes:
                t_down, t_up = down_nodes[node]
                if t_down <= arrival < t_up:
                    pair = (on_failure(i, node) if on_failure is not None
                            else int(self.arrays.cloud_fallback_pair))
                    node = int(self.pair_node[pair])
                    failover = "node-down"
            self._trace_issue(tracer, audit, i, arrival, pol, g, inp, raw,
                              pair, failover)

            hf, service_i, prefill_i, cost_i = self._discounted(cache, i,
                                                                pair)
            slow_n = 1.0
            if fc is not None:
                slow_n = float(fc[2][node])
                service_i = service_i * slow_n
                prefill_i = prefill_i * slow_n
                ready = fc[0] + self.up[i, pair]
            else:
                ready = arrival + self.up[i, pair]
            s = int(np.argmin(slots[node]))
            start = max(ready, slots[node][s])
            finish = start + service_i
            completion = finish + self.down[i, pair]
            slots[node][s] = finish
            client_ready[c] = completion
            self._cache_admit(cache, i, node)
            if pol is not None:
                pstate = pol.update_py(g, pstate, inp, pair, cost_i)
            if lstate is not None:
                lstate = self._learn_after_colo(
                    lstate, i, inp, pair, node, slow_n, est_p, est_t,
                    real_p, real_t)

            q[i] = self.quality[i, pair]
            cost[i] = cost_i
            rt[i] = completion - arrival
            wait[i] = start - ready
            # first token leaves prefill at start + (uncached) prefill_time
            ttft[i] = (start + prefill_i) - arrival
            tpot[i] = self.tpot_pair[pair] * slow_n
            hit[i] = hf
            out_assign[i] = pair
            busy[node] += service_i
            self._trace_colo(tracer, i, arrival, pair, node, wait[i],
                             prefill_i, service_i - prefill_i, completion)

        extra = ({"est_prefill": est_p, "est_tpot": est_t,
                  "real_prefill": real_p, "real_tpot": real_t,
                  "learn_state": lstate} if lstate is not None else {})
        res = SimResult(q=q, cost=cost, rt=rt, assign=out_assign, wait=wait,
                        node_busy_time=busy, ttft=ttft, tpot=tpot, hit=hit,
                        transfer=transfer, **extra)
        self._record_metrics(metrics, res)
        return res

    # -- event-heap variant -------------------------------------------------
    def run_event_heap(self, assign: Optional[Sequence[int]] = None,
                       concurrency: int = 1,
                       arrivals: Optional[Sequence[float]] = None,
                       policy: Optional[str] = None, genome=None,
                       tracer=None, audit=None, metrics=None,
                       learn_state=None) -> SimResult:
        """Same semantics via an explicit event heap (belt-and-braces oracle:
        two independent queueing implementations must agree). With
        ``arrivals`` (or a trace carrying ``arrival_time``) every request's
        issue event is scheduled at its own timestamp — open-loop mode.
        ``policy=``/``genome=`` decide each request at issue time through the
        RoutingPolicy registry instead of a fixed ``assign``."""
        I = self.trace.n_requests
        G = concurrency
        n_nodes = len(self.cluster.nodes)
        tracer = NOOP_TRACER if tracer is None else tracer
        pol, g, pstate = self._resolve_policy(policy, genome, assign)
        lstate = self._learn_init(pol, learn_state)
        if arrivals is None and self.trace.has_arrivals:
            arrivals = self.trace.arrival_time

        q = np.zeros(I); cost = np.zeros(I); rt = np.zeros(I)
        wait = np.zeros(I); out_assign = np.zeros(I, np.int64)
        ttft = np.zeros(I); tpot = np.zeros(I); hit = np.zeros(I)
        transfer = np.zeros(I)
        est_p = np.zeros(I); est_t = np.zeros(I)
        real_p = np.zeros(I); real_t = np.zeros(I)
        busy = np.zeros(n_nodes)
        cache = self._cache_state()

        # events: (time, seq, kind, payload)
        heap: List[Tuple[float, int, str, tuple]] = []
        seq = 0
        node_free: List[List[float]] = [
            [0.0] * int(self.node_conc[n]) for n in range(n_nodes)]
        if arrivals is not None:
            arrivals = np.asarray(arrivals, np.float64)
            assert arrivals.shape == (I,)
            assert (np.diff(arrivals) >= 0).all(), "arrivals must be sorted"
            for i in range(I):
                heapq.heappush(heap, (float(arrivals[i]), seq, "issue",
                                      (i, None))); seq += 1
            issued = I
        else:
            next_req = [c for c in range(min(G, I))]
            for c, i in enumerate(next_req):
                heapq.heappush(heap, (0.0, seq, "issue", (i, c))); seq += 1
            issued = min(G, I)

        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if kind == "issue":
                i, c = payload
                fc = self._fault_ctx(i, t)
                t_dec = t if fc is None else fc[0]
                if pol is not None:
                    busy_slots = [sum(1 for f in node_free[n] if f > t_dec)
                                  for n in range(n_nodes)]
                    inp = self._policy_inputs(
                        i, busy_slots, cache, t_dec,
                        avail=None if fc is None else fc[1], lstate=lstate)
                    pair = int(pol.decide_py(g, inp, self.np_arrays, pstate))
                else:
                    inp = None
                    pair = int(assign[i])
                raw = pair
                fault_failover = None
                if fc is not None:
                    pair, fo = self._fault_failover(pair, fc[1])
                    if fo:
                        fault_failover = "fault-node-down"
                self._trace_issue(tracer, audit, i, t, pol, g, inp, raw,
                                  pair, fault_failover)
                if self.disaggregated:
                    row = self._disagg_exec(cache, i, pair, node_free, t,
                                            tracer=tracer, fc=fc)
                    if pol is not None:
                        pstate = pol.update_py(g, pstate, inp, row["pair"],
                                               row["cost"])
                    if lstate is not None:
                        lstate = self._learn_after_disagg(
                            lstate, i, inp, row, fc, est_p, est_t, real_p,
                            real_t)
                    q[i] = row["q"]; cost[i] = row["cost"]
                    rt[i] = row["completion"] - t
                    wait[i] = row["wait"]; ttft[i] = row["ttft"]
                    tpot[i] = row["tpot"]; hit[i] = row["hf"]
                    transfer[i] = row["transfer"]
                    out_assign[i] = pair
                    for nd, dur in row["busy"]:
                        busy[nd] += dur
                    heapq.heappush(heap, (row["completion"], seq, "done",
                                          (i, c))); seq += 1
                    continue
                node = int(self.pair_node[pair])
                hf, service_i, prefill_i, cost_i = self._discounted(cache, i,
                                                                    pair)
                slow_n = 1.0
                if fc is not None:
                    slow_n = float(fc[2][node])
                    service_i = service_i * slow_n
                    prefill_i = prefill_i * slow_n
                ready = t_dec + self.up[i, pair]
                s = int(np.argmin(node_free[node]))
                start = max(ready, node_free[node][s])
                finish = start + service_i
                node_free[node][s] = finish
                completion = finish + self.down[i, pair]
                self._cache_admit(cache, i, node)
                if pol is not None:
                    pstate = pol.update_py(g, pstate, inp, pair, cost_i)
                if lstate is not None:
                    lstate = self._learn_after_colo(
                        lstate, i, inp, pair, node, slow_n, est_p, est_t,
                        real_p, real_t)
                q[i] = self.quality[i, pair]; cost[i] = cost_i
                rt[i] = completion - t; wait[i] = start - ready
                ttft[i] = (start + prefill_i) - t
                tpot[i] = self.tpot_pair[pair] * slow_n; hit[i] = hf
                out_assign[i] = pair; busy[node] += service_i
                self._trace_colo(tracer, i, t, pair, node, wait[i],
                                 prefill_i, service_i - prefill_i,
                                 completion)
                heapq.heappush(heap, (completion, seq, "done", (i, c))); seq += 1
            else:  # done -> closed-loop client issues its next request
                _, c = payload
                if c is not None and issued < I:
                    heapq.heappush(heap, (t, seq, "issue", (issued, c)))
                    seq += 1; issued += 1

        extra = ({"est_prefill": est_p, "est_tpot": est_t,
                  "real_prefill": real_p, "real_tpot": real_t,
                  "learn_state": lstate} if lstate is not None else {})
        res = SimResult(q=q, cost=cost, rt=rt, assign=out_assign, wait=wait,
                        node_busy_time=busy, ttft=ttft, tpot=tpot, hit=hit,
                        transfer=transfer, **extra)
        self._record_metrics(metrics, res)
        return res
