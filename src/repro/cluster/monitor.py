"""Monitoring module (paper §IV-B.6: q_j "obtained from the monitoring
module"; §VI future work: "real-time monitoring mechanisms for node and model
status, coupled with fault-tolerant strategies").

Tracks, per node: outstanding request count (the q_j feature), health state
with heartbeat expiry, and EWMA latency per (node, model) used for straggler
detection (hedging threshold) by the serving scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class NodeStats:
    outstanding: int = 0
    total_dispatched: int = 0
    total_completed: int = 0
    total_failed: int = 0
    healthy: bool = True
    last_heartbeat: float = 0.0
    ewma_latency: float = 0.0
    ewma_alpha: float = 0.2


class ClusterMonitor:
    """Thread-light monitor; all methods take an explicit ``now`` so the same
    code runs under the discrete-event simulator and in wall-clock serving."""

    def __init__(self, n_nodes: int, heartbeat_timeout: float = 10.0):
        self.stats: Dict[int, NodeStats] = {j: NodeStats() for j in range(n_nodes)}
        self.heartbeat_timeout = heartbeat_timeout

    # -- data plane callbacks -------------------------------------------------
    def on_dispatch(self, node: int) -> None:
        s = self.stats[node]
        s.outstanding += 1
        s.total_dispatched += 1

    def on_complete(self, node: int, latency: float) -> None:
        s = self.stats[node]
        s.outstanding = max(0, s.outstanding - 1)
        s.total_completed += 1
        s.ewma_latency = (s.ewma_alpha * latency
                          + (1 - s.ewma_alpha) * (s.ewma_latency or latency))

    def on_failure(self, node: int) -> None:
        s = self.stats[node]
        s.outstanding = max(0, s.outstanding - 1)
        s.total_failed += 1

    def heartbeat(self, node: int, now: Optional[float] = None) -> None:
        s = self.stats[node]
        s.last_heartbeat = time.monotonic() if now is None else now
        s.healthy = True

    def mark_down(self, node: int) -> None:
        self.stats[node].healthy = False

    def sweep(self, now: float) -> None:
        """Expire nodes whose heartbeat is stale."""
        for s in self.stats.values():
            if now - s.last_heartbeat > self.heartbeat_timeout:
                s.healthy = False

    # -- router-facing views ---------------------------------------------------
    def queue_lengths(self) -> Tuple[int, ...]:
        return tuple(self.stats[j].outstanding for j in sorted(self.stats))

    def healthy_mask(self) -> Tuple[bool, ...]:
        return tuple(self.stats[j].healthy for j in sorted(self.stats))

    def straggler_threshold(self, node: int, factor: float = 3.0) -> float:
        """Hedge a request if it exceeds factor × EWMA latency of its node."""
        base = self.stats[node].ewma_latency
        return factor * base if base > 0 else float("inf")
