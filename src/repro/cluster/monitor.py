"""Monitoring module (paper §IV-B.6: q_j "obtained from the monitoring
module"; §VI future work: "real-time monitoring mechanisms for node and model
status, coupled with fault-tolerant strategies").

Tracks, per node: outstanding request count (the q_j feature), health state
with heartbeat expiry, and EWMA latency per node used for straggler detection
(hedging threshold) by the serving scheduler.

Beyond that, the monitor is the **drift sensor** for the rolling-horizon
re-optimization loop (``core.router.maybe_reoptimize``): each completion
updates a fast and a slow EWMA of observed latency; a sustained gap between
them means the workload/queueing regime has shifted away from the window the
current policy was optimized on, and :meth:`drift_score` quantifies that
shift as a relative latency change (0 = stationary).

Clock discipline: every method that touches time takes an explicit ``now`` so
the same code runs under the discrete-event simulator (simulated seconds or
scheduler ticks) and in wall-clock serving. Heartbeats are initialized to the
construction time — a node that has *never* heartbeated is not considered
stale until a full ``heartbeat_timeout`` has elapsed since construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.learn import OnlineEstimator
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class NodeStats:
    outstanding: int = 0
    total_dispatched: int = 0
    total_completed: int = 0
    total_failed: int = 0
    total_cancelled: int = 0
    healthy: bool = True
    last_heartbeat: float = 0.0
    ewma_latency: float = 0.0
    ewma_alpha: float = 0.2
    # seeded on the FIRST completion (whatever its latency, zero included);
    # the old ``ewma or latency`` idiom re-seeded whenever the EWMA happened
    # to be exactly 0.0
    ewma_initialized: bool = False
    # drift sensing: fast tracker vs slow baseline of the same signal
    ewma_fast: float = 0.0
    ewma_slow: float = 0.0
    alpha_fast: float = 0.3
    alpha_slow: float = 0.03
    # prefix-cache state: (kind, group) -> cached prefix tokens on this node,
    # where kind is "sess" (one session's latest prompt) or "sys" (a shared
    # system prompt). The cache-affinity router reads this to estimate the
    # cached-prefix fraction per candidate node.
    cached_prefixes: Dict[Tuple[str, int], int] = dataclasses.field(
        default_factory=dict)
    # circuit breaker (closed -> open on error-rate EWMA, open -> half-open
    # after a cooldown, half-open admits ONE probe whose outcome decides
    # closed vs re-open). Inert unless the monitor was built with
    # ``breaker_threshold``.
    breaker_state: str = "closed"
    err_ewma: float = 0.0
    err_obs: int = 0
    breaker_opened_at: float = 0.0
    probe_inflight: bool = False


class ClusterMonitor:
    """Thread-light monitor; all methods take an explicit ``now`` so the same
    code runs under the discrete-event simulator and in wall-clock serving."""

    def __init__(self, n_nodes: int, heartbeat_timeout: float = 10.0,
                 now: float = 0.0,
                 metrics: Optional[MetricsRegistry] = None,
                 breaker_threshold: Optional[float] = None,
                 breaker_alpha: float = 0.3, breaker_min_obs: int = 4,
                 breaker_cooldown: float = 20.0,
                 estimator: Optional[OnlineEstimator] = None):
        self.stats: Dict[int, NodeStats] = {
            j: NodeStats(last_heartbeat=now) for j in range(n_nodes)}
        self.heartbeat_timeout = heartbeat_timeout
        # the monitor's own clock: every caller advances it explicitly
        # (simulated seconds under the DES, scheduler ticks when serving)
        # via :meth:`advance` — heartbeats, staleness expiry, and breaker
        # cooldowns all live in this ONE domain, never mixed with wall time
        self.now = now
        # per-node circuit breakers: disabled unless a threshold is given
        # (error-rate EWMA >= threshold after >= min_obs observations opens
        # the breaker; after ``breaker_cooldown`` clock units it admits one
        # half-open probe whose outcome decides closed vs re-open)
        self.breaker_threshold = breaker_threshold
        self.breaker_alpha = breaker_alpha
        self.breaker_min_obs = breaker_min_obs
        self.breaker_cooldown = breaker_cooldown
        # all monitor series live in one queryable MetricsRegistry (shared
        # with the scheduler's when serving; private otherwise)
        self.metrics = MetricsRegistry() if metrics is None else metrics
        # fleet counters: per-node emitted-token / retired-slot totals fed in
        # one vectorized update per cohort dispatch from the stacked
        # (member, n, 3, B) chunk output — no per-engine host pulls. Backed
        # by registry CounterVecs so fleet_totals() and metrics_flat() read
        # the same storage.
        self.fleet_emitted = self.metrics.counter(
            "fleet_tokens_emitted", n_nodes).values
        self.fleet_retired = self.metrics.counter(
            "fleet_slots_retired", n_nodes).values
        self.breaker_opens = self.metrics.counter(
            "breaker_open_total", n_nodes).values
        # optional online-learned estimator (repro.learn): the live third
        # leg of the learned-estimator loop — the router reads its residual
        # predictions on the hot path, completion observations feed it via
        # :meth:`feed_estimator`
        self.estimator = estimator

    # -- data plane callbacks -------------------------------------------------
    def on_dispatch(self, node: int) -> None:
        s = self.stats[node]
        s.outstanding += 1
        s.total_dispatched += 1
        # the first dispatch into a half-open breaker is its probe; until
        # it resolves, healthy_mask hides the node again
        if s.breaker_state == "half-open" and not s.probe_inflight:
            s.probe_inflight = True

    def on_complete(self, node: int, latency: float) -> None:
        s = self.stats[node]
        s.outstanding = max(0, s.outstanding - 1)
        s.total_completed += 1
        if not s.ewma_initialized:
            # seed all trackers on the first observation — 0.0 is a
            # legitimate first latency and must not leave them unseeded
            s.ewma_latency = s.ewma_fast = s.ewma_slow = latency
            s.ewma_initialized = True
        else:
            s.ewma_latency = (s.ewma_alpha * latency
                              + (1 - s.ewma_alpha) * s.ewma_latency)
            s.ewma_fast = (s.alpha_fast * latency
                           + (1 - s.alpha_fast) * s.ewma_fast)
            s.ewma_slow = (s.alpha_slow * latency
                           + (1 - s.alpha_slow) * s.ewma_slow)
        self.metrics.observe("latency", latency, node=node)
        self._breaker_observe(node, 0.0)

    def on_failure(self, node: int) -> None:
        s = self.stats[node]
        s.outstanding = max(0, s.outstanding - 1)
        s.total_failed += 1
        self._breaker_observe(node, 1.0)

    def on_cancel(self, node: int) -> None:
        """A dispatched request was cancelled (e.g. a hedged duplicate lost
        the race): close its accounting without counting it as served."""
        s = self.stats[node]
        s.outstanding = max(0, s.outstanding - 1)
        s.total_cancelled += 1

    def record_fleet(self, nodes, emitted, retired) -> None:
        """Accumulate per-node decode progress from one cohort dispatch.

        ``nodes``/``emitted``/``retired`` are parallel arrays over the
        cohort's members (a node hosting several member engines accumulates
        via ``np.add.at``). Called once per stacked dispatch — the fleet
        counterpart of per-request ``on_complete`` accounting."""
        np.add.at(self.fleet_emitted, np.asarray(nodes, np.int64),
                  np.asarray(emitted, np.int64))
        np.add.at(self.fleet_retired, np.asarray(nodes, np.int64),
                  np.asarray(retired, np.int64))

    def fleet_totals(self) -> Dict[str, int]:
        return {"emitted": int(self.fleet_emitted.sum()),
                "retired": int(self.fleet_retired.sum())}

    def heartbeat(self, node: int, now: float) -> None:
        """Mark ``node`` alive at ``now`` (the caller's clock).

        ``now`` is required: the pre-clock-discipline silent
        ``time.monotonic()`` fallback mixed wall clock into simulated-tick
        runs, poisoning ``sweep`` expiry. It survived one release as a
        DeprecationWarning shim and has been removed — wall-clock callers
        pass ``heartbeat(node, now=time.monotonic())`` explicitly.
        """
        s = self.stats[node]
        s.last_heartbeat = now
        s.healthy = True

    def feed_estimator(self, category: int, node_p: int, node_q: int,
                       prompt_tokens: float, complexity: float,
                       y_prefill: float, y_tpot: float,
                       y_quality: float = 0.0) -> None:
        """Forward one completed request's residual targets into the
        attached :class:`~repro.learn.OnlineEstimator` (no-op without one).

        ``y_*`` are residual targets computed by the caller in its own clock
        domain — typically ``OnlineEstimator.ratio(expected, realized)`` for
        the latency signals; decision-time queue depths come from this
        monitor's outstanding counts."""
        if self.estimator is None:
            return
        self.estimator.observe(
            category, node_p, node_q, prompt_tokens, complexity,
            np.asarray(self.queue_lengths(), np.int64),
            self.estimator.node_conc, y_prefill, y_tpot, y_quality)

    def mark_down(self, node: int) -> None:
        self.stats[node].healthy = False

    def sweep(self, now: float) -> None:
        """Expire nodes whose heartbeat is stale."""
        for s in self.stats.values():
            if now - s.last_heartbeat > self.heartbeat_timeout:
                s.healthy = False

    def advance(self, now: float) -> None:
        """Advance the monitor's clock to ``now`` (the caller's domain —
        scheduler ticks or simulated seconds): expires stale heartbeats and
        moves cooled-down open breakers to half-open (one probe admitted).
        The one clock entry point a periodic caller needs."""
        self.now = now
        self.sweep(now)
        if self.breaker_threshold is None:
            return
        for s in self.stats.values():
            if (s.breaker_state == "open"
                    and now - s.breaker_opened_at >= self.breaker_cooldown):
                s.breaker_state = "half-open"
                s.probe_inflight = False

    # -- circuit breakers ------------------------------------------------------
    def _breaker_observe(self, node: int, err: float) -> None:
        """Feed one request outcome (0 = success, 1 = failure) into the
        node's breaker state machine. No-op when breakers are disabled."""
        if self.breaker_threshold is None:
            return
        s = self.stats[node]
        s.err_ewma = (self.breaker_alpha * err
                      + (1 - self.breaker_alpha) * s.err_ewma)
        s.err_obs += 1
        if s.breaker_state == "half-open":
            if err > 0:                      # the probe failed: re-open
                s.breaker_state = "open"
                s.breaker_opened_at = self.now
                s.probe_inflight = False
                self.breaker_opens[node] += 1
            else:                            # the probe succeeded: close
                s.breaker_state = "closed"
                s.err_ewma = 0.0
                s.err_obs = 0
                s.probe_inflight = False
        elif (s.breaker_state == "closed" and err > 0
              and s.err_obs >= self.breaker_min_obs
              and s.err_ewma >= self.breaker_threshold):
            s.breaker_state = "open"
            s.breaker_opened_at = self.now
            self.breaker_opens[node] += 1

    def reset_breaker(self, node: int) -> None:
        """Explicit recovery (``ClusterServer.recover_node``): close the
        breaker and forget its error history."""
        s = self.stats[node]
        s.breaker_state = "closed"
        s.err_ewma = 0.0
        s.err_obs = 0
        s.probe_inflight = False

    def breaker_states(self) -> Tuple[str, ...]:
        return tuple(self.stats[j].breaker_state for j in sorted(self.stats))

    # -- prefix-cache state (cache-affinity routing) ---------------------------
    def record_prefix(self, node: int, key: Tuple[str, int],
                      tokens: int) -> None:
        """A prompt prefix of ``tokens`` tokens is now cached on ``node``
        (monotone max: sessions only ever extend their prompts)."""
        cp = self.stats[node].cached_prefixes
        cp[key] = max(cp.get(key, 0), int(tokens))

    def cached_tokens(self, node: int, key: Tuple[str, int]) -> int:
        return self.stats[node].cached_prefixes.get(key, 0)

    def drop_prefixes(self, node: int) -> None:
        """Node restart / cache flush: forget its prefix state."""
        self.stats[node].cached_prefixes.clear()

    def hit_fractions(self, session: int, sys: int, prompt_tokens: float,
                      sys_tokens: float, block: int = 16) -> Tuple[float, ...]:
        """Expected cached-prefix fraction of this prompt per node.

        Whole-block granularity (the paged pool shares only full blocks);
        the session's own cached prompt dominates the shared system prompt
        when both are resident."""
        blk_p = (int(prompt_tokens) // block) * block
        blk_s = (int(sys_tokens) // block) * block
        out = []
        for j in sorted(self.stats):
            hit = 0
            if session >= 0:
                hit = min(self.cached_tokens(j, ("sess", session)), blk_p)
            if sys >= 0:
                hit = max(hit, min(self.cached_tokens(j, ("sys", sys)),
                                   blk_s))
            out.append(hit / max(float(prompt_tokens), 1.0))
        return tuple(out)

    # -- router-facing views ---------------------------------------------------
    def queue_lengths(self) -> Tuple[int, ...]:
        return tuple(self.stats[j].outstanding for j in sorted(self.stats))

    def healthy_mask(self) -> Tuple[bool, ...]:
        """Routable nodes: heartbeat-healthy AND breaker not open (a
        half-open breaker exposes the node only until its probe departs)."""
        def ok(s: NodeStats) -> bool:
            if not s.healthy or s.breaker_state == "open":
                return False
            return not (s.breaker_state == "half-open" and s.probe_inflight)
        return tuple(ok(self.stats[j]) for j in sorted(self.stats))

    def straggler_threshold(self, node: int, factor: float = 3.0) -> float:
        """Hedge a request if it exceeds factor × EWMA latency of its node."""
        base = self.stats[node].ewma_latency
        return factor * base if base > 0 else float("inf")

    def drift_score(self) -> float:
        """Max over nodes of the relative fast-vs-slow EWMA latency gap.

        ~0 while the workload is stationary; grows toward |Δ|/baseline when
        recent latencies diverge from the long-run level (arrival burst, mix
        shift, slow node). The router's re-optimization trigger compares this
        against a threshold (see ``RequestRouter.should_reoptimize``).
        """
        score = 0.0
        for s in self.stats.values():
            if s.ewma_slow > 0:
                score = max(score,
                            abs(s.ewma_fast - s.ewma_slow) / s.ewma_slow)
        return score

    def rebaseline_drift(self) -> None:
        """Re-arm the drift detector: snap the slow baseline to the current
        fast tracker. Called after a re-optimization installs a new policy,
        so one regime shift triggers one re-fit instead of firing on every
        subsequent check until the slow EWMA reconverges (~1/alpha_slow
        completions)."""
        for s in self.stats.values():
            s.ewma_slow = s.ewma_fast
