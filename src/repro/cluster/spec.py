"""Cloud-edge cluster specification (paper §III / §V-C).

A cluster is a set of nodes N = N_cloud ∪ N_edge, a global model set M, and a
deployment map M(n_j) ⊆ M (the paper's model deployment function). The routing
solution space is S = {(n_j, m_k) | m_k ∈ M(n_j)} — we enumerate it once as a
flat *pair table* so both the NSGA-II fitness evaluator (JAX) and the runtime
router can index decisions by a single integer.

``ClusterArrays`` is the jnp-struct view consumed by ``repro.core.fitness``.

Quality model
-------------
q(r, m) = clip(base[m, task] + slope_m · (0.5 − difficulty_r) + ε(r, m), 0, 1)

Large (cloud) models have a flat slope — they absorb hard requests; small
(edge) models degrade steeply with difficulty. ε is deterministic per
(request, model) noise so the whole objective is reproducible. Constants are
calibrated against Table II anchors (see workload/calibration.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

TASKS = ("mbpp", "gsm8k", "squad", "hellaswag")
TASK_INDEX = {t: i for i, t in enumerate(TASKS)}
MODEL_TYPES = ("instruct", "coder", "math", "general")
MODEL_TYPE_INDEX = {t: i for i, t in enumerate(MODEL_TYPES)}


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """One LLM in the global model set M (paper §III)."""

    name: str
    model_type: str                 # 'instruct' | 'coder' | 'math' | 'general'
    params_b: float                 # billions of parameters
    price_per_mtok: float           # $ per 1e6 tokens (Together.ai-style)
    base_quality: Tuple[float, float, float, float]  # per TASKS order
    difficulty_slope: float         # quality sensitivity to request difficulty
    verbosity: float = 1.0          # response-length multiplier vs task mean
    kv_bytes_per_token: float = 0.0  # KV-cache footprint; 0 → params_b * 1024

    def __post_init__(self):
        assert self.model_type in MODEL_TYPES
        assert len(self.base_quality) == len(TASKS)

    @property
    def kv_bytes(self) -> float:
        """Bytes of KV cache per prompt token (drives transfer sizing)."""
        return self.kv_bytes_per_token or self.params_b * 1024.0


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Router <-> node link (Eq. 5 terms)."""

    bw_up_bps: float      # router -> node bandwidth B_{r->j}, bytes/s
    bw_down_bps: float    # node -> router bandwidth B_{j->r}, bytes/s
    latency_up_s: float   # latency_{r->j}
    latency_down_s: float  # latency_{j->r}


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One node with its deployed models and serving speeds."""

    name: str
    kind: str                         # 'cloud' | 'edge'
    models: Tuple[str, ...]           # deployment map M(n_j)
    link: LinkSpec
    # serving speed per deployed model (tokens/s), keyed by model name
    prefill_tps: Dict[str, float]
    decode_tps: Dict[str, float]
    concurrency: int = 4              # parallel execution slots (capacity C_j)
    # disaggregated serving: a node may specialize in one phase
    role: str = "unified"             # 'unified' | 'prefill' | 'decode'
    price_factor: float = 1.0         # node price multiplier on model $/Mtok
    # node<->node KV-transfer link (prefill -> decode handoff)
    kv_bw_bps: float = 1e9            # KV link bandwidth, bytes/s
    kv_lat_s: float = 0.002           # per-transfer setup latency, s
    kv_egress_per_gb: float = 0.0     # $ per GB leaving this node

    def __post_init__(self):
        assert self.kind in ("cloud", "edge")
        assert self.role in ("unified", "prefill", "decode"), self.role
        assert self.price_factor > 0 and self.kv_bw_bps > 0
        for m in self.models:
            assert m in self.prefill_tps and m in self.decode_tps, m


class ClusterArrays(NamedTuple):
    """Flat jnp view of the (node, model) pair table for the JAX evaluator."""

    # pairs (n_pairs,)
    pair_node: jnp.ndarray            # int32 node index
    pair_model: jnp.ndarray           # int32 model index (into global M)
    pair_is_edge: jnp.ndarray         # bool
    pair_model_type: jnp.ndarray      # int32 into MODEL_TYPES
    pair_price: jnp.ndarray           # $ / Mtok
    pair_prefill_tps: jnp.ndarray     # float32
    pair_decode_tps: jnp.ndarray      # float32
    pair_base_quality: jnp.ndarray    # (n_pairs, n_tasks)
    pair_diff_slope: jnp.ndarray      # (n_pairs,)
    pair_verbosity: jnp.ndarray       # (n_pairs,)
    # nodes (n_nodes,)
    node_is_edge: jnp.ndarray
    node_bw_up: jnp.ndarray
    node_bw_down: jnp.ndarray
    node_lat_up: jnp.ndarray
    node_lat_down: jnp.ndarray
    node_conc: jnp.ndarray            # int32 capacity slots
    # routing helper tables
    # first-edge-pair by model type, ordered by node index: (n_types, n_edge)
    edge_pairs_by_type: jnp.ndarray   # int32 pair idx, -1 padded
    cloud_fallback_pair: jnp.ndarray  # int32 scalar: high-capacity cloud model
    # disaggregated prefill/decode tables
    node_role: jnp.ndarray            # int32: 0 unified, 1 prefill, 2 decode
    kv_lat: jnp.ndarray               # (n_nodes, n_nodes) transfer setup, s
    kv_inv_bw: jnp.ndarray            # (n_nodes, n_nodes) s/byte, 0 diagonal
    kv_egress: jnp.ndarray            # (n_nodes, n_nodes) $/byte, 0 diagonal
    pair_kv_bytes_per_token: jnp.ndarray  # (n_pairs,) KV footprint per token
    # route table: every feasible (prefill_pair, decode_pair) combination,
    # same model on both legs; colocated routes (p == q) are included so a
    # tuned policy can *choose* not to disaggregate
    route_prefill: jnp.ndarray        # (n_routes,) int32 pair idx
    route_decode: jnp.ndarray         # (n_routes,) int32 pair idx

    @property
    def n_pairs(self) -> int:
        return self.pair_node.shape[0]

    @property
    def n_nodes(self) -> int:
        return self.node_is_edge.shape[0]

    @property
    def n_routes(self) -> int:
        return self.route_prefill.shape[0]

    def numpy(self) -> "ClusterArrays":
        """Host-side view (every field as np.ndarray) for per-request hot
        paths that must not pay device transfers per decision."""
        return ClusterArrays(*(np.asarray(a) for a in self))


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    nodes: Tuple[NodeSpec, ...]
    models: Tuple[ModelSpec, ...]

    def model_index(self, name: str) -> int:
        for i, m in enumerate(self.models):
            if m.name == name:
                return i
        raise KeyError(name)

    def pairs(self) -> List[Tuple[int, int]]:
        """Enumerate the solution space S = {(node, model)} (paper §III)."""
        out = []
        for j, node in enumerate(self.nodes):
            for mname in node.models:
                out.append((j, self.model_index(mname)))
        return out

    @property
    def n_pairs(self) -> int:
        return len(self.pairs())

    def pair_index(self, node_name: str, model_name: str) -> int:
        for p, (j, k) in enumerate(self.pairs()):
            if self.nodes[j].name == node_name and self.models[k].name == model_name:
                return p
        raise KeyError((node_name, model_name))

    def describe(self) -> str:
        lines = [f"cluster: {len(self.nodes)} nodes, {len(self.models)} models, "
                 f"{self.n_pairs} routable (node, model) pairs"]
        for j, n in enumerate(self.nodes):
            lines.append(f"  [{j}] {n.name} ({n.kind}, conc={n.concurrency}): "
                         + ", ".join(n.models))
        return "\n".join(lines)

    # -- jnp view -----------------------------------------------------------
    def to_arrays(self) -> ClusterArrays:
        pairs = self.pairs()
        n_pairs = len(pairs)
        pair_node = np.zeros(n_pairs, np.int32)
        pair_model = np.zeros(n_pairs, np.int32)
        pair_is_edge = np.zeros(n_pairs, bool)
        pair_model_type = np.zeros(n_pairs, np.int32)
        pair_price = np.zeros(n_pairs, np.float32)
        pair_prefill = np.zeros(n_pairs, np.float32)
        pair_decode = np.zeros(n_pairs, np.float32)
        pair_bq = np.zeros((n_pairs, len(TASKS)), np.float32)
        pair_slope = np.zeros(n_pairs, np.float32)
        pair_verb = np.zeros(n_pairs, np.float32)
        pair_kv_bpt = np.zeros(n_pairs, np.float32)
        for p, (j, k) in enumerate(pairs):
            node, model = self.nodes[j], self.models[k]
            pair_node[p] = j
            pair_model[p] = k
            pair_is_edge[p] = node.kind == "edge"
            pair_model_type[p] = MODEL_TYPE_INDEX[model.model_type]
            pair_price[p] = model.price_per_mtok * node.price_factor
            pair_prefill[p] = node.prefill_tps[model.name]
            pair_decode[p] = node.decode_tps[model.name]
            pair_bq[p] = model.base_quality
            pair_slope[p] = model.difficulty_slope
            pair_verb[p] = model.verbosity
            pair_kv_bpt[p] = model.kv_bytes

        n_nodes = len(self.nodes)
        node_is_edge = np.array([n.kind == "edge" for n in self.nodes])
        node_bw_up = np.array([n.link.bw_up_bps for n in self.nodes], np.float32)
        node_bw_down = np.array([n.link.bw_down_bps for n in self.nodes], np.float32)
        node_lat_up = np.array([n.link.latency_up_s for n in self.nodes], np.float32)
        node_lat_down = np.array([n.link.latency_down_s for n in self.nodes], np.float32)
        node_conc = np.array([n.concurrency for n in self.nodes], np.int32)

        # routing helper: per model type, edge pairs ordered by node index
        n_edge = int(node_is_edge.sum())
        edge_by_type = -np.ones((len(MODEL_TYPES), max(n_edge, 1)), np.int32)
        for t in range(len(MODEL_TYPES)):
            col = 0
            for p, (j, k) in enumerate(pairs):
                if pair_is_edge[p] and pair_model_type[p] == t:
                    edge_by_type[t, col] = p
                    col += 1

        # cloud fallback = largest-params model on a cloud node
        cloud_pairs = [(p, self.models[k].params_b)
                       for p, (j, k) in enumerate(pairs)
                       if self.nodes[j].kind == "cloud"]
        assert cloud_pairs, "cluster must contain at least one cloud pair"
        fallback = max(cloud_pairs, key=lambda t: t[1])[0]

        # disaggregated tables: node roles, KV link matrices, route table
        role_ix = {"unified": 0, "prefill": 1, "decode": 2}
        node_role = np.array([role_ix[n.role] for n in self.nodes], np.int32)
        kv_lat = np.zeros((n_nodes, n_nodes), np.float32)
        kv_inv_bw = np.zeros((n_nodes, n_nodes), np.float32)
        kv_egress = np.zeros((n_nodes, n_nodes), np.float32)
        for a, na in enumerate(self.nodes):
            for b, nb in enumerate(self.nodes):
                if a == b:
                    continue
                kv_lat[a, b] = na.kv_lat_s + nb.kv_lat_s
                kv_inv_bw[a, b] = 1.0 / min(na.kv_bw_bps, nb.kv_bw_bps)
                kv_egress[a, b] = na.kv_egress_per_gb / 1e9
        # routes: same model on both legs; prefill leg never on a
        # decode-specialized node, decode leg never on a prefill-specialized
        # node. Colocated (p == q) routes therefore exist exactly on unified
        # nodes, so the route-valued genome can decline to disaggregate.
        route_p, route_q = [], []
        for p, (jp, kp) in enumerate(pairs):
            if node_role[jp] == 2:          # decode-only node can't prefill
                continue
            for q, (jq, kq) in enumerate(pairs):
                if kq != kp or node_role[jq] == 1:   # model mismatch / no decode
                    continue
                route_p.append(p)
                route_q.append(q)
        assert route_p, "cluster must admit at least one (prefill, decode) route"

        return ClusterArrays(
            pair_node=jnp.asarray(pair_node),
            pair_model=jnp.asarray(pair_model),
            pair_is_edge=jnp.asarray(pair_is_edge),
            pair_model_type=jnp.asarray(pair_model_type),
            pair_price=jnp.asarray(pair_price),
            pair_prefill_tps=jnp.asarray(pair_prefill),
            pair_decode_tps=jnp.asarray(pair_decode),
            pair_base_quality=jnp.asarray(pair_bq),
            pair_diff_slope=jnp.asarray(pair_slope),
            pair_verbosity=jnp.asarray(pair_verb),
            node_is_edge=jnp.asarray(node_is_edge),
            node_bw_up=jnp.asarray(node_bw_up),
            node_bw_down=jnp.asarray(node_bw_down),
            node_lat_up=jnp.asarray(node_lat_up),
            node_lat_down=jnp.asarray(node_lat_down),
            node_conc=jnp.asarray(node_conc),
            edge_pairs_by_type=jnp.asarray(edge_by_type),
            cloud_fallback_pair=jnp.asarray(fallback, dtype=jnp.int32),
            node_role=jnp.asarray(node_role),
            kv_lat=jnp.asarray(kv_lat),
            kv_inv_bw=jnp.asarray(kv_inv_bw),
            kv_egress=jnp.asarray(kv_egress),
            pair_kv_bytes_per_token=jnp.asarray(pair_kv_bpt),
            route_prefill=jnp.asarray(route_p, dtype=jnp.int32),
            route_decode=jnp.asarray(route_q, dtype=jnp.int32),
        )


# ---------------------------------------------------------------------------
# The paper's testbed (§V-C): 1 cloud node (A40, gemma3:27b) + 3 edge nodes
# (4-core CPU, qwen2.5 1.5b instruct / coder / math). Speeds, prices and
# quality constants are calibrated to Table II — see workload/calibration.py
# for the calibration procedure and residuals.
# ---------------------------------------------------------------------------

def paper_models() -> Tuple[ModelSpec, ...]:
    return (
        ModelSpec(
            name="gemma3:27b", model_type="general", params_b=27.0,
            price_per_mtok=0.83,
            #              mbpp   gsm8k  squad  hellaswag
            base_quality=(0.650, 0.420, 0.905, 0.320),
            difficulty_slope=0.08, verbosity=1.0),
        ModelSpec(
            name="qwen2.5:1.5b-instruct", model_type="instruct", params_b=1.5,
            price_per_mtok=0.0665,
            base_quality=(0.180, 0.140, 0.700, 0.200),
            difficulty_slope=0.45, verbosity=0.9),
        ModelSpec(
            name="qwen2.5-coder:1.5b-instruct", model_type="coder", params_b=1.5,
            price_per_mtok=0.0665,
            base_quality=(0.480, 0.120, 0.450, 0.150),
            difficulty_slope=0.45, verbosity=1.0),
        ModelSpec(
            name="qwen2.5-math:1.5b-instruct", model_type="math", params_b=1.5,
            price_per_mtok=0.0665,
            base_quality=(0.100, 0.330, 0.300, 0.120),
            difficulty_slope=0.45, verbosity=1.1),
    )


def paper_testbed(edge_concurrency: int = 4, cloud_concurrency: int = 8
                  ) -> ClusterSpec:
    """§V-C: 3 edge nodes (4-core CPU, 8GB, no GPU) + 1 cloud node (A40)."""
    models = paper_models()
    edge_models = tuple(m.name for m in models[1:])
    # LAN to edge (fast, near), WAN to cloud (slower, farther)
    edge_link = LinkSpec(bw_up_bps=12.5e6, bw_down_bps=12.5e6,
                         latency_up_s=0.004, latency_down_s=0.004)
    cloud_link = LinkSpec(bw_up_bps=6.25e6, bw_down_bps=6.25e6,
                          latency_up_s=0.035, latency_down_s=0.035)
    # Ollama-style speeds: 27b on A40 GPU vs 1.5b on 4-core CPU
    cloud_speeds_pre = {"gemma3:27b": 2200.0}
    cloud_speeds_dec = {"gemma3:27b": 19.0}
    edge_pre = {m: 300.0 for m in edge_models}
    edge_dec = {m: 5.2 for m in edge_models}
    nodes = (
        NodeSpec(name="cloud-0", kind="cloud", models=("gemma3:27b",),
                 link=cloud_link, prefill_tps=cloud_speeds_pre,
                 decode_tps=cloud_speeds_dec, concurrency=cloud_concurrency),
    ) + tuple(
        NodeSpec(name=f"edge-{i}", kind="edge", models=edge_models,
                 link=edge_link, prefill_tps=dict(edge_pre),
                 decode_tps=dict(edge_dec), concurrency=edge_concurrency)
        for i in range(3)
    )
    return ClusterSpec(nodes=nodes, models=models)


def disagg_testbed(kv_bw_bps: float = 2.5e9,
                   n_decode: int = 2,
                   unified_concurrency: int = 4) -> ClusterSpec:
    """Disaggregated variant of the testbed: one shared cloud model served
    by a prefill-optimized node (batchy compute, weak decode), decode-
    optimized nodes (high decode throughput, poor prefill, cheaper $/Mtok via
    ``price_factor``), and unified nodes that can do both. ``kv_bw_bps``
    parameterizes the prefill->decode KV link so benchmarks can sweep it.
    """
    model = ModelSpec(
        name="gemma3:27b", model_type="general", params_b=27.0,
        price_per_mtok=0.83,
        base_quality=(0.650, 0.420, 0.905, 0.320),
        difficulty_slope=0.08, verbosity=1.0)
    link = LinkSpec(bw_up_bps=6.25e6, bw_down_bps=6.25e6,
                    latency_up_s=0.020, latency_down_s=0.020)
    name = model.name
    nodes = (
        NodeSpec(name="prefill-0", kind="cloud", models=(name,), link=link,
                 prefill_tps={name: 9000.0}, decode_tps={name: 6.0},
                 concurrency=8, role="prefill", price_factor=0.9,
                 kv_bw_bps=kv_bw_bps, kv_lat_s=0.002),
    ) + tuple(
        NodeSpec(name=f"decode-{i}", kind="cloud", models=(name,), link=link,
                 prefill_tps={name: 250.0}, decode_tps={name: 34.0},
                 concurrency=8, role="decode", price_factor=0.7,
                 kv_bw_bps=kv_bw_bps, kv_lat_s=0.002)
        for i in range(n_decode)
    ) + (
        NodeSpec(name="unified-0", kind="cloud", models=(name,), link=link,
                 prefill_tps={name: 2200.0}, decode_tps={name: 19.0},
                 concurrency=unified_concurrency, role="unified",
                 kv_bw_bps=kv_bw_bps, kv_lat_s=0.002),
        NodeSpec(name="unified-1", kind="cloud", models=(name,), link=link,
                 prefill_tps={name: 2200.0}, decode_tps={name: 19.0},
                 concurrency=unified_concurrency, role="unified",
                 kv_bw_bps=kv_bw_bps, kv_lat_s=0.002),
    )
    return ClusterSpec(nodes=nodes, models=(model,))


def fleet_testbed(n_edge: int = 56, n_cloud: int = 8,
                  edge_concurrency: int = 4, cloud_concurrency: int = 8
                  ) -> ClusterSpec:
    """Large heterogeneous fleet for fleet-vectorized serving benchmarks:
    ``n_cloud`` cloud nodes each serving the big general model and
    ``n_edge`` edge nodes each serving the three small specialist models —
    the paper testbed's shape scaled to the open-loop replay regime
    (``benchmarks/fleet_scale.py``). With one set of engine weights per
    model size the serving layer collapses to exactly two decode cohorts
    (one per (ModelConfig, params) identity) regardless of node count."""
    models = paper_models()
    edge_models = tuple(m.name for m in models[1:])
    edge_link = LinkSpec(bw_up_bps=12.5e6, bw_down_bps=12.5e6,
                         latency_up_s=0.004, latency_down_s=0.004)
    cloud_link = LinkSpec(bw_up_bps=6.25e6, bw_down_bps=6.25e6,
                          latency_up_s=0.035, latency_down_s=0.035)
    nodes = tuple(
        NodeSpec(name=f"cloud-{i}", kind="cloud", models=("gemma3:27b",),
                 link=cloud_link, prefill_tps={"gemma3:27b": 2200.0},
                 decode_tps={"gemma3:27b": 19.0},
                 concurrency=cloud_concurrency)
        for i in range(n_cloud)
    ) + tuple(
        NodeSpec(name=f"edge-{i}", kind="edge", models=edge_models,
                 link=edge_link, prefill_tps={m: 300.0 for m in edge_models},
                 decode_tps={m: 5.2 for m in edge_models},
                 concurrency=edge_concurrency)
        for i in range(n_edge)
    )
    return ClusterSpec(nodes=nodes, models=models)
