"""Deterministic sharded token pipeline.

Production properties reproduced here:

* **deterministic per-host sharding** — batch row r of global step t is a
  pure function of (seed, t, r); each host materializes only its addressable
  rows, so the pipeline is identical on 1 host or 1000 and a restart at step
  t resumes mid-epoch with no state file;
* **background prefetch** — a one-slot prefetch thread overlaps host batch
  synthesis with device execution;
* **learnable structure** — the synthetic corpus is a mixture of k-order
  Markov chains over the vocab (per-document transition keys), so
  cross-entropy genuinely decreases during the example training runs —
  a pure-uniform stream would pin the loss at log V and hide optimizer bugs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_chains: int = 8          # Markov mixture components
    branch: int = 32           # successors per state


class SyntheticLMData:
    def __init__(self, cfg: DataConfig, host_index: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        root = np.random.default_rng(np.random.SeedSequence([cfg.seed, 99]))
        # per-chain successor tables: state -> branch successors
        self._succ = root.integers(
            0, cfg.vocab, size=(cfg.n_chains, cfg.vocab, cfg.branch),
            dtype=np.int32)

    # -- pure row synthesis ---------------------------------------------------
    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row]))
        chain = int(rng.integers(0, cfg.n_chains))
        succ = self._succ[chain]
        out = np.empty(cfg.seq_len + 1, np.int32)
        tok = int(rng.integers(0, cfg.vocab))
        picks = rng.integers(0, cfg.branch, size=cfg.seq_len + 1)
        for i in range(cfg.seq_len + 1):
            out[i] = tok
            tok = int(succ[tok, picks[i]])
        return out

    def batch(self, step: int) -> dict:
        rows = [self._row(step, self.host_index * self.local_batch + r)
                for r in range(self.local_batch)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    # -- prefetching iterator ---------------------------------------------------
    def iterator(self, start_step: int = 0, prefetch: int = 2
                 ) -> Iterator[dict]:
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch(step)))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                _, b = q.get()
                yield b
        finally:
            stop.set()
