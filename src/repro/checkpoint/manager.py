"""Fault-tolerant distributed checkpointing (no orbax in this container).

Design (the usual production recipe):

* **per-shard files** — each host writes only the addressable shards of each
  array (`<step>/<host>/arrays.npz`), so checkpoint bandwidth scales with
  hosts and no host ever materializes a global array;
* **manifest + atomic commit** — a JSON manifest (pytree structure, global
  shapes/dtypes, mesh axes, PartitionSpecs, step metadata) is written last
  and the whole step directory is `os.rename`d from `<step>.tmp` to
  `<step>` — a crash mid-write never leaves a checkpoint that parses;
* **elastic restore** — load reconstructs global arrays from any number of
  shard files and re-shards onto the *current* mesh (which may have a
  different shape/axis layout than the writer's), enabling restart on a
  degraded pod or a differently-sized slice;
* **keep-last-k** — old steps garbage-collected after commit;
* **async save** — a background thread serializes device-to-host transfer
  from the step loop (double-buffered: at most one pending save).

On this single-process container "host" is process 0 and shards are the
full arrays; the format is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# numpy's npz cannot represent ml_dtypes (bf16 saves as void): store such
# arrays as bit-equal uint views and record the logical dtype in the manifest
_BITCAST = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
            "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}

_SEP = "/"


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{_SEP}{k}" if prefix else str(k))
    # PartitionSpec subclasses tuple on some jax versions: it is a leaf, not
    # a container (recursing into it shreds specs into None/str fragments)
    elif isinstance(tree, (list, tuple)) and not isinstance(tree, P):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{_SEP}{i}")
    else:
        yield prefix, tree


def _unflatten_into(template, flat: Dict[str, Any]):
    def walk(t, prefix=""):
        if isinstance(t, dict):
            return {k: walk(v, f"{prefix}{_SEP}{k}" if prefix else str(k))
                    for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            typ = type(t)
            return typ(walk(v, f"{prefix}{_SEP}{i}") for i, v in enumerate(t))
        return flat[prefix]
    return walk(template)


def _spec_to_json(spec: P):
    return [list(a) if isinstance(a, tuple) else a for a in tuple(spec)]


def _spec_from_json(j):
    return P(*[tuple(a) if isinstance(a, list) else a for a in j])


def save_checkpoint(directory: str | Path, step: int, tree,
                    specs=None, metadata: Optional[dict] = None,
                    process_index: int = 0, keep: int = 3) -> Path:
    """Write one checkpoint step atomically. Returns the committed path."""
    directory = Path(directory)
    tmp = directory / f"step_{step:08d}.tmp"
    final = directory / f"step_{step:08d}"
    shard_dir = tmp / f"host_{process_index}"
    shard_dir.mkdir(parents=True, exist_ok=True)

    flat = dict(_flatten(tree))
    arrays = {}
    manifest_entries = {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _BITCAST:
            arr = arr.view(_BITCAST[logical][0])
        arrays[path] = arr
        manifest_entries[path] = {"shape": list(arr.shape),
                                  "dtype": logical}
    np.savez(shard_dir / "arrays.npz",
             **{k.replace("/", "|"): v for k, v in arrays.items()})

    if process_index == 0:
        manifest = {
            "step": step,
            "time": time.time(),
            "entries": manifest_entries,
            "metadata": metadata or {},
            "specs": ({k: _spec_to_json(v) for k, v in
                       dict(_flatten(specs)).items()} if specs is not None
                      else None),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)                       # atomic commit
        _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    steps = sorted(p for p in directory.glob("step_[0-9]*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)
    for p in directory.glob("*.tmp"):               # crashed writers
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    steps = sorted(int(p.name.split("_")[1])
                   for p in directory.glob("step_[0-9]*")
                   if p.is_dir() and not p.name.endswith(".tmp")
                   and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def load_checkpoint(directory: str | Path, template, step: Optional[int] = None,
                    mesh: Optional[Mesh] = None, specs=None):
    """Restore a checkpoint into ``template``'s structure.

    If ``mesh`` (and optionally ``specs``) is given, arrays are placed
    sharded onto it — the *elastic* path: the mesh need not match the one
    the checkpoint was written on; specs default to the recorded ones with
    non-dividing axes dropped.
    """
    directory = Path(directory)
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    entries = manifest["entries"]
    flat_np: Dict[str, np.ndarray] = {}
    for host_dir in sorted(d.glob("host_*")):
        with np.load(host_dir / "arrays.npz") as z:
            for k in z.files:
                path = k.replace("|", "/")
                arr = z[k]
                logical = entries.get(path, {}).get("dtype", str(arr.dtype))
                if logical in _BITCAST:
                    arr = arr.view(_BITCAST[logical][1])
                flat_np[path] = arr

    rec_specs = manifest.get("specs")
    out: Dict[str, Any] = {}
    for path, arr in flat_np.items():
        if mesh is not None:
            if specs is not None:
                spec = dict(_flatten(specs))[path]
            elif rec_specs and path in rec_specs:
                spec = _spec_from_json(rec_specs[path])
            else:
                spec = P()
            spec = _fit_spec(mesh, spec, arr.shape)
            out[path] = jax.device_put(arr, NamedSharding(mesh, spec))
        else:
            out[path] = jax.numpy.asarray(arr)
    return _unflatten_into(template, out), manifest


def _fit_spec(mesh: Mesh, spec: P, shape):
    from ..models.sharding import _fit
    return _fit(mesh, spec, shape)


class CheckpointManager:
    """Async, keep-last-k manager used by the trainer."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 save_interval_steps: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.save_interval_steps = save_interval_steps
        self._pending: Optional[threading.Thread] = None
        self.directory.mkdir(parents=True, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval_steps == 0

    def save(self, step: int, tree, specs=None, metadata=None,
             blocking: bool = False):
        self.wait()
        # materialize on host *before* handing to the thread so the step
        # loop can mutate its arrays freely afterwards
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, specs=specs,
                            metadata=metadata, keep=self.keep)

        if blocking:
            work()
        else:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, template, mesh=None, specs=None, step=None):
        return load_checkpoint(self.directory, template, step=step,
                               mesh=mesh, specs=specs)

    def latest_step(self):
        return latest_step(self.directory)
