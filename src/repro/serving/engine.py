"""Continuous-batching inference engine for one LLM instance.

Orca-style iteration-level scheduling (the paper's §II-D background) on a
fixed slot pool:

* ``submit`` queues a request; admission runs its prefill (padded to a bucket
  length) and writes the resulting KV/state into a free slot of the batched
  decode cache;
* ``step`` advances *all* active slots by one decode token (one jit'd
  ``decode_step`` call — iteration-level batching), retiring slots that hit
  max_new_tokens or emit EOS and immediately admitting queued requests into
  the freed slots;
* per-slot fill lives in ``cache.kv_len`` so ragged occupancy needs no
  re-padding.

The engine is exact: admission uses the same ``lm.prefill`` the tests
validate against teacher forcing, so a routed request's tokens are identical
to an offline forward pass.

Each result carries **QoE phase accounting** in engine-step units (the
discrete clock advanced by ``step``): ``submit_step``/``first_token_step``/
``finish_step`` timestamps plus the derived ``ttft_steps`` (queue wait until
the prefill emits the first token) and ``tpot_steps`` (decode iterations per
generated token after the first). These are the serving-layer ground truth
the analytical TTFT/TPOT tables in ``core.fitness`` model.

**Prefix reuse** (``EngineConfig.prefix_cache``): admission looks up the
longest cached whole-block prefix of the prompt in a paged KV store
(``serving.kvcache``), runs ``lm.prefill_extend`` on only the uncached
suffix, and caches the freshly computed whole blocks for later requests.
Reuse is exact — output tokens are bit-identical to the non-caching engine —
while ``cache_stats()["prefill_tokens_run"]`` drops with every shared
prefix (multi-turn sessions, shared system prompts).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig
from .fleet import ChunkWork, FleetMemberStore, decode_chunk_body
from .kvcache import CacheStats, PagedKVStore

# ---------------------------------------------------------------------------
# Module-level jitted entry points, keyed on the (hashable) ModelConfig:
# every engine with the same model shares one compiled executable per shape
# bucket instead of re-jitting per instance, and admission pads prompts /
# suffixes to `EngineConfig.prefill_bucket` multiples so distinct lengths
# stop compiling distinct executables.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_one(params, cfg: ModelConfig, tok, cache):
    return lm.decode_step(params, cfg, tok, cache)


@functools.partial(jax.jit, static_argnames=("cfg", "max_seq"))
def _prefill_bucketed(params, cfg: ModelConfig, tokens, length, max_seq):
    return lm.prefill(params, cfg, {"tokens": tokens}, max_seq=max_seq,
                      length=length)


@functools.partial(jax.jit, static_argnames=("cfg", "max_seq"))
def _prefill_extend_bucketed(params, cfg: ModelConfig, tokens, length,
                             prefix, prefix_len, max_seq):
    return lm.prefill_extend(params, cfg, {"tokens": tokens}, prefix,
                             max_seq=max_seq, prefix_len=prefix_len,
                             length=length)


@functools.partial(jax.jit, static_argnames=("cfg", "n", "eos"))
def _decode_chunk(params, cfg: ModelConfig, tok, cache, budget, alive,
                  n: int, eos: int):
    """``n`` fused decode iterations with device-side retirement.

    Mirrors ``LLMEngine.step`` state evolution exactly: every iteration
    decodes all slots, budgets decrement for live slots, a live slot retires
    on exhausted budget or EOS (its ``kv_len`` zeroes and its next token
    resets, exactly like ``_release_slot``), and already-dead slots keep
    decoding garbage that nothing reads — so the chunk is bit-identical to
    ``n`` single steps when no admission happens in between. Emits one
    stacked (n, 3, B) int32 tensor (token, emitted-this-iter, retired-this-
    iter) so the caller needs a single device->host transfer per chunk.

    The scan body lives in ``serving.fleet.decode_chunk_body`` — the same
    code is vmapped over a node axis by ``fleet._cohort_decode_chunk`` so a
    whole cohort of engines decodes in one dispatch."""
    return decode_chunk_body(params, cfg, tok, cache, budget, alive, n, eos)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    eos_token: int = -1            # -1: never (synthetic vocab)
    prefill_bucket: int = 32       # prompts padded up to a bucket multiple
    # paged prefix reuse (pure-attention patterns only)
    prefix_cache: bool = False
    block_size: int = 8            # tokens per KV block
    cache_blocks: int = 64         # pool capacity (blocks)


@dataclasses.dataclass
class _Slot:
    request_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    submit_step: int = 0       # engine step at submit()
    first_token_step: int = 0  # engine step when prefill emitted token 0
    block_ids: List[int] = dataclasses.field(default_factory=list)
    prompt_tokens: int = 0     # prompt length at admission
    cached_tokens: int = 0     # whole-block prefix reused from the paged pool


class _LocalStore:
    """Engine-local device state (the standalone, non-fleet backing)."""

    __slots__ = ("cache", "next_token")

    def __init__(self, cache, next_token):
        self.cache = cache
        self.next_token = next_token


class LLMEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        B = ecfg.max_slots
        # device state lives behind a store: engine-local arrays until a
        # fleet Cohort adopts this engine, a member view into the cohort's
        # stacked node-axis pytree afterwards (serving.fleet). The control
        # plane below never knows the difference.
        self._store = _LocalStore(lm.make_cache(cfg, B, ecfg.max_seq),
                                  jnp.zeros((B, 1), jnp.int32))
        self._fleet = None   # (Cohort, member index) once adopted
        self.slots = [_Slot() for _ in range(B)]
        self.queue: deque = deque()
        self.results: Dict[int, dict] = {}
        self._steps = 0
        self.host_syncs = 0   # device->host transfer count (decode path)
        self.decode_dispatches = 0   # jitted decode calls issued by THIS engine
        self.tokens_emitted = 0
        # bucketed prefill is exact only for pure-attention dense patterns:
        # recurrent mixers integrate padding tokens into their state, and
        # MoE capacity (GShard-style drop) lets padding tokens displace
        # real tokens from expert slots
        self._bucket_ok = (ecfg.prefill_bucket > 0 and
                           all(m == "attn" and f != "moe"
                               for m, f in cfg.pattern))
        self.kv: Optional[PagedKVStore] = (
            PagedKVStore(cfg, ecfg.cache_blocks, ecfg.block_size)
            if ecfg.prefix_cache else None)

    # -- device-state views (local or fleet-backed) ---------------------------
    @property
    def cache(self):
        return self._store.cache

    @cache.setter
    def cache(self, value):
        self._store.cache = value

    @property
    def _next_token(self):
        return self._store.next_token

    @_next_token.setter
    def _next_token(self, value):
        self._store.next_token = value

    @property
    def fleet_ok(self) -> bool:
        """Fleet vectorization is exact only when batch rows are independent:
        a cohort dispatch may overrun a member's committed iterations
        (``n_f > n_eff``), mutating dead-slot rows the per-engine path never
        touched — invisible unless MoE expert capacity couples rows."""
        return all(f != "moe" for _, f in self.cfg.pattern)

    def _attach_fleet(self, cohort, member: int) -> None:
        """Adopt this engine into a fleet cohort: device state moves into
        the cohort's stacked pytree (the cohort stacks it before calling
        this) and all reads/writes go through a member view."""
        self._fleet = (cohort, member)
        self._store = FleetMemberStore(cohort, member)
        if self.kv is not None and cohort.kv_pools is not None:
            # Cohort construction stacks the members' pools itself
            # (FleetKVPools.stack attaches them); a flushed store re-attaches
            # through flush_kv instead.
            pass
        self._sync_fleet_counters()

    def _sync_fleet_counters(self) -> None:
        if self._fleet is None:
            return
        cohort, m = self._fleet
        cohort.counters.active[m] = sum(
            s.request_id is not None for s in self.slots)
        cohort.counters.queued[m] = len(self.queue)

    def _decode(self, params, tok, cache):
        return _decode_one(params, self.cfg, tok, cache)

    # -- public API -----------------------------------------------------------
    def submit(self, request_id: int, tokens: np.ndarray,
               max_new_tokens: Optional[int] = None,
               extra: Optional[dict] = None) -> None:
        self.queue.append((request_id, np.asarray(tokens, np.int32),
                           max_new_tokens or self.ecfg.max_new_tokens,
                           extra or {}, self._steps))
        self._admit()
        self._sync_fleet_counters()

    def step(self) -> List[int]:
        """One decode iteration for all active slots. Returns retired ids."""
        active = [i for i, s in enumerate(self.slots) if s.request_id is not None]
        if not active:
            self._admit()
            return []
        logits, self.cache = self._decode(self.params, self._next_token,
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.host_syncs += 1
        self.decode_dispatches += 1
        self._next_token = jnp.asarray(nxt[:, None])
        retired = []
        for i in active:
            s = self.slots[i]
            tok = int(nxt[i])
            s.generated.append(tok)
            s.budget -= 1
            self.tokens_emitted += 1
            if s.budget <= 0 or tok == self.ecfg.eos_token:
                self.results[s.request_id] = self._result(s, self._steps + 1)
                retired.append(s.request_id)
                self._release_slot(i)
        self._steps += 1
        if retired:
            self._admit()
        return retired

    def step_n(self, n: int) -> List[int]:
        """Up to ``n`` fused decode iterations with ONE host transfer.

        Host-sync-free stepping: the whole chunk (decode, budget/EOS
        retirement masks, slot bookkeeping) runs device-side via
        ``_decode_chunk``'s ``lax.scan``; the host sees a single stacked
        (token, emitted, retired) tensor per chunk instead of one transfer
        per decoded token. Bit-identical to ``n`` consecutive ``step()``
        calls **when no admission is pending** — with queued work (which
        ``step()`` would admit into freed slots mid-chunk) or ``n <= 1``
        it falls back to a single ``step()``. The chunk is clipped to the
        largest active budget so it never decodes past all retirements.
        Returns all ids retired during the chunk."""
        if n <= 1 or self.queue:
            return self.step()
        active = [i for i, s in enumerate(self.slots)
                  if s.request_id is not None]
        if not active:
            self._admit()
            return []
        budgets = [s.budget if s.request_id is not None else 0
                   for s in self.slots]
        n_eff = min(n, max(budgets[i] for i in active))
        alive = np.asarray([s.request_id is not None for s in self.slots])
        tok, cache, outs = _decode_chunk(
            self.params, self.cfg, self._next_token, self.cache,
            jnp.asarray(budgets, jnp.int32), jnp.asarray(alive),
            n_eff, self.ecfg.eos_token)
        self._next_token = tok
        self.cache = cache
        outs = np.asarray(outs)               # (n_eff, 3, B) — one transfer
        self.host_syncs += 1
        self.decode_dispatches += 1
        return self._commit_chunk(ChunkWork(outs=outs, n_eff=n_eff,
                                            active=tuple(active)))

    def _commit_chunk(self, work: ChunkWork) -> List[int]:
        """Host-side half of a fused decode chunk: token append, budget and
        retirement bookkeeping for ``work.n_eff`` iterations. Shared by
        ``step_n`` (engine-local chunk) and ``fleet.Cohort.dispatch`` (this
        engine's slice of a whole-cohort chunk — the device state was
        already advanced in the stacked dispatch, so only the books move
        here). Admits queued work into freed slots exactly like ``step``."""
        toks, emits, retires = (work.outs[:, 0], work.outs[:, 1],
                                work.outs[:, 2])
        retired: List[int] = []
        for t in range(work.n_eff):
            for i in work.active:
                if not emits[t, i]:
                    continue
                s = self.slots[i]
                s.generated.append(int(toks[t, i]))
                s.budget -= 1
                self.tokens_emitted += 1
                if retires[t, i]:
                    self.results[s.request_id] = self._result(
                        s, self._steps + t + 1)
                    retired.append(s.request_id)
                    # device-side state (kv_len, next token) was already
                    # released inside the chunk
                    self._release_slot_host(i)
        self._steps += work.n_eff
        if retired:
            self._admit()
        return retired

    # -- disaggregated prefill/decode (KV handoff) ----------------------------
    def prefill_only(self, request_id: int, tokens: np.ndarray) -> List[int]:
        """Prefill-role half of a disaggregated request: compute (or reuse)
        the whole-block KV of ``tokens`` in the paged store **without**
        occupying a decode slot, and return the block ids pinned for export.

        The pins keep the blocks alive while the payload is in flight; the
        scheduler must pair every call with :meth:`release_export` (delivery
        or abort) or the blocks leak as permanently-active. May return fewer
        than ``len(tokens) // block_size`` blocks when the pool runs dry —
        the decode side simply re-prefills the uncovered tail, so a short
        export is still exact."""
        assert self.kv is not None, "prefill_only requires prefix_cache=True"
        tokens = np.asarray(tokens, np.int32)
        L = len(tokens)
        assert L <= self.ecfg.max_seq, "request exceeds engine max_seq"
        bs = self.kv.block_size
        st = self.kv.cache.stats
        # uncapped whole-block match: unlike decode admission we need no
        # suffix token here, a fully cached prompt exports with zero compute
        cached = self.kv.cache.index.match(tokens)
        self.kv.cache.acquire(cached)
        prefix_len = len(cached) * bs
        st.lookups += 1
        if cached:
            st.hits += 1
            st.hit_tokens += prefix_len
        st.prefill_tokens_total += L
        st.prefill_tokens_run += L - prefix_len
        n_whole = L // bs
        new_ids: List[int] = []
        if n_whole > len(cached):
            suffix = jnp.asarray(tokens[prefix_len:], jnp.int32)[None]
            if cached:
                _, cache1 = lm.prefill_extend(
                    self.params, self.cfg, {"tokens": suffix},
                    self.kv.gather(cached), max_seq=self.ecfg.max_seq)
            else:
                _, cache1 = lm.prefill(self.params, self.cfg,
                                       {"tokens": suffix},
                                       max_seq=self.ecfg.max_seq)
            for _ in range(len(cached), n_whole):
                bid = self.kv.cache.allocate()
                if bid is None:   # pool exhausted: export what we have
                    break
                new_ids.append(bid)
            if new_ids:
                self.kv.scatter(new_ids, len(cached), cache1.layer)
                n_tok = (len(cached) + len(new_ids)) * bs
                self.kv.cache.commit(tokens[:n_tok], cached + new_ids)
        return cached + new_ids

    def export_kv(self, block_ids: List[int]):
        """Host-copy the pinned blocks of a :meth:`prefill_only` result (the
        wire payload of the KV handoff)."""
        assert self.kv is not None
        return self.kv.export_blocks(block_ids)

    def release_export(self, block_ids: List[int]) -> None:
        """Drop the export pins: committed blocks become evictable-cached,
        uncommitted duplicates return to the free list. Refcounts return to
        their pre-handoff baseline."""
        if self.kv is not None and block_ids:
            self.kv.cache.release(block_ids)

    def import_kv(self, tokens: np.ndarray, slabs) -> bool:
        """Decode-role half of the handoff: land exported slabs covering the
        whole-block prefix ``tokens`` into this engine's pool and index them,
        so the next ``submit`` of the full prompt reuses them bit-identically
        (paged reuse is exact). Returns False — caller falls back to a full
        re-prefill — when this engine has no paged store or its pool cannot
        supply enough blocks."""
        if self.kv is None:
            return False
        tokens = np.asarray(tokens, np.int32)
        bs = self.kv.block_size
        n = len(tokens) // bs
        assert n * bs == len(tokens), "KV import must be whole-block"
        if n == 0:
            return False
        ids: List[int] = []
        for _ in range(n):
            bid = self.kv.cache.allocate()
            if bid is None:
                self.kv.cache.release(ids)   # uncommitted -> free list
                return False
            ids.append(bid)
        self.kv.import_blocks(ids, slabs)
        # commit keeps canonical blocks for chunks already indexed here; our
        # duplicates stay unindexed and free on release, new chunks become
        # evictable-cached — either way no pin outlives this call
        self.kv.cache.commit(tokens, ids)
        self.kv.cache.release(ids)
        return True

    def cancel(self, request_id: int) -> bool:
        """Abort a request wherever it currently lives (active slot or
        admission queue). Frees the slot immediately and admits queued work
        into it; produces no result entry. Returns True if found.

        Used by the cluster scheduler to retire the losing copy of a hedged
        request and to purge zombies from a crashed node's engine."""
        for i, s in enumerate(self.slots):
            if s.request_id == request_id:
                self._release_slot(i)
                self._admit()
                self._sync_fleet_counters()
                return True
        for k, item in enumerate(self.queue):
            if item[0] == request_id:
                del self.queue[k]
                self._sync_fleet_counters()
                return True
        return False

    def run_to_completion(self, max_iters: int = 10000,
                          chunk: int = 1) -> Dict[int, dict]:
        """Drain queue + slots. ``chunk > 1`` decodes via :meth:`step_n`
        whenever no admission is pending (one host sync per chunk)."""
        it = 0
        while (self.queue or any(s.request_id is not None
                                 for s in self.slots)):
            if chunk > 1:
                self.step_n(chunk)
            else:
                self.step()
            it += 1
            if it > max_iters:
                raise RuntimeError("engine did not drain")
        return self.results

    @property
    def active_count(self) -> int:
        return sum(s.request_id is not None for s in self.slots)

    @property
    def queue_len(self) -> int:
        return self.active_count + len(self.queue)

    def cache_stats(self) -> dict:
        """Prefix-cache counters (zeros when paged reuse is disabled)."""
        st = self.kv.cache.stats if self.kv is not None else CacheStats()
        return st.as_dict()

    def flush_kv(self) -> None:
        """Simulated node restart: drop every cached KV block. Active slots
        keep decoding — their contiguous caches own a gathered copy — but
        their block pins go down with the pool, so nothing dangles."""
        if self.kv is None:
            return
        for s in self.slots:
            s.block_ids = []
        self.kv = PagedKVStore(self.cfg, self.ecfg.cache_blocks,
                               self.ecfg.block_size)
        if self._fleet is not None:
            cohort, m = self._fleet
            if cohort.kv_pools is not None:
                # re-home the fresh store onto this member's slab slice
                # (copying the fresh zeros wipes the dead pool's bytes too)
                self.kv.attach(cohort.kv_pools, m)

    # -- internals -------------------------------------------------------------
    def _release_slot_host(self, i: int) -> None:
        """Host-side half of slot retirement: drop KV-block references and
        reset the slot record (``step_n`` chunks already performed the
        device-side release inside the scan)."""
        s = self.slots[i]
        if self.kv is not None and s.block_ids:
            self.kv.cache.release(s.block_ids)
        self.slots[i] = _Slot()
        self._sync_fleet_counters()

    def _release_slot(self, i: int) -> None:
        """Retire/cancel slot ``i``: drop its KV-block references and zero its
        ``kv_len`` so ``decode_step`` stops attending over the dead slot's KV
        (stale lengths previously kept streaming the dead cache until the
        slot's next reuse)."""
        self._release_slot_host(i)
        self.cache = self.cache._replace(
            kv_len=self.cache.kv_len.at[i].set(0))
        self._next_token = self._next_token.at[i, 0].set(0)

    def _result(self, s: "_Slot", finish_step: int) -> dict:
        n_decode = max(len(s.generated) - 1, 0)  # token 0 comes from prefill
        return {
            "tokens": list(s.generated),
            "n_steps": len(s.generated),
            "submit_step": s.submit_step,
            "first_token_step": s.first_token_step,
            "finish_step": finish_step,
            "ttft_steps": s.first_token_step - s.submit_step,
            "tpot_steps": ((finish_step - s.first_token_step) / n_decode
                           if n_decode else 0.0),
            "decode_steps": finish_step - s.first_token_step,
            # realized prefix-cache reuse of this request's admission (the
            # per-request cache-hit observation the obs metrics ingest)
            "prompt_tokens": s.prompt_tokens,
            "cached_tokens": s.cached_tokens,
            "cached_frac": (s.cached_tokens / s.prompt_tokens
                            if s.prompt_tokens else 0.0),
        }

    def qoe_summary(self) -> dict:
        """Mean phase timings (in engine steps) over completed requests."""
        if not self.results:
            return {"avg_ttft_steps": 0.0, "avg_tpot_steps": 0.0}
        rs = list(self.results.values())
        return {"avg_ttft_steps": float(np.mean([r["ttft_steps"] for r in rs])),
                "avg_tpot_steps": float(np.mean([r["tpot_steps"] for r in rs]))}

    def _admit(self):
        while self.queue:
            free = [i for i, s in enumerate(self.slots) if s.request_id is None]
            if not free:
                break
            i = free[0]
            request_id, tokens, budget, extra, submit_step = self.queue.popleft()
            self._prefill_into(i, request_id, tokens, budget, extra,
                               submit_step)
        self._sync_fleet_counters()

    def _bucket_len(self, n: int) -> int:
        """Smallest prefill-bucket multiple >= n, capped at max_seq."""
        b = self.ecfg.prefill_bucket
        return min(-(-n // b) * b, self.ecfg.max_seq)

    def _prefill_into(self, slot: int, request_id: int, tokens: np.ndarray,
                      budget: int, extra: dict, submit_step: int = 0):
        e = self.ecfg
        L = len(tokens)
        assert L + budget <= e.max_seq, "request exceeds engine max_seq"
        matched: List[int] = []
        if self.kv is not None:
            matched = self.kv.cache.match(tokens)
            self.kv.cache.acquire(matched)
        prefix_len = len(matched) * (self.kv.block_size if self.kv else 0)
        if prefix_len:
            suffix = tokens[prefix_len:]
            Sn = len(suffix)
            Sn_pad = self._bucket_len(Sn) if self._bucket_ok else Sn
            if self._bucket_ok and prefix_len + Sn_pad <= e.max_seq:
                # compile-once admission: suffix padded to the bucket,
                # prefix gathered at the fixed full-block budget — one
                # executable per suffix bucket instead of one per distinct
                # (matched-blocks, suffix-length) combination
                pad_blocks = e.max_seq // self.kv.block_size
                toks = np.zeros(Sn_pad, np.int32)
                toks[:Sn] = suffix
                logits, cache1 = _prefill_extend_bucketed(
                    self.params, self.cfg, jnp.asarray(toks)[None],
                    jnp.int32(Sn), self.kv.gather(matched, pad_to=pad_blocks),
                    jnp.int32(prefix_len), e.max_seq)
            else:
                logits, cache1 = lm.prefill_extend(
                    self.params, self.cfg,
                    {"tokens": jnp.asarray(suffix, jnp.int32)[None]},
                    self.kv.gather(matched), max_seq=e.max_seq)
        elif self._bucket_ok:
            # pad the prompt to the bucket; logits are read at the true last
            # row and kv_len masks the tail, so outputs match exact-length
            # prefill while all lengths in a bucket share one executable
            L_pad = self._bucket_len(L)
            toks = np.zeros(L_pad, np.int32)
            toks[:L] = tokens
            logits, cache1 = _prefill_bucketed(
                self.params, self.cfg, jnp.asarray(toks)[None],
                jnp.int32(L), e.max_seq)
        else:
            batch = {"tokens": jnp.asarray(tokens, jnp.int32)[None]}
            if self.cfg.family == "audio":
                batch["frames"] = jnp.asarray(
                    extra.get("frames",
                              np.zeros((1, self.cfg.encoder.n_frames,
                                        self.cfg.d_model), np.float32)),
                    jnp.bfloat16)
            if self.cfg.family == "vlm":
                batch["patches"] = jnp.asarray(
                    extra.get("patches",
                              np.zeros((1, self.cfg.cross_kv_tokens,
                                        self.cfg.d_model), np.float32)),
                    jnp.bfloat16)
            logits, cache1 = lm.prefill(self.params, self.cfg, batch,
                                        max_seq=e.max_seq)
        block_ids = matched
        if self.kv is not None:
            st = self.kv.cache.stats
            st.prefill_tokens_total += L
            st.prefill_tokens_run += L - prefix_len
            # cache the freshly prefilled whole-block suffix chunks. Start
            # past every chunk the index already holds, not just the capped
            # match: when the whole prompt is cached, match() drops the last
            # block to leave a suffix to prefill, and re-allocating (possibly
            # evicting a live leaf for) that chunk's duplicate would only be
            # thrown away by commit().
            cached = self.kv.cache.index.match(tokens)
            new_ids: List[int] = []
            for _ in range(len(cached), L // self.kv.block_size):
                bid = self.kv.cache.allocate()
                if bid is None:   # pool exhausted: serve uncached, no caching
                    break
                new_ids.append(bid)
            if new_ids:
                self.kv.scatter(new_ids, len(cached), cache1.layer)
                n_tok = (len(cached) + len(new_ids)) * self.kv.block_size
                self.kv.cache.commit(tokens[:n_tok], cached + new_ids)
            block_ids = matched + new_ids
        # splice single-request cache into batch cache at `slot`
        def splice(full, one):
            if full.ndim < 2:
                return full
            return jax.lax.dynamic_update_slice_in_dim(full, one, slot, 1)

        self.cache = self.cache._replace(
            layer=jax.tree.map(splice, self.cache.layer, cache1.layer),
            cross=jax.tree.map(splice, self.cache.cross, cache1.cross),
            kv_len=self.cache.kv_len.at[slot].set(L),
        )
        first = int(jnp.argmax(logits[0]))
        s = self.slots[slot]
        s.request_id = request_id
        s.generated = [first]
        s.budget = budget - 1
        s.submit_step = submit_step
        s.first_token_step = self._steps
        s.block_ids = block_ids
        s.prompt_tokens = L
        s.cached_tokens = prefix_len
        self._next_token = self._next_token.at[slot, 0].set(first)
        if s.budget <= 0:
            self.results[request_id] = self._result(s, self._steps)
            self._release_slot(slot)
