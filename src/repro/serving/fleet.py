"""Fleet-vectorized device data plane: decode a whole cohort in one dispatch.

``ClusterServer`` used to advance N ``LLMEngine``s in a Python loop — one
device dispatch (or fused ``step_n`` chunk) per engine per tick, the hard
ceiling on simulated fleet scale. This module stacks per-engine device state
(decode caches, slot next-token arrays) into a single pytree with a leading
**node axis** and ``vmap``s the fused decode chunk of PR 4 over that axis, so
every engine in a **cohort** — engines sharing an identical
``(ModelConfig, EngineConfig, params)`` triple — advances in ONE jitted
dispatch per chunk with ONE stacked ``(member, n, 3, B)`` host transfer.

Split of responsibilities (the host/device contract):

* **device data plane (here)** — ``FleetState`` (stacked ``lm.Cache`` +
  next-token array), ``decode_chunk_body`` (the un-jitted scan shared with
  ``engine._decode_chunk``), and the module-level ``_cohort_decode_chunk``
  jit keyed on the shared static config. A dispatch gathers only the
  **participating** members — their rows are indexed out inside the jit at
  a power-of-two-padded participant count (the PR 4 bucketing idiom, so the
  drain tail of a replay costs O(participants), not O(members)) — runs the
  vmapped chunk on that sub-fleet, and scatters the survivors back; a
  skipped member's device state never advances.
* **host control plane (``engine.LLMEngine``)** — admission, continuous
  batching, prefix-cache matching and result accounting are unchanged; a
  fleet-adopted engine simply reads and writes its device state through a
  member view into the stacked arrays (``FleetMemberStore``). The view is
  **write-back**: reads gather the member's slice once per dispatch epoch
  (one jitted call), writes land host-side and are flushed into the stacked
  pytree at most once per member per dispatch — an admission no longer pays
  a whole-fleet copy per slot write.

Byte-identity: a cohort dispatch runs ``n_f = max`` over the participating
members' clipped chunk lengths, but each member commits only its own
``n_eff`` iterations host-side — device state past a member's ``n_eff``
touches only slots that are already dead (all ops are row-independent for
the no-MoE patterns ``LLMEngine.fleet_ok`` admits), and admission rewrites a
slot's rows wholesale, so fleet stepping reproduces per-engine ``step()`` /
``step_n()`` bit-for-bit. ``tests/test_fleet.py`` enforces this across every
registered routing policy, disaggregated KV handoffs and node failures.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig
from .kvcache import FleetKVPools


class FleetState(NamedTuple):
    """Stacked device state for one cohort: every leaf carries a leading
    member (node) axis."""

    cache: object          # lm.Cache, leaves (M, ...)
    next_token: jnp.ndarray  # (M, B, 1)


def decode_chunk_body(params, cfg: ModelConfig, tok, cache, budget, alive,
                      n: int, eos: int):
    """``n`` fused decode iterations with device-side retirement (un-jitted).

    The single source of truth for the chunk state evolution: jitted
    per-engine as ``engine._decode_chunk`` and vmapped over the member axis
    by ``_cohort_decode_chunk``. Mirrors ``LLMEngine.step`` exactly: every
    iteration decodes all slots, budgets decrement for live slots, a live
    slot retires on exhausted budget or EOS (its ``kv_len`` zeroes and its
    next token resets, exactly like ``_release_slot``), and already-dead
    slots keep decoding garbage that nothing reads. Emits one stacked
    (n, 3, B) int32 tensor (token, emitted-this-iter, retired-this-iter)."""

    def body(carry, _):
        tok, cache, budget, alive = carry
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        emit = alive
        budget = budget - alive.astype(jnp.int32)
        retire = alive & ((budget <= 0) | (nxt == eos))
        alive = alive & ~retire
        cache = cache._replace(kv_len=jnp.where(retire, 0, cache.kv_len))
        tok = jnp.where(retire, 0, nxt)[:, None]
        out = jnp.stack([nxt, emit.astype(jnp.int32),
                         retire.astype(jnp.int32)])
        return (tok, cache, budget, alive), out

    (tok, cache, budget, alive), outs = jax.lax.scan(
        body, (tok, cache, budget, alive), None, length=n)
    return tok, cache, outs


@functools.partial(jax.jit, static_argnames=("cfg", "n", "eos"))
def _cohort_decode_chunk(params, cfg: ModelConfig, state: FleetState,
                         budget, alive, idx, valid, n: int, eos: int):
    """One dispatch for a cohort's participating members: gather the rows
    named by ``idx`` out of the stacked state, ``decode_chunk_body`` vmapped
    over that sub-fleet, scatter the valid rows back.

    ``budget``/``alive`` are (K, B) for the K-row participant bucket;
    ``idx`` is (K,) **unique** member rows (participants first, padded to a
    power of two with distinct idle members so the scatter stays
    deterministic) and ``valid`` the (K,) mask of real participants —
    padding rows write their gathered pre-dispatch values straight back, so
    only participants advance. Keyed on the shared static ``(cfg, n, eos)``
    — every cohort with the same model identity, member count and bucket
    size reuses one executable."""
    sub = jax.tree.map(lambda a: a[idx], state)
    tok, cache, outs = jax.vmap(
        lambda t, c, b, a: decode_chunk_body(params, cfg, t, c, b, a, n, eos),
        in_axes=(0, 0, 0, 0))(sub.next_token, sub.cache, budget, alive)

    def merge(full, new, old):
        mask = valid.reshape(valid.shape + (1,) * (new.ndim - 1))
        return full.at[idx].set(jnp.where(mask, new, old))

    new_state = FleetState(
        cache=jax.tree.map(merge, state.cache, cache, sub.cache),
        next_token=merge(state.next_token, tok, sub.next_token))
    return new_state, outs


@jax.jit
def _member_gather(state: FleetState, m):
    """One member's slice of the stacked state — ONE jitted call for the
    whole pytree (an eager per-leaf gather costs ~a millisecond of Python
    per read on the admission hot path)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, m, 0, keepdims=False),
        state)


@jax.jit
def _member_scatter(state: FleetState, local: FleetState, m):
    """Write one member's slice back into the stacked state (one jitted
    call; traced member index, so every member shares one executable)."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_index_in_dim(
            full, one, m, 0), state, local)


class FleetMemberStore:
    """One engine's **write-back** view into a cohort's stacked device state.

    Drop-in replacement for the engine-local store. Reads gather the
    member's slice once per dispatch epoch (one jitted call) and serve
    repeats from the host-held local copy; writes land in the local copy and
    mark the member dirty — the cohort flushes every dirty member into the
    stacked pytree right before its next dispatch (``Cohort._flush``), so an
    admission's slot writes cost O(member slice), not O(whole fleet). The
    control plane keeps its exact per-engine semantics while the
    authoritative bytes live in the fleet pytree."""

    def __init__(self, cohort: "Cohort", member: int):
        self._cohort = cohort
        self._member = member
        self._local: Optional[FleetState] = None   # member slice, write-back
        self._epoch = -1

    def _fresh(self) -> FleetState:
        c = self._cohort
        if self._local is None or self._epoch != c.epoch:
            self._local = _member_gather(c.state, jnp.int32(self._member))
            self._epoch = c.epoch
        return self._local

    @property
    def cache(self):
        return self._fresh().cache

    @cache.setter
    def cache(self, value):
        self._local = self._fresh()._replace(cache=value)
        self._cohort._dirty.add(self._member)

    @property
    def next_token(self):
        return self._fresh().next_token

    @next_token.setter
    def next_token(self, value):
        self._local = self._fresh()._replace(next_token=value)
        self._cohort._dirty.add(self._member)


class ChunkWork(NamedTuple):
    """One member's share of a decode chunk, ready for host commit."""

    outs: np.ndarray     # (n_f, 3, B) host array (token, emitted, retired)
    n_eff: int           # iterations this member actually commits
    active: Sequence[int]  # slots active at dispatch time


class CohortCounters:
    """Vectorized per-member fleet counters (numpy, host-side).

    ``active``/``queued`` mirror each member engine's slot/queue occupancy
    (synced by the engine on every mutation), so ``ClusterServer`` can
    aggregate load without a Python loop over engines. ``emitted``/
    ``retired`` accumulate from the stacked chunk outputs — one vectorized
    sum per dispatch, no per-engine host pulls."""

    def __init__(self, n_members: int):
        self.active = np.zeros(n_members, np.int64)
        self.queued = np.zeros(n_members, np.int64)
        self.emitted = np.zeros(n_members, np.int64)
        self.retired = np.zeros(n_members, np.int64)
        self.dispatches = 0


class CohortDispatch(NamedTuple):
    """Result of one cohort decode dispatch."""

    work: Dict[int, ChunkWork]   # member -> commit work (empty: no dispatch)
    emitted: np.ndarray          # (M,) tokens emitted this chunk, per member
    retired: np.ndarray          # (M,) slots retired this chunk, per member

    @property
    def participants(self) -> int:
        """Members that rode this stacked dispatch (0 = no dispatch ran) —
        telemetry reads it host-side, no extra device sync."""
        return len(self.work)


class Cohort:
    """A group of engines sharing one (ModelConfig, EngineConfig, params)
    identity whose device state is stacked into a single ``FleetState``.

    Adoption re-homes each engine's decode cache, next-token array and (when
    paged prefix reuse is on) K/V pools into stacked arrays with a leading
    member axis; the engines keep operating on views (``FleetMemberStore``).
    ``dispatch`` advances every participating member in one jitted call."""

    def __init__(self, engines: Sequence):
        assert engines, "a cohort needs at least one engine"
        e0 = engines[0]
        self.cfg = e0.cfg
        self.ecfg = e0.ecfg
        self.params = e0.params
        for e in engines[1:]:
            assert e.cfg == self.cfg and e.ecfg == self.ecfg, \
                "cohort members must share (ModelConfig, EngineConfig)"
            assert e.params is self.params, \
                "cohort members must share one params pytree"
            assert e.fleet_ok, "engine pattern is not fleet-vectorizable"
        self.members = list(engines)
        M = len(self.members)
        stack = lambda *xs: jnp.stack(xs)
        self.state = FleetState(
            cache=jax.tree.map(stack, *[e.cache for e in self.members]),
            next_token=jnp.stack([e._next_token for e in self.members]))
        self.kv_pools: Optional[FleetKVPools] = None
        if self.ecfg.prefix_cache and all(e.kv is not None
                                          for e in self.members):
            self.kv_pools = FleetKVPools.stack([e.kv for e in self.members])
        self.counters = CohortCounters(M)
        self.host_syncs = 0   # one stacked device->host transfer per dispatch
        self.epoch = 0        # bumps per dispatch: invalidates member views
        self._dirty: set = set()   # members with unflushed local writes
        for m, eng in enumerate(self.members):
            eng._attach_fleet(self, m)

    def __len__(self) -> int:
        return len(self.members)

    def _flush(self) -> None:
        """Write every dirty member's local slice into the stacked state —
        at most one jitted scatter per member per dispatch, however many
        slot writes its admissions made since the last one."""
        for m in sorted(self._dirty):
            self.state = _member_scatter(self.state,
                                         self.members[m]._store._local,
                                         jnp.int32(m))
        self._dirty.clear()

    def dispatch(self, n: int, eligible: Sequence[int]) -> CohortDispatch:
        """One vmapped decode chunk for every participating member.

        ``eligible`` pre-filters members (the scheduler drops crashed
        nodes); participation additionally requires active slots and — for
        ``n > 1``, mirroring ``step_n``'s fallback — an empty admission
        queue, so chunking never skips a mid-chunk admission a per-engine
        ``step()`` would have run. The participant rows are gathered inside
        the jit at a power-of-two-padded bucket size, so a near-idle tick
        (the drain tail of a replay) costs O(participants) decode compute,
        not O(members). Returns per-member ``ChunkWork`` for the host commit
        plus vectorized emit/retire counts straight off the stacked
        (bucket, n, 3, B) output — the single transfer for the whole
        cohort."""
        n = max(int(n), 1)
        work_slots: Dict[int, List[int]] = {}
        for m in eligible:
            eng = self.members[m]
            if n > 1 and eng.queue:
                continue   # step_n would fall back: keep per-engine semantics
            active = [i for i, s in enumerate(eng.slots)
                      if s.request_id is not None]
            if active:
                work_slots[m] = active
        M = len(self.members)
        zero = np.zeros(M, np.int64)
        if not work_slots:
            return CohortDispatch({}, zero, zero)
        self._flush()
        B = self.ecfg.max_slots
        parts = sorted(work_slots)
        k = len(parts)
        k_b = min(1 << (k - 1).bit_length(), M)   # pow2 bucket, capped at M
        pads = [m for m in range(M) if m not in work_slots][:k_b - k]
        idx = np.asarray(parts + pads, np.int32)
        valid = np.zeros(k_b, bool)
        valid[:k] = True
        budgets = np.zeros((k_b, B), np.int32)
        alive = np.zeros((k_b, B), bool)
        n_eff: Dict[int, int] = {}
        for r, m in enumerate(parts):
            for i in work_slots[m]:
                s = self.members[m].slots[i]
                budgets[r, i] = s.budget
                alive[r, i] = True
            n_eff[m] = min(n, int(budgets[r, alive[r]].max()))
        n_f = max(n_eff.values())
        self.state, outs = _cohort_decode_chunk(
            self.params, self.cfg, self.state, jnp.asarray(budgets),
            jnp.asarray(alive), jnp.asarray(idx), jnp.asarray(valid), n_f,
            self.ecfg.eos_token)
        self.epoch += 1
        # non-participants' stacked rows are untouched: their (flushed)
        # local views stay valid across the epoch bump, so an idle member
        # never re-gathers; participants re-gather lazily on next read
        changed = set(parts)
        for m, eng in enumerate(self.members):
            st = eng._store
            if m not in changed and st._local is not None \
                    and st._epoch == self.epoch - 1:
                st._epoch = self.epoch
        outs_np = np.asarray(outs)        # ONE transfer for the whole cohort
        self.host_syncs += 1
        self.counters.dispatches += 1
        # fleet counters straight from the stacked emit/retire masks: rows
        # past a member's n_eff are all-dead (emit == retire == 0), padding
        # rows all-idle, so the vectorized sum is exact
        emitted = np.zeros(M, np.int64)
        retired = np.zeros(M, np.int64)
        emitted[parts] = outs_np[:k, :, 1, :].sum(axis=(1, 2))
        retired[parts] = outs_np[:k, :, 2, :].sum(axis=(1, 2))
        self.counters.emitted += emitted
        self.counters.retired += retired
        work = {m: ChunkWork(outs=outs_np[r], n_eff=n_eff[m],
                             active=tuple(work_slots[m]))
                for r, m in enumerate(parts)}
        return CohortDispatch(work, emitted, retired)


def build_cohorts(engines: Dict[int, object]):
    """Group engines into cohorts by shared (ModelConfig, EngineConfig,
    params-identity); non-vectorizable engines (MoE patterns) stay loose.

    Returns ``(cohorts, cohort_pairs, pair_to_cohort)`` where
    ``cohort_pairs[c]`` lists the pair ids of cohort ``c`` in pair order and
    ``pair_to_cohort`` maps pair id -> (cohort index, member index)."""
    groups: Dict[tuple, List[int]] = {}
    for pair in sorted(engines):
        eng = engines[pair]
        if not eng.fleet_ok:
            continue
        key = (eng.cfg, eng.ecfg, id(eng.params))
        groups.setdefault(key, []).append(pair)
    cohorts: List[Cohort] = []
    cohort_pairs: List[List[int]] = []
    pair_to_cohort: Dict[int, tuple] = {}
    for pairs in groups.values():
        c = len(cohorts)
        cohorts.append(Cohort([engines[p] for p in pairs]))
        cohort_pairs.append(pairs)
        for m, p in enumerate(pairs):
            pair_to_cohort[p] = (c, m)
    return cohorts, cohort_pairs, pair_to_cohort
