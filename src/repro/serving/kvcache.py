"""Paged KV-cache with prefix reuse (vLLM-style, adapted to this engine).

Production LLM serving wins most of its prefill latency/cost budget by
reusing the KV of shared prompt prefixes (system prompts, earlier turns of a
conversation). This module is the serving-state subsystem that makes that
possible here, in three layers:

* :class:`BlockPool` — fixed-size token blocks with a free list, per-block
  reference counts (blocks are *shared* between slots that extend the same
  prefix) and LRU eviction of unreferenced cached blocks. Invariants (all
  property-tested): ``free + allocated == capacity``, a refcount never goes
  negative, and eviction never frees a referenced block.
* :class:`RadixIndex` — a radix/trie over block-granular token chunks mapping
  token prefixes to cached block ids (the lookup structure behind
  ``lmcache``/vLLM production-stack prefix-aware routing). Only leaf blocks
  are evictable, so a cached prefix never dangles mid-path.
* :class:`PagedKVStore` — the physical store one :class:`~.engine.LLMEngine`
  owns: per-pattern-position K/V pool tensors of shape
  ``(n_periods, n_blocks, block_size, n_kv_heads, head_dim)`` plus the
  logical :class:`PagedKVCache`. ``gather`` reads a matched prefix back as
  the contiguous ``(P, 1, S, H, D)`` view ``models.lm.prefill_extend``
  consumes; ``scatter`` writes freshly prefilled blocks into the pool.

Reuse is **exact**: K/V at position *j* depend only on tokens ``<= j``
(causal attention, absolute RoPE), so a cached prefix block is bitwise
identical to what a full prefill of the longer prompt would have computed —
the engine-level test asserts byte-identical output tokens against the
contiguous non-caching engine.

On TPU, decode over pool-resident pages uses the block-table-gathering
Pallas kernel (``kernels.paged_attention``); this engine gathers the prefix
into the slot's contiguous decode cache at admission, which keeps the jitted
``decode_step`` unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig


class BlockPool:
    """Fixed-capacity pool of KV blocks with ref-counted sharing + LRU.

    A block is in exactly one of three states:

    * **free** — on the free list, content meaningless;
    * **active** — ``ref > 0``; pinned by one or more engine slots;
    * **evictable** — ``ref == 0`` but still indexed by the radix tree;
      kept in LRU order and reclaimed when the free list runs dry.
    """

    def __init__(self, n_blocks: int):
        assert n_blocks > 0
        self.n_blocks = n_blocks
        self.free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.ref = np.zeros(n_blocks, np.int32)
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # evictable blocks

    # -- accounting ---------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_evictable(self) -> int:
        return len(self.lru)

    def check_invariants(self) -> None:
        active = int(np.sum(self.ref > 0))
        assert np.all(self.ref >= 0), "negative refcount"
        assert active + self.n_free + self.n_evictable == self.n_blocks, (
            active, self.n_free, self.n_evictable, self.n_blocks)
        assert all(self.ref[b] == 0 for b in self.lru), \
            "referenced block on the LRU list"

    # -- state transitions --------------------------------------------------
    def take_free(self) -> Optional[int]:
        """Pop a free block with ``ref = 1`` (no eviction attempted)."""
        if not self.free:
            return None
        b = self.free.pop()
        self.ref[b] = 1
        return b

    def acquire(self, block: int) -> None:
        """Pin a cached block (a slot starts sharing it)."""
        self.ref[block] += 1
        self.lru.pop(block, None)

    def release(self, block: int, cached: bool) -> None:
        """Unpin; an unreferenced block becomes evictable (if the radix index
        still maps to it) or free (if it was never / no longer cached)."""
        assert self.ref[block] > 0, f"release of unreferenced block {block}"
        self.ref[block] -= 1
        if self.ref[block] == 0:
            if cached:
                self.lru[block] = None
                self.lru.move_to_end(block)
            else:
                self.free.append(block)

    def touch(self, block: int) -> None:
        """LRU bump on a cache hit of an evictable block."""
        if block in self.lru:
            self.lru.move_to_end(block)

    def pop_evictable(self, can_evict) -> Optional[int]:
        """Reclaim the least-recently-used evictable block accepted by
        ``can_evict`` (the radix index only admits leaves). Returns the block
        id with ``ref = 1``, or None if nothing qualifies."""
        for b in self.lru:
            if can_evict(b):
                del self.lru[b]
                self.ref[b] = 1
                return b
        return None


class _TrieNode:
    __slots__ = ("children", "parent", "key", "block")

    def __init__(self, parent: Optional["_TrieNode"] = None,
                 key: Optional[Tuple[int, ...]] = None, block: int = -1):
        self.children: Dict[Tuple[int, ...], _TrieNode] = {}
        self.parent = parent
        self.key = key
        self.block = block


class RadixIndex:
    """Radix tree over block-granular token chunks -> cached block ids.

    Keys are the *token contents* of one block (a ``block_size`` tuple), so
    two prompts share a path exactly as far as their token streams agree in
    whole blocks — the longest-cached-prefix query of vLLM's prefix caching.
    """

    def __init__(self, block_size: int):
        assert block_size > 0
        self.block_size = block_size
        self.root = _TrieNode()
        self._by_block: Dict[int, _TrieNode] = {}

    def _chunks(self, tokens: Sequence[int]):
        bs = self.block_size
        for i in range(0, (len(tokens) // bs) * bs, bs):
            yield tuple(int(t) for t in tokens[i:i + bs])

    @property
    def n_blocks(self) -> int:
        return len(self._by_block)

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Block ids of the longest cached whole-block prefix of ``tokens``."""
        node = self.root
        out: List[int] = []
        for chunk in self._chunks(tokens):
            node = node.children.get(chunk)
            if node is None:
                break
            out.append(node.block)
        return out

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> List[int]:
        """Index the whole-block prefix of ``tokens`` as ``blocks``.

        Existing path nodes keep their canonical block (a racing duplicate
        block stays unindexed and returns to the free list on release).
        Returns the block ids that were newly indexed.
        """
        node = self.root
        added: List[int] = []
        for chunk, blk in zip(self._chunks(tokens), blocks):
            child = node.children.get(chunk)
            if child is None:
                child = _TrieNode(parent=node, key=chunk, block=int(blk))
                node.children[chunk] = child
                self._by_block[int(blk)] = child
                added.append(int(blk))
            node = child
        return added

    def has_block(self, block: int) -> bool:
        return block in self._by_block

    def is_evictable(self, block: int) -> bool:
        """Only leaves may be evicted — an interior block is on the lookup
        path of every cached descendant."""
        node = self._by_block.get(block)
        return node is not None and not node.children

    def remove(self, block: int) -> None:
        node = self._by_block.pop(block)
        assert not node.children, "evicting an interior radix node"
        del node.parent.children[node.key]


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0              # lookups that matched >= 1 block
    hit_tokens: int = 0        # tokens served from cache
    prefill_tokens_total: int = 0
    prefill_tokens_run: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hits / self.lookups if self.lookups else 0.0
        d["token_hit_rate"] = (self.hit_tokens / self.prefill_tokens_total
                               if self.prefill_tokens_total else 0.0)
        return d


class PagedKVCache:
    """Logical pool + index pair: the allocation protocol the engine drives.

    Lifecycle per admitted request: ``match`` -> ``acquire`` matched blocks ->
    prefill the suffix -> ``allocate`` blocks for new whole-block suffix
    chunks -> ``commit`` the prefix into the index -> (at retire/cancel)
    ``release`` the slot's block table.
    """

    def __init__(self, n_blocks: int, block_size: int):
        self.pool = BlockPool(n_blocks)
        self.index = RadixIndex(block_size)
        self.block_size = block_size
        self.stats = CacheStats()

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest cached whole-block prefix, capped so at least one token is
        left to prefill (the model needs >= 1 suffix token for logits)."""
        blocks = self.index.match(tokens)
        while blocks and len(blocks) * self.block_size >= len(tokens):
            blocks = blocks[:-1]
        self.stats.lookups += 1
        if blocks:
            self.stats.hits += 1
            self.stats.hit_tokens += len(blocks) * self.block_size
        return blocks

    def acquire(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.pool.acquire(b)

    def allocate(self) -> Optional[int]:
        """One fresh block (ref = 1), evicting an LRU leaf if needed."""
        b = self.pool.take_free()
        if b is not None:
            return b
        b = self.pool.pop_evictable(self.index.is_evictable)
        if b is None:
            return None
        self.index.remove(b)
        self.stats.evictions += 1
        return b

    def commit(self, tokens: Sequence[int], blocks: Sequence[int]) -> None:
        self.index.insert(tokens, blocks)

    def release(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.pool.release(b, cached=self.index.has_block(b))

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        for b in list(self.pool.lru):
            assert self.index.has_block(b), \
                "evictable block missing from the radix index"


class PagedKVStore:
    """Physical paged K/V tensors for one engine (+ the logical cache).

    One ``(k, v)`` pool pair per block-pattern position, each of shape
    ``(n_periods, n_blocks, block_size, n_kv_heads, head_dim)``. Only
    pure-attention patterns page their KV (recurrent state is per-slot and
    tiny); the engine gates paged mode accordingly.
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int):
        assert all(mixer == "attn" for mixer, _ in cfg.pattern), \
            "paged KV supports pure-attention block patterns"
        assert cfg.encoder is None and cfg.family not in ("audio", "vlm")
        self.cfg = cfg
        self.cache = PagedKVCache(n_blocks, block_size)
        self.block_size = block_size
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        shp = (cfg.n_periods, n_blocks, block_size, cfg.n_kv_heads, cfg.hd)
        self._local_pools: Optional[List[Tuple[jnp.ndarray, jnp.ndarray]]] = \
            [(jnp.zeros(shp, dt), jnp.zeros(shp, dt)) for _ in cfg.pattern]
        # fleet adoption (serving.fleet): when set, the physical bytes live
        # in a cohort-wide FleetKVPools slab and this store is a member view
        self._fleet: Optional["FleetKVPools"] = None
        self._member = 0

    @property
    def pools(self) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Per-pattern-position (k, v) pools for THIS store — local arrays,
        or this member's slice of the cohort's node-axis stacked slab."""
        if self._fleet is None:
            return self._local_pools
        m = self._member
        return [(k[m], v[m]) for k, v in self._fleet.pools]

    def attach(self, fleet: "FleetKVPools", member: int,
               copy: bool = True) -> None:
        """Re-home this store's physical bytes into slot ``member`` of a
        cohort slab. ``copy=True`` writes the current local pool contents
        into the slab (a fresh/flushed store zeroes its slot); the stacking
        constructor passes ``copy=False`` because the slab was built from
        the members' pools directly. The logical cache (block pool, radix
        index, refcounts) stays per-engine — only the bytes are stacked."""
        if copy:
            for pos, (k, v) in enumerate(self._local_pools):
                fk, fv = fleet.pools[pos]
                fleet.pools[pos] = (fk.at[member].set(k),
                                    fv.at[member].set(v))
        self._fleet, self._member = fleet, member
        self._local_pools = None

    def _update_pool(self, pos: int, ids, k_slab, v_slab) -> None:
        """Write ``(P, n, bs, H, D)`` slabs at physical block ids ``ids`` —
        one batched index update on the local pool, or on this member's row
        of the fleet slab."""
        if self._fleet is None:
            k, v = self._local_pools[pos]
            self._local_pools[pos] = (k.at[:, ids].set(k_slab),
                                      v.at[:, ids].set(v_slab))
        else:
            # mixed scalar+slice+array indexing moves the block axis first:
            # fk[m, :, ids] has shape (n, P, bs, H, D), so swap the slab's
            # (P, n, ...) leading axes to match
            m = self._member
            fk, fv = self._fleet.pools[pos]
            self._fleet.pools[pos] = (
                fk.at[m, :, ids].set(jnp.moveaxis(k_slab, 1, 0)),
                fv.at[m, :, ids].set(jnp.moveaxis(v_slab, 1, 0)))

    def gather(self, blocks: Sequence[int], pad_to: Optional[int] = None):
        """Prefix K/V for ``models.lm.prefill_extend``: tuple over pattern
        positions of (k, v), each ``(P, 1, len(blocks)*bs, H, D)``.

        ``pad_to`` pads the block list to a fixed count with block 0 (the
        compile-once admission path: every gather then has the same static
        shape; the garbage tail rows are masked out by the dynamic
        ``prefix_len`` in the bucketed ``prefill_extend``)."""
        ids_list = list(blocks)
        if pad_to is not None:
            assert pad_to >= len(ids_list)
            ids_list = ids_list + [0] * (pad_to - len(ids_list))
        ids = jnp.asarray(ids_list, jnp.int32)
        out = []
        for k_pool, v_pool in self.pools:
            def view(pool):
                g = jnp.take(pool, ids, axis=1)       # (P, m, bs, H, D)
                P, m, bs, H, D = g.shape
                return g.reshape(P, 1, m * bs, H, D)
            out.append((view(k_pool), view(v_pool)))
        return tuple(out)

    def export_blocks(self, blocks: Sequence[int]):
        """Host copy of the K/V contents of ``blocks`` — the payload of a
        disaggregated prefill→decode KV handoff. Returns one ``(k, v)`` slab
        pair per pattern position, each ``(P, len(blocks), bs, H, D)``; the
        copy is taken eagerly so the transfer survives the source pool
        mutating (or the source node dying) while the payload is in flight."""
        ids = jnp.asarray(list(blocks), jnp.int32)
        out: List[Tuple[np.ndarray, np.ndarray]] = []
        for k_pool, v_pool in self.pools:
            out.append((np.asarray(jnp.take(k_pool, ids, axis=1)),
                        np.asarray(jnp.take(v_pool, ids, axis=1))))
        return out

    def import_blocks(self, blocks: Sequence[int], slabs):
        """Write slabs from :meth:`export_blocks` into this pool at physical
        ids ``blocks`` (the decode-side half of a KV handoff) — one batched
        index update per pool, mirroring :meth:`scatter`."""
        ids = jnp.asarray(list(blocks), jnp.int32)
        dt = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        for pos, (k_slab, v_slab) in enumerate(slabs):
            self._update_pool(pos, ids, jnp.asarray(k_slab, dt),
                              jnp.asarray(v_slab, dt))

    def scatter(self, blocks: Sequence[int], start_block: int, layer_cache):
        """Write whole blocks ``start_block..`` of a single-request prefill
        cache (tuple over positions of (k, v) ``(P, 1, Smax, H, D)``) into
        the pool at physical ids ``blocks`` — one batched index update per
        pool (a per-block ``.at[].set`` would copy the whole pool once per
        block on the admission hot path)."""
        bs = self.block_size
        n = len(blocks)
        ids = jnp.asarray(list(blocks), jnp.int32)
        lo = start_block * bs

        def slab(full):
            seg = full[:, 0, lo:lo + n * bs]          # (P, n*bs, H, D)
            P, _, H, D = seg.shape
            return seg.reshape(P, n, bs, H, D)

        for pos, (k_full, v_full) in enumerate(layer_cache):
            self._update_pool(pos, ids, slab(k_full), slab(v_full))


class FleetKVPools:
    """Node-axis stacked K/V pools shared by a fleet cohort.

    One ``(k, v)`` pair per pattern position, each of shape
    ``(n_members, n_periods, n_blocks, block_size, n_kv_heads, head_dim)`` —
    the fleet-stacked counterpart of :class:`PagedKVStore.pools`. Block
    allocation, refcounts and the radix index stay per-engine (host
    control-plane state); only the physical bytes are stacked, and every
    member store reads/writes its own leading-axis slice, so export/import
    stay unchanged at the block level."""

    def __init__(self, cfg: ModelConfig, n_members: int, n_blocks: int,
                 block_size: int):
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        shp = (n_members, cfg.n_periods, n_blocks, block_size,
               cfg.n_kv_heads, cfg.hd)
        self.pools: List[Tuple[jnp.ndarray, jnp.ndarray]] = [
            (jnp.zeros(shp, dt), jnp.zeros(shp, dt)) for _ in cfg.pattern]

    @classmethod
    def stack(cls, stores: Sequence[PagedKVStore]) -> "FleetKVPools":
        """Stack member stores' pools into one slab and re-home every store
        onto its slice (adoption path — no extra copy beyond the stack)."""
        self = cls.__new__(cls)
        self.pools = [
            (jnp.stack([s.pools[pos][0] for s in stores]),
             jnp.stack([s.pools[pos][1] for s in stores]))
            for pos in range(len(stores[0].pools))]
        for m, s in enumerate(stores):
            s.attach(self, m, copy=False)
        return self
