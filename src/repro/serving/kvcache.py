"""Paged KV-cache with prefix reuse (vLLM-style, adapted to this engine).

Production LLM serving wins most of its prefill latency/cost budget by
reusing the KV of shared prompt prefixes (system prompts, earlier turns of a
conversation). This module is the serving-state subsystem that makes that
possible here, in three layers:

* :class:`BlockPool` — fixed-size token blocks with a free list, per-block
  reference counts (blocks are *shared* between slots that extend the same
  prefix) and LRU eviction of unreferenced cached blocks. Invariants (all
  property-tested): ``free + allocated == capacity``, a refcount never goes
  negative, and eviction never frees a referenced block.
* :class:`RadixIndex` — a radix/trie over block-granular token chunks mapping
  token prefixes to cached block ids (the lookup structure behind
  ``lmcache``/vLLM production-stack prefix-aware routing). Only leaf blocks
  are evictable, so a cached prefix never dangles mid-path.
* :class:`PagedKVStore` — the physical store one :class:`~.engine.LLMEngine`
  owns: per-pattern-position K/V pool tensors of shape
  ``(n_periods, n_blocks, block_size, n_kv_heads, head_dim)`` plus the
  logical :class:`PagedKVCache`. ``gather`` reads a matched prefix back as
  the contiguous ``(P, 1, S, H, D)`` view ``models.lm.prefill_extend``
  consumes; ``scatter`` writes freshly prefilled blocks into the pool.

Reuse is **exact**: K/V at position *j* depend only on tokens ``<= j``
(causal attention, absolute RoPE), so a cached prefix block is bitwise
identical to what a full prefill of the longer prompt would have computed —
the engine-level test asserts byte-identical output tokens against the
contiguous non-caching engine.

On TPU, decode over pool-resident pages uses the block-table-gathering
Pallas kernel (``kernels.paged_attention``); this engine gathers the prefix
into the slot's contiguous decode cache at admission, which keeps the jitted
``decode_step`` unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig


class BlockPool:
    """Fixed-capacity pool of KV blocks with ref-counted sharing + LRU.

    A block is in exactly one of three states:

    * **free** — on the free list, content meaningless;
    * **active** — ``ref > 0``; pinned by one or more engine slots;
    * **evictable** — ``ref == 0`` but still indexed by the radix tree;
      kept in LRU order and reclaimed when the free list runs dry.
    """

    def __init__(self, n_blocks: int):
        assert n_blocks > 0
        self.n_blocks = n_blocks
        self.free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.ref = np.zeros(n_blocks, np.int32)
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # evictable blocks

    # -- accounting ---------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_evictable(self) -> int:
        return len(self.lru)

    def check_invariants(self) -> None:
        active = int(np.sum(self.ref > 0))
        assert np.all(self.ref >= 0), "negative refcount"
        assert active + self.n_free + self.n_evictable == self.n_blocks, (
            active, self.n_free, self.n_evictable, self.n_blocks)
        assert all(self.ref[b] == 0 for b in self.lru), \
            "referenced block on the LRU list"

    # -- state transitions --------------------------------------------------
    def take_free(self) -> Optional[int]:
        """Pop a free block with ``ref = 1`` (no eviction attempted)."""
        if not self.free:
            return None
        b = self.free.pop()
        self.ref[b] = 1
        return b

    def acquire(self, block: int) -> None:
        """Pin a cached block (a slot starts sharing it)."""
        self.ref[block] += 1
        self.lru.pop(block, None)

    def release(self, block: int, cached: bool) -> None:
        """Unpin; an unreferenced block becomes evictable (if the radix index
        still maps to it) or free (if it was never / no longer cached)."""
        assert self.ref[block] > 0, f"release of unreferenced block {block}"
        self.ref[block] -= 1
        if self.ref[block] == 0:
            if cached:
                self.lru[block] = None
                self.lru.move_to_end(block)
            else:
                self.free.append(block)

    def touch(self, block: int) -> None:
        """LRU bump on a cache hit of an evictable block."""
        if block in self.lru:
            self.lru.move_to_end(block)

    def pop_evictable(self, can_evict) -> Optional[int]:
        """Reclaim the least-recently-used evictable block accepted by
        ``can_evict`` (the radix index only admits leaves). Returns the block
        id with ``ref = 1``, or None if nothing qualifies."""
        for b in self.lru:
            if can_evict(b):
                del self.lru[b]
                self.ref[b] = 1
                return b
        return None


class _TrieNode:
    __slots__ = ("children", "parent", "key", "block")

    def __init__(self, parent: Optional["_TrieNode"] = None,
                 key: Optional[Tuple[int, ...]] = None, block: int = -1):
        self.children: Dict[Tuple[int, ...], _TrieNode] = {}
        self.parent = parent
        self.key = key
        self.block = block


class RadixIndex:
    """Radix tree over block-granular token chunks -> cached block ids.

    Keys are the *token contents* of one block (a ``block_size`` tuple), so
    two prompts share a path exactly as far as their token streams agree in
    whole blocks — the longest-cached-prefix query of vLLM's prefix caching.
    """

    def __init__(self, block_size: int):
        assert block_size > 0
        self.block_size = block_size
        self.root = _TrieNode()
        self._by_block: Dict[int, _TrieNode] = {}

    def _chunks(self, tokens: Sequence[int]):
        bs = self.block_size
        for i in range(0, (len(tokens) // bs) * bs, bs):
            yield tuple(int(t) for t in tokens[i:i + bs])

    @property
    def n_blocks(self) -> int:
        return len(self._by_block)

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Block ids of the longest cached whole-block prefix of ``tokens``."""
        node = self.root
        out: List[int] = []
        for chunk in self._chunks(tokens):
            node = node.children.get(chunk)
            if node is None:
                break
            out.append(node.block)
        return out

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> List[int]:
        """Index the whole-block prefix of ``tokens`` as ``blocks``.

        Existing path nodes keep their canonical block (a racing duplicate
        block stays unindexed and returns to the free list on release).
        Returns the block ids that were newly indexed.
        """
        node = self.root
        added: List[int] = []
        for chunk, blk in zip(self._chunks(tokens), blocks):
            child = node.children.get(chunk)
            if child is None:
                child = _TrieNode(parent=node, key=chunk, block=int(blk))
                node.children[chunk] = child
                self._by_block[int(blk)] = child
                added.append(int(blk))
            node = child
        return added

    def has_block(self, block: int) -> bool:
        return block in self._by_block

    def is_evictable(self, block: int) -> bool:
        """Only leaves may be evicted — an interior block is on the lookup
        path of every cached descendant."""
        node = self._by_block.get(block)
        return node is not None and not node.children

    def remove(self, block: int) -> None:
        node = self._by_block.pop(block)
        assert not node.children, "evicting an interior radix node"
        del node.parent.children[node.key]


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0              # lookups that matched >= 1 block
    hit_tokens: int = 0        # tokens served from cache
    prefill_tokens_total: int = 0
    prefill_tokens_run: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hits / self.lookups if self.lookups else 0.0
        d["token_hit_rate"] = (self.hit_tokens / self.prefill_tokens_total
                               if self.prefill_tokens_total else 0.0)
        return d


class PagedKVCache:
    """Logical pool + index pair: the allocation protocol the engine drives.

    Lifecycle per admitted request: ``match`` -> ``acquire`` matched blocks ->
    prefill the suffix -> ``allocate`` blocks for new whole-block suffix
    chunks -> ``commit`` the prefix into the index -> (at retire/cancel)
    ``release`` the slot's block table.
    """

    def __init__(self, n_blocks: int, block_size: int):
        self.pool = BlockPool(n_blocks)
        self.index = RadixIndex(block_size)
        self.block_size = block_size
        self.stats = CacheStats()

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest cached whole-block prefix, capped so at least one token is
        left to prefill (the model needs >= 1 suffix token for logits)."""
        blocks = self.index.match(tokens)
        while blocks and len(blocks) * self.block_size >= len(tokens):
            blocks = blocks[:-1]
        self.stats.lookups += 1
        if blocks:
            self.stats.hits += 1
            self.stats.hit_tokens += len(blocks) * self.block_size
        return blocks

    def acquire(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.pool.acquire(b)

    def allocate(self) -> Optional[int]:
        """One fresh block (ref = 1), evicting an LRU leaf if needed."""
        b = self.pool.take_free()
        if b is not None:
            return b
        b = self.pool.pop_evictable(self.index.is_evictable)
        if b is None:
            return None
        self.index.remove(b)
        self.stats.evictions += 1
        return b

    def commit(self, tokens: Sequence[int], blocks: Sequence[int]) -> None:
        self.index.insert(tokens, blocks)

    def release(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.pool.release(b, cached=self.index.has_block(b))

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        for b in list(self.pool.lru):
            assert self.index.has_block(b), \
                "evictable block missing from the radix index"


class PagedKVStore:
    """Physical paged K/V tensors for one engine (+ the logical cache).

    One ``(k, v)`` pool pair per block-pattern position, each of shape
    ``(n_periods, n_blocks, block_size, n_kv_heads, head_dim)``. Only
    pure-attention patterns page their KV (recurrent state is per-slot and
    tiny); the engine gates paged mode accordingly.
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int):
        assert all(mixer == "attn" for mixer, _ in cfg.pattern), \
            "paged KV supports pure-attention block patterns"
        assert cfg.encoder is None and cfg.family not in ("audio", "vlm")
        self.cfg = cfg
        self.cache = PagedKVCache(n_blocks, block_size)
        self.block_size = block_size
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        shp = (cfg.n_periods, n_blocks, block_size, cfg.n_kv_heads, cfg.hd)
        self.pools: List[Tuple[jnp.ndarray, jnp.ndarray]] = [
            (jnp.zeros(shp, dt), jnp.zeros(shp, dt)) for _ in cfg.pattern]

    def gather(self, blocks: Sequence[int], pad_to: Optional[int] = None):
        """Prefix K/V for ``models.lm.prefill_extend``: tuple over pattern
        positions of (k, v), each ``(P, 1, len(blocks)*bs, H, D)``.

        ``pad_to`` pads the block list to a fixed count with block 0 (the
        compile-once admission path: every gather then has the same static
        shape; the garbage tail rows are masked out by the dynamic
        ``prefix_len`` in the bucketed ``prefill_extend``)."""
        ids_list = list(blocks)
        if pad_to is not None:
            assert pad_to >= len(ids_list)
            ids_list = ids_list + [0] * (pad_to - len(ids_list))
        ids = jnp.asarray(ids_list, jnp.int32)
        out = []
        for k_pool, v_pool in self.pools:
            def view(pool):
                g = jnp.take(pool, ids, axis=1)       # (P, m, bs, H, D)
                P, m, bs, H, D = g.shape
                return g.reshape(P, 1, m * bs, H, D)
            out.append((view(k_pool), view(v_pool)))
        return tuple(out)

    def export_blocks(self, blocks: Sequence[int]):
        """Host copy of the K/V contents of ``blocks`` — the payload of a
        disaggregated prefill→decode KV handoff. Returns one ``(k, v)`` slab
        pair per pattern position, each ``(P, len(blocks), bs, H, D)``; the
        copy is taken eagerly so the transfer survives the source pool
        mutating (or the source node dying) while the payload is in flight."""
        ids = jnp.asarray(list(blocks), jnp.int32)
        out: List[Tuple[np.ndarray, np.ndarray]] = []
        for k_pool, v_pool in self.pools:
            out.append((np.asarray(jnp.take(k_pool, ids, axis=1)),
                        np.asarray(jnp.take(v_pool, ids, axis=1))))
        return out

    def import_blocks(self, blocks: Sequence[int], slabs):
        """Write slabs from :meth:`export_blocks` into this pool at physical
        ids ``blocks`` (the decode-side half of a KV handoff) — one batched
        index update per pool, mirroring :meth:`scatter`."""
        ids = jnp.asarray(list(blocks), jnp.int32)
        for pos, (k_slab, v_slab) in enumerate(slabs):
            k_pool, v_pool = self.pools[pos]
            self.pools[pos] = (
                k_pool.at[:, ids].set(jnp.asarray(k_slab, k_pool.dtype)),
                v_pool.at[:, ids].set(jnp.asarray(v_slab, v_pool.dtype)))

    def scatter(self, blocks: Sequence[int], start_block: int, layer_cache):
        """Write whole blocks ``start_block..`` of a single-request prefill
        cache (tuple over positions of (k, v) ``(P, 1, Smax, H, D)``) into
        the pool at physical ids ``blocks`` — one batched index update per
        pool (a per-block ``.at[].set`` would copy the whole pool once per
        block on the admission hot path)."""
        bs = self.block_size
        n = len(blocks)
        ids = jnp.asarray(list(blocks), jnp.int32)
        lo = start_block * bs

        def slab(full):
            seg = full[:, 0, lo:lo + n * bs]          # (P, n*bs, H, D)
            P, _, H, D = seg.shape
            return seg.reshape(P, n, bs, H, D)

        for pos, (k_full, v_full) in enumerate(layer_cache):
            k_pool, v_pool = self.pools[pos]
            self.pools[pos] = (k_pool.at[:, ids].set(slab(k_full)),
                               v_pool.at[:, ids].set(slab(v_full)))
