"""Cluster-level serving: NSGA-II-routed dispatch across real LLM engines.

``ClusterServer`` is the end-to-end data plane: it instantiates one
``LLMEngine`` per (node, model) pair of a ``ClusterSpec`` (with real JAX
models — the examples use reduced configs on CPU), routes each incoming
request through the paper's runtime router (Algorithm 2 + failover), and
drives all engines' continuous-batching loops. Beyond-paper fault tolerance:

* **node failure** — ``fail_node`` marks a node down; its in-flight requests
  are re-queued and re-routed; the monitor masks it from Algorithm 2 until
  ``recover_node``;
* **straggler hedging** — a request whose engine has run more than
  ``hedge_after`` iterations beyond the node's EWMA issues a duplicate on
  the router's backup pair; first completion wins, the loser is **cancelled**
  (``LLMEngine.cancel``) and its dispatch accounting closed via
  ``monitor.on_cancel`` — queue lengths drain back to zero, so hedging never
  skews later queue-based routing decisions.

The server keeps a simulated clock (``self.ticks``, one unit per ``step``)
and feeds it to every monitor call that takes a timestamp, so heartbeat /
sweep bookkeeping stays in scheduler time rather than leaking wall-clock
``time.monotonic()`` into simulated runs.
"""
from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..cluster.monitor import ClusterMonitor
from ..cluster.spec import ClusterSpec
from ..core.router import RequestRouter
from ..models import lm
from ..workload.datasets import Request
from ..workload.tokenizer import count_tokens
from .engine import EngineConfig, LLMEngine


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    req: Request
    max_new_tokens: int = 8


@dataclasses.dataclass
class _Flight:
    sreq: ServeRequest
    pair: int
    iters: int = 0
    hedge_pair: Optional[int] = None


class ClusterServer:
    def __init__(self, cluster: ClusterSpec, model_builders: Dict[str, tuple],
                 thresholds, engine_cfg: EngineConfig = EngineConfig(),
                 hedge_after: int = 64, vocab_cap: Optional[int] = None,
                 router_kwargs: Optional[dict] = None):
        """model_builders: model name -> (ModelConfig, params).
        router_kwargs: extra RequestRouter arguments (e.g.
        ``mode="affinity"`` for cache-affinity dispatch)."""
        self.cluster = cluster
        self.monitor = ClusterMonitor(len(cluster.nodes))
        self.router = RequestRouter(cluster, thresholds, monitor=self.monitor,
                                    **(router_kwargs or {}))
        self.engines: Dict[int, LLMEngine] = {}
        self.pair_model_cfg: Dict[int, object] = {}
        for p, (j, k) in enumerate(cluster.pairs()):
            name = cluster.models[k].name
            mcfg, params = model_builders[name]
            self.engines[p] = LLMEngine(mcfg, params, engine_cfg)
            self.pair_model_cfg[p] = mcfg
        self.inflight: Dict[int, _Flight] = {}
        self.done: Dict[int, dict] = {}
        self.hedge_after = hedge_after
        self._hedges = 0
        self._reroutes = 0
        self.ticks = 0   # simulated scheduler clock: one unit per step()

    # -- helpers ---------------------------------------------------------------
    def _tokenize(self, req: Request, vocab: int, cap: int = 24) -> np.ndarray:
        """Deterministic, **prefix-stable** word-level tokenization.

        Each whitespace word hashes independently via ``zlib.crc32`` — stable
        across processes (``hash()`` is salted by PYTHONHASHSEED, which made
        served token streams, and thus every prefix-cache hit, irreproducible
        between runs) and prefix-preserving: a prompt that textually extends
        another maps to a token stream extending the other's, which is what
        lets the engine's paged KV cache reuse earlier turns of a session.
        """
        words = req.text.split()
        # never pad past the real words (position-keyed filler would break
        # the extension property when a longer prompt's words displace it);
        # only a fully empty prompt gets a single placeholder token
        n = min(max(4, req.prompt_tokens), cap, len(words))
        toks = [zlib.crc32(w.encode()) % vocab for w in words[:n]]
        if not toks:
            toks = [zlib.crc32(b"<empty>") % vocab]
        return np.asarray(toks, np.int32)

    def _dispatch(self, sreq: ServeRequest, pair: int):
        eng = self.engines[pair]
        mcfg = self.pair_model_cfg[pair]
        eng.submit(sreq.request_id, self._tokenize(sreq.req, mcfg.vocab),
                   max_new_tokens=sreq.max_new_tokens)
        node = int(np.asarray(self.router.arrays.pair_node)[pair])
        self.monitor.on_dispatch(node)
        # keep the monitor's prefix-cache view in sync with what this node's
        # engine now holds (cache-affinity routing reads it)
        req = sreq.req
        blk = self.router.cache_block
        sid = getattr(req, "session_id", -1)
        if sid >= 0:
            self.monitor.record_prefix(
                node, ("sess", sid), int(req.prompt_tokens) // blk * blk)
        yid = getattr(req, "sys_id", -1)
        if yid >= 0:
            self.monitor.record_prefix(
                node, ("sys", yid),
                int(getattr(req, "sys_tokens", 0)) // blk * blk)

    # -- public ------------------------------------------------------------------
    def submit(self, sreq: ServeRequest):
        decision = self.router.route(sreq.req)
        self._dispatch(sreq, decision.pair)
        self.inflight[sreq.request_id] = _Flight(sreq=sreq, pair=decision.pair)

    def fail_node(self, node: int):
        """Crash a node: mask it and re-route its in-flight requests. The
        dead copy is cancelled from its engine (no zombie completion after
        recovery), its dispatch accounting closed as a failure, and the
        node's KV caches flushed — a restarted node holds no prefixes, so
        neither may the monitor's residency view nor its engines' pools."""
        self.monitor.mark_down(node)
        self.monitor.drop_prefixes(node)
        pair_node = np.asarray(self.router.arrays.pair_node)
        for rid, fl in list(self.inflight.items()):
            hedge_dead = (fl.hedge_pair is not None
                          and int(pair_node[fl.hedge_pair]) == node)
            if hedge_dead:
                self.engines[fl.hedge_pair].cancel(rid)
                self.monitor.on_failure(node)
                fl.hedge_pair = None
            if int(pair_node[fl.pair]) == node:
                self._reroutes += 1
                self.engines[fl.pair].cancel(rid)
                self.monitor.on_failure(node)
                decision = self.router.route(fl.sreq.req)
                assert int(pair_node[decision.pair]) != node
                self._dispatch(fl.sreq, decision.pair)
                self.inflight[rid] = _Flight(sreq=fl.sreq, pair=decision.pair,
                                             iters=fl.iters,
                                             hedge_pair=fl.hedge_pair)
        # dead copies are cancelled above, so no slot still pins a block
        for pair, eng in self.engines.items():
            if int(pair_node[pair]) == node:
                eng.flush_kv()

    def recover_node(self, node: int, now: Optional[float] = None):
        """Heartbeat the node back to life at simulated-scheduler time (or an
        explicit ``now``) — never at wall-clock ``time.monotonic()``."""
        self.monitor.heartbeat(node, now=self.ticks if now is None else now)

    def step(self, chunk: int = 1):
        """One scheduling tick: every engine advances one decode iteration.

        ``chunk > 1`` advances each engine by up to ``chunk`` fused decode
        iterations via ``LLMEngine.step_n`` — engines with queued admissions
        fall back to a single iteration internally, so chunking only fuses
        where no admission is pending. Hedging/latency bookkeeping advances
        by the iterations each request's engine *actually* executed (a
        congested engine that fell back to one iteration must not age its
        requests by the whole chunk, or stragglers would hedge chunk-times
        early exactly where the cluster is already loaded); the scheduler
        clock stays one tick per call."""
        self.ticks += 1
        pair_node = np.asarray(self.router.arrays.pair_node)
        advanced: Dict[int, int] = {}
        for pair, eng in self.engines.items():
            node = int(pair_node[pair])
            if not self.monitor.healthy_mask()[node]:
                continue  # crashed node makes no progress
            steps_before = eng._steps
            retired = eng.step_n(chunk) if chunk > 1 else eng.step()
            advanced[pair] = eng._steps - steps_before
            for rid in retired:
                if rid in self.inflight:
                    fl = self.inflight.pop(rid)
                    self.done[rid] = eng.results[rid]
                    self.monitor.on_complete(node, latency=fl.iters + 1.0)
                    if fl.hedge_pair is not None:
                        # first completion wins: cancel the losing copy and
                        # close its dispatch accounting, or `outstanding`
                        # counts inflate forever and poison every later
                        # queue-based routing decision
                        loser = fl.hedge_pair if pair == fl.pair else fl.pair
                        self.engines[loser].cancel(rid)
                        # exactly one dispatch was charged to the loser node;
                        # close it even if the copy already drained
                        self.monitor.on_cancel(int(pair_node[loser]))
        # straggler hedging: age each request by its own engine's progress
        # (min 1 keeps the chunk=1 semantics for idle/crashed engines)
        for rid, fl in list(self.inflight.items()):
            fl.iters += max(advanced.get(fl.pair, 0), 1)
            if fl.iters > self.hedge_after and fl.hedge_pair is None:
                backup = self.router.backup_pair(fl.pair)
                if backup is not None:
                    fl.hedge_pair = backup
                    self._hedges += 1
                    self._dispatch(fl.sreq, backup)

    def run(self, max_ticks: int = 2000, chunk: int = 1) -> Dict[int, dict]:
        t = 0
        while self.inflight:
            self.step(chunk=chunk)
            t += 1
            if t > max_ticks:
                raise RuntimeError(
                    f"requests stuck: {list(self.inflight)[:5]}")
        return self.done

    def stats(self) -> dict:
        return {"completed": len(self.done), "hedges": self._hedges,
                "reroutes": self._reroutes,
                "cancelled": sum(s.total_cancelled
                                 for s in self.monitor.stats.values()),
                "queue_lengths": self.monitor.queue_lengths()}
