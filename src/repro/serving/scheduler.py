"""Cluster-level serving: NSGA-II-routed dispatch across real LLM engines.

``ClusterServer`` is the end-to-end data plane: it instantiates one
``LLMEngine`` per (node, model) pair of a ``ClusterSpec`` (with real JAX
models — the examples use reduced configs on CPU), routes each incoming
request through the paper's runtime router (Algorithm 2 + failover), and
drives all engines' continuous-batching loops. Beyond-paper fault tolerance:

* **node failure** — ``fail_node`` marks a node down; its in-flight requests
  are re-queued and re-routed; the monitor masks it from Algorithm 2 until
  ``recover_node``;
* **disaggregated prefill/decode** — when the router runs a route-valued
  policy (``mode="disagg"``) and picks a split route, the prefill leg runs
  via ``LLMEngine.prefill_only`` on the prefill-role node, the exported KV
  rides a **transfer-in-flight queue** for ``ceil(link_seconds /
  tick_seconds)`` ticks, and delivery imports the blocks into the decode
  engine's paged pool so admission reuses them bit-identically. Either
  endpoint dying mid-handoff aborts the transfer (export pins released /
  gone with the dead pool), closes the prefill leg's accounting, and
  re-routes the request with a full re-prefill;
* **straggler hedging** — a request whose engine has run more than
  ``hedge_after`` iterations beyond the node's EWMA issues a duplicate on
  the router's backup pair; first completion wins, the loser is **cancelled**
  (``LLMEngine.cancel``) and its dispatch accounting closed via
  ``monitor.on_cancel`` — queue lengths drain back to zero, so hedging never
  skews later queue-based routing decisions;
* **chaos hardening** — an optional ``repro.faults.FaultSchedule`` replays
  deterministic crash windows, stragglers (executed-iteration slow-credit),
  KV-link flaps, heartbeat losses, and transient dispatch errors against the
  runtime, and a ``ResilienceConfig`` arms deadline-aware timeouts with
  budgeted jittered retries, per-node circuit breakers (``ClusterMonitor``),
  and SLO-class load shedding on admission.

The server keeps a simulated clock (``self.ticks``, one unit per ``step``)
and feeds it to every monitor call that takes a timestamp, so heartbeat /
sweep bookkeeping stays in scheduler time rather than leaking wall-clock
``time.monotonic()`` into simulated runs.
"""
from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..cluster.monitor import ClusterMonitor
from ..cluster.spec import ClusterSpec
from ..core.router import RequestRouter
from ..faults import (FaultSchedule, backoff_jitter_u, heartbeat_lost,
                      link_slowdown_np, node_available_np, node_slowdown_np,
                      transient_hit_np)
from ..learn import OnlineEstimator
from ..models import lm
from ..obs import Obs
from ..workload.datasets import Request
from ..workload.tokenizer import count_tokens
from .engine import EngineConfig, LLMEngine
from .fleet import Cohort, build_cohorts


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for the server's retry / breaker / shedding machinery.

    Timeouts and backoffs are in scheduler ticks. A request times out after
    ``min(request_timeout_ticks, deadline_timeout_factor * ttft_slo /
    tick_seconds)`` iterations of aging (deadline-aware: interactive SLO
    classes give up and retry sooner than batch ones), is retried at most
    ``max_retries`` times with deterministic exponential backoff
    (``backoff_base_ticks * backoff_mult**attempt``, counter-hash jitter —
    same stream as the analytic layers' ``faults.backoff_jitter_u``), and
    every retry draws on a **global budget** of ``max(retry_budget_min,
    retry_budget_frac * total_dispatches)`` so a mass failure degrades to
    slow-but-bounded instead of a retry storm. ``shed_threshold`` /
    ``shed_interactive_threshold`` are cluster-utilization fractions
    (queued+active over total slots) above which ``submit`` sheds batch-class
    and then all requests. ``breaker_threshold`` feeds the monitor's per-node
    circuit breakers (error-rate EWMA; ``None`` disables them)."""

    request_timeout_ticks: int = 200
    deadline_timeout_factor: float = 8.0
    min_timeout_ticks: int = 24
    max_retries: int = 2
    backoff_base_ticks: float = 2.0
    backoff_mult: float = 2.0
    jitter: float = 0.5
    jitter_seed: int = 0x5EED5EED
    retry_budget_frac: float = 0.2
    retry_budget_min: int = 8
    shed_threshold: float = 0.9
    shed_interactive_threshold: float = 1.5
    breaker_threshold: Optional[float] = 0.5
    breaker_cooldown_ticks: float = 50.0


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    req: Request
    max_new_tokens: int = 8
    slo_class: str = "interactive"   # "interactive" | "batch" (shed order)


@dataclasses.dataclass
class _Flight:
    sreq: ServeRequest
    pair: int
    iters: int = 0
    hedge_pair: Optional[int] = None
    depart_tick: int = 0   # scheduler tick of the (original) dispatch
    category: int = -1     # classifier category at routing (metrics label)
    est_cost: float = 0.0  # modelled $ of the routed pair (spend metric)
    attempt: int = 0       # 0 = first dispatch; bumps on each timeout retry
    timeout_ticks: float = float("inf")   # deadline-aware per-request timeout
    # learned-estimator feedback: the estimates the routing decision acted
    # on (0 = policy never requested estimate rows -> nothing to learn) and
    # the decision-time features; the prefill residual attributes to
    # ``prefill_node`` on disaggregated routes (-1 = colocated)
    est_ttft: float = 0.0
    est_tpot: float = 0.0
    complexity: float = 0.0
    prefill_node: int = -1


@dataclasses.dataclass
class _Transfer:
    """A KV handoff in flight between a prefill-role and a decode-role node.

    The payload is host-copied at departure, but delivery is gated on the
    ETA tick *and* both endpoints staying alive: either endpoint dying
    mid-transfer aborts the handoff and the request re-routes with a full
    re-prefill (``ClusterServer.fail_node``)."""

    sreq: ServeRequest
    prefill_pair: int
    decode_pair: int
    block_ids: list
    tokens: np.ndarray
    n_cov: int                 # whole-block tokens covered by the payload
    payload: object            # host K/V slabs (kvcache.export_blocks)
    depart_tick: int
    eta: int
    category: int = -1         # classifier category (metrics label)
    est_cost: float = 0.0      # modelled $ of the decode pair (spend metric)
    # learned-estimator feedback carried through to the decode-side _Flight
    est_ttft: float = 0.0
    est_tpot: float = 0.0
    complexity: float = 0.0


class ClusterServer:
    def __init__(self, cluster: ClusterSpec, model_builders: Dict[str, tuple],
                 thresholds, engine_cfg: EngineConfig = EngineConfig(),
                 hedge_after: int = 64, vocab_cap: Optional[int] = None,
                 router_kwargs: Optional[dict] = None,
                 tick_seconds: float = 0.05, fleet: bool = True,
                 obs: Optional[Obs] = None,
                 faults: Optional[FaultSchedule] = None,
                 resilience: Optional[ResilienceConfig] = None):
        """model_builders: model name -> (ModelConfig, params).
        router_kwargs: extra RequestRouter arguments (e.g.
        ``mode="affinity"`` for cache-affinity dispatch).
        fleet: stack engines sharing a (ModelConfig, EngineConfig, params)
        identity into cohorts (``serving.fleet``) so each cohort decodes in
        ONE jitted dispatch per tick; ``False`` keeps the per-engine Python
        loop (byte-identical results, O(#engines) dispatches).
        obs: optional ``repro.obs.Obs`` telemetry bundle — lifecycle spans
        on the scheduler-tick clock, the shared metrics registry, and the
        router decision audit. Defaults to ``Obs.noop()``: no span/audit
        recording, but the metrics registry (always owned by the monitor)
        still feeds ``stats()['percentiles']``.
        faults: optional ``repro.faults.FaultSchedule`` replayed against the
        runtime with times in **scheduler ticks** — crash windows fail/recover
        nodes, stragglers slow their decode rate, link flaps stretch KV
        handoffs, heartbeat losses go telemetry-dark, transient errors bounce
        dispatches into the retry path. The same schedule drives the DES
        oracles and the fitness scan, so a genome tuned under it is tested
        here against the identical regime.
        resilience: retry / breaker / shedding knobs (``ResilienceConfig``);
        defaults on when ``faults`` is given, otherwise off."""
        self.cluster = cluster
        self.obs = Obs.noop() if obs is None else obs
        self.tracer = self.obs.tracer
        if faults is not None and resilience is None:
            resilience = ResilienceConfig()
        self.resilience = resilience
        self.fault_schedule = faults
        self._fault_tables = (faults.compile(len(cluster.nodes))
                              if faults is not None else None)
        self.monitor = ClusterMonitor(
            len(cluster.nodes), metrics=self.obs.metrics,
            breaker_threshold=(None if resilience is None
                               else resilience.breaker_threshold),
            breaker_cooldown=(20.0 if resilience is None
                              else resilience.breaker_cooldown_ticks))
        self.metrics = self.monitor.metrics  # always a live registry
        rkw = dict(router_kwargs or {})
        rkw.setdefault("audit", self.obs.audit)
        self.router = RequestRouter(cluster, thresholds, monitor=self.monitor,
                                    **rkw)
        self.engines: Dict[int, LLMEngine] = {}
        self.pair_model_cfg: Dict[int, object] = {}
        for p, (j, k) in enumerate(cluster.pairs()):
            name = cluster.models[k].name
            mcfg, params = model_builders[name]
            self.engines[p] = LLMEngine(mcfg, params, engine_cfg)
            self.pair_model_cfg[p] = mcfg
        self.fleet = fleet
        self._cohorts: List[Cohort] = []
        self._cohort_pairs: List[List[int]] = []
        self._pair_cohort: Dict[int, tuple] = {}
        self._cohort_nodes: List[np.ndarray] = []
        if fleet:
            self._cohorts, self._cohort_pairs, self._pair_cohort = \
                build_cohorts(self.engines)
            pair_node = np.asarray(self.router.arrays.pair_node)
            self._cohort_nodes = [
                np.asarray([pair_node[p] for p in pairs], np.int64)
                for pairs in self._cohort_pairs]
        # per-cohort stacked-dispatch participation (host-side counter: the
        # dispatch result already carries the count, no extra device sync)
        self._cohort_part = self.metrics.counter(
            "cohort_participants", max(len(self._cohorts), 1))
        self.inflight: Dict[int, _Flight] = {}
        self.transfers: Dict[int, _Transfer] = {}   # KV handoffs in flight
        self.done: Dict[int, dict] = {}
        self.hedge_after = hedge_after
        self.tick_seconds = tick_seconds   # converts KV-link seconds -> ticks
        self._hedges = 0
        self._reroutes = 0
        self._handoffs = 0
        self.ticks = 0   # simulated scheduler clock: one unit per step()
        # resilience state. _down_nodes is the liveness ground truth for
        # engine progress (a breaker-open or telemetry-dark node is routing-
        # masked but its engines keep executing); _fault_down tracks which of
        # those crashes the schedule caused, so schedule-window exits recover
        # only them and never a manually failed node.
        self._down_nodes: set = set()
        self._fault_down: set = set()
        self._slow_credit = np.zeros(len(cluster.nodes))
        self._advance = np.ones(len(cluster.nodes), bool)
        self._transient_done: set = set()   # rids whose transient fault fired
        self._retry_queue: Dict[int, Tuple[int, ServeRequest, int]] = {}
        self._retries_spent = 0
        self._timeouts = 0
        self._transients = 0
        self._sheds = 0
        self._capacity = max(1, engine_cfg.max_slots * len(self.engines))

    # -- resilience helpers ----------------------------------------------------
    def _timeout_ticks(self, cat: int) -> float:
        """Deadline-aware timeout: the configured ceiling, tightened to a
        multiple of the category's TTFT SLO (converted seconds -> ticks) when
        the router carries one — interactive classes give up and retry long
        before a batch request would."""
        rcfg = self.resilience
        if rcfg is None:
            return float("inf")
        t = float(rcfg.request_timeout_ticks)
        slo = getattr(self.router, "_slo_ttft", None)
        if slo is not None and 0 <= cat < len(slo):
            dl = float(np.asarray(slo)[cat])
            if np.isfinite(dl):
                t = min(t, max(float(rcfg.min_timeout_ticks),
                               rcfg.deadline_timeout_factor * dl
                               / self.tick_seconds))
        return t

    def _retry_budget_ok(self) -> bool:
        """Global anti-storm budget: total retries so far must stay under a
        fraction of total dispatches (with a floor so a cold cluster can
        still retry at all)."""
        rcfg = self.resilience
        total = sum(s.total_dispatched for s in self.monitor.stats.values())
        budget = max(rcfg.retry_budget_min,
                     int(rcfg.retry_budget_frac * total))
        return self._retries_spent < budget

    def _schedule_retry(self, sreq: ServeRequest, attempt: int) -> None:
        """Queue a retry after deterministic exponential backoff with
        counter-hash jitter — same ``backoff_jitter_u`` stream the analytic
        layers use, so a replayed schedule reproduces the exact retry times."""
        rcfg = self.resilience
        self._retries_spent += 1
        u = backoff_jitter_u(rcfg.jitter_seed, sreq.request_id, attempt)
        back = rcfg.backoff_base_ticks * (rcfg.backoff_mult ** attempt)
        due = self.ticks + max(1, int(round(back * (1.0 + rcfg.jitter * u))))
        self._retry_queue[sreq.request_id] = (due, sreq, attempt + 1)

    def _fault_tick(self) -> None:
        """Replay this tick's slice of the fault schedule and advance the
        monitor clock. Crash-window entries ``fail_node`` (once), exits
        ``recover_node`` only schedule-crashed nodes; every live node then
        auto-heartbeats unless its heartbeat is schedule-lost (node alive,
        telemetry dark -> staleness masks it from routing while its engines
        keep running); ``monitor.advance`` runs the staleness sweep and
        breaker cooldowns on the tick clock. Straggler slowdown integrates
        **slow-credit**: a node at factor s earns 1/s credit per tick and its
        engines execute a decode iteration only on ticks where credit >= 1 —
        executed-iteration scaling, the runtime twin of the oracles'
        service-time scaling."""
        n = len(self.cluster.nodes)
        t = np.float32(self.ticks)
        ft = self._fault_tables
        if ft is not None:
            avail = node_available_np(ft, t)
            for node in range(n):
                if not avail[node] and node not in self._down_nodes:
                    self._fault_down.add(node)
                    self.fail_node(node)
                elif avail[node] and node in self._fault_down:
                    self.recover_node(node)
        for node in range(n):
            if node in self._down_nodes:
                continue
            if (self.fault_schedule is not None
                    and heartbeat_lost(self.fault_schedule, node, float(t))):
                continue
            self.monitor.heartbeat(node, now=self.ticks)
        self.monitor.advance(float(self.ticks))
        if ft is not None:
            slow = node_slowdown_np(ft, t)
            self._slow_credit += 1.0 / np.maximum(slow, 1.0)
            adv = self._slow_credit >= 1.0 - 1e-9
            self._slow_credit[adv] -= 1.0
            self._advance = adv

    # -- helpers ---------------------------------------------------------------
    def _tokenize(self, req: Request, vocab: int, cap: int = 24) -> np.ndarray:
        """Deterministic, **prefix-stable** word-level tokenization.

        Each whitespace word hashes independently via ``zlib.crc32`` — stable
        across processes (``hash()`` is salted by PYTHONHASHSEED, which made
        served token streams, and thus every prefix-cache hit, irreproducible
        between runs) and prefix-preserving: a prompt that textually extends
        another maps to a token stream extending the other's, which is what
        lets the engine's paged KV cache reuse earlier turns of a session.
        """
        words = req.text.split()
        # never pad past the real words (position-keyed filler would break
        # the extension property when a longer prompt's words displace it);
        # only a fully empty prompt gets a single placeholder token
        n = min(max(4, req.prompt_tokens), cap, len(words))
        toks = [zlib.crc32(w.encode()) % vocab for w in words[:n]]
        if not toks:
            toks = [zlib.crc32(b"<empty>") % vocab]
        return np.asarray(toks, np.int32)

    def _dispatch(self, sreq: ServeRequest, pair: int):
        eng = self.engines[pair]
        mcfg = self.pair_model_cfg[pair]
        eng.submit(sreq.request_id, self._tokenize(sreq.req, mcfg.vocab),
                   max_new_tokens=sreq.max_new_tokens)
        node = int(np.asarray(self.router.arrays.pair_node)[pair])
        self.monitor.on_dispatch(node)
        # span event mirrors the monitor accounting call one-for-one
        self.tracer.event(sreq.request_id, "dispatch", self.ticks,
                          node=node, pair=pair)
        # keep the monitor's prefix-cache view in sync with what this node's
        # engine now holds (cache-affinity routing reads it)
        req = sreq.req
        blk = self.router.cache_block
        sid = getattr(req, "session_id", -1)
        if sid >= 0:
            self.monitor.record_prefix(
                node, ("sess", sid), int(req.prompt_tokens) // blk * blk)
        yid = getattr(req, "sys_id", -1)
        if yid >= 0:
            self.monitor.record_prefix(
                node, ("sys", yid),
                int(getattr(req, "sys_tokens", 0)) // blk * blk)

    def _start_handoff(self, sreq: ServeRequest, prefill_pair: int,
                       decode_pair: int, category: int = -1,
                       est_cost: float = 0.0, est_ttft: float = 0.0,
                       est_tpot: float = 0.0,
                       complexity: float = 0.0) -> bool:
        """Disaggregated dispatch: run the prefill leg now, put the exported
        KV on the transfer-in-flight queue. Returns False when the route
        cannot hand off (no paged stores, same node, or nothing block-aligned
        to ship) — the caller then serves the request colocated on the decode
        pair with a full prefill."""
        eng_p = self.engines[prefill_pair]
        eng_q = self.engines[decode_pair]
        arr = self.router._np_arrays
        node_p = int(arr.pair_node[prefill_pair])
        node_q = int(arr.pair_node[decode_pair])
        if eng_p.kv is None or eng_q.kv is None or node_p == node_q:
            return False
        mcfg = self.pair_model_cfg[decode_pair]
        tokens = self._tokenize(sreq.req, mcfg.vocab)
        bs = eng_p.kv.block_size
        if len(tokens) < bs:
            return False   # no whole block to ship
        self.monitor.on_dispatch(node_p)
        self.tracer.event(sreq.request_id, "dispatch", self.ticks,
                          node=node_p, pair=prefill_pair)
        block_ids = eng_p.prefill_only(sreq.request_id, tokens)
        n_cov = len(block_ids) * bs
        if not block_ids:
            # pool exhausted before the first block: close the prefill leg
            # and fall back to a colocated full prefill
            self.monitor.on_cancel(node_p)
            self.tracer.event(sreq.request_id, "cancel", self.ticks,
                              node=node_p)
            return False
        payload = eng_p.export_kv(block_ids)
        kv_bytes = float(n_cov) * float(arr.pair_kv_bytes_per_token[
            prefill_pair])
        tt = float(arr.kv_lat[node_p, node_q]) + \
            kv_bytes * float(arr.kv_inv_bw[node_p, node_q])
        if self._fault_tables is not None:
            # a degraded/flapping KV link stretches the transfer in flight
            tt *= float(link_slowdown_np(self._fault_tables,
                                         np.float32(self.ticks)))
        ticks = max(1, int(np.ceil(tt / self.tick_seconds)))
        self.transfers[sreq.request_id] = _Transfer(
            sreq=sreq, prefill_pair=prefill_pair, decode_pair=decode_pair,
            block_ids=block_ids, tokens=tokens, n_cov=n_cov, payload=payload,
            depart_tick=self.ticks, eta=self.ticks + ticks,
            category=category, est_cost=est_cost, est_ttft=est_ttft,
            est_tpot=est_tpot, complexity=complexity)
        self._handoffs += 1
        self.tracer.event(sreq.request_id, "handoff-start", self.ticks,
                          node=node_p, decode_node=node_q,
                          eta=self.ticks + ticks)
        return True

    def _route_dispatch(self, sreq: ServeRequest, iters: int = 0,
                        attempt: int = 0):
        """Route one request and dispatch it — colocated into an engine, or
        through the KV-handoff pipeline when a route-valued policy split the
        (prefill, decode) legs across nodes. ``attempt`` counts timeout
        retries of this request (aging restarts; the retry keeps its span)."""
        decision = self.router.route(sreq.req)
        cat = int(decision.features[1])
        self.tracer.set_category(sreq.request_id, cat)
        self.tracer.event(sreq.request_id, "route-decision", self.ticks,
                          pair=decision.pair, node=decision.node,
                          prefill_pair=decision.prefill_pair)
        rcfg = self.resilience
        ft = self._fault_tables
        if (ft is not None and rcfg is not None
                and float(ft.err_rate) > 0.0
                and sreq.request_id not in self._transient_done
                and transient_hit_np(ft, sreq.request_id)):
            # deterministic transient dispatch error (same counter-hash draw
            # as the analytic layers' per-request delay): charge one failed
            # dispatch to the routed node — breaker food — and bounce the
            # request into the backoff/retry queue. Fires at most once per
            # request, mirroring the oracles' one-shot delay semantics.
            self._transient_done.add(sreq.request_id)
            self._transients += 1
            node = decision.node
            self.monitor.on_dispatch(node)
            self.monitor.on_failure(node)
            self.tracer.event(sreq.request_id, "failure", self.ticks,
                              node=node, transient=True)
            if attempt < rcfg.max_retries:
                self._schedule_retry(sreq, attempt)
            else:
                self.tracer.end(sreq.request_id, self.ticks, "failed")
                self.done[sreq.request_id] = {"status": "failed"}
            return decision
        if (decision.prefill_pair is not None
                and decision.prefill_pair != decision.pair
                and self._start_handoff(sreq, decision.prefill_pair,
                                        decision.pair, category=cat,
                                        est_cost=decision.est_cost,
                                        est_ttft=decision.est_ttft,
                                        est_tpot=decision.est_tpot,
                                        complexity=float(
                                            decision.features[0]))):
            return decision
        self._dispatch(sreq, decision.pair)
        self.inflight[sreq.request_id] = _Flight(
            sreq=sreq, pair=decision.pair, iters=iters,
            depart_tick=self.ticks, category=cat,
            est_cost=decision.est_cost, attempt=attempt,
            timeout_ticks=self._timeout_ticks(cat),
            est_ttft=decision.est_ttft, est_tpot=decision.est_tpot,
            complexity=float(decision.features[0]))
        return decision

    # -- public ------------------------------------------------------------------
    def submit(self, sreq: ServeRequest):
        # the span opens once here; reroutes/hedges reuse the open span
        self.tracer.begin(sreq.request_id, self.ticks)
        rcfg = self.resilience
        if rcfg is not None:
            # graceful load shedding, by SLO class: above shed_threshold the
            # cluster stops admitting batch-class work; above the (higher)
            # interactive threshold it sheds everything. An immediate cheap
            # rejection beats queueing work that will blow its deadline and
            # steal slots from requests that could still meet theirs.
            util = self.queue_len / self._capacity
            if (util >= rcfg.shed_interactive_threshold
                    or (util >= rcfg.shed_threshold
                        and sreq.slo_class == "batch")):
                self._sheds += 1
                self.tracer.event(sreq.request_id, "shed", self.ticks)
                self.tracer.end(sreq.request_id, self.ticks, "shed")
                self.done[sreq.request_id] = {"status": "shed"}
                return
        self._route_dispatch(sreq)

    def fail_node(self, node: int):
        """Crash a node: mask it and re-route its in-flight requests. The
        dead copy is cancelled from its engine (no zombie completion after
        recovery), its dispatch accounting closed as a failure, and the
        node's KV caches flushed — a restarted node holds no prefixes, so
        neither may the monitor's residency view nor its engines' pools."""
        self._down_nodes.add(node)
        self.monitor.mark_down(node)
        self.monitor.drop_prefixes(node)
        pair_node = np.asarray(self.router.arrays.pair_node)
        # abort KV handoffs touching the dead node. Source died (covers both
        # "prefill complete but pre-transfer" and mid-transfer): the payload
        # pins go down with the node's pools below, close the prefill leg as
        # a failure. Destination died: the source is alive, drop its export
        # pins explicitly (orphaned blocks return to the cache baseline) and
        # close the leg as cancelled. Either way the request re-routes and
        # re-prefills from scratch on a healthy route.
        for rid, tr in list(self.transfers.items()):
            node_p = int(pair_node[tr.prefill_pair])
            node_q = int(pair_node[tr.decode_pair])
            if node_p != node and node_q != node:
                continue
            del self.transfers[rid]
            if node_p == node:
                self.monitor.on_failure(node_p)
                self.tracer.event(rid, "failure", self.ticks, node=node_p)
            else:
                self.engines[tr.prefill_pair].release_export(tr.block_ids)
                self.monitor.on_cancel(node_p)
                self.tracer.event(rid, "cancel", self.ticks, node=node_p)
            self._reroutes += 1
            self.tracer.event(rid, "reroute", self.ticks, node=node)
            self._route_dispatch(tr.sreq)
        for rid, fl in list(self.inflight.items()):
            hedge_dead = (fl.hedge_pair is not None
                          and int(pair_node[fl.hedge_pair]) == node)
            if hedge_dead:
                self.engines[fl.hedge_pair].cancel(rid)
                self.monitor.on_failure(node)
                self.tracer.event(rid, "failure", self.ticks, node=node)
                fl.hedge_pair = None
            if int(pair_node[fl.pair]) == node:
                self._reroutes += 1
                self.engines[fl.pair].cancel(rid)
                self.monitor.on_failure(node)
                self.tracer.event(rid, "failure", self.ticks, node=node)
                self.tracer.event(rid, "reroute", self.ticks, node=node)
                decision = self.router.route(fl.sreq.req)
                assert int(pair_node[decision.pair]) != node
                cat = int(decision.features[1])
                self.tracer.set_category(rid, cat)
                self.tracer.event(rid, "route-decision", self.ticks,
                                  pair=decision.pair, node=decision.node,
                                  prefill_pair=decision.prefill_pair)
                self._dispatch(fl.sreq, decision.pair)
                # keep the original depart tick: the monitor's completion
                # latency measures end-to-end ticks since first dispatch,
                # matching how `iters` keeps aging across the re-route
                self.inflight[rid] = _Flight(sreq=fl.sreq, pair=decision.pair,
                                             iters=fl.iters,
                                             hedge_pair=fl.hedge_pair,
                                             depart_tick=fl.depart_tick,
                                             category=cat,
                                             est_cost=decision.est_cost,
                                             est_ttft=decision.est_ttft,
                                             est_tpot=decision.est_tpot,
                                             complexity=float(
                                                 decision.features[0]))
        # dead copies are cancelled above, so no slot still pins a block
        for pair, eng in self.engines.items():
            if int(pair_node[pair]) == node:
                eng.flush_kv()

    def recover_node(self, node: int, now: Optional[float] = None):
        """Heartbeat the node back to life at simulated-scheduler time (or an
        explicit ``now``) — never at wall-clock ``time.monotonic()``. Explicit
        recovery is the ONE place a circuit breaker resets to closed: the
        per-tick auto-heartbeat deliberately never touches breakers, or they
        would re-close the instant they opened."""
        self._down_nodes.discard(node)
        self._fault_down.discard(node)
        self.monitor.reset_breaker(node)
        self.monitor.heartbeat(node, now=self.ticks if now is None else now)

    def step(self, chunk: int = 1):
        """One scheduling tick: every engine advances one decode iteration.

        ``chunk > 1`` advances each engine by up to ``chunk`` fused decode
        iterations via ``LLMEngine.step_n`` — engines with queued admissions
        fall back to a single iteration internally, so chunking only fuses
        where no admission is pending. Hedging/latency bookkeeping advances
        by the iterations each request's engine *actually* executed (a
        congested engine that fell back to one iteration must not age its
        requests by the whole chunk, or stragglers would hedge chunk-times
        early exactly where the cluster is already loaded); the scheduler
        clock stays one tick per call."""
        self.ticks += 1
        pair_node = np.asarray(self.router.arrays.pair_node)
        # fault schedule + monitor clock first: crash/recover transitions,
        # heartbeats (minus schedule-lost ones), breaker cooldowns, and the
        # straggler slow-credit mask all apply to THIS tick's work below
        self._fault_tick()
        # deliver due KV handoffs: drop the source's export pins, land the
        # payload in the decode engine's pool (a full pool degrades to a
        # plain re-prefill — outputs stay byte-identical either way) and
        # admit the request on the decode pair, which now matches the
        # imported prefix
        for rid, tr in list(self.transfers.items()):
            if self.ticks < tr.eta:
                continue
            del self.transfers[rid]
            node_p = int(pair_node[tr.prefill_pair])
            self.engines[tr.prefill_pair].release_export(tr.block_ids)
            lat = float(self.ticks - tr.depart_tick)
            self.monitor.on_complete(node_p, latency=lat)
            self.metrics.observe("transfer", lat, node=node_p,
                                 category=tr.category)
            if self.tracer.enabled:
                self.tracer.phase(rid, "kv-transfer", tr.depart_tick, lat,
                                  node_p)
                self.tracer.event(rid, "complete", self.ticks, node=node_p)
            try:
                self.engines[tr.decode_pair].import_kv(
                    tr.tokens[:tr.n_cov], tr.payload)
                self._dispatch(tr.sreq, tr.decode_pair)
            except Exception:
                # delivery blew up mid-import: the decode pool may hold a
                # partial landing, so crash the node (flushes its pools back
                # to the refcount baseline, reroutes its flights) and send
                # this request back through routing with a full re-prefill
                node_q = int(pair_node[tr.decode_pair])
                self.monitor.on_dispatch(node_q)
                self.monitor.on_failure(node_q)
                self.tracer.event(rid, "failure", self.ticks, node=node_q)
                if node_q not in self._down_nodes:
                    self.fail_node(node_q)
                self._reroutes += 1
                self.tracer.event(rid, "reroute", self.ticks, node=node_q)
                self._route_dispatch(tr.sreq)
                continue
            self.inflight[rid] = _Flight(
                sreq=tr.sreq, pair=tr.decode_pair, depart_tick=self.ticks,
                category=tr.category, est_cost=tr.est_cost,
                timeout_ticks=self._timeout_ticks(tr.category),
                est_ttft=tr.est_ttft, est_tpot=tr.est_tpot,
                complexity=tr.complexity, prefill_node=node_p)
        # drain due retries (transient bounces and timed-out requests) —
        # after fault transitions so they route against this tick's masks
        for rid in [r for r, (due, _, _) in self._retry_queue.items()
                    if self.ticks >= due]:
            _, sreq, attempt = self._retry_queue.pop(rid)
            self.tracer.event(rid, "retry", self.ticks, attempt=attempt)
            self._route_dispatch(sreq, attempt=attempt)
        # phase A — fleet data plane: one stacked decode dispatch per cohort.
        # Members mid-admission (queued work at chunk > 1), empty, on a
        # crashed node, or on a straggler without slow-credit this tick are
        # masked out and fall back to the per-engine path in phase B;
        # everyone else advances device-side here, and the host bookkeeping
        # for their chunks runs in phase B in global pair order, so
        # monitor/hedge accounting is ordered exactly as per-engine mode.
        # Liveness (_down_nodes), not monitor.healthy_mask(), gates engine
        # progress: a breaker-open or telemetry-dark node is hidden from
        # ROUTING but its engines keep executing — only a crash stops them.
        chunk_work: Dict[int, object] = {}
        for ci, cohort in enumerate(self._cohorts):
            pairs = self._cohort_pairs[ci]
            eligible = [m for m, p in enumerate(pairs)
                        if int(pair_node[p]) not in self._down_nodes
                        and self._advance[int(pair_node[p])]]
            if not eligible:
                continue
            res = cohort.dispatch(chunk, eligible)
            if not res.work:
                continue
            # fleet counters straight off the stacked retirement mask
            self.monitor.record_fleet(self._cohort_nodes[ci],
                                      res.emitted, res.retired)
            self._cohort_part.add(ci, res.participants)
            for m, w in res.work.items():
                chunk_work[pairs[m]] = w
        # phase B — host control plane, in pair order
        advanced: Dict[int, int] = {}
        for pair, eng in self.engines.items():
            node = int(pair_node[pair])
            if node in self._down_nodes:
                continue  # crashed node makes no progress
            if not self._advance[node]:
                continue  # straggler: no slow-credit, no iteration this tick
            steps_before = eng._steps
            try:
                if pair in chunk_work:
                    retired = eng._commit_chunk(chunk_work[pair])
                else:
                    retired = eng.step_n(chunk) if chunk > 1 else eng.step()
            except Exception:
                # exception safety: an error mid-commit must not leak export
                # pins or cohort write-backs. Treat it as a node crash —
                # fail_node cancels this node's flights, re-routes them, and
                # flushes its pools back to the refcount baseline; later
                # pairs on the node are skipped via _down_nodes above.
                self.fail_node(node)
                continue
            advanced[pair] = eng._steps - steps_before
            for rid in retired:
                if rid in self.inflight:
                    fl = self.inflight.pop(rid)
                    res = eng.results[rid]
                    self.done[rid] = res
                    # completion latency in scheduler ticks — the same unit
                    # KV-handoff deliveries record — not decode iterations,
                    # which diverge by a factor of `chunk` when chunking
                    lat = float(max(self.ticks - fl.depart_tick, 1))
                    self.monitor.on_complete(node, latency=lat)
                    # QoE metrics come from the engine's step clock (decode
                    # iterations); the span phase stays in scheduler ticks
                    # so phase durations match monitor latencies exactly
                    m = self.metrics
                    m.observe("ttft", float(res["ttft_steps"]), node=node,
                              category=fl.category)
                    m.observe("tpot", float(res["tpot_steps"]), node=node,
                              category=fl.category)
                    m.observe("queue_wait", float(res["ttft_steps"]),
                              node=node, category=fl.category)
                    m.observe("cache_hit_frac", float(res["cached_frac"]),
                              node=node, category=fl.category)
                    m.observe("spend", float(fl.est_cost), node=node,
                              category=fl.category)
                    if (self.monitor.estimator is not None
                            and (fl.est_ttft > 0.0 or fl.est_tpot > 0.0)):
                        # close the online-learning loop: realized engine
                        # steps vs the estimates the decision acted on (the
                        # multiplicative residual absorbs the model-seconds
                        # -> engine-steps unit scale); prefill residual on
                        # the prefill-leg node of disaggregated routes
                        self.monitor.feed_estimator(
                            fl.category,
                            fl.prefill_node if fl.prefill_node >= 0
                            else node,
                            node, fl.sreq.req.prompt_tokens, fl.complexity,
                            OnlineEstimator.ratio(
                                fl.est_ttft, float(res["ttft_steps"])),
                            OnlineEstimator.ratio(
                                fl.est_tpot, float(res["tpot_steps"])))
                    if self.tracer.enabled:
                        self.tracer.phase(rid, "serve", fl.depart_tick, lat,
                                          node)
                        self.tracer.event(rid, "complete", self.ticks,
                                          node=node)
                    if fl.hedge_pair is not None:
                        # first completion wins: cancel the losing copy and
                        # close its dispatch accounting, or `outstanding`
                        # counts inflate forever and poison every later
                        # queue-based routing decision
                        loser = fl.hedge_pair if pair == fl.pair else fl.pair
                        self.engines[loser].cancel(rid)
                        # exactly one dispatch was charged to the loser node;
                        # close it even if the copy already drained
                        self.monitor.on_cancel(int(pair_node[loser]))
                        self.tracer.event(rid, "cancel", self.ticks,
                                          node=int(pair_node[loser]))
                    self.tracer.end(rid, self.ticks, "completed")
        # straggler hedging + deadline timeouts: age each request by its own
        # engine's progress (min 1 keeps the chunk=1 semantics for idle,
        # crashed, or credit-starved engines — wall-tick aging is exactly
        # what lets hedges and timeouts fire against a straggler)
        rcfg = self.resilience
        for rid, fl in list(self.inflight.items()):
            fl.iters += max(advanced.get(fl.pair, 0), 1)
            if (rcfg is not None and fl.iters > fl.timeout_ticks
                    and fl.attempt < rcfg.max_retries
                    and self._retry_budget_ok()):
                # deadline blown: cancel every copy (hedge included), close
                # their dispatch accounting, and re-queue with backoff. When
                # retries or the global budget are exhausted the request
                # instead keeps running — degraded service beats a drop.
                self._timeouts += 1
                self.tracer.event(rid, "timeout", self.ticks,
                                  node=int(pair_node[fl.pair]))
                copies = [fl.pair] + ([fl.hedge_pair]
                                      if fl.hedge_pair is not None else [])
                for p in copies:
                    self.engines[p].cancel(rid)
                    self.monitor.on_cancel(int(pair_node[p]))
                    self.tracer.event(rid, "cancel", self.ticks,
                                      node=int(pair_node[p]))
                del self.inflight[rid]
                self._schedule_retry(fl.sreq, fl.attempt)
                continue
            if fl.iters > self.hedge_after and fl.hedge_pair is None:
                backup = self.router.backup_pair(fl.pair)
                if backup is not None:
                    fl.hedge_pair = backup
                    self._hedges += 1
                    self.tracer.event(
                        rid, "hedge", self.ticks,
                        node=int(pair_node[backup]), pair=backup)
                    self._dispatch(fl.sreq, backup)

    def run(self, max_ticks: int = 2000, chunk: int = 1) -> Dict[int, dict]:
        t = 0
        while self.inflight or self.transfers or self._retry_queue:
            self.step(chunk=chunk)
            t += 1
            if t > max_ticks:
                raise RuntimeError(
                    f"requests stuck: {list(self.inflight)[:5]}")
        return self.done

    # -- fleet-counter aggregation (no per-engine Python loop in fleet mode) --
    @property
    def _loose_engines(self) -> List[LLMEngine]:
        """Engines outside every cohort (fleet off, or non-vectorizable)."""
        return [e for p, e in self.engines.items()
                if p not in self._pair_cohort]

    @property
    def active_count(self) -> int:
        """Occupied decode slots across the cluster — one vectorized sum per
        cohort (members sync their numpy counter slot on every slot/queue
        mutation) plus the loose stragglers."""
        n = sum(int(c.counters.active.sum()) for c in self._cohorts)
        return n + sum(e.active_count for e in self._loose_engines)

    @property
    def queue_len(self) -> int:
        """Active + queued requests across the cluster (engine semantics)."""
        n = sum(int(c.counters.active.sum() + c.counters.queued.sum())
                for c in self._cohorts)
        return n + sum(e.queue_len for e in self._loose_engines)

    @property
    def decode_dispatches(self) -> int:
        """Total jitted decode dispatches: one per cohort chunk plus one per
        per-engine (fallback or loose) step — the benchmark's O(#cohorts)
        vs O(#engines) evidence."""
        return (sum(c.counters.dispatches for c in self._cohorts)
                + sum(e.decode_dispatches for e in self.engines.values()))

    def stats(self) -> dict:
        cohorts = [{"pairs": list(pairs), "size": len(pairs),
                    "dispatches": c.counters.dispatches,
                    "emitted": int(c.counters.emitted.sum()),
                    "retired": int(c.counters.retired.sum())}
                   for c, pairs in zip(self._cohorts, self._cohort_pairs)]
        return {"completed": len(self.done), "hedges": self._hedges,
                "reroutes": self._reroutes, "handoffs": self._handoffs,
                "sheds": self._sheds, "retries": self._retries_spent,
                "timeouts": self._timeouts,
                "transient_faults": self._transients,
                "breakers": self.monitor.breaker_states(),
                "breaker_opens": [int(x)
                                  for x in self.monitor.breaker_opens],
                "transfers_inflight": len(self.transfers),
                "cancelled": sum(s.total_cancelled
                                 for s in self.monitor.stats.values()),
                "queue_lengths": self.monitor.queue_lengths(),
                "active": self.active_count,
                "queued": self.queue_len,
                "decode_dispatches": self.decode_dispatches,
                "cohorts": cohorts,
                "fleet": self.monitor.fleet_totals(),
                "percentiles": self.metrics.summary(
                    names=("latency", "ttft", "tpot", "queue_wait",
                           "transfer", "cache_hit_frac", "spend"))}
