from .engine import EngineConfig, LLMEngine
from .fleet import Cohort, FleetState, build_cohorts
from .kvcache import (BlockPool, FleetKVPools, PagedKVCache, PagedKVStore,
                      RadixIndex)
from .scheduler import ClusterServer, ResilienceConfig, ServeRequest

__all__ = ["LLMEngine", "EngineConfig", "ClusterServer", "ServeRequest",
           "ResilienceConfig",
           "BlockPool", "RadixIndex", "PagedKVCache", "PagedKVStore",
           "Cohort", "FleetState", "FleetKVPools", "build_cohorts"]
