from .engine import EngineConfig, LLMEngine
from .scheduler import ClusterServer, ServeRequest

__all__ = ["LLMEngine", "EngineConfig", "ClusterServer", "ServeRequest"]
