from .engine import EngineConfig, LLMEngine
from .kvcache import BlockPool, PagedKVCache, PagedKVStore, RadixIndex
from .scheduler import ClusterServer, ServeRequest

__all__ = ["LLMEngine", "EngineConfig", "ClusterServer", "ServeRequest",
           "BlockPool", "RadixIndex", "PagedKVCache", "PagedKVStore"]
