"""Run export: Chrome-trace/Perfetto JSON and flat metric dicts.

``chrome_trace`` converts any tracer's span log into the Trace Event
Format that chrome://tracing and ui.perfetto.dev load directly:

* each span **phase** becomes a complete duration event (``"ph": "X"``)
  with ``pid`` = node (lane per node in the UI), ``tid`` = request id,
  ``ts``/``dur`` in microseconds of the emitter's clock scaled by
  ``time_unit`` (seconds for DES runs, one tick := 1 "second" for serving);
* each span **event** becomes an instant event (``"ph": "i"``) on the same
  lane, carrying its attrs;
* per-node process-name metadata events label the lanes.

``metrics_flat`` flattens a :class:`~repro.obs.metrics.MetricsRegistry`
into one ``{dotted.key: float}`` dict for benchmark JSON payloads.
"""
from __future__ import annotations

import json
from typing import Optional

__all__ = ["chrome_trace", "metrics_flat"]


def chrome_trace(tracer, path: Optional[str] = None,
                 time_unit: float = 1.0) -> dict:
    """Export a tracer's closed (and still-open) spans.

    ``time_unit`` is seconds per clock unit of the emitter (use e.g. the
    scheduler's tick length for tick-clock tracers). Returns the document;
    writes JSON to ``path`` when given.
    """
    scale = 1e6 * time_unit          # clock units -> microseconds
    events = []
    nodes = set()
    for span in list(tracer.spans()) + list(tracer.open_spans()):
        rid = span.request_id
        for ph in span.phases:
            nodes.add(ph.node)
            events.append({
                "name": ph.name, "ph": "X", "cat": f"cat{span.category}",
                "ts": float(ph.start * scale),
                "dur": float(max(ph.duration, 0.0) * scale),
                "pid": int(ph.node), "tid": int(rid),
                "args": {"request": int(rid), "status": span.status},
            })
        for ev in span.events:
            attrs = dict(ev.attrs)
            node = int(attrs.get("node", -1))
            nodes.add(node)
            events.append({
                "name": ev.name, "ph": "i", "s": "t",
                "cat": f"cat{span.category}", "ts": float(ev.t * scale),
                "pid": node, "tid": int(rid),
                "args": {str(k): _plain(v) for k, v in attrs.items()},
            })
    for node in sorted(nodes):
        events.append({
            "name": "process_name", "ph": "M", "pid": int(node), "tid": 0,
            "args": {"name": f"node {node}" if node >= 0 else "router"},
        })
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc


def _plain(v):
    """JSON-safe scalar."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


def metrics_flat(registry, qs=(50, 95, 99)) -> dict:
    """Flatten a registry to ``{key: float}`` for benchmark payloads.

    Keys: ``<name>.p50`` for global series, ``<name>.node<j>.cat<c>.p95``
    for labelled ones, ``<counter>.node<j>`` / ``<counter>.total`` for
    counters and ``<gauge>`` for gauges.
    """
    out = {}
    for name, summ in registry.summary(qs=qs).items():
        for k, v in summ.items():
            out[f"{name}.{k}"] = float(v)
        for node, cat in registry.labels(name):
            p = registry.percentiles(name, qs, node=node, category=cat)
            tag = name
            if node != -1:
                tag += f".node{node}"
            if cat != -1:
                tag += f".cat{cat}"
            for k, v in p.items():
                out[f"{tag}.{k}"] = float(v)
    for name, vals in registry.counters().items():
        if vals.size == 1:
            out[f"{name}.total"] = float(vals[0])
        else:
            out[f"{name}.total"] = float(vals.sum())
            for j, v in enumerate(vals):
                out[f"{name}.node{j}"] = float(v)
    for name, vals in registry.gauges().items():
        if vals.size == 1:
            out[name] = float(vals[0])
        else:
            for j, v in enumerate(vals):
                out[f"{name}.node{j}"] = float(v)
    return out
