"""Routing-decision audit: one record per ``route()`` call.

Answers "why did it pick node 7?" after the fact: each record snapshots the
policy identity (name + genome), the live feasibility picture (healthy
mask, per-node queue), the per-candidate estimate rows the decision
actually consumed (upload / prefill / tpot / cost / expected hit fraction —
the score breakdown for every estimate-driven policy), the raw policy
decision, the final decision after health failover, and the failover
reason when the two differ.

Records live in a bounded ring buffer like spans; ``explain()`` renders a
human-readable account of one decision. The DES oracles log through the
same ``AuditLog`` as the runtime router, so a decision divergence between
simulation and serving shows up as a diffable record stream.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["RouteAudit", "AuditLog"]


def _tup(x) -> Optional[tuple]:
    """Snapshot an array-ish as a plain tuple of floats (None passes)."""
    if x is None:
        return None
    return tuple(np.asarray(x, np.float64).ravel().round(9).tolist())


@dataclasses.dataclass(frozen=True)
class RouteAudit:
    """One routing decision, fully reconstructible."""

    index: int                     # request index / id
    now: float                     # decision timestamp (emitter's clock)
    policy: str                    # registry name of the deciding policy
    decides: str                   # "pair" | "route"
    genome: Optional[tuple]        # genome vector driving the decision
    raw_decision: int              # policy output before failover
    pair: int                      # final decode (node, model) pair
    node: int                      # final decode node
    prefill_pair: Optional[int]    # disagg prefill pair (None = colocated)
    failover: Optional[str]        # None | "node-down" | "route-endpoint-down"
    healthy: Optional[tuple]       # per-node feasibility mask at decision
    queue: Optional[tuple]         # per-node busy slots at decision
    category: int = -1             # predicted request category
    # per-candidate score breakdown (per-pair rows; None when the policy
    # never requested estimates)
    cand_up: Optional[tuple] = None
    cand_prefill: Optional[tuple] = None
    cand_tpot: Optional[tuple] = None
    cand_cost: Optional[tuple] = None
    cand_hit: Optional[tuple] = None
    est_cost: float = 0.0          # modelled $ of the chosen pair
    backup_pair: Optional[int] = None

    def key(self) -> tuple:
        """Content tuple for stream-equality comparisons."""
        return dataclasses.astuple(self)


class AuditLog:
    """Bounded ring of :class:`RouteAudit` records."""

    def __init__(self, capacity: int = 8192):
        self._records: Deque[RouteAudit] = deque(maxlen=capacity)
        self.capacity = capacity
        self.dropped = 0

    def log(self, rec: RouteAudit) -> None:
        if len(self._records) == self.capacity:
            self.dropped += 1
        self._records.append(rec)

    def record(self, index: int, now: float, policy: str, decides: str,
               genome, raw_decision: int, pair: int, node: int,
               prefill_pair: Optional[int] = None,
               failover: Optional[str] = None, healthy=None, queue=None,
               category: int = -1, up=None, prefill=None, tpot=None,
               cost=None, hit=None, est_cost: float = 0.0,
               backup_pair: Optional[int] = None) -> RouteAudit:
        """Build + log in one call; snapshots all arrays."""
        rec = RouteAudit(
            index=int(index), now=float(now), policy=policy, decides=decides,
            genome=_tup(genome), raw_decision=int(raw_decision),
            pair=int(pair), node=int(node),
            prefill_pair=None if prefill_pair is None else int(prefill_pair),
            failover=failover, healthy=_tup(healthy), queue=_tup(queue),
            category=int(category), cand_up=_tup(up),
            cand_prefill=_tup(prefill), cand_tpot=_tup(tpot),
            cand_cost=_tup(cost), cand_hit=_tup(hit),
            est_cost=float(est_cost),
            backup_pair=None if backup_pair is None else int(backup_pair))
        self.log(rec)
        return rec

    def records(self) -> List[RouteAudit]:
        return list(self._records)

    def for_request(self, index: int) -> List[RouteAudit]:
        return [r for r in self._records if r.index == index]

    def failovers(self) -> List[RouteAudit]:
        return [r for r in self._records if r.failover is not None]

    def counts_by_policy(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self._records:
            out[r.policy] = out.get(r.policy, 0) + 1
        return out

    def explain(self, index: int) -> str:
        """Human-readable account of the decision(s) for one request."""
        recs = self.for_request(index)
        if not recs:
            return f"request {index}: no audit record"
        lines = []
        for r in recs:
            lines.append(
                f"request {r.index} @ {r.now:g}: policy={r.policy} "
                f"({r.decides}) -> pair {r.pair} (node {r.node})")
            if r.prefill_pair is not None and r.prefill_pair != r.pair:
                lines.append(f"  disagg prefill on pair {r.prefill_pair}")
            if r.failover is not None:
                lines.append(f"  failover[{r.failover}]: raw decision "
                             f"{r.raw_decision} overridden")
            if r.queue is not None:
                lines.append("  queue=" +
                             str([int(q) for q in r.queue]))
            if r.cand_cost is not None:
                lines.append("  candidates (up/prefill/tpot/cost):")
                n = len(r.cand_cost)
                for p in range(n):
                    mark = " <-- chosen" if p == r.pair else ""
                    up = r.cand_up[p] if r.cand_up else float("nan")
                    pf = (r.cand_prefill[p] if r.cand_prefill
                          else float("nan"))
                    tp = r.cand_tpot[p] if r.cand_tpot else float("nan")
                    lines.append(f"    pair {p}: {up:.4g}/{pf:.4g}/"
                                 f"{tp:.4g}/${r.cand_cost[p]:.4g}{mark}")
        return "\n".join(lines)

    def __len__(self) -> int:
        return len(self._records)

    def __iter__(self):
        return iter(self._records)

    def clear(self) -> None:
        self._records.clear()
        self.dropped = 0
