"""Unified observability: request-lifecycle spans, percentile metrics, and
routing-decision audit — one telemetry vocabulary shared by the DES oracles
(`cluster.simulator`), the physical serving runtime (`serving.scheduler`)
and fleet mode.

Three small modules, all explicit-clock (no wall time is ever read here;
callers pass their own ``now`` — simulated seconds in the DES, scheduler
ticks in serving):

* :mod:`repro.obs.trace`   — ``Tracer``: per-request span trees with phase
  events (submit, route-decision, queue-wait, prefill, kv-transfer, decode,
  hedge/cancel, retire) in a bounded ring buffer, plus a zero-overhead
  ``NOOP_TRACER``.
* :mod:`repro.obs.metrics` — ``MetricsRegistry``: vectorized numpy
  histograms (fixed log-spaced bucket edges so per-label counts merge
  exactly), counters and gauges; p50/p95/p99 per (node, category).
* :mod:`repro.obs.audit`   — ``AuditLog``: one record per router ``route()``
  call (policy, genome, feasible mask, per-candidate estimate rows, chosen
  pair/route, failover reason) so "why did it pick node 7?" is answerable.
* :mod:`repro.obs.export`  — Chrome-trace/Perfetto JSON for any tracer, and
  a flat metrics dict for benchmarks.

``Obs`` bundles the three so runtime constructors take a single optional
argument; ``Obs.noop()`` (the default everywhere) keeps the hot paths at
method-call cost only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .audit import AuditLog, RouteAudit
from .export import chrome_trace, metrics_flat
from .metrics import Histogram, MetricsRegistry
from .trace import NOOP_TRACER, NoopTracer, Phase, Span, SpanEvent, Tracer

__all__ = [
    "AuditLog", "RouteAudit", "Histogram", "MetricsRegistry",
    "NOOP_TRACER", "NoopTracer", "Obs", "Phase", "Span", "SpanEvent",
    "Tracer", "chrome_trace", "metrics_flat",
]


@dataclasses.dataclass
class Obs:
    """The full telemetry bundle threaded through a run.

    ``Obs()`` gives live instances of all three surfaces; ``Obs.noop()``
    swaps the tracer for the shared no-op and leaves metrics/audit unset so
    consumers skip them entirely.
    """

    tracer: Tracer = dataclasses.field(default_factory=Tracer)
    metrics: Optional[MetricsRegistry] = dataclasses.field(
        default_factory=MetricsRegistry)
    audit: Optional[AuditLog] = dataclasses.field(default_factory=AuditLog)

    @classmethod
    def noop(cls) -> "Obs":
        return cls(tracer=NOOP_TRACER, metrics=None, audit=None)

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled or self.metrics is not None \
            or self.audit is not None
