"""Request-lifecycle span tracing with an explicit clock.

One ``Span`` per request: opened at submit, closed exactly once at retire
(completed / failed / cancelled), carrying two kinds of children:

* **phases** — named intervals ``(name, start, duration, node)`` covering
  the request's wall-to-wall lifetime in the emitter's clock. The emitters
  are written so the phase durations of a completed span sum to its
  recorded completion latency (the span-conservation property,
  tests/test_obs.py).
* **events** — named instants ``(name, t, attrs)``: route-decision,
  dispatch, hedge, cancel, failure, complete, …  Accounting events
  (``dispatch``/``complete``/``failure``/``cancel``) mirror the
  ``ClusterMonitor`` counter calls one-for-one so the span log can be
  cross-checked against ``total_dispatched == completed+failed+cancelled``.

Clock discipline: the tracer NEVER reads wall time. Every mutator takes the
caller's ``now`` — simulated seconds in the DES oracles, scheduler ticks in
the serving runtime. Mixing clocks in one tracer is the caller's bug.

Closed spans live in a bounded ring buffer (``capacity`` newest spans are
kept; ``dropped`` counts evictions). ``NOOP_TRACER`` is the zero-overhead
mode: same API, every method an immediate no-op, shared singleton — hot
paths call it unconditionally and pay one Python method call per event
(benchmarks/obs_overhead.py asserts the fleet warm-throughput cost of that
is within 5%).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Iterator, List, NamedTuple, Optional, Tuple

__all__ = ["Phase", "SpanEvent", "Span", "Tracer", "NoopTracer",
           "NOOP_TRACER"]

#: Canonical phase / event vocabulary. Emitters must not invent names
#: outside this set — the docs and the Chrome-trace colouring key off it.
PHASE_NAMES = ("upload", "queue-wait", "prefill", "kv-transfer",
               "queue-wait-decode", "decode", "download", "serve")
EVENT_NAMES = ("submit", "route-decision", "dispatch", "hedge", "cancel",
               "failure", "complete", "reroute", "handoff-start", "retire",
               "cohort-dispatch", "retry", "timeout", "shed")


class Phase(NamedTuple):
    """A named interval inside a span, in the emitter's clock."""

    name: str
    start: float
    duration: float
    node: int = -1


class SpanEvent(NamedTuple):
    """A named instant inside a span."""

    name: str
    t: float
    attrs: Tuple[Tuple[str, object], ...] = ()


class Span:
    """Lifecycle record of one request. Mutated only via its ``Tracer``."""

    __slots__ = ("request_id", "start", "category", "end", "status",
                 "phases", "events")

    def __init__(self, request_id: int, start: float, category: int = -1):
        self.request_id = request_id
        self.start = start
        self.category = category
        self.end: Optional[float] = None
        self.status = "open"
        self.phases: List[Phase] = []
        self.events: List[SpanEvent] = []

    @property
    def closed(self) -> bool:
        return self.end is not None

    def phase_total(self, names: Optional[Tuple[str, ...]] = None) -> float:
        """Sum of phase durations (optionally restricted to ``names``)."""
        return sum(p.duration for p in self.phases
                   if names is None or p.name in names)

    def key(self) -> tuple:
        """Content tuple for stream-equality comparisons (test oracle)."""
        return (self.request_id, self.start, self.category, self.end,
                self.status, tuple(self.phases), tuple(self.events))

    def rel_key(self) -> tuple:
        """Like :meth:`key` with all timestamps relative to span start —
        the equality oracle for closed-loop DES runs, where the two oracles
        assign requests to clients in different order (identical per-span
        timelines at shifted absolute offsets)."""
        t0 = self.start
        return (self.request_id, self.category,
                None if self.end is None else self.end - t0, self.status,
                tuple(Phase(p.name, p.start - t0, p.duration, p.node)
                      for p in self.phases),
                tuple(SpanEvent(e.name, e.t - t0, e.attrs)
                      for e in self.events))

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"<Span rid={self.request_id} [{self.start}, {self.end}] "
                f"{self.status} phases={len(self.phases)} "
                f"events={len(self.events)}>")


class Tracer:
    """Explicit-clock span recorder with a bounded ring buffer.

    Open spans are keyed by request id; ``end`` moves a span into the
    closed ring exactly once (double-close raises — the conservation
    property is enforced, not hoped for). All methods are cheap pure-Python
    appends; nothing here touches jax or allocates per-token.
    """

    enabled = True

    def __init__(self, capacity: int = 8192):
        self._open: Dict[int, Span] = {}
        self._closed: Deque[Span] = deque(maxlen=capacity)
        self.capacity = capacity
        self.dropped = 0

    # -- span lifecycle ------------------------------------------------------
    def begin(self, rid: int, now: float, category: int = -1) -> None:
        if rid in self._open:
            raise ValueError(f"span {rid} already open")
        self._open[rid] = Span(rid, now, category)

    def end(self, rid: int, now: float, status: str = "completed") -> None:
        span = self._open.pop(rid, None)
        if span is None:
            raise ValueError(f"span {rid} not open (double close?)")
        span.end = now
        span.status = status
        if len(self._closed) == self.capacity:
            self.dropped += 1
        self._closed.append(span)

    def set_category(self, rid: int, category: int) -> None:
        """Late category annotation (serving learns the classifier category
        only when the router decides, after the span opened at submit)."""
        span = self._open.get(rid)
        if span is not None:
            span.category = category

    # -- children ------------------------------------------------------------
    def event(self, rid: int, name: str, now: float, **attrs) -> None:
        span = self._open.get(rid)
        if span is not None:
            span.events.append(
                SpanEvent(name, now, tuple(sorted(attrs.items()))))

    def phase(self, rid: int, name: str, start: float, duration: float,
              node: int = -1) -> None:
        span = self._open.get(rid)
        if span is not None:
            span.phases.append(Phase(name, start, duration, node))

    # -- queries -------------------------------------------------------------
    def spans(self) -> List[Span]:
        """Closed spans, oldest first (bounded by ``capacity``)."""
        return list(self._closed)

    def open_spans(self) -> List[Span]:
        return list(self._open.values())

    def span(self, rid: int) -> Optional[Span]:
        """The open span for ``rid``, or the newest closed one."""
        if rid in self._open:
            return self._open[rid]
        for s in reversed(self._closed):
            if s.request_id == rid:
                return s
        return None

    def __iter__(self) -> Iterator[Span]:
        return iter(self._closed)

    def __len__(self) -> int:
        return len(self._closed)

    def clear(self) -> None:
        self._open.clear()
        self._closed.clear()
        self.dropped = 0


class NoopTracer:
    """API-compatible zero-overhead tracer: every mutator returns
    immediately, every query reports empty. Shared singleton ``NOOP_TRACER``
    is the default everywhere so call sites stay unconditional."""

    enabled = False
    capacity = 0
    dropped = 0

    def begin(self, rid, now, category=-1):
        pass

    def end(self, rid, now, status="completed"):
        pass

    def set_category(self, rid, category):
        pass

    def event(self, rid, name, now, **attrs):
        pass

    def phase(self, rid, name, start, duration, node=-1):
        pass

    def spans(self):
        return []

    def open_spans(self):
        return []

    def span(self, rid):
        return None

    def clear(self):
        pass

    def __iter__(self):
        return iter(())

    def __len__(self):
        return 0


NOOP_TRACER = NoopTracer()
