"""Vectorized metrics: histograms, counters, gauges on numpy.

Design constraints (and why):

* **Fixed log-spaced bucket edges** shared by every histogram. Percentiles
  come from bucket counts, so two histograms of the same metric (e.g. the
  per-node TTFT hists) merge *exactly* by summing their count vectors —
  ``MetricsRegistry.percentiles(name)`` aggregates across labels without
  re-touching raw samples.
* **Vectorized observe**: DES runs ingest whole result arrays in a handful
  of ``np.searchsorted`` + ``np.add.at`` calls; the serving runtime
  observes scalars per retirement. Both land in the same buckets.
* **Label model**: every series is keyed ``(name, node, category)`` with
  ``-1`` meaning "unlabelled/all". The registry auto-maintains the global
  ``(-1, -1)`` series on labelled observes so unqualified percentile
  queries never need a merge.

Canonical metric names (unit = the emitter's clock/currency, documented in
docs/architecture.md): ``ttft``, ``tpot``, ``queue_wait``, ``transfer``,
``cache_hit_frac``, ``spend``, ``latency``.
"""
from __future__ import annotations

import math
from bisect import bisect_right
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

__all__ = ["BUCKET_LO", "BUCKET_HI", "N_BUCKETS", "Histogram",
           "CounterVec", "Gauge", "MetricsRegistry", "METRIC_NAMES"]

#: Metric vocabulary every emitter draws from (free-form names still work,
#: these are the ones the docs/tests pin down).
METRIC_NAMES = ("ttft", "tpot", "queue_wait", "transfer", "cache_hit_frac",
                "spend", "latency")

# Shared bucket layout: 120 log-spaced buckets spanning 1e-6 .. 1e6 plus an
# underflow bucket for values <= lo (zeros included). ~26% resolution per
# bucket; percentile error is bounded by one bucket width and further
# clamped to the observed [min, max].
BUCKET_LO = 1e-6
BUCKET_HI = 1e6
N_BUCKETS = 120

_EDGES = np.logspace(math.log10(BUCKET_LO), math.log10(BUCKET_HI),
                     N_BUCKETS - 1)
# geometric bucket representatives: underflow -> lo, bucket k -> geo-mean
# of its bounds, overflow -> hi
_REPR = np.concatenate([
    [BUCKET_LO],
    np.sqrt(_EDGES[:-1] * _EDGES[1:]),
    [BUCKET_HI],
])
# bisect on a plain list beats np.searchsorted ~10x for single samples —
# the serving retire path observes scalars, and its budget is 5% of fleet
# throughput (benchmarks/obs_overhead.py)
_EDGES_LIST = _EDGES.tolist()


class Histogram:
    """Fixed-edge log histogram with exact count, sum, min and max.

    ``observe`` accepts scalars or arrays. ``percentile(q)`` returns the
    geometric midpoint of the bucket holding the q-th sample, clamped to
    the observed range — so degenerate distributions (all zeros, single
    value) report exactly.
    """

    __slots__ = ("counts", "n", "total", "vmin", "vmax")

    def __init__(self):
        self.counts = np.zeros(N_BUCKETS, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, values) -> None:
        if isinstance(values, (int, float)):
            return self.observe_one(values)
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        idx = np.searchsorted(_EDGES, v, side="right")
        np.add.at(self.counts, idx, 1)
        self.n += v.size
        self.total += float(v.sum())
        self.vmin = min(self.vmin, float(v.min()))
        self.vmax = max(self.vmax, float(v.max()))

    def observe_one(self, value: float) -> None:
        """Scalar fast path (identical buckets to :meth:`observe`)."""
        v = float(value)
        self.counts[bisect_right(_EDGES_LIST, v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def merge(self, other: "Histogram") -> None:
        """Exact merge (same fixed edges everywhere)."""
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else math.nan

    def percentile(self, q: float) -> float:
        """q in [0, 100]; nan when empty."""
        if self.n == 0:
            return math.nan
        rank = q / 100.0 * (self.n - 1)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank + 1))
        est = float(_REPR[min(b, N_BUCKETS - 1)])
        return min(max(est, self.vmin), self.vmax)

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)) -> dict:
        return {f"p{g:g}": self.percentile(g) for g in qs}


class CounterVec:
    """A named vector of monotonic counters (one slot per node, say).

    ``add`` is vectorized: ``add(nodes, values)`` scatters with
    ``np.add.at`` so fleet phase-B commits update per-node token counters
    in one call from the already-host-side stacked arrays.
    """

    __slots__ = ("values",)

    def __init__(self, size: int = 1, dtype=np.int64):
        self.values = np.zeros(size, dtype)

    def add(self, idx=None, amount=1) -> None:
        if idx is None:
            self.values[0] += amount
        elif isinstance(idx, (int, np.integer)):
            self.values[idx] += amount
        else:
            np.add.at(self.values, np.asarray(idx), amount)

    @property
    def total(self):
        return self.values.sum()

    def __getitem__(self, i):
        return self.values[i]


class Gauge:
    """Last-write-wins scalar (or vector) measurement."""

    __slots__ = ("values",)

    def __init__(self, size: int = 1):
        self.values = np.zeros(size, np.float64)

    def set(self, value, idx=None) -> None:
        if idx is None:
            self.values[...] = value
        else:
            self.values[np.asarray(idx)] = value

    def __getitem__(self, i):
        return self.values[i]


LabelKey = Tuple[str, int, int]


class MetricsRegistry:
    """One queryable surface for every series a run produces.

    Histograms are keyed ``(name, node, category)``; counters and gauges by
    name alone (they carry their own vector index). Labelled observes also
    feed the global ``(name, -1, -1)`` series, so ``percentiles("ttft")``
    needs no merge and ``percentiles("ttft", node=3)`` is one lookup.
    """

    def __init__(self):
        self._hists: Dict[LabelKey, Histogram] = {}
        self._counters: Dict[str, CounterVec] = {}
        self._gauges: Dict[str, Gauge] = {}

    # -- histograms ----------------------------------------------------------
    def hist(self, name: str, node: int = -1, category: int = -1
             ) -> Histogram:
        key = (name, node, category)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram()
        return h

    def observe(self, name: str, values, node: int = -1,
                category: int = -1) -> None:
        self.hist(name, node, category).observe(values)
        if node != -1 or category != -1:
            self.hist(name).observe(values)

    def observe_by(self, name: str, values, nodes,
                   categories=None) -> None:
        """Vectorized labelled ingest: group ``values`` by (node, category)
        and observe each group once. One Python iteration per distinct
        label pair, numpy everywhere else."""
        v = np.asarray(values, np.float64).ravel()
        nd = np.broadcast_to(np.asarray(nodes), v.shape)
        ct = (np.broadcast_to(np.asarray(categories), v.shape)
              if categories is not None else np.full(v.shape, -1))
        self.hist(name).observe(v)
        pairs = np.stack([nd, ct], axis=1)
        uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
        for k, (node, cat) in enumerate(uniq):
            self.hist(name, int(node), int(cat)).observe(v[inv == k])

    # -- counters / gauges ---------------------------------------------------
    def counter(self, name: str, size: int = 1) -> CounterVec:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = CounterVec(size)
        return c

    def gauge(self, name: str, size: int = 1) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(size)
        return g

    # -- queries -------------------------------------------------------------
    def percentiles(self, name: str, qs: Sequence[float] = (50, 95, 99),
                    node: Optional[int] = None,
                    category: Optional[int] = None) -> dict:
        """p-summary for one series; None label = aggregate across it."""
        if node is not None and category is not None:
            h = self._hists.get((name, node, category))
        elif node is None and category is None:
            h = self._hists.get((name, -1, -1))
        else:  # one side fixed: exact merge over the free label
            h = Histogram()
            for (n, nd, ct), src in self._hists.items():
                if n != name or nd == -1 and ct == -1:
                    continue
                if (node is None or nd == node) and \
                        (category is None or ct == category):
                    h.merge(src)
        if h is None or h.n == 0:
            return {f"p{g:g}": math.nan for g in qs} | {"n": 0}
        return h.percentiles(qs) | {"n": h.n, "mean": h.mean}

    def summary(self, names: Optional[Iterable[str]] = None,
                qs: Sequence[float] = (50, 95, 99)) -> dict:
        """{name: p-summary} for the global series of each metric name."""
        if names is None:
            names = sorted({k[0] for k in self._hists})
        return {n: self.percentiles(n, qs) for n in names
                if (n, -1, -1) in self._hists}

    def labels(self, name: str) -> list:
        """All (node, category) label pairs recorded for ``name``."""
        return sorted((nd, ct) for (n, nd, ct) in self._hists
                      if n == name and not (nd == -1 and ct == -1))

    def counters(self) -> dict:
        return {n: c.values.copy() for n, c in self._counters.items()}

    def gauges(self) -> dict:
        return {n: g.values.copy() for n, g in self._gauges.items()}
