"""Deterministic, declarative fault schedules.

A :class:`FaultSchedule` is a seed-reproducible description of *when the
cluster misbehaves*: node crash/recover windows, straggler slowdowns,
KV-link degradation/flaps, heartbeat loss without a crash, and per-request
transient dispatch errors. It compiles into a :class:`FaultTables`
NamedTuple of dense float32 arrays consumed identically by

* the JAX fitness scan (``core/fitness.py``, ``EvalConfig(faulty=True)``) —
  so NSGA-II can tune a genome *against* a degraded regime,
* both DES oracles (``cluster/simulator.py`` loop + event heap), and
* the serving runtime (``serving/scheduler.py`` tick hook).

All time-varying lookups come in mirrored numpy/jnp twins
(:func:`node_available_np` ≡ :func:`node_available_jnp`, …) computed
op-for-op in float32 so the three layers stay equivalence-testable under
faults, exactly like the policy decision twins in ``core/policies``.

Transient errors are *counter-hashed*, not sampled: request index ``i``
is mixed through the same splitmix-style uint32 finalizer the p2c-hedge
policy uses, so whether request ``i`` hits a transient error — and its
backoff jitter — is a pure function of ``(seed, i)`` on every layer and
every backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Tuple

import numpy as np

_INF = np.float32(np.inf)
_MIX_C = np.uint32(0x45D9F3B)
_MIX_PHI = 0x9E3779B9   # golden-ratio constant decorrelating hash streams


# ---------------------------------------------------------------------------
# declarative fault vocabulary

@dataclass(frozen=True)
class CrashWindow:
    """Node ``node`` is down (unavailable, fails heartbeats) on
    ``start <= t < end``. In the serving runtime entering the window calls
    ``fail_node`` (KV flushed, inflight rerouted) and leaving it calls
    ``recover_node``."""
    node: int
    start: float
    end: float


@dataclass(frozen=True)
class Straggler:
    """Node ``node`` executes ``factor``x slower on ``start <= t < end``
    (factor >= 1). Analytic layers scale prefill/decode service time;
    engines honor it via executed-iteration scaling (a slowed node
    advances fewer decode iterations per tick)."""
    node: int
    start: float
    end: float
    factor: float


@dataclass(frozen=True)
class LinkFlap:
    """The cluster-wide KV link runs ``factor``x slower on
    ``start <= t < end`` (factor >= 1); transfer times stretch by it."""
    start: float
    end: float
    factor: float


@dataclass(frozen=True)
class HeartbeatLoss:
    """Node ``node`` stops heartbeating on ``start <= t < end`` while its
    engines keep running — the monitor marks it stale and routing avoids
    it, but inflight work completes. A monitoring-plane fault only: the
    analytic layers (fitness scan, DES oracles) model data-plane time and
    treat it as a no-op."""
    node: int
    start: float
    end: float


@dataclass(frozen=True)
class TransientErrors:
    """Per-request transient dispatch failures. Request ``i`` fails its
    first attempt iff ``mix32(seed ^ i) / 2^32 < rate``; the retry lands
    after ``backoff * (1 + jitter * u_i)`` seconds where ``u_i`` is a
    second independent hash stream. Deterministic in ``(seed, i)``."""
    rate: float
    backoff: float = 0.05
    jitter: float = 0.5
    seed: int = 0


# ---------------------------------------------------------------------------
# compiled representation

class FaultTables(NamedTuple):
    """Dense float32 compilation of a FaultSchedule.

    Window arrays are padded to at least one column so the pytree
    structure (and therefore the jitted fitness program) is identical
    whether a fault class is present or not: crash pads with empty
    ``[inf, inf)`` windows, slowdown pads with factor-1.0 windows.
    """
    crash_start: np.ndarray    # (n_nodes, Kc) f32, inf-padded
    crash_end: np.ndarray      # (n_nodes, Kc) f32
    slow_start: np.ndarray     # (n_nodes, Ks) f32
    slow_end: np.ndarray       # (n_nodes, Ks) f32
    slow_factor: np.ndarray    # (n_nodes, Ks) f32, 1.0-padded
    link_start: np.ndarray     # (Kl,) f32
    link_end: np.ndarray       # (Kl,) f32
    link_factor: np.ndarray    # (Kl,) f32, 1.0-padded
    err_rate: np.ndarray       # () f32
    err_backoff: np.ndarray    # () f32
    err_jitter: np.ndarray     # () f32
    err_seed: np.ndarray       # () int32


@dataclass(frozen=True)
class FaultSchedule:
    """A declarative, seed-reproducible fault scenario."""
    crashes: Tuple[CrashWindow, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()
    link_flaps: Tuple[LinkFlap, ...] = ()
    heartbeat_losses: Tuple[HeartbeatLoss, ...] = ()
    transient: TransientErrors = field(
        default_factory=lambda: TransientErrors(rate=0.0))

    def compile(self, n_nodes: int) -> FaultTables:
        """Compile to dense per-node window tables for ``n_nodes``."""
        def windows(items, get_node, cols):
            k = max(1, max((len([w for w in items if get_node(w) == n])
                            for n in range(n_nodes)), default=0))
            out = [np.full((n_nodes, k), pad, np.float32)
                   for pad in cols.values()]
            fill = [0] * n_nodes
            for w in items:
                n = get_node(w)
                assert 0 <= n < n_nodes, f"fault names node {n} of {n_nodes}"
                j = fill[n]
                fill[n] = j + 1
                for out_a, attr in zip(out, cols.keys()):
                    out_a[n, j] = np.float32(getattr(w, attr))
            return out

        crash_start, crash_end = windows(
            self.crashes, lambda w: w.node,
            {"start": _INF, "end": _INF})
        slow_start, slow_end, slow_factor = windows(
            self.stragglers, lambda w: w.node,
            {"start": _INF, "end": _INF, "factor": np.float32(1.0)})
        kl = max(1, len(self.link_flaps))
        link_start = np.full((kl,), _INF, np.float32)
        link_end = np.full((kl,), _INF, np.float32)
        link_factor = np.ones((kl,), np.float32)
        for j, w in enumerate(self.link_flaps):
            link_start[j] = np.float32(w.start)
            link_end[j] = np.float32(w.end)
            link_factor[j] = np.float32(w.factor)
        t = self.transient
        return FaultTables(
            crash_start=crash_start, crash_end=crash_end,
            slow_start=slow_start, slow_end=slow_end,
            slow_factor=slow_factor,
            link_start=link_start, link_end=link_end,
            link_factor=link_factor,
            err_rate=np.float32(t.rate), err_backoff=np.float32(t.backoff),
            err_jitter=np.float32(t.jitter),
            err_seed=np.int32(np.uint32(t.seed).view(np.int32)))

    # -- seeded preset generators ------------------------------------------
    @classmethod
    def crash_storm(cls, n_nodes: int, *, seed: int = 0, n_crashes: int = 4,
                    horizon: float = 60.0, mean_down: float = 8.0,
                    spare: int = 0) -> "FaultSchedule":
        """Repeated node crashes across the horizon. Nodes ``< spare``
        never crash (keeps a fallback alive)."""
        rng = np.random.default_rng(seed)
        eligible = list(range(spare, n_nodes))
        crashes = []
        for _ in range(n_crashes):
            node = int(rng.choice(eligible))
            start = float(rng.uniform(0.05, 0.75) * horizon)
            down = float(rng.exponential(mean_down)) + 1.0
            crashes.append(CrashWindow(node, start, start + down))
        return cls(crashes=tuple(crashes))

    @classmethod
    def link_flap(cls, *, seed: int = 0, n_flaps: int = 3,
                  horizon: float = 60.0, factor: float = 20.0,
                  mean_len: float = 5.0) -> "FaultSchedule":
        """The KV link degrades ``factor``x in short repeated windows."""
        rng = np.random.default_rng(seed)
        flaps = []
        for _ in range(n_flaps):
            start = float(rng.uniform(0.0, 0.8) * horizon)
            dur = float(rng.exponential(mean_len)) + 0.5
            flaps.append(LinkFlap(start, start + dur, factor))
        return cls(link_flaps=tuple(flaps))

    @classmethod
    def straggler_storm(cls, n_nodes: int, *, seed: int = 0,
                        n_stragglers: int = 2, horizon: float = 60.0,
                        factor: float = 4.0,
                        mean_len: float = 15.0) -> "FaultSchedule":
        """A few nodes run ``factor``x slow for stretches of the run."""
        rng = np.random.default_rng(seed)
        slows = []
        for _ in range(n_stragglers):
            node = int(rng.integers(0, n_nodes))
            start = float(rng.uniform(0.0, 0.6) * horizon)
            dur = float(rng.exponential(mean_len)) + 2.0
            slows.append(Straggler(node, start, start + dur, factor))
        return cls(stragglers=tuple(slows))


# ---------------------------------------------------------------------------
# counter hash (splitmix-style uint32 finalizer, p2c-hedge twin pattern)

def _mix32_py(x: int) -> int:
    """uint32 avalanche hash — Python-int reference (masked to 32 bits so
    it is bit-identical to the wrapping uint32 arithmetic of the jnp twin,
    the p2c-hedge twin pattern)."""
    x &= 0xFFFFFFFF
    x = (((x >> 16) ^ x) * int(_MIX_C)) & 0xFFFFFFFF
    x = (((x >> 16) ^ x) * int(_MIX_C)) & 0xFFFFFFFF
    return ((x >> 16) ^ x) & 0xFFFFFFFF


def _mix32_jnp(x):
    import jax.numpy as jnp
    x = x.astype(jnp.uint32)
    c = jnp.uint32(0x45D9F3B)
    x = ((x >> 16) ^ x) * c
    x = ((x >> 16) ^ x) * c
    return (x >> 16) ^ x


# ---------------------------------------------------------------------------
# time-varying lookup twins (float32 op-for-op)

def node_available_np(ft: FaultTables, t) -> np.ndarray:
    """(n_nodes,) bool — node NOT inside any crash window at time t."""
    t = np.float32(t)
    hit = (t >= ft.crash_start) & (t < ft.crash_end)
    return ~np.any(hit, axis=1)


def node_available_jnp(ft, t):
    import jax.numpy as jnp
    t = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    hit = (t >= ft.crash_start) & (t < ft.crash_end)
    return ~jnp.any(hit, axis=1)


def node_slowdown_np(ft: FaultTables, t) -> np.ndarray:
    """(n_nodes,) f32 — max slowdown factor of active windows, else 1."""
    t = np.float32(t)
    active = (t >= ft.slow_start) & (t < ft.slow_end)
    fac = np.where(active, ft.slow_factor, np.float32(1.0))
    return np.max(fac, axis=1).astype(np.float32)


def node_slowdown_jnp(ft, t):
    import jax.numpy as jnp
    t = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    active = (t >= ft.slow_start) & (t < ft.slow_end)
    fac = jnp.where(active, ft.slow_factor, jnp.float32(1.0))
    return jnp.max(fac, axis=1).astype(jnp.float32)


def link_slowdown_np(ft: FaultTables, t) -> np.float32:
    """() f32 — max active KV-link slowdown factor, else 1."""
    t = np.float32(t)
    active = (t >= ft.link_start) & (t < ft.link_end)
    fac = np.where(active, ft.link_factor, np.float32(1.0))
    return np.float32(np.max(fac))


def link_slowdown_jnp(ft, t):
    import jax.numpy as jnp
    t = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    active = (t >= ft.link_start) & (t < ft.link_end)
    fac = jnp.where(active, ft.link_factor, jnp.float32(1.0))
    return jnp.max(fac).astype(jnp.float32)


_U32_SCALE = np.float32(1.0 / 4294967296.0)


def transient_hit_np(ft: FaultTables, i: int) -> bool:
    """Does request ``i`` hit a transient error on its first attempt?"""
    seed = int(np.uint32(np.asarray(ft.err_seed).view(np.uint32)))
    u = np.float32(_mix32_py(seed ^ int(i)) * _U32_SCALE)
    return bool(u < np.float32(ft.err_rate))


def transient_delay_np(ft: FaultTables, i: int) -> np.float32:
    """Added latency (seconds) request ``i`` pays for its transient
    retry; 0 when the request does not hit an error."""
    seed = int(np.uint32(np.asarray(ft.err_seed).view(np.uint32)))
    u = np.float32(_mix32_py(seed ^ int(i)) * _U32_SCALE)
    j = np.float32(_mix32_py(seed ^ int(i) ^ _MIX_PHI) * _U32_SCALE)
    delay = np.float32(ft.err_backoff) * (
        np.float32(1.0) + np.float32(ft.err_jitter) * j)
    return np.where(u < np.float32(ft.err_rate), delay,
                    np.float32(0.0)).astype(np.float32)


def transient_delay_jnp(ft, i):
    import jax.numpy as jnp
    seed = jnp.asarray(ft.err_seed).view(jnp.uint32)
    i = i.astype(jnp.uint32) if hasattr(i, "astype") else jnp.uint32(i)
    u = _mix32_jnp(seed ^ i).astype(jnp.float32) * _U32_SCALE
    j = _mix32_jnp(seed ^ i ^ jnp.uint32(_MIX_PHI)
                   ).astype(jnp.float32) * _U32_SCALE
    delay = ft.err_backoff.astype(jnp.float32) * (
        jnp.float32(1.0) + ft.err_jitter.astype(jnp.float32) * j)
    return jnp.where(u < ft.err_rate.astype(jnp.float32), delay,
                     jnp.float32(0.0)).astype(jnp.float32)


def backoff_jitter_u(seed: int, rid: int, attempt: int) -> float:
    """Uniform [0, 1) jitter for retry ``attempt`` of request ``rid`` —
    the runtime's deterministic exponential-backoff jitter stream."""
    return _mix32_py((int(seed) ^ int(rid) ^ (int(attempt) * _MIX_PHI))
                     & 0xFFFFFFFF) / 4294967296.0


def heartbeat_lost(schedule: FaultSchedule, node: int, t: float) -> bool:
    """Is ``node`` inside a heartbeat-loss window at time ``t``? (Host-side
    only — the monitoring plane is not part of the analytic model.)"""
    return any(w.node == node and w.start <= t < w.end
               for w in schedule.heartbeat_losses)


def jnp_tables(ft: FaultTables):
    """Device copy of the tables for the fitness scan."""
    import jax.numpy as jnp
    return FaultTables(*(jnp.asarray(a) for a in ft))
