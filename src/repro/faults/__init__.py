"""Deterministic fault injection: declarative schedules compiled to dense
tables consumed identically by the fitness scan, both DES oracles, and the
serving runtime. See ``schedule.py``."""
from .schedule import (   # noqa: F401
    CrashWindow,
    FaultSchedule,
    FaultTables,
    HeartbeatLoss,
    LinkFlap,
    Straggler,
    TransientErrors,
    backoff_jitter_u,
    heartbeat_lost,
    jnp_tables,
    link_slowdown_jnp,
    link_slowdown_np,
    node_available_jnp,
    node_available_np,
    node_slowdown_jnp,
    node_slowdown_np,
    transient_delay_jnp,
    transient_delay_np,
    transient_hit_np,
)
