"""Back-compat shim: the policy implementations live in ``core.policies``.

Historically this module held the threshold / SLO / cache-affinity decision
functions and their genome constants; they are now one registered
:class:`~repro.core.policies.base.RoutingPolicy` module each under
``repro/core/policies/`` (the unit of extension — see the registry package
docstring and docs/architecture.md "Policy registry & extension guide").
Every public name keeps importing from here so existing call sites and the
oracle tests stay valid.
"""
from __future__ import annotations

from .policies.affinity import (AFFINITY_BOUNDS_HI, AFFINITY_BOUNDS_LO,
                                AFFINITY_DEFAULTS, AFFINITY_PARAM_NAMES,
                                CACHED_TOKEN_PRICE_FACTOR,
                                decide_pair_affinity_jnp,
                                decide_pair_affinity_py)
from .policies.slo import (SLO_BOUNDS_HI, SLO_BOUNDS_LO, SLO_DEFAULTS,
                           SLO_PARAM_NAMES, _slo_scores_np,
                           decide_pair_slo_jnp, decide_pair_slo_py)
from .policies.threshold import (BOUNDS_HI, BOUNDS_LO, CAT_CODE, CAT_GENERAL,
                                 CAT_MATH, PAPER_DEFAULTS, THRESHOLD_NAMES,
                                 TYPE_CODER, TYPE_INSTRUCT, TYPE_MATH,
                                 Thresholds, decide_pair_jnp, decide_pair_py)

__all__ = [
    "THRESHOLD_NAMES", "BOUNDS_LO", "BOUNDS_HI", "PAPER_DEFAULTS",
    "Thresholds", "decide_pair_jnp", "decide_pair_py",
    "CAT_CODE", "CAT_MATH", "CAT_GENERAL",
    "TYPE_INSTRUCT", "TYPE_CODER", "TYPE_MATH",
    "SLO_PARAM_NAMES", "SLO_BOUNDS_LO", "SLO_BOUNDS_HI", "SLO_DEFAULTS",
    "decide_pair_slo_jnp", "decide_pair_slo_py", "_slo_scores_np",
    "AFFINITY_PARAM_NAMES", "AFFINITY_BOUNDS_LO", "AFFINITY_BOUNDS_HI",
    "AFFINITY_DEFAULTS", "CACHED_TOKEN_PRICE_FACTOR",
    "decide_pair_affinity_jnp", "decide_pair_affinity_py",
]
