"""Threshold routing policy — paper Algorithm 2 ("Runtime LLM Request
Routing") plus the threshold genome the NSGA-II optimizes (§IV-B.6).

Genome layout (6 decision variables, all continuous):

    [θ_d_code, θ_d_math, θ_d_general, θ_q, θ_t_code, θ_t_math]

``decide_pair_jnp`` is the jit-friendly decoder used inside the fitness scan
and by the serving scheduler; ``decide_pair_py`` is a line-by-line Python
transcription of Algorithm 2 used as the test oracle.

Beyond Algorithm 2, this module hosts the **SLO-aware decision mode**
(``decide_pair_slo_jnp`` / ``decide_pair_slo_py``): instead of difficulty
thresholds it estimates each pair's TTFT (upload + predicted queue wait +
prefill) and TPOT against the request's phase deadlines and picks the
*cheapest feasible* pair — deadline-tight requests therefore land on
low-queue/cloud pairs while relaxed ones ride cheap edge pairs. Its genome is

    [γ (deadline headroom, <1 = conservative), κ (est. wait s per unit load)]

searchable by the same NSGA-II via ``TraceEvaluator.make_fitness("slo")``.

Category encoding follows workload.classifier.CATEGORIES:
0 = 'code', 1 = 'math', 2 = 'general'. Model types follow
cluster.spec.MODEL_TYPES: 0 = 'instruct', 1 = 'coder', 2 = 'math',
3 = 'general'.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from ..cluster.spec import ClusterArrays

THRESHOLD_NAMES = ("theta_d_code", "theta_d_math", "theta_d_general",
                   "theta_q", "theta_t_code", "theta_t_math")

# search bounds for NSGA-II (θ_d in [0,1], θ_q in [0, 16] requests,
# θ_t in [0.34, 1] — below 1/3 the classifier confidence gate is vacuous)
BOUNDS_LO = np.array([0.0, 0.0, 0.0, 0.0, 0.34, 0.34], np.float32)
BOUNDS_HI = np.array([1.0, 1.0, 1.0, 16.0, 1.0, 1.0], np.float32)

# paper's illustrative defaults (θ_d,general = 0.5, θ_q = 5, θ_t = 0.7)
PAPER_DEFAULTS = np.array([0.5, 0.5, 0.5, 5.0, 0.7, 0.7], np.float32)

CAT_CODE, CAT_MATH, CAT_GENERAL = 0, 1, 2
TYPE_INSTRUCT, TYPE_CODER, TYPE_MATH = 0, 1, 2


class Thresholds(NamedTuple):
    d_code: jnp.ndarray
    d_math: jnp.ndarray
    d_general: jnp.ndarray
    q: jnp.ndarray
    t_code: jnp.ndarray
    t_math: jnp.ndarray

    @classmethod
    def from_genome(cls, g) -> "Thresholds":
        return cls(*(g[i] for i in range(6)))


def decide_pair_jnp(genome: jnp.ndarray, *, complexity: jnp.ndarray,
                    pred_category: jnp.ndarray, pred_conf: jnp.ndarray,
                    queue_len: jnp.ndarray, arrays: ClusterArrays
                    ) -> jnp.ndarray:
    """Algorithm 2, fully vectorizable. Returns a pair index (int32 scalar).

    Lines reference the paper's pseudo-code:
      5-13: go_edge from per-category difficulty thresholds
      15-17: filter edge nodes by queue (θ_q); none -> cloud fallback
      19-25: model type from classifier confidence gates (θ_t)
      26: first edge node (by node order) hosting the matching model whose
          queue passes; if the chosen type is unavailable on passing nodes,
          fall back to cloud (conservative reading of line 17).
    """
    th = Thresholds.from_genome(genome)
    is_code = pred_category == CAT_CODE
    is_math = pred_category == CAT_MATH

    # Algorithm 2 lines 5-13: note the elif-chain semantics — a code/math
    # request that fails its own threshold still falls through to the
    # general-threshold check (line 9).
    go_edge = ((is_code & (complexity < th.d_code))
               | (is_math & (complexity < th.d_math))
               | (complexity < th.d_general))

    sel_type = jnp.where(is_code & (pred_conf >= th.t_code), TYPE_CODER,
                         jnp.where(is_math & (pred_conf >= th.t_math),
                                   TYPE_MATH, TYPE_INSTRUCT))

    # candidate pairs of the selected type, ordered by node index (-1 pad)
    cand = arrays.edge_pairs_by_type[sel_type]          # (n_edge,)
    cand_valid = cand >= 0
    cand_node = arrays.pair_node[jnp.maximum(cand, 0)]
    cand_q_ok = queue_len[cand_node] <= th.q
    ok = cand_valid & cand_q_ok
    any_ok = jnp.any(ok)
    first = jnp.argmax(ok)                              # first True
    edge_pair = jnp.where(any_ok, cand[first], arrays.cloud_fallback_pair)

    return jnp.where(go_edge, edge_pair,
                     arrays.cloud_fallback_pair).astype(jnp.int32)


def decide_pair_py(genome: Sequence[float], *, complexity: float,
                   pred_category: int, pred_conf: float,
                   queue_len: Sequence[int], arrays: ClusterArrays) -> int:
    """Reference transcription of Algorithm 2 (test oracle)."""
    (d_code, d_math, d_general, th_q, t_code, t_math) = [float(x) for x in genome]
    pair_node = np.asarray(arrays.pair_node)
    edge_by_type = np.asarray(arrays.edge_pairs_by_type)
    fallback = int(arrays.cloud_fallback_pair)

    if pred_category == CAT_CODE and complexity < d_code:
        go_edge = True
    elif pred_category == CAT_MATH and complexity < d_math:
        go_edge = True
    elif complexity < d_general:
        go_edge = True
    else:
        go_edge = False

    if not go_edge:
        return fallback

    if pred_category == CAT_CODE and pred_conf >= t_code:
        sel_type = TYPE_CODER
    elif pred_category == CAT_MATH and pred_conf >= t_math:
        sel_type = TYPE_MATH
    else:
        sel_type = TYPE_INSTRUCT

    for pair in edge_by_type[sel_type]:
        if pair < 0:
            continue
        if queue_len[pair_node[pair]] <= th_q:
            return int(pair)
    return fallback


# ---------------------------------------------------------------------------
# SLO-aware decision mode (QoE extension)
# ---------------------------------------------------------------------------
SLO_PARAM_NAMES = ("gamma", "kappa")

# γ in [0.3, 1.1] (fraction of the deadline budget the estimate may use),
# κ in [0, 20] s of predicted wait at full load.
SLO_BOUNDS_LO = np.array([0.3, 0.0], np.float32)
SLO_BOUNDS_HI = np.array([1.1, 20.0], np.float32)

# sensible hand defaults: 10% headroom, ~3 s wait at a saturated node
SLO_DEFAULTS = np.array([0.9, 3.0], np.float32)


def _slo_scores_np(genome, ttft_deadline, tpot_deadline, up, prefill, tpot,
                   queue_len, node, conc):
    """Shared float32 arithmetic for the numpy oracle (mirrors the jnp path
    op-for-op so argmin tie-breaking is identical)."""
    gamma = np.float32(genome[0])
    kappa = np.float32(genome[1])
    load = queue_len.astype(np.float32) / conc.astype(np.float32)
    est_wait = kappa * load[node]
    est_ttft = up + est_wait + prefill
    # γ headroom hedges the *uncertain* TTFT estimate; TPOT is a known
    # constant per pair, so γ > 1 must not admit guaranteed TPOT misses
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= np.minimum(gamma, np.float32(1.0)) * tpot_deadline)
    overshoot = np.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    return feasible, est_ttft, overshoot


def decide_pair_slo_jnp(genome: jnp.ndarray, *, ttft_deadline: jnp.ndarray,
                        tpot_deadline: jnp.ndarray, up: jnp.ndarray,
                        prefill: jnp.ndarray, tpot: jnp.ndarray,
                        cost: jnp.ndarray, queue_len: jnp.ndarray,
                        arrays: ClusterArrays) -> jnp.ndarray:
    """SLO-aware routing: cheapest pair whose estimated phase times fit the
    deadline budget scaled by γ; if no pair is feasible, minimize the worst
    normalized deadline overshoot (degrades gracefully toward fast pairs).

    ``up``/``prefill``/``cost`` are this request's (n_pairs,) rows of the
    precomputed tables; ``tpot`` is the per-pair decode time (n_pairs,);
    ``queue_len`` is the (n_nodes,) busy-slot view from the monitor.
    """
    gamma = genome[0]
    kappa = genome[1]
    load = queue_len.astype(jnp.float32) / arrays.node_conc.astype(jnp.float32)
    est_wait = kappa * load[arrays.pair_node]
    est_ttft = up + est_wait + prefill
    # γ headroom applies to the uncertain TTFT estimate only; the TPOT term
    # clamps γ at 1 so a searchable γ > 1 cannot admit certain TPOT misses
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= jnp.minimum(gamma, 1.0) * tpot_deadline)
    any_ok = jnp.any(feasible)
    cheapest = jnp.argmin(jnp.where(feasible, cost, jnp.inf))
    overshoot = jnp.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    least_bad = jnp.argmin(overshoot)
    return jnp.where(any_ok, cheapest, least_bad).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Cache-affinity decision mode (prefix-reuse extension)
# ---------------------------------------------------------------------------
AFFINITY_PARAM_NAMES = ("gamma", "kappa", "rho")

# γ, κ as in the SLO genome; ρ in [0, 4] weighs expected prefix-cache hits
# beyond their realized discount (stickiness: a hit now also keeps the
# session's *future* turns cheap on the same node).
AFFINITY_BOUNDS_LO = np.array([0.3, 0.0, 0.0], np.float32)
AFFINITY_BOUNDS_HI = np.array([1.1, 20.0, 4.0], np.float32)
AFFINITY_DEFAULTS = np.array([0.9, 3.0, 1.0], np.float32)

# cached prompt tokens bill at this fraction of the full input price
# (Anthropic/OpenAI-style cached-input discount; vLLM skips the compute)
CACHED_TOKEN_PRICE_FACTOR = 0.1


def decide_pair_affinity_jnp(genome: jnp.ndarray, *,
                             ttft_deadline: jnp.ndarray,
                             tpot_deadline: jnp.ndarray, up: jnp.ndarray,
                             prefill: jnp.ndarray, tpot: jnp.ndarray,
                             cost: jnp.ndarray, prompt_cost: jnp.ndarray,
                             hit_frac: jnp.ndarray, queue_len: jnp.ndarray,
                             arrays: ClusterArrays) -> jnp.ndarray:
    """SLO decision with a cache-hit-probability term: the expected
    cached-prefix fraction (``hit_frac``, per pair) discounts both the
    prefill term of the TTFT estimate and the prompt part of the cost, and
    ``ρ`` adds an affinity bonus for pairs already holding the prefix.
    ``prompt_cost`` is the request's (n_pairs,) prompt-only cost row.
    """
    gamma, kappa, rho = genome[0], genome[1], genome[2]
    load = queue_len.astype(jnp.float32) / arrays.node_conc.astype(jnp.float32)
    est_wait = kappa * load[arrays.pair_node]
    prefill_eff = prefill * (1.0 - hit_frac)
    est_ttft = up + est_wait + prefill_eff
    cost_eff = cost - hit_frac * (1.0 - CACHED_TOKEN_PRICE_FACTOR) * prompt_cost
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= jnp.minimum(gamma, 1.0) * tpot_deadline)
    score = cost_eff - rho * hit_frac * prompt_cost
    any_ok = jnp.any(feasible)
    best = jnp.argmin(jnp.where(feasible, score, jnp.inf))
    overshoot = jnp.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    least_bad = jnp.argmin(overshoot)
    return jnp.where(any_ok, best, least_bad).astype(jnp.int32)


def decide_pair_affinity_py(genome: Sequence[float], *, ttft_deadline: float,
                            tpot_deadline: float, up: np.ndarray,
                            prefill: np.ndarray, tpot: np.ndarray,
                            cost: np.ndarray, prompt_cost: np.ndarray,
                            hit_frac: np.ndarray, queue_len: Sequence[int],
                            arrays: ClusterArrays) -> int:
    """Reference numpy transcription of the affinity decision (test oracle);
    mirrors the jnp path op-for-op so argmin tie-breaking is identical."""
    g = np.asarray(genome, np.float32)
    gamma, kappa, rho = np.float32(g[0]), np.float32(g[1]), np.float32(g[2])
    node = np.asarray(arrays.pair_node)
    conc = np.asarray(arrays.node_conc)
    up = np.asarray(up, np.float32)
    prefill = np.asarray(prefill, np.float32)
    tpot = np.asarray(tpot, np.float32)
    cost = np.asarray(cost, np.float32)
    prompt_cost = np.asarray(prompt_cost, np.float32)
    hit_frac = np.asarray(hit_frac, np.float32)
    ttft_deadline = np.float32(ttft_deadline)
    tpot_deadline = np.float32(tpot_deadline)

    load = np.asarray(queue_len).astype(np.float32) / conc.astype(np.float32)
    est_wait = kappa * load[node]
    prefill_eff = prefill * (np.float32(1.0) - hit_frac)
    est_ttft = up + est_wait + prefill_eff
    cost_eff = cost - hit_frac * np.float32(
        1.0 - CACHED_TOKEN_PRICE_FACTOR) * prompt_cost
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= np.minimum(gamma, np.float32(1.0)) * tpot_deadline)
    score = cost_eff - rho * hit_frac * prompt_cost
    if feasible.any():
        return int(np.argmin(np.where(feasible, score, np.inf)))
    overshoot = np.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    return int(np.argmin(overshoot))


def decide_pair_slo_py(genome: Sequence[float], *, ttft_deadline: float,
                       tpot_deadline: float, up: np.ndarray,
                       prefill: np.ndarray, tpot: np.ndarray,
                       cost: np.ndarray, queue_len: Sequence[int],
                       arrays: ClusterArrays) -> int:
    """Reference numpy transcription of the SLO decision (test oracle)."""
    node = np.asarray(arrays.pair_node)
    conc = np.asarray(arrays.node_conc)
    feasible, est_ttft, overshoot = _slo_scores_np(
        np.asarray(genome, np.float32),
        np.float32(ttft_deadline), np.float32(tpot_deadline),
        np.asarray(up, np.float32), np.asarray(prefill, np.float32),
        np.asarray(tpot, np.float32), np.asarray(queue_len), node, conc)
    if feasible.any():
        return int(np.argmin(np.where(feasible, np.asarray(cost, np.float32),
                                      np.inf)))
    return int(np.argmin(overshoot))
