"""Runtime request router (paper §IV-B.6, "Routing Policy Execution").

Executes a policy π* selected from the NSGA-II Pareto set. The hot path is
``route()``: feature lookup + one registered policy decision — microseconds
per call (the paper claims "millisecond-level routing decisions"; our
benchmark measures it). Beyond the paper (its §VI future work), the router
is fault-aware:

* **failover** — unhealthy nodes are masked from the candidate set; if the
  chosen node is down the request falls back to the cloud pair, or any
  healthy pair as last resort;
* **hedging** — the scheduler may ask for a *backup* pair to duplicate a
  straggling request onto (different node than the primary);
* **re-optimization** — the rolling-horizon control loop implementing (and
  extending) the paper's "small-scale NSGA-II re-optimization triggered
  periodically": ``record`` appends every served request + realized
  objectives to a bounded history; ``should_reoptimize`` fires when the
  monitor's fast/slow EWMA latency gap signals workload drift;
  ``maybe_reoptimize`` rebuilds a trace from the recorded window (open-loop
  when arrival timestamps were recorded, with the recorded SLO deadlines when
  present), re-runs a small NSGA-II over it **warm-started** from the
  previous run's population archive (``evolve_scan(..., archive=)``), and
  installs the re-selected policy parameters. Re-fits are **compile-once**:
  the window trace is padded to a power-of-two bucket
  (``TraceEvaluator(bucket="pow2")``) and the optimizer's generation step is
  a module-level jitted function keyed on static config, so every re-fit
  after the first reuses cached executables (ms-scale instead of an XLA
  retrace per window).

The decision rule itself is pluggable: ``mode=`` names any runtime-capable
policy in the RoutingPolicy registry (``core.policies.runtime_policies()``
— "threshold", "slo", "affinity", "p2c-hedge", "budget", ...). The router
consults the policy's declared ``requires`` set to decide which inputs to
assemble per request (per-pair phase/cost estimates, deadline contract,
prefix-cache hit fractions), builds one ``PolicyInputs`` bundle, and calls
``policy.decide_py``. Per-policy runtime state (e.g. the budget policy's
spend ledger) is threaded through ``update_py`` after every decision.
Unknown mode names raise ``ValueError`` listing the registered policies;
the re-optimization loop derives its NSGA-II genome encoding from the same
policy object (``NSGA2Config.from_policy``), so a newly registered policy
drives the router — including re-fit — with zero edits here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..cluster.monitor import ClusterMonitor
from ..cluster.spec import ClusterArrays, ClusterSpec
from ..learn import LearnConfig, OnlineEstimator
from ..learn import estimators as learn_est
from ..workload.classifier import classify
from ..workload.datasets import Request
from ..workload.features import complexity_score
from ..workload.slo import DEFAULT_SLO_TABLE, slo_arrays
from .fitness import request_pair_estimates
from .policies import (PolicyInputs, get_policy, list_policies,
                       runtime_policies)


@dataclasses.dataclass
class RouteDecision:
    pair: int
    node: int
    model: int
    go_edge: bool
    features: Tuple[float, int, float]   # (c_i, t_i, p_t)
    backup_pair: Optional[int] = None
    # disaggregated serving (route-valued policies): the prefill leg's pair.
    # None for ordinary pair decisions; equal to ``pair`` on colocated
    # routes. ``pair`` is always the decode (billing/retirement) pair.
    prefill_pair: Optional[int] = None
    # modelled $ of the chosen pair (0.0 when the policy never requested
    # estimate rows); the serving scheduler's "spend" metric observation
    est_cost: float = 0.0
    # the (possibly learned-corrected) estimates the decision acted on —
    # fed back through ``record(ttft=, tpot=)`` as the expected side of the
    # online estimator's realized-vs-estimated residual targets. Zero when
    # the policy never requested estimate rows.
    est_ttft: float = 0.0
    est_tpot: float = 0.0
    est_quality: float = 0.0


@dataclasses.dataclass
class Observation:
    """One served request in the router's rolling history window."""

    req: Request
    pair: int
    features: Tuple[float, int, float]
    quality: float
    cost: float
    rt: float
    now: Optional[float] = None            # arrival timestamp (open loop)
    ttft_deadline: Optional[float] = None  # QoE contract, if any
    tpot_deadline: Optional[float] = None


class RequestRouter:
    def __init__(self, cluster: ClusterSpec, thresholds=None,
                 monitor: Optional[ClusterMonitor] = None,
                 hedge_factor: float = 3.0, mode: str = "threshold",
                 slo_params: Optional[Sequence[float]] = None,
                 slo_table=DEFAULT_SLO_TABLE,
                 affinity_params: Optional[Sequence[float]] = None,
                 cache_block: int = 16,
                 params: Optional[Sequence[float]] = None,
                 audit=None, learned: bool = False,
                 learner: LearnConfig = LearnConfig()):
        self.policy = get_policy(mode)     # ValueError lists registry names
        if self.policy.genome_spec.per_request:
            raise ValueError(
                f"policy {self.policy.name!r} has a per-request genome and "
                f"cannot drive the runtime router; runtime-capable policies: "
                f"{', '.join(runtime_policies())}")
        self.cluster = cluster
        self.arrays: ClusterArrays = cluster.to_arrays()
        # per-policy genome store, seeded from every registered policy's
        # GenomeSpec defaults; explicit ctor args override their slot
        self._params: Dict[str, np.ndarray] = {}
        for name in list_policies():
            spec = get_policy(name).genome_spec
            if spec.defaults is not None:
                self._params[name] = np.asarray(spec.defaults, np.float32)
        if thresholds is not None:
            self._params["threshold"] = np.asarray(thresholds, np.float32)
        if slo_params is not None:
            self._params["slo"] = np.asarray(slo_params, np.float32)
        if affinity_params is not None:
            self._params["affinity"] = np.asarray(affinity_params, np.float32)
        if params is not None:
            self._params[self.policy.name] = np.asarray(params, np.float32)
        self.cache_block = cache_block
        self._slo_ttft, self._slo_tpot = slo_arrays(slo_table)
        self.monitor = monitor or ClusterMonitor(len(cluster.nodes))
        self.hedge_factor = hedge_factor
        # optional repro.obs.AuditLog: every route() call logs its
        # per-candidate decision breakdown (None = zero overhead)
        self.audit = audit
        self._rng = np.random.default_rng(0)
        # numpy view of the pair table, converted once: the per-request hot
        # path must not pay device-to-host transfers on every decision
        self._np_arrays = self.arrays.numpy()
        # online learned estimators (repro.learn): corrections ride on the
        # monitor so the scheduler's completion path can feed observations
        # without holding a router reference
        self.learned = learned
        self.learner = learner
        if learned and self.monitor.estimator is None:
            self.monitor.estimator = OnlineEstimator(
                learner, len(cluster.nodes),
                node_conc=self._np_arrays.node_conc)
        self._pair_node = self._np_arrays.pair_node
        self._pair_is_edge = self._np_arrays.pair_is_edge
        self._n_pairs = len(self._pair_node)
        self._pstate = self.policy.init_state()  # per-policy runtime state
        self._history: list = []        # Observation rolling window
        self._archive = None            # (P, D) genomes from the last re-opt
        self._n_recorded = 0            # monotone (history list is trimmed)
        self._last_reopt_at = 0         # _n_recorded at the last re-fit
        self._n_routed = 0              # decision counter (PolicyInputs.index)

    # -- params compatibility views ------------------------------------------
    @property
    def mode(self) -> str:
        return self.policy.name

    @property
    def params(self) -> np.ndarray:
        """Active policy's genome."""
        return self._params[self.policy.name]

    @params.setter
    def params(self, value) -> None:
        self._params[self.policy.name] = np.asarray(value, np.float32)

    @property
    def thresholds(self) -> np.ndarray:
        return self._params["threshold"]

    @thresholds.setter
    def thresholds(self, value) -> None:
        self._params["threshold"] = np.asarray(value, np.float32)

    @property
    def slo_params(self) -> np.ndarray:
        return self._params["slo"]

    @slo_params.setter
    def slo_params(self, value) -> None:
        self._params["slo"] = np.asarray(value, np.float32)

    @property
    def affinity_params(self) -> np.ndarray:
        return self._params["affinity"]

    @affinity_params.setter
    def affinity_params(self, value) -> None:
        self._params["affinity"] = np.asarray(value, np.float32)

    # -- hot path -------------------------------------------------------------
    def route(self, req: Request, want_backup: bool = False,
              ttft_deadline: Optional[float] = None,
              tpot_deadline: Optional[float] = None,
              now: Optional[float] = None) -> RouteDecision:
        """Route one request through the active policy.

        Explicit per-request deadlines override the per-category SLO table
        defaults (consumed by policies declaring the "deadlines"
        requirement). ``now`` is the decision timestamp for time-windowed
        policies (e.g. the budget ledger); it defaults to the router's
        request counter (pseudo-seconds: one window = WINDOW_S requests).
        Callers driving a time-windowed policy under real/simulated
        timestamps — in particular anyone also passing ``now=`` to
        :meth:`record`, whose re-fit evaluates the genome against those
        recorded trace-seconds — must pass the same clock here, or the
        tuned window budget is applied on a different time base than the
        one NSGA-II optimized it for."""
        pol = self.policy
        pred_cat, conf = classify(req, self._rng)
        c_i = complexity_score(req, pred_cat)
        queue = self.monitor.queue_lengths()
        healthy = self.monitor.healthy_mask()

        # mask unhealthy nodes by making their queues look infinite
        masked_queue = np.asarray(
            [q if healthy[j] else 10 ** 6 for j, q in enumerate(queue)],
            np.int64)

        zeros = np.zeros(self._n_pairs, np.float32)
        up = prefill = tpot = cost = prompt_cost = zeros
        if "estimates" in pol.requires:
            est = request_pair_estimates(req.prompt_tokens,
                                         req.resp_tokens_mean,
                                         req.query_bytes, self._np_arrays)
            # unhealthy nodes: push their pairs out of feasibility
            dead = ~np.asarray(healthy)[self._pair_node]
            up = np.where(dead, np.float32(1e9), est["up"])
            prefill, tpot = est["prefill"], est["tpot"]
            cost, prompt_cost = est["cost"], est["prompt_cost"]
        # static expected-quality prior: the build_tables q_mean formula with
        # the observable complexity score standing in for latent difficulty
        quality_row = np.clip(
            np.asarray(self._np_arrays.pair_base_quality,
                       np.float32)[:, req.task_id]
            + np.asarray(self._np_arrays.pair_diff_slope, np.float32)
            * (np.float32(0.5) - np.float32(c_i)),
            np.float32(0.0), np.float32(1.0)).astype(np.float32)
        unc_row = zeros
        if self.learned and self.monitor.estimator is not None:
            d_p, d_t, d_q, unc_n = self.monitor.estimator.predict(
                pred_cat, req.prompt_tokens, c_i, masked_queue,
                self._np_arrays.node_conc)
            prefill, tpot, quality_row, unc_row = learn_est.corrected_rows(
                np, np.asarray(prefill, np.float32),
                np.asarray(tpot, np.float32), quality_row, d_p, d_t, d_q,
                unc_n, self._pair_node)
        ttft_dl = (ttft_deadline if ttft_deadline is not None
                   else float(self._slo_ttft[pred_cat]))
        tpot_dl = (tpot_deadline if tpot_deadline is not None
                   else float(self._slo_tpot[pred_cat]))
        hit = zeros
        if "cache" in pol.requires:
            hit_node = self.monitor.hit_fractions(
                getattr(req, "session_id", -1),
                getattr(req, "sys_id", -1), float(req.prompt_tokens),
                float(getattr(req, "sys_tokens", 0)),
                block=self.cache_block)
            hit = np.asarray(hit_node, np.float32)[self._pair_node]
        kv_bytes = zeros
        if "transfer" in pol.requires:
            blk = float(self.cache_block)
            kv_blk = np.float32(np.floor(
                np.float32(req.prompt_tokens) / np.float32(blk)) * blk)
            kv_bytes = (kv_blk * np.asarray(
                self._np_arrays.pair_kv_bytes_per_token,
                np.float32)).astype(np.float32)

        inp = PolicyInputs(
            index=np.int32(self._n_routed),
            now=np.float32(self._n_routed if now is None else now),
            complexity=np.float32(c_i), pred_category=np.int32(pred_cat),
            pred_conf=np.float32(conf), ttft_deadline=np.float32(ttft_dl),
            tpot_deadline=np.float32(tpot_dl),
            prompt_tokens=np.float32(req.prompt_tokens),
            up=up, prefill=prefill, tpot=tpot, cost=cost,
            prompt_cost=prompt_cost, hit_frac=hit, queue_len=masked_queue,
            kv_bytes=kv_bytes, quality=quality_row, unc=unc_row)
        decision = int(pol.decide_py(self.params, inp, self._np_arrays,
                                     self._pstate))
        raw_decision = decision
        failover = None

        prefill_pair = None
        if pol.decides == "route":
            # route-valued decision: resolve the (prefill, decode) legs;
            # ``pair`` is the decode pair from here on
            rp = self._np_arrays.route_prefill
            rq = self._np_arrays.route_decode
            prefill_pair, pair = int(rp[decision]), int(rq[decision])
            node_p = int(self._pair_node[prefill_pair])
            node = int(self._pair_node[pair])
            if not (healthy[node_p] and healthy[node]):
                # failover: re-pick among routes with both endpoints healthy,
                # preferring colocated ones (no handoff exposure while the
                # cluster is degraded), then least-loaded decode node
                cands = [r for r in range(len(rp))
                         if healthy[self._pair_node[rp[r]]]
                         and healthy[self._pair_node[rq[r]]]]
                if not cands:
                    raise RuntimeError("no healthy nodes in cluster")
                colo = [r for r in cands if rp[r] == rq[r]]
                pool = colo or cands
                decision = min(pool,
                               key=lambda r: queue[self._pair_node[rq[r]]])
                prefill_pair, pair = int(rp[decision]), int(rq[decision])
                node = int(self._pair_node[pair])
                failover = "route-endpoint-down"
        else:
            pair = decision
            node = int(self._pair_node[pair])

            # failover: if the policy returned a pair on a dead node (e.g.
            # the cloud fallback itself is down), pick any healthy pair
            if not healthy[node]:
                alive = [p for p in range(self._n_pairs)
                         if healthy[self._pair_node[p]]]
                if not alive:
                    raise RuntimeError("no healthy nodes in cluster")
                # prefer healthy cloud, then least-loaded healthy edge
                cloud_alive = [p for p in alive if not self._pair_is_edge[p]]
                pair = (cloud_alive[0] if cloud_alive else
                        min(alive, key=lambda p: queue[self._pair_node[p]]))
                node = int(self._pair_node[pair])
                failover = "node-down"

        # policy state advances on the pair actually dispatched (post
        # failover) so e.g. the budget ledger bills real spend, and only for
        # requests that are dispatched at all (the no-healthy-nodes raise
        # above leaves the state untouched)
        self._pstate = pol.update_py(self.params, self._pstate, inp, pair,
                                     float(cost[pair]))
        self._n_routed += 1

        backup = None
        if want_backup:
            backup = self.backup_pair(pair)
        if self.audit is not None:
            self.audit.record(
                int(inp.index), float(inp.now), pol.name, pol.decides,
                self.params, raw_decision, pair, node,
                prefill_pair=prefill_pair, failover=failover,
                healthy=np.asarray(healthy, np.float64), queue=masked_queue,
                category=int(pred_cat),
                up=up if "estimates" in pol.requires else None,
                prefill=prefill if "estimates" in pol.requires else None,
                tpot=tpot if "estimates" in pol.requires else None,
                cost=cost if "estimates" in pol.requires else None,
                hit=hit if "cache" in pol.requires else None,
                est_cost=float(cost[pair]), backup_pair=backup)
        # the estimates this decision acted on (TTFT on the prefill leg,
        # TPOT on the decode pair) — the "expected" side of the estimator's
        # residual targets fed back via record()
        pp = pair if prefill_pair is None else prefill_pair
        return RouteDecision(
            pair=int(pair), node=node,
            model=int(self._np_arrays.pair_model[pair]),
            go_edge=bool(self._pair_is_edge[pair]),
            features=(c_i, pred_cat, conf), backup_pair=backup,
            prefill_pair=prefill_pair, est_cost=float(cost[pair]),
            est_ttft=float(up[pp] + prefill[pp]),
            est_tpot=float(tpot[pair]),
            est_quality=float(quality_row[pair]))

    def backup_pair(self, primary: int) -> Optional[int]:
        """A healthy pair on a *different* node, for hedged duplicates."""
        healthy = self.monitor.healthy_mask()
        pnode = int(self._pair_node[primary])
        cands = [p for p in range(self._n_pairs)
                 if int(self._pair_node[p]) != pnode
                 and healthy[self._pair_node[p]]]
        if not cands:
            return None
        # cheapest viable alternative: cloud if primary was edge, else the
        # least-loaded edge instruct pair
        queue = self.monitor.queue_lengths()
        return min(cands, key=lambda p: (queue[self._pair_node[p]],
                                         self._pair_is_edge[p]))

    # -- feedback & re-optimization --------------------------------------------
    def record(self, req: Request, decision: RouteDecision, quality: float,
               cost: float, rt: float, now: Optional[float] = None,
               ttft_deadline: Optional[float] = None,
               tpot_deadline: Optional[float] = None,
               ttft: Optional[float] = None,
               tpot: Optional[float] = None) -> None:
        """Append one served request + realized objectives to the rolling
        history window ``maybe_reoptimize`` re-fits against. ``now`` is the
        request's arrival timestamp (enables open-loop re-fitting); the
        deadline pair is its QoE contract if it carried one. Realized
        ``ttft``/``tpot`` (caller clock units — the estimator's ratio
        residual absorbs the scale) close the learning loop: each is turned
        into a realized-vs-estimated residual against the decision's own
        estimates and fed to the monitor's :class:`OnlineEstimator`."""
        est_obj = self.monitor.estimator
        if est_obj is not None and (ttft is not None or tpot is not None):
            y_p = (OnlineEstimator.ratio(decision.est_ttft, ttft)
                   if ttft is not None else 0.0)
            y_t = (OnlineEstimator.ratio(decision.est_tpot, tpot)
                   if tpot is not None else 0.0)
            y_q = (float(np.float32(quality)
                         - np.float32(decision.est_quality))
                   if decision.est_quality > 0.0 else 0.0)
            node_p = (decision.node if decision.prefill_pair is None
                      else int(self._pair_node[decision.prefill_pair]))
            self.monitor.feed_estimator(
                int(decision.features[1]), node_p, decision.node,
                req.prompt_tokens, float(decision.features[0]),
                y_p, y_t, y_q)
        self._history.append(Observation(
            req=req, pair=decision.pair, features=decision.features,
            quality=quality, cost=cost, rt=rt, now=now,
            ttft_deadline=ttft_deadline, tpot_deadline=tpot_deadline))
        self._n_recorded += 1
        if len(self._history) > 10000:
            self._history = self._history[-5000:]

    @property
    def history_size(self) -> int:
        return len(self._history)

    def should_reoptimize(self, drift_threshold: float = 0.25,
                          min_history: int = 64,
                          min_new: int = 32) -> bool:
        """Drift trigger: re-optimize when the monitor's fast EWMA latency
        has moved more than ``drift_threshold`` (relative) away from its slow
        baseline, enough history is banked to re-fit on, and at least
        ``min_new`` requests were observed since the last re-fit (cooldown —
        together with the post-re-fit drift re-baseline this makes one
        regime shift trigger one re-fit, not one per check)."""
        return (len(self._history) >= min_history
                and self._n_recorded - self._last_reopt_at >= min_new
                and self.monitor.drift_score() >= drift_threshold)

    def maybe_reoptimize(self, window: int = 256, generations: int = 20,
                         pop_size: int = 32,
                         weights: Optional[Sequence[float]] = None,
                         seed: int = 0, concurrency: int = 4,
                         drift_threshold: float = 0.25,
                         min_history: int = 64,
                         force: bool = False) -> Optional[np.ndarray]:
        """Rolling-horizon re-optimization (paper §IV-B.6, made real).

        Unless ``force``, runs only when :meth:`should_reoptimize` fires.
        Re-fits a small NSGA-II against the last ``window`` *recorded*
        requests: the observed trace is rebuilt with
        ``workload.trace.trace_from_requests`` (open-loop at the recorded
        arrival timestamps when every observation carries one, closed-loop
        with ``concurrency`` clients otherwise; with the recorded deadlines
        and the 4-objective QoE fitness when every observation carries a
        contract). The genome encoding and fitness kind come from the active
        policy's registry entry, so any registered policy re-fits here. The
        search is warm-started from the previous re-opt's survival-ordered
        population (``evolve_scan(..., archive=)``), then the Eq. (1)
        weighted-sum pick (uniform ``weights`` by default) replaces the live
        policy parameters. Returns them, or None if skipped.
        """
        from ..workload.trace import trace_from_requests
        from .fitness import EvalConfig, TraceEvaluator
        from .nsga2 import NSGA2, NSGA2Config

        if not force and not self.should_reoptimize(drift_threshold,
                                                    min_history):
            return None
        obs = self._history[-window:]
        if not obs:
            return None
        pol = self.policy

        arrivals = None
        if all(o.now is not None for o in obs):
            t = np.asarray([o.now for o in obs], np.float32)
            if (np.diff(t) >= 0).all():
                arrivals = t
        trace = trace_from_requests([o.req for o in obs], seed=seed,
                                    arrival_time=arrivals)
        # re-fit against the features the live router actually observed and
        # acted on, not a fresh classifier noise draw
        trace.complexity = np.asarray([o.features[0] for o in obs],
                                      np.float32)
        trace.pred_category = np.asarray([o.features[1] for o in obs],
                                         np.int32)
        trace.pred_conf = np.asarray([o.features[2] for o in obs],
                                     np.float32)
        if all(o.ttft_deadline is not None and o.tpot_deadline is not None
               for o in obs):
            trace.ttft_deadline = np.asarray(
                [o.ttft_deadline for o in obs], np.float32)
            trace.tpot_deadline = np.asarray(
                [o.tpot_deadline for o in obs], np.float32)
        elif "deadlines" in pol.requires:
            # deadline-aware genomes are meaningless against +inf deadlines
            # (every parameter vector is equally feasible -> degenerate flat
            # fitness): fall back to the per-category table defaults
            # route() applies
            cat = trace.pred_category
            trace.ttft_deadline = self._slo_ttft[cat].astype(np.float32)
            trace.tpot_deadline = self._slo_tpot[cat].astype(np.float32)

        cfg_eval = EvalConfig(
            mode="open" if arrivals is not None else "queued",
            concurrency=concurrency,
            # re-fit against the cache dynamics the window actually had
            prefix_cache=(arrivals is not None and trace.has_sessions),
            cache_block=self.cache_block,
            # route-valued policies re-fit against the disaggregated model
            disaggregated=pol.decides == "route",
            # re-fit with the same estimator loop the live router runs, so
            # the tuned genome is optimal for corrected (not static-prior)
            # estimate rows
            learned=self.learned, learner=self.learner)
        # bucketed (compile-once) evaluation: windows of different lengths
        # pad to the same power-of-two bucket, so every re-fit after the
        # first reuses the compiled trace-eval + NSGA-II executables instead
        # of paying an XLA retrace per drifting window
        evaluator = TraceEvaluator(trace, self.cluster, cfg_eval,
                                   bucket="pow2")

        # genome encoding from the active policy's registry entry
        cfg = NSGA2Config.from_policy(pol, pop_size=pop_size,
                                      n_generations=generations)
        objectives = "qoe" if trace.has_slos else "paper"
        opt = NSGA2(evaluator.make_fitness(pol.name, objectives=objectives),
                    cfg)
        # warm start from the previous re-fit's survival-ordered population;
        # the archive is a dynamic argument (same shape every re-fit), so
        # warm-started runs share the compiled executable too
        archive = self._archive
        if archive is not None and archive.shape[1] != cfg.n_genes:
            archive = None              # policy switched since the last fit
        state = opt.evolve_scan(jax.random.key(seed), generations,
                                archive=archive)
        # archive the survival-ordered population for the next warm start
        self._archive = np.asarray(state.genomes)

        M = state.F_raw.shape[1]
        w = (jnp.full((M,), 1.0 / M) if weights is None
             else jnp.asarray(weights, jnp.float32))
        genome, _ = opt.select_by_weights(state, w)
        self.params = np.asarray(genome, np.float32)
        # cooldown: re-arm the drift detector for the *next* regime shift
        self._last_reopt_at = self._n_recorded
        self.monitor.rebaseline_drift()
        return self.params
