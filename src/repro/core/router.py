"""Runtime request router (paper §IV-B.6, "Routing Policy Execution").

Executes a policy π* selected from the NSGA-II Pareto set. The hot path is
``route()``: feature lookup + Algorithm 2 threshold rules — microseconds per
decision (the paper claims "millisecond-level routing decisions"; our
benchmark measures it). Beyond the paper (its §VI future work), the router is
fault-aware:

* **failover** — unhealthy nodes are masked from the candidate set; if the
  chosen node is down the request falls back to the cloud pair, or any
  healthy pair as last resort;
* **hedging** — the scheduler may ask for a *backup* pair to duplicate a
  straggling request onto (different node than the primary);
* **re-optimization** — the rolling-horizon control loop implementing (and
  extending) the paper's "small-scale NSGA-II re-optimization triggered
  periodically": ``record`` appends every served request + realized
  objectives to a bounded history; ``should_reoptimize`` fires when the
  monitor's fast/slow EWMA latency gap signals workload drift;
  ``maybe_reoptimize`` rebuilds a trace from the recorded window (open-loop
  when arrival timestamps were recorded, with the recorded SLO deadlines when
  present), re-runs a small NSGA-II over it **warm-started** from the
  previous run's population archive (``evolve_scan(..., archive=)``), and
  installs the re-selected policy parameters. Re-fits are **compile-once**:
  the window trace is padded to a power-of-two bucket
  (``TraceEvaluator(bucket="pow2")``) and the optimizer's generation step is
  a module-level jitted function keyed on static config, so every re-fit
  after the first reuses cached executables (ms-scale instead of an XLA
  retrace per window).

Three decision modes (``mode=``):

* ``"threshold"`` — the paper's Algorithm 2 over difficulty/queue/confidence
  thresholds;
* ``"slo"`` — QoE-aware phase-split routing: estimates each pair's TTFT and
  TPOT against the request's (per-category or explicit) deadlines and picks
  the cheapest feasible pair (see ``core.policy.decide_pair_slo_py`` and
  ``workload.slo``);
* ``"affinity"`` — cache-affinity routing: the SLO decision with the
  monitor's per-node prefix-cache state folded in — the expected
  cached-prefix fraction discounts the prefill term of the TTFT estimate and
  the cached prompt tokens' price, and ρ adds stickiness toward nodes
  already holding the session's (or shared system prompt's) KV
  (``core.policy.decide_pair_affinity_py``, ``serving.kvcache``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..cluster.monitor import ClusterMonitor
from ..cluster.spec import ClusterArrays, ClusterSpec
from ..workload.classifier import classify
from ..workload.datasets import Request
from ..workload.features import complexity_score
from ..workload.slo import DEFAULT_SLO_TABLE, slo_arrays
from .fitness import request_pair_estimates
from .policy import (AFFINITY_DEFAULTS, SLO_DEFAULTS,
                     decide_pair_affinity_py, decide_pair_py,
                     decide_pair_slo_py)


@dataclasses.dataclass
class RouteDecision:
    pair: int
    node: int
    model: int
    go_edge: bool
    features: Tuple[float, int, float]   # (c_i, t_i, p_t)
    backup_pair: Optional[int] = None


@dataclasses.dataclass
class Observation:
    """One served request in the router's rolling history window."""

    req: Request
    pair: int
    features: Tuple[float, int, float]
    quality: float
    cost: float
    rt: float
    now: Optional[float] = None            # arrival timestamp (open loop)
    ttft_deadline: Optional[float] = None  # QoE contract, if any
    tpot_deadline: Optional[float] = None


class RequestRouter:
    def __init__(self, cluster: ClusterSpec, thresholds: Sequence[float],
                 monitor: Optional[ClusterMonitor] = None,
                 hedge_factor: float = 3.0, mode: str = "threshold",
                 slo_params: Optional[Sequence[float]] = None,
                 slo_table=DEFAULT_SLO_TABLE,
                 affinity_params: Optional[Sequence[float]] = None,
                 cache_block: int = 16):
        assert mode in ("threshold", "slo", "affinity")
        self.cluster = cluster
        self.arrays: ClusterArrays = cluster.to_arrays()
        self.thresholds = np.asarray(thresholds, np.float32)
        self.mode = mode
        self.slo_params = np.asarray(
            SLO_DEFAULTS if slo_params is None else slo_params, np.float32)
        self.affinity_params = np.asarray(
            AFFINITY_DEFAULTS if affinity_params is None else affinity_params,
            np.float32)
        self.cache_block = cache_block
        self._slo_ttft, self._slo_tpot = slo_arrays(slo_table)
        self.monitor = monitor or ClusterMonitor(len(cluster.nodes))
        self.hedge_factor = hedge_factor
        self._rng = np.random.default_rng(0)
        # numpy view of the pair table, converted once: the per-request hot
        # path must not pay device-to-host transfers on every decision
        self._np_arrays = ClusterArrays(*(np.asarray(a) for a in self.arrays))
        self._pair_node = self._np_arrays.pair_node
        self._pair_is_edge = self._np_arrays.pair_is_edge
        self._history: list = []        # Observation rolling window
        self._archive = None            # (P, D) genomes from the last re-opt
        self._n_recorded = 0            # monotone (history list is trimmed)
        self._last_reopt_at = 0         # _n_recorded at the last re-fit

    # -- hot path -------------------------------------------------------------
    def route(self, req: Request, want_backup: bool = False,
              ttft_deadline: Optional[float] = None,
              tpot_deadline: Optional[float] = None) -> RouteDecision:
        """Route one request. In ``slo`` mode explicit per-request deadlines
        override the per-category SLO table defaults."""
        pred_cat, conf = classify(req, self._rng)
        c_i = complexity_score(req, pred_cat)
        queue = self.monitor.queue_lengths()
        healthy = self.monitor.healthy_mask()

        # mask unhealthy nodes by making their queues look infinite
        masked_queue = [q if healthy[j] else 10 ** 6
                        for j, q in enumerate(queue)]

        if self.mode in ("slo", "affinity"):
            est = request_pair_estimates(req.prompt_tokens,
                                         req.resp_tokens_mean,
                                         req.query_bytes, self._np_arrays)
            # unhealthy nodes: push their pairs out of feasibility
            dead = ~np.asarray(healthy)[self._pair_node]
            up = np.where(dead, np.float32(1e9), est["up"])
            ttft_dl = (ttft_deadline if ttft_deadline is not None
                       else float(self._slo_ttft[pred_cat]))
            tpot_dl = (tpot_deadline if tpot_deadline is not None
                       else float(self._slo_tpot[pred_cat]))
            if self.mode == "affinity":
                hit_node = self.monitor.hit_fractions(
                    getattr(req, "session_id", -1),
                    getattr(req, "sys_id", -1), float(req.prompt_tokens),
                    float(getattr(req, "sys_tokens", 0)),
                    block=self.cache_block)
                pair = decide_pair_affinity_py(
                    self.affinity_params, ttft_deadline=ttft_dl,
                    tpot_deadline=tpot_dl, up=up, prefill=est["prefill"],
                    tpot=est["tpot"], cost=est["cost"],
                    prompt_cost=est["prompt_cost"],
                    hit_frac=np.asarray(hit_node,
                                        np.float32)[self._pair_node],
                    queue_len=masked_queue, arrays=self._np_arrays)
            else:
                pair = decide_pair_slo_py(
                    self.slo_params, ttft_deadline=ttft_dl,
                    tpot_deadline=tpot_dl,
                    up=up, prefill=est["prefill"], tpot=est["tpot"],
                    cost=est["cost"], queue_len=masked_queue,
                    arrays=self._np_arrays)
        else:
            pair = decide_pair_py(self.thresholds, complexity=c_i,
                                  pred_category=pred_cat, pred_conf=conf,
                                  queue_len=masked_queue,
                                  arrays=self._np_arrays)
        node = int(self._pair_node[pair])

        # failover: if Algorithm 2 returned a pair on a dead node (e.g. the
        # cloud fallback itself is down), pick any healthy pair
        if not healthy[node]:
            alive = [p for p in range(len(self._pair_node))
                     if healthy[self._pair_node[p]]]
            if not alive:
                raise RuntimeError("no healthy nodes in cluster")
            # prefer healthy cloud, then least-loaded healthy edge
            cloud_alive = [p for p in alive if not self._pair_is_edge[p]]
            pair = (cloud_alive[0] if cloud_alive else
                    min(alive, key=lambda p: queue[self._pair_node[p]]))
            node = int(self._pair_node[pair])

        backup = None
        if want_backup:
            backup = self.backup_pair(pair)
        return RouteDecision(
            pair=int(pair), node=node,
            model=int(self._np_arrays.pair_model[pair]),
            go_edge=bool(self._pair_is_edge[pair]),
            features=(c_i, pred_cat, conf), backup_pair=backup)

    def backup_pair(self, primary: int) -> Optional[int]:
        """A healthy pair on a *different* node, for hedged duplicates."""
        healthy = self.monitor.healthy_mask()
        pnode = int(self._pair_node[primary])
        cands = [p for p in range(len(self._pair_node))
                 if int(self._pair_node[p]) != pnode
                 and healthy[self._pair_node[p]]]
        if not cands:
            return None
        # cheapest viable alternative: cloud if primary was edge, else the
        # least-loaded edge instruct pair
        queue = self.monitor.queue_lengths()
        return min(cands, key=lambda p: (queue[self._pair_node[p]],
                                         self._pair_is_edge[p]))

    # -- feedback & re-optimization --------------------------------------------
    def record(self, req: Request, decision: RouteDecision, quality: float,
               cost: float, rt: float, now: Optional[float] = None,
               ttft_deadline: Optional[float] = None,
               tpot_deadline: Optional[float] = None) -> None:
        """Append one served request + realized objectives to the rolling
        history window ``maybe_reoptimize`` re-fits against. ``now`` is the
        request's arrival timestamp (enables open-loop re-fitting); the
        deadline pair is its QoE contract if it carried one."""
        self._history.append(Observation(
            req=req, pair=decision.pair, features=decision.features,
            quality=quality, cost=cost, rt=rt, now=now,
            ttft_deadline=ttft_deadline, tpot_deadline=tpot_deadline))
        self._n_recorded += 1
        if len(self._history) > 10000:
            self._history = self._history[-5000:]

    @property
    def history_size(self) -> int:
        return len(self._history)

    def should_reoptimize(self, drift_threshold: float = 0.25,
                          min_history: int = 64,
                          min_new: int = 32) -> bool:
        """Drift trigger: re-optimize when the monitor's fast EWMA latency
        has moved more than ``drift_threshold`` (relative) away from its slow
        baseline, enough history is banked to re-fit on, and at least
        ``min_new`` requests were observed since the last re-fit (cooldown —
        together with the post-re-fit drift re-baseline this makes one
        regime shift trigger one re-fit, not one per check)."""
        return (len(self._history) >= min_history
                and self._n_recorded - self._last_reopt_at >= min_new
                and self.monitor.drift_score() >= drift_threshold)

    def maybe_reoptimize(self, window: int = 256, generations: int = 20,
                         pop_size: int = 32,
                         weights: Optional[Sequence[float]] = None,
                         seed: int = 0, concurrency: int = 4,
                         drift_threshold: float = 0.25,
                         min_history: int = 64,
                         force: bool = False) -> Optional[np.ndarray]:
        """Rolling-horizon re-optimization (paper §IV-B.6, made real).

        Unless ``force``, runs only when :meth:`should_reoptimize` fires.
        Re-fits a small NSGA-II against the last ``window`` *recorded*
        requests: the observed trace is rebuilt with
        ``workload.trace.trace_from_requests`` (open-loop at the recorded
        arrival timestamps when every observation carries one, closed-loop
        with ``concurrency`` clients otherwise; with the recorded deadlines
        and the 4-objective QoE fitness when every observation carries a
        contract). The search is warm-started from the previous re-opt's
        survival-ordered population (``evolve_scan(..., archive=)``), then the
        Eq. (1) weighted-sum pick (uniform ``weights`` by default) replaces
        the live policy parameters. Returns them, or None if skipped.
        """
        from ..workload.trace import trace_from_requests
        from .fitness import EvalConfig, TraceEvaluator
        from .nsga2 import NSGA2, NSGA2Config
        from .policy import (AFFINITY_BOUNDS_HI, AFFINITY_BOUNDS_LO,
                             BOUNDS_HI, BOUNDS_LO, SLO_BOUNDS_HI,
                             SLO_BOUNDS_LO)

        if not force and not self.should_reoptimize(drift_threshold,
                                                    min_history):
            return None
        obs = self._history[-window:]
        if not obs:
            return None

        arrivals = None
        if all(o.now is not None for o in obs):
            t = np.asarray([o.now for o in obs], np.float32)
            if (np.diff(t) >= 0).all():
                arrivals = t
        trace = trace_from_requests([o.req for o in obs], seed=seed,
                                    arrival_time=arrivals)
        # re-fit against the features the live router actually observed and
        # acted on, not a fresh classifier noise draw
        trace.complexity = np.asarray([o.features[0] for o in obs],
                                      np.float32)
        trace.pred_category = np.asarray([o.features[1] for o in obs],
                                         np.int32)
        trace.pred_conf = np.asarray([o.features[2] for o in obs],
                                     np.float32)
        if all(o.ttft_deadline is not None and o.tpot_deadline is not None
               for o in obs):
            trace.ttft_deadline = np.asarray(
                [o.ttft_deadline for o in obs], np.float32)
            trace.tpot_deadline = np.asarray(
                [o.tpot_deadline for o in obs], np.float32)
        elif self.mode in ("slo", "affinity"):
            # slo/affinity genomes are meaningless against +inf deadlines
            # (every [γ, κ(, ρ)] is equally feasible -> degenerate flat
            # fitness): fall back to the per-category table defaults
            # route() applies
            cat = trace.pred_category
            trace.ttft_deadline = self._slo_ttft[cat].astype(np.float32)
            trace.tpot_deadline = self._slo_tpot[cat].astype(np.float32)

        cfg_eval = EvalConfig(
            mode="open" if arrivals is not None else "queued",
            concurrency=concurrency,
            # re-fit against the cache dynamics the window actually had
            prefix_cache=(arrivals is not None and trace.has_sessions),
            cache_block=self.cache_block)
        # bucketed (compile-once) evaluation: windows of different lengths
        # pad to the same power-of-two bucket, so every re-fit after the
        # first reuses the compiled trace-eval + NSGA-II executables instead
        # of paying an XLA retrace per drifting window
        evaluator = TraceEvaluator(trace, self.cluster, cfg_eval,
                                   bucket="pow2")

        if self.mode == "slo":
            genome_kind, lo, hi = "slo", SLO_BOUNDS_LO, SLO_BOUNDS_HI
        elif self.mode == "affinity":
            genome_kind, lo, hi = ("affinity", AFFINITY_BOUNDS_LO,
                                   AFFINITY_BOUNDS_HI)
        else:
            genome_kind, lo, hi = "continuous", BOUNDS_LO, BOUNDS_HI
        cfg = NSGA2Config(pop_size=pop_size, n_generations=generations,
                          lo=jnp.asarray(lo), hi=jnp.asarray(hi))
        objectives = "qoe" if trace.has_slos else "paper"
        opt = NSGA2(evaluator.make_fitness(genome_kind, objectives=objectives),
                    cfg)
        # warm start from the previous re-fit's survival-ordered population;
        # the archive is a dynamic argument (same shape every re-fit), so
        # warm-started runs share the compiled executable too
        state = opt.evolve_scan(jax.random.key(seed), generations,
                                archive=self._archive)
        # archive the survival-ordered population for the next warm start
        self._archive = np.asarray(state.genomes)

        M = state.F_raw.shape[1]
        w = (jnp.full((M,), 1.0 / M) if weights is None
             else jnp.asarray(weights, jnp.float32))
        genome, _ = opt.select_by_weights(state, w)
        params = np.asarray(genome, np.float32)
        if self.mode == "slo":
            self.slo_params = params
        elif self.mode == "affinity":
            self.affinity_params = params
        else:
            self.thresholds = params
        # cooldown: re-arm the drift detector for the *next* regime shift
        self._last_reopt_at = self._n_recorded
        self.monitor.rebaseline_drift()
        return params
