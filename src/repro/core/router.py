"""Runtime request router (paper §IV-B.6, "Routing Policy Execution").

Executes a policy π* selected from the NSGA-II Pareto set. The hot path is
``route()``: feature lookup + Algorithm 2 threshold rules — microseconds per
decision (the paper claims "millisecond-level routing decisions"; our
benchmark measures it). Beyond the paper (its §VI future work), the router is
fault-aware:

* **failover** — unhealthy nodes are masked from the candidate set; if the
  chosen node is down the request falls back to the cloud pair, or any
  healthy pair as last resort;
* **hedging** — the scheduler may ask for a *backup* pair to duplicate a
  straggling request onto (different node than the primary);
* **re-optimization** — ``maybe_reoptimize`` re-runs a small NSGA-II against
  the latest observed trace window, implementing the paper's "small-scale
  NSGA-II re-optimization triggered periodically".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..cluster.monitor import ClusterMonitor
from ..cluster.spec import ClusterArrays, ClusterSpec
from ..workload.classifier import classify
from ..workload.datasets import Request
from ..workload.features import complexity_score
from .policy import decide_pair_py


@dataclasses.dataclass
class RouteDecision:
    pair: int
    node: int
    model: int
    go_edge: bool
    features: Tuple[float, int, float]   # (c_i, t_i, p_t)
    backup_pair: Optional[int] = None


class RequestRouter:
    def __init__(self, cluster: ClusterSpec, thresholds: Sequence[float],
                 monitor: Optional[ClusterMonitor] = None,
                 hedge_factor: float = 3.0):
        self.cluster = cluster
        self.arrays: ClusterArrays = cluster.to_arrays()
        self.thresholds = np.asarray(thresholds, np.float32)
        self.monitor = monitor or ClusterMonitor(len(cluster.nodes))
        self.hedge_factor = hedge_factor
        self._rng = np.random.default_rng(0)
        self._pair_node = np.asarray(self.arrays.pair_node)
        self._pair_is_edge = np.asarray(self.arrays.pair_is_edge)
        self._history: list = []   # (features, realized objectives) window

    # -- hot path -------------------------------------------------------------
    def route(self, req: Request, want_backup: bool = False) -> RouteDecision:
        pred_cat, conf = classify(req, self._rng)
        c_i = complexity_score(req, pred_cat)
        queue = self.monitor.queue_lengths()
        healthy = self.monitor.healthy_mask()

        # mask unhealthy nodes by making their queues look infinite
        masked_queue = [q if healthy[j] else 10 ** 6
                        for j, q in enumerate(queue)]

        pair = decide_pair_py(self.thresholds, complexity=c_i,
                              pred_category=pred_cat, pred_conf=conf,
                              queue_len=masked_queue, arrays=self.arrays)
        node = int(self._pair_node[pair])

        # failover: if Algorithm 2 returned a pair on a dead node (e.g. the
        # cloud fallback itself is down), pick any healthy pair
        if not healthy[node]:
            alive = [p for p in range(len(self._pair_node))
                     if healthy[self._pair_node[p]]]
            if not alive:
                raise RuntimeError("no healthy nodes in cluster")
            # prefer healthy cloud, then least-loaded healthy edge
            cloud_alive = [p for p in alive if not self._pair_is_edge[p]]
            pair = (cloud_alive[0] if cloud_alive else
                    min(alive, key=lambda p: queue[self._pair_node[p]]))
            node = int(self._pair_node[pair])

        backup = None
        if want_backup:
            backup = self.backup_pair(pair)
        return RouteDecision(
            pair=int(pair), node=node,
            model=int(np.asarray(self.arrays.pair_model)[pair]),
            go_edge=bool(self._pair_is_edge[pair]),
            features=(c_i, pred_cat, conf), backup_pair=backup)

    def backup_pair(self, primary: int) -> Optional[int]:
        """A healthy pair on a *different* node, for hedged duplicates."""
        healthy = self.monitor.healthy_mask()
        pnode = int(self._pair_node[primary])
        cands = [p for p in range(len(self._pair_node))
                 if int(self._pair_node[p]) != pnode
                 and healthy[self._pair_node[p]]]
        if not cands:
            return None
        # cheapest viable alternative: cloud if primary was edge, else the
        # least-loaded edge instruct pair
        queue = self.monitor.queue_lengths()
        return min(cands, key=lambda p: (queue[self._pair_node[p]],
                                         self._pair_is_edge[p]))

    # -- feedback & re-optimization --------------------------------------------
    def record(self, decision: RouteDecision, quality: float, cost: float,
               rt: float) -> None:
        self._history.append((decision.features, decision.pair,
                              (quality, cost, rt)))
        if len(self._history) > 10000:
            self._history = self._history[-5000:]

    def maybe_reoptimize(self, trace, evaluator, generations: int = 20,
                         pop_size: int = 32,
                         weights: Sequence[float] = (1 / 3, 1 / 3, 1 / 3),
                         seed: int = 0) -> np.ndarray:
        """Small-scale periodic re-optimization (paper §IV-B.6)."""
        from .nsga2 import NSGA2, NSGA2Config
        from .policy import BOUNDS_HI, BOUNDS_LO
        cfg = NSGA2Config(pop_size=pop_size, n_generations=generations,
                          lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
        opt = NSGA2(evaluator.make_fitness("continuous"), cfg)
        state = opt.evolve_scan(jax.random.key(seed), generations)
        genome, _ = opt.select_by_weights(state, jnp.asarray(weights))
        self.thresholds = np.asarray(genome, np.float32)
        return self.thresholds
