"""Runtime request router (paper §IV-B.6, "Routing Policy Execution").

Executes a policy π* selected from the NSGA-II Pareto set. The hot path is
``route()``: feature lookup + Algorithm 2 threshold rules — microseconds per
decision (the paper claims "millisecond-level routing decisions"; our
benchmark measures it). Beyond the paper (its §VI future work), the router is
fault-aware:

* **failover** — unhealthy nodes are masked from the candidate set; if the
  chosen node is down the request falls back to the cloud pair, or any
  healthy pair as last resort;
* **hedging** — the scheduler may ask for a *backup* pair to duplicate a
  straggling request onto (different node than the primary);
* **re-optimization** — ``maybe_reoptimize`` re-runs a small NSGA-II against
  the latest observed trace window, implementing the paper's "small-scale
  NSGA-II re-optimization triggered periodically".

Two decision modes (``mode=``):

* ``"threshold"`` — the paper's Algorithm 2 over difficulty/queue/confidence
  thresholds;
* ``"slo"`` — QoE-aware phase-split routing: estimates each pair's TTFT and
  TPOT against the request's (per-category or explicit) deadlines and picks
  the cheapest feasible pair (see ``core.policy.decide_pair_slo_py`` and
  ``workload.slo``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..cluster.monitor import ClusterMonitor
from ..cluster.spec import ClusterArrays, ClusterSpec
from ..workload.classifier import classify
from ..workload.datasets import Request
from ..workload.features import complexity_score
from ..workload.slo import DEFAULT_SLO_TABLE, slo_arrays
from .fitness import request_pair_estimates
from .policy import SLO_DEFAULTS, decide_pair_py, decide_pair_slo_py


@dataclasses.dataclass
class RouteDecision:
    pair: int
    node: int
    model: int
    go_edge: bool
    features: Tuple[float, int, float]   # (c_i, t_i, p_t)
    backup_pair: Optional[int] = None


class RequestRouter:
    def __init__(self, cluster: ClusterSpec, thresholds: Sequence[float],
                 monitor: Optional[ClusterMonitor] = None,
                 hedge_factor: float = 3.0, mode: str = "threshold",
                 slo_params: Optional[Sequence[float]] = None,
                 slo_table=DEFAULT_SLO_TABLE):
        assert mode in ("threshold", "slo")
        self.cluster = cluster
        self.arrays: ClusterArrays = cluster.to_arrays()
        self.thresholds = np.asarray(thresholds, np.float32)
        self.mode = mode
        self.slo_params = np.asarray(
            SLO_DEFAULTS if slo_params is None else slo_params, np.float32)
        self._slo_ttft, self._slo_tpot = slo_arrays(slo_table)
        self.monitor = monitor or ClusterMonitor(len(cluster.nodes))
        self.hedge_factor = hedge_factor
        self._rng = np.random.default_rng(0)
        # numpy view of the pair table, converted once: the per-request hot
        # path must not pay device-to-host transfers on every decision
        self._np_arrays = ClusterArrays(*(np.asarray(a) for a in self.arrays))
        self._pair_node = self._np_arrays.pair_node
        self._pair_is_edge = self._np_arrays.pair_is_edge
        self._history: list = []   # (features, realized objectives) window

    # -- hot path -------------------------------------------------------------
    def route(self, req: Request, want_backup: bool = False,
              ttft_deadline: Optional[float] = None,
              tpot_deadline: Optional[float] = None) -> RouteDecision:
        """Route one request. In ``slo`` mode explicit per-request deadlines
        override the per-category SLO table defaults."""
        pred_cat, conf = classify(req, self._rng)
        c_i = complexity_score(req, pred_cat)
        queue = self.monitor.queue_lengths()
        healthy = self.monitor.healthy_mask()

        # mask unhealthy nodes by making their queues look infinite
        masked_queue = [q if healthy[j] else 10 ** 6
                        for j, q in enumerate(queue)]

        if self.mode == "slo":
            est = request_pair_estimates(req.prompt_tokens,
                                         req.resp_tokens_mean,
                                         req.query_bytes, self._np_arrays)
            # unhealthy nodes: push their pairs out of feasibility
            dead = ~np.asarray(healthy)[self._pair_node]
            up = np.where(dead, np.float32(1e9), est["up"])
            pair = decide_pair_slo_py(
                self.slo_params,
                ttft_deadline=(ttft_deadline if ttft_deadline is not None
                               else float(self._slo_ttft[pred_cat])),
                tpot_deadline=(tpot_deadline if tpot_deadline is not None
                               else float(self._slo_tpot[pred_cat])),
                up=up, prefill=est["prefill"], tpot=est["tpot"],
                cost=est["cost"], queue_len=masked_queue,
                arrays=self._np_arrays)
        else:
            pair = decide_pair_py(self.thresholds, complexity=c_i,
                                  pred_category=pred_cat, pred_conf=conf,
                                  queue_len=masked_queue,
                                  arrays=self._np_arrays)
        node = int(self._pair_node[pair])

        # failover: if Algorithm 2 returned a pair on a dead node (e.g. the
        # cloud fallback itself is down), pick any healthy pair
        if not healthy[node]:
            alive = [p for p in range(len(self._pair_node))
                     if healthy[self._pair_node[p]]]
            if not alive:
                raise RuntimeError("no healthy nodes in cluster")
            # prefer healthy cloud, then least-loaded healthy edge
            cloud_alive = [p for p in alive if not self._pair_is_edge[p]]
            pair = (cloud_alive[0] if cloud_alive else
                    min(alive, key=lambda p: queue[self._pair_node[p]]))
            node = int(self._pair_node[pair])

        backup = None
        if want_backup:
            backup = self.backup_pair(pair)
        return RouteDecision(
            pair=int(pair), node=node,
            model=int(self._np_arrays.pair_model[pair]),
            go_edge=bool(self._pair_is_edge[pair]),
            features=(c_i, pred_cat, conf), backup_pair=backup)

    def backup_pair(self, primary: int) -> Optional[int]:
        """A healthy pair on a *different* node, for hedged duplicates."""
        healthy = self.monitor.healthy_mask()
        pnode = int(self._pair_node[primary])
        cands = [p for p in range(len(self._pair_node))
                 if int(self._pair_node[p]) != pnode
                 and healthy[self._pair_node[p]]]
        if not cands:
            return None
        # cheapest viable alternative: cloud if primary was edge, else the
        # least-loaded edge instruct pair
        queue = self.monitor.queue_lengths()
        return min(cands, key=lambda p: (queue[self._pair_node[p]],
                                         self._pair_is_edge[p]))

    # -- feedback & re-optimization --------------------------------------------
    def record(self, decision: RouteDecision, quality: float, cost: float,
               rt: float) -> None:
        self._history.append((decision.features, decision.pair,
                              (quality, cost, rt)))
        if len(self._history) > 10000:
            self._history = self._history[-5000:]

    def maybe_reoptimize(self, trace, evaluator, generations: int = 20,
                         pop_size: int = 32,
                         weights: Sequence[float] = (1 / 3, 1 / 3, 1 / 3),
                         seed: int = 0) -> np.ndarray:
        """Small-scale periodic re-optimization (paper §IV-B.6)."""
        from .nsga2 import NSGA2, NSGA2Config
        from .policy import BOUNDS_HI, BOUNDS_LO
        cfg = NSGA2Config(pop_size=pop_size, n_generations=generations,
                          lo=jnp.asarray(BOUNDS_LO), hi=jnp.asarray(BOUNDS_HI))
        opt = NSGA2(evaluator.make_fitness("continuous"), cfg)
        state = opt.evolve_scan(jax.random.key(seed), generations)
        genome, _ = opt.select_by_weights(state, jnp.asarray(weights))
        self.thresholds = np.asarray(genome, np.float32)
        return self.thresholds
