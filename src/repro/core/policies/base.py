"""RoutingPolicy API: the unit of extension for routing decision rules.

The paper's contribution is a *family* of routing policies tuned by NSGA-II;
this module defines the contract one policy must satisfy to plug into every
consumer at once — the JAX trace evaluator (``core.fitness._run_trace``),
both discrete-event oracles (``cluster.simulator``), the runtime router
(``core.router.RequestRouter``, including its rolling-horizon re-fit) and
the NSGA-II genome configuration (``core.nsga2.NSGA2Config.from_policy``).

A policy owns:

* ``name`` — the registry key. Every consumer dispatches on this string,
  and the JAX evaluator jits with the name as a **static** argument, so one
  policy identity compiles exactly one ``_run_trace`` executable (the
  compile-once guarantee of the bucketed evaluator extends to new policies
  for free).
* ``genome_spec`` — length, bounds, defaults, and the discrete/per-request
  flags of the decision-variable vector NSGA-II searches. NSGA2Config
  derives its genome encoding from this, so genome-length defaults cannot
  drift between the optimizer and the decision rule.
* ``requires`` — which inputs the decision actually reads (see
  :data:`REQUIREMENTS`). The runtime router uses this to skip computing
  per-pair estimates / cache state / deadlines for policies that never look
  at them (the hot path stays microseconds for Algorithm-2 thresholds).
* ``decide_jnp`` / ``decide_py`` — twin implementations of the decision.
  ``decide_jnp`` must be scan-traceable (pure jnp, no Python branching on
  traced values); ``decide_py`` is an independent numpy transcription used
  as the test oracle and by the runtime router / DES simulators. The two
  must mirror each other **op-for-op in float32** so argmin tie-breaking is
  identical — the registry-wide equivalence property test
  (tests/test_policies.py) enforces this for every registered policy.
* optional per-policy scan state (``state_size`` > 0 with
  ``update_jnp``/``update_py``): a small float32 vector threaded through
  the evaluation in dispatch order (e.g. the budget policy's per-window
  spend ledger). Stateless policies leave the default no-op hooks.

Decision inputs are normalized into :class:`PolicyInputs` — one NamedTuple
carrying every feature any policy may consume, built identically by the JAX
scan body, the DES oracles, and the runtime router. Fields a policy does not
declare in ``requires`` may be zero-filled by the caller.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

#: Requirement flags a policy may declare. "features" = classifier outputs
#: (complexity / category / confidence); "estimates" = per-pair phase/cost
#: estimate rows (up, prefill, tpot, cost, prompt_cost); "deadlines" = the
#: request's (TTFT, TPOT) QoE contract; "cache" = per-pair expected
#: cached-prefix fractions from the prefix-cache state; "transfer" = per-pair
#: KV-transfer byte sizes for disaggregated (prefill, decode) routing;
#: "quality" = per-pair expected response quality + estimator uncertainty
#: (zero-filled unless the caller runs with learned estimators — see
#: ``repro.learn``).
REQUIREMENTS = ("features", "estimates", "deadlines", "cache", "transfer",
                "quality")


class PolicyInputs(NamedTuple):
    """Uniform decision context for one request.

    Scalars are 0-d (float32/int32); vectors are per-pair ``(n_pairs,)``
    except ``queue_len`` which is per-node ``(n_nodes,)``. The same tuple is
    built from jnp arrays inside the evaluator scan and from numpy arrays by
    the DES oracles / runtime router.
    """

    index: np.ndarray          # int32 request index (monotone at runtime)
    now: np.ndarray            # float32 arrival / decision timestamp
    # classifier features
    complexity: np.ndarray     # float32 c_i
    pred_category: np.ndarray  # int32 (0=code, 1=math, 2=general)
    pred_conf: np.ndarray      # float32
    # QoE contract (+inf when the request carries no SLOs)
    ttft_deadline: np.ndarray  # float32 seconds
    tpot_deadline: np.ndarray  # float32 s/token
    prompt_tokens: np.ndarray  # float32
    # per-pair estimate rows (the request's row of the precomputed tables)
    up: np.ndarray             # (n_pairs,) upload seconds
    prefill: np.ndarray        # (n_pairs,) prefill seconds
    tpot: np.ndarray           # (n_pairs,) decode seconds per output token
    cost: np.ndarray           # (n_pairs,) full-request $ cost
    prompt_cost: np.ndarray    # (n_pairs,) prompt-only $ cost
    hit_frac: np.ndarray       # (n_pairs,) expected cached-prefix fraction
    # live cluster state
    queue_len: np.ndarray      # (n_nodes,) busy execution slots
    # disaggregated serving: whole-block KV footprint of this prompt on each
    # pair's model (bytes to move if prefill and decode run on different
    # nodes). Zero-filled for policies that don't declare "transfer".
    kv_bytes: np.ndarray = np.float32(0.0)  # (n_pairs,) float32 bytes
    # learned-estimator rows (repro.learn): per-pair expected response
    # quality and the estimator's per-pair uncertainty (LinUCB width /
    # 1/sqrt(1+n)). Zero-filled for policies that don't declare "quality"
    # or when the caller runs on static priors (learned=False).
    quality: np.ndarray = np.float32(0.0)   # (n_pairs,) float32 in [0, 1]
    unc: np.ndarray = np.float32(0.0)       # (n_pairs,) float32 >= 0


@dataclasses.dataclass(frozen=True)
class GenomeSpec:
    """Shape/bounds contract of a policy's decision-variable vector.

    ``per_request=True`` marks genomes with one gene per trace request
    (the direct-assignment encoding): their length is trace-dependent
    (``length`` is -1) and they cannot drive the runtime router.
    """

    names: Tuple[str, ...] = ()
    lo: Optional[np.ndarray] = None       # (D,) float32 search bounds
    hi: Optional[np.ndarray] = None
    defaults: Optional[np.ndarray] = None  # (D,) sensible hand defaults
    discrete: bool = False
    per_request: bool = False

    def __post_init__(self):
        if not self.per_request:
            assert self.lo is not None and self.hi is not None, \
                "fixed-length genomes need search bounds"
            assert len(self.lo) == len(self.hi) == len(self.names)
            if self.defaults is not None:
                assert len(self.defaults) == len(self.names)

    @property
    def length(self) -> int:
        """Genome dimensionality D; -1 when per-request (trace-dependent)."""
        return -1 if self.per_request else len(self.names)


class RoutingPolicy:
    """Base class; subclasses override the class attributes + decide twins.

    ``decide_*`` receive ``(genome, inp, arrays, state)`` and return a pair
    index; ``update_*`` receive ``(genome, state, inp, pair, cost)`` — the
    realized (cache-discounted) cost of the dispatched request — and return
    the next state vector. Default hooks are stateless no-ops.
    """

    name: str = ""
    genome_spec: GenomeSpec = GenomeSpec(per_request=True)
    requires: frozenset = frozenset()
    state_size: int = 0
    #: decision index space: "pair" policies return an index into the
    #: (node, model) pair table; "route" policies return an index into the
    #: (prefill_pair, decode_pair) route table (disaggregated serving) and
    #: must be evaluated with ``EvalConfig(disaggregated=True)``.
    decides: str = "pair"

    # -- decisions -----------------------------------------------------------
    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        raise NotImplementedError

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        raise NotImplementedError

    # -- optional per-policy scan state --------------------------------------
    def init_state(self) -> np.ndarray:
        return np.zeros((self.state_size,), np.float32)

    def update_jnp(self, genome, state, inp: PolicyInputs, pair, cost):
        return state

    def update_py(self, genome, state, inp: PolicyInputs, pair: int,
                  cost: float) -> np.ndarray:
        return state

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<RoutingPolicy {self.name!r} D={self.genome_spec.length}>"
