"""Disaggregated (prefill, decode) routing policy.

The paper routes each request to a single (node, model) pair; this policy
routes over the cluster's *route table* instead — every feasible
``(prefill_pair, decode_pair)`` combination from
``ClusterArrays.route_prefill/route_decode``, including colocated routes
(prefill_pair == decode_pair) on unified nodes. NSGA-II therefore discovers
*when* disaggregation wins: with a fast KV link and long prompts the tuned
genome splits phases across prefill-/decode-optimized nodes; when the
transfer cost dominates it collapses onto colocated routes.

Genome (searchable by ``TraceEvaluator.make_fitness("disagg")``):

    [γ (deadline headroom on the TTFT estimate),
     κ (estimated queue wait, s per unit load),
     τ (latency price, $ per second of est. TTFT + KV transfer)]

The decision scores each route by its *realized* dollar cost — prompt side
billed on the prefill pair (with the cache discount), decode side on the
decode pair, plus KV egress for split routes — and a τ-weighted latency
term that includes the KV-transfer time ``kv_bytes × 1/bw + setup``. Among
deadline-feasible routes the cheapest score wins; with none feasible it
minimizes the worst normalized deadline overshoot, like the SLO policy.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import register_policy
from .affinity import CACHED_TOKEN_PRICE_FACTOR
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

DISAGG_PARAM_NAMES = ("gamma", "kappa", "tau")
DISAGG_BOUNDS_LO = np.array([0.3, 0.0, 0.0], np.float32)
DISAGG_BOUNDS_HI = np.array([1.1, 20.0, 1.0], np.float32)
DISAGG_DEFAULTS = np.array([0.9, 3.0, 1e-3], np.float32)


def decide_route_disagg_jnp(genome, inp: PolicyInputs, arrays):
    """Route-table scoring, scan-traceable."""
    gamma, kappa, tau = genome[0], genome[1], genome[2]
    p = arrays.route_prefill
    q = arrays.route_decode
    node_p = arrays.pair_node[p]
    node_q = arrays.pair_node[q]
    load = inp.queue_len.astype(jnp.float32) / \
        arrays.node_conc.astype(jnp.float32)
    hit = inp.hit_frac[p]
    kv_bytes = jnp.broadcast_to(jnp.asarray(inp.kv_bytes), inp.up.shape)
    est_ttft = inp.up[p] + kappa * load[node_p] + inp.prefill[p] * (1.0 - hit)
    tt = arrays.kv_lat[node_p, node_q] + \
        kv_bytes[p] * arrays.kv_inv_bw[node_p, node_q]
    feasible = (est_ttft <= gamma * inp.ttft_deadline) & \
               (inp.tpot[q] <= jnp.minimum(gamma, 1.0) * inp.tpot_deadline)
    discount = jnp.float32(1.0) - hit * \
        jnp.float32(1.0 - CACHED_TOKEN_PRICE_FACTOR)
    cost_r = inp.prompt_cost[p] * discount + \
        (inp.cost[q] - inp.prompt_cost[q]) + \
        kv_bytes[p] * arrays.kv_egress[node_p, node_q]
    score = cost_r + tau * (est_ttft + tt + kappa * load[node_q])
    any_ok = jnp.any(feasible)
    cheapest = jnp.argmin(jnp.where(feasible, score, jnp.inf))
    overshoot = jnp.maximum((est_ttft + tt) / inp.ttft_deadline,
                            inp.tpot[q] / inp.tpot_deadline)
    least_bad = jnp.argmin(overshoot)
    return jnp.where(any_ok, cheapest, least_bad).astype(jnp.int32)


def decide_route_disagg_py(genome, inp: PolicyInputs, arrays) -> int:
    """Numpy transcription, op-for-op in float32 (test oracle / runtime)."""
    g = np.asarray(genome, np.float32)
    gamma, kappa, tau = g[0], g[1], g[2]
    p = np.asarray(arrays.route_prefill)
    q = np.asarray(arrays.route_decode)
    node_p = np.asarray(arrays.pair_node)[p]
    node_q = np.asarray(arrays.pair_node)[q]
    load = np.asarray(inp.queue_len).astype(np.float32) / \
        np.asarray(arrays.node_conc).astype(np.float32)
    up = np.asarray(inp.up, np.float32)
    prefill = np.asarray(inp.prefill, np.float32)
    tpot = np.asarray(inp.tpot, np.float32)
    cost = np.asarray(inp.cost, np.float32)
    prompt_cost = np.asarray(inp.prompt_cost, np.float32)
    kv_bytes = np.broadcast_to(
        np.asarray(inp.kv_bytes, np.float32), up.shape)
    hit = np.asarray(inp.hit_frac, np.float32)[p]
    kv_lat = np.asarray(arrays.kv_lat, np.float32)
    kv_inv_bw = np.asarray(arrays.kv_inv_bw, np.float32)
    kv_egress = np.asarray(arrays.kv_egress, np.float32)
    ttft_dl = np.float32(inp.ttft_deadline)
    tpot_dl = np.float32(inp.tpot_deadline)

    est_ttft = up[p] + kappa * load[node_p] + \
        prefill[p] * (np.float32(1.0) - hit)
    tt = kv_lat[node_p, node_q] + kv_bytes[p] * kv_inv_bw[node_p, node_q]
    feasible = (est_ttft <= gamma * ttft_dl) & \
               (tpot[q] <= np.minimum(gamma, np.float32(1.0)) * tpot_dl)
    discount = np.float32(1.0) - hit * \
        np.float32(1.0 - CACHED_TOKEN_PRICE_FACTOR)
    cost_r = prompt_cost[p] * discount + (cost[q] - prompt_cost[q]) + \
        kv_bytes[p] * kv_egress[node_p, node_q]
    score = cost_r + tau * (est_ttft + tt + kappa * load[node_q])
    if feasible.any():
        return int(np.argmin(np.where(feasible, score, np.inf)))
    overshoot = np.maximum((est_ttft + tt) / ttft_dl, tpot[q] / tpot_dl)
    return int(np.argmin(overshoot))


class DisaggPolicy(RoutingPolicy):
    """Registered route-valued policy for disaggregated prefill/decode."""

    name = "disagg"
    genome_spec = GenomeSpec(names=DISAGG_PARAM_NAMES, lo=DISAGG_BOUNDS_LO,
                             hi=DISAGG_BOUNDS_HI, defaults=DISAGG_DEFAULTS)
    requires = frozenset({"estimates", "deadlines", "cache", "transfer"})
    decides = "route"

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        return decide_route_disagg_jnp(genome, inp, arrays)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        return decide_route_disagg_py(genome, inp, arrays)


register_policy(DisaggPolicy())
