"""Threshold routing policy — paper Algorithm 2 ("Runtime LLM Request
Routing") plus the threshold genome the NSGA-II optimizes (§IV-B.6).

Genome layout (6 decision variables, all continuous):

    [θ_d_code, θ_d_math, θ_d_general, θ_q, θ_t_code, θ_t_math]

``decide_pair_jnp`` is the jit-friendly decoder used inside the fitness scan
and by the serving scheduler; ``decide_pair_py`` is a line-by-line Python
transcription of Algorithm 2 used as the test oracle. ``ThresholdPolicy``
wraps the pair as the registered ``"threshold"`` policy.

Category encoding follows workload.classifier.CATEGORIES:
0 = 'code', 1 = 'math', 2 = 'general'. Model types follow
cluster.spec.MODEL_TYPES: 0 = 'instruct', 1 = 'coder', 2 = 'math',
3 = 'general'.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from ...cluster.spec import ClusterArrays
from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

THRESHOLD_NAMES = ("theta_d_code", "theta_d_math", "theta_d_general",
                   "theta_q", "theta_t_code", "theta_t_math")

# search bounds for NSGA-II (θ_d in [0,1], θ_q in [0, 16] requests,
# θ_t in [0.34, 1] — below 1/3 the classifier confidence gate is vacuous)
BOUNDS_LO = np.array([0.0, 0.0, 0.0, 0.0, 0.34, 0.34], np.float32)
BOUNDS_HI = np.array([1.0, 1.0, 1.0, 16.0, 1.0, 1.0], np.float32)

# paper's illustrative defaults (θ_d,general = 0.5, θ_q = 5, θ_t = 0.7)
PAPER_DEFAULTS = np.array([0.5, 0.5, 0.5, 5.0, 0.7, 0.7], np.float32)

CAT_CODE, CAT_MATH, CAT_GENERAL = 0, 1, 2
TYPE_INSTRUCT, TYPE_CODER, TYPE_MATH = 0, 1, 2


class Thresholds(NamedTuple):
    d_code: jnp.ndarray
    d_math: jnp.ndarray
    d_general: jnp.ndarray
    q: jnp.ndarray
    t_code: jnp.ndarray
    t_math: jnp.ndarray

    @classmethod
    def from_genome(cls, g) -> "Thresholds":
        return cls(*(g[i] for i in range(6)))


def decide_pair_jnp(genome: jnp.ndarray, *, complexity: jnp.ndarray,
                    pred_category: jnp.ndarray, pred_conf: jnp.ndarray,
                    queue_len: jnp.ndarray, arrays: ClusterArrays
                    ) -> jnp.ndarray:
    """Algorithm 2, fully vectorizable. Returns a pair index (int32 scalar).

    Lines reference the paper's pseudo-code:
      5-13: go_edge from per-category difficulty thresholds
      15-17: filter edge nodes by queue (θ_q); none -> cloud fallback
      19-25: model type from classifier confidence gates (θ_t)
      26: first edge node (by node order) hosting the matching model whose
          queue passes; if the chosen type is unavailable on passing nodes,
          fall back to cloud (conservative reading of line 17).
    """
    th = Thresholds.from_genome(genome)
    is_code = pred_category == CAT_CODE
    is_math = pred_category == CAT_MATH

    # Algorithm 2 lines 5-13: note the elif-chain semantics — a code/math
    # request that fails its own threshold still falls through to the
    # general-threshold check (line 9).
    go_edge = ((is_code & (complexity < th.d_code))
               | (is_math & (complexity < th.d_math))
               | (complexity < th.d_general))

    sel_type = jnp.where(is_code & (pred_conf >= th.t_code), TYPE_CODER,
                         jnp.where(is_math & (pred_conf >= th.t_math),
                                   TYPE_MATH, TYPE_INSTRUCT))

    # candidate pairs of the selected type, ordered by node index (-1 pad)
    cand = arrays.edge_pairs_by_type[sel_type]          # (n_edge,)
    cand_valid = cand >= 0
    cand_node = arrays.pair_node[jnp.maximum(cand, 0)]
    cand_q_ok = queue_len[cand_node] <= th.q
    ok = cand_valid & cand_q_ok
    any_ok = jnp.any(ok)
    first = jnp.argmax(ok)                              # first True
    edge_pair = jnp.where(any_ok, cand[first], arrays.cloud_fallback_pair)

    return jnp.where(go_edge, edge_pair,
                     arrays.cloud_fallback_pair).astype(jnp.int32)


def decide_pair_py(genome: Sequence[float], *, complexity: float,
                   pred_category: int, pred_conf: float,
                   queue_len: Sequence[int], arrays: ClusterArrays) -> int:
    """Reference transcription of Algorithm 2 (test oracle)."""
    (d_code, d_math, d_general, th_q, t_code, t_math) = [float(x) for x in genome]
    pair_node = np.asarray(arrays.pair_node)
    edge_by_type = np.asarray(arrays.edge_pairs_by_type)
    fallback = int(arrays.cloud_fallback_pair)

    if pred_category == CAT_CODE and complexity < d_code:
        go_edge = True
    elif pred_category == CAT_MATH and complexity < d_math:
        go_edge = True
    elif complexity < d_general:
        go_edge = True
    else:
        go_edge = False

    if not go_edge:
        return fallback

    if pred_category == CAT_CODE and pred_conf >= t_code:
        sel_type = TYPE_CODER
    elif pred_category == CAT_MATH and pred_conf >= t_math:
        sel_type = TYPE_MATH
    else:
        sel_type = TYPE_INSTRUCT

    for pair in edge_by_type[sel_type]:
        if pair < 0:
            continue
        if queue_len[pair_node[pair]] <= th_q:
            return int(pair)
    return fallback


class ThresholdPolicy(RoutingPolicy):
    """Registered wrapper over the Algorithm-2 decision pair."""

    name = "threshold"
    genome_spec = GenomeSpec(names=THRESHOLD_NAMES, lo=BOUNDS_LO,
                             hi=BOUNDS_HI, defaults=PAPER_DEFAULTS)
    requires = frozenset({"features"})

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        return decide_pair_jnp(genome, complexity=inp.complexity,
                               pred_category=inp.pred_category,
                               pred_conf=inp.pred_conf,
                               queue_len=inp.queue_len, arrays=arrays)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        return decide_pair_py(genome, complexity=float(inp.complexity),
                              pred_category=int(inp.pred_category),
                              pred_conf=float(inp.pred_conf),
                              queue_len=inp.queue_len, arrays=arrays)


register_policy(ThresholdPolicy())
