"""Contextual-bandit routing policy (LinUCB-style explore–exploit).

The first registry policy built for the learned-estimator loop
(``repro.learn``): it scores every pair by an optimistic reward estimate

    score = (quality + β·unc) − w_rt·RT_est − w_cost·cost_scaled
    RT_est = up + κ·load + prefill + H·tpot          (H = 64-token horizon)

and routes to the argmax. ``quality``/``unc`` are the learned-quality
posterior mean and its uncertainty from the ``PolicyInputs`` quality rows
(zero-filled on static-prior runs — the policy then degrades to a greedy
quality/latency/cost trade-off), and ``prefill``/``tpot`` are the
(possibly learned-corrected) estimate rows, so the policy sharpens as
observations accumulate. β is the **searchable exploration dimension**:
β = 0 is pure exploitation, larger β routes deliberately through
uncertain (node, category) slots to buy estimator confidence — NSGA-II
tunes it like any other gene via ``make_fitness("bandit")``.

Cold-start note: with neutral estimator state the uncertainty row is
*constant across pairs* (EWMA: 1/√1 everywhere; BLR: identical features
when all queues are empty), so β shifts every score equally and the first
decision is byte-identical to a static-prior run — the cold-start
contract tests/test_learn.py asserts.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

BANDIT_PARAM_NAMES = ("beta", "w_rt", "w_cost", "kappa")

# β exploration bonus per unit uncertainty; w_rt quality-points per second
# of estimated response time; w_cost quality-points per scaled $; κ
# estimated wait seconds per unit node load (the slo/affinity convention).
BANDIT_BOUNDS_LO = np.array([0.0, 0.0, 0.0, 0.0], np.float32)
BANDIT_BOUNDS_HI = np.array([2.0, 2.0, 2.0, 20.0], np.float32)
BANDIT_DEFAULTS = np.array([0.5, 0.5, 0.5, 3.0], np.float32)

_DECODE_HORIZON = np.float32(64.0)   # tokens of decode in the RT estimate
_COST_SCALE = np.float32(100.0)      # per-request $ -> comparable magnitude


def _bandit_scores(xp, genome, quality, unc, up, prefill, tpot, cost,
                   queue_len, node, conc):
    """Shared float32 score tree (identical op-for-op in np and jnp)."""
    beta = genome[0]
    w_rt = genome[1]
    w_cost = genome[2]
    kappa = genome[3]
    load = queue_len.astype(xp.float32) / conc.astype(xp.float32)
    rt_est = (up + kappa * load[node]) + (prefill + _DECODE_HORIZON * tpot)
    return (quality + beta * unc) - (w_rt * rt_est
                                     + w_cost * (cost * _COST_SCALE))


class BanditPolicy(RoutingPolicy):
    """Optimistic (UCB) quality/latency/cost router over learned estimates.

    Stateless as a policy — the exploration state it exercises is the
    *shared* learned-estimator carry (``EvalConfig(learned=True)``), which
    also feeds every other registered policy; dead-node masking works
    unchanged because DEAD_UP/DEAD_QUEUE sentinels drive masked pairs'
    scores to -inf territory.
    """

    name = "bandit"
    genome_spec = GenomeSpec(names=BANDIT_PARAM_NAMES, lo=BANDIT_BOUNDS_LO,
                             hi=BANDIT_BOUNDS_HI, defaults=BANDIT_DEFAULTS)
    requires = frozenset({"features", "estimates", "quality"})

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        score = _bandit_scores(
            jnp, genome, inp.quality, inp.unc, inp.up, inp.prefill,
            inp.tpot, inp.cost, inp.queue_len, arrays.pair_node,
            arrays.node_conc)
        return jnp.argmax(score).astype(jnp.int32)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        score = _bandit_scores(
            np, np.asarray(genome, np.float32),
            np.asarray(inp.quality, np.float32),
            np.asarray(inp.unc, np.float32), np.asarray(inp.up, np.float32),
            np.asarray(inp.prefill, np.float32),
            np.asarray(inp.tpot, np.float32),
            np.asarray(inp.cost, np.float32), np.asarray(inp.queue_len),
            np.asarray(arrays.pair_node), np.asarray(arrays.node_conc))
        return int(np.argmax(score))


register_policy(BanditPolicy())
